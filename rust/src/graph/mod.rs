//! Communication-graph substrate (paper §2.1).
//!
//! Workers are nodes of an undirected graph `G = (N, E)`; an edge (i, j)
//! means i and j can exchange parameter updates. The paper assumes `G` is
//! strongly connected (w.l.o.g.) and evaluates on randomly generated
//! connected graphs of 6 and 10 workers (Fig. 2).
//!
//! - [`Graph`] — adjacency-set representation + invariants
//! - [`topology`] — generators: ring, complete, star, grid, random-connected
//! - [`paths`] — BFS distances, diameter, and the "shortest path that
//!   connects all nodes" P required by DTUR (paper §4.1)

pub mod paths;
pub mod topology;

use std::collections::BTreeSet;

/// Undirected simple graph over nodes `0..n`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Graph {
    n: usize,
    adj: Vec<BTreeSet<usize>>,
}

impl Graph {
    pub fn empty(n: usize) -> Self {
        Graph {
            n,
            adj: vec![BTreeSet::new(); n],
        }
    }

    pub fn from_edges(n: usize, edges: &[(usize, usize)]) -> Self {
        let mut g = Graph::empty(n);
        for &(a, b) in edges {
            g.add_edge(a, b);
        }
        g
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn add_edge(&mut self, a: usize, b: usize) {
        assert!(a < self.n && b < self.n && a != b, "bad edge ({a},{b})");
        self.adj[a].insert(b);
        self.adj[b].insert(a);
    }

    pub fn has_edge(&self, a: usize, b: usize) -> bool {
        self.adj[a].contains(&b)
    }

    /// Neighbours of `v`, NOT including `v` itself.
    pub fn neighbors(&self, v: usize) -> impl Iterator<Item = usize> + '_ {
        self.adj[v].iter().copied()
    }

    pub fn degree(&self, v: usize) -> usize {
        self.adj[v].len()
    }

    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(|s| s.len()).sum::<usize>() / 2
    }

    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::with_capacity(self.edge_count());
        for a in 0..self.n {
            for &b in &self.adj[a] {
                if a < b {
                    out.push((a, b));
                }
            }
        }
        out
    }

    /// Is the graph connected? (Assumption: W.l.o.g. `G` strongly connected.)
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &u in &self.adj[v] {
                if !seen[u] {
                    seen[u] = true;
                    count += 1;
                    stack.push(u);
                }
            }
        }
        count == self.n
    }

    /// The closed neighbourhood N_j = {i | (i,j) ∈ E} ∪ {j} (paper §2.1).
    pub fn closed_neighborhood(&self, v: usize) -> Vec<usize> {
        let mut out: Vec<usize> = self.adj[v].iter().copied().collect();
        out.push(v);
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edges_are_undirected() {
        let g = Graph::from_edges(3, &[(0, 1)]);
        assert!(g.has_edge(0, 1) && g.has_edge(1, 0));
        assert!(!g.has_edge(0, 2));
        assert_eq!(g.edge_count(), 1);
    }

    #[test]
    fn duplicate_edges_ignored() {
        let g = Graph::from_edges(3, &[(0, 1), (1, 0), (0, 1)]);
        assert_eq!(g.edge_count(), 1);
    }

    #[test]
    fn connectivity() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)]);
        assert!(g.is_connected());
        let g2 = Graph::from_edges(4, &[(0, 1), (2, 3)]);
        assert!(!g2.is_connected());
    }

    #[test]
    fn closed_neighborhood_includes_self() {
        let g = Graph::from_edges(4, &[(0, 1), (0, 2)]);
        assert_eq!(g.closed_neighborhood(0), vec![0, 1, 2]);
        assert_eq!(g.closed_neighborhood(3), vec![3]);
    }

    #[test]
    #[should_panic]
    fn self_loop_rejected() {
        let mut g = Graph::empty(2);
        g.add_edge(1, 1);
    }
}
