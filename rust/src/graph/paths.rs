//! Shortest paths, diameter, and the DTUR connecting path P (paper §4.1).
//!
//! DTUR needs "the shortest path that connects all nodes" — the minimal
//! link set touching every worker. A minimal connecting link set is a
//! spanning tree (N-1 edges); when the graph admits a Hamiltonian path the
//! tree degenerates to an actual path. Finding a shortest Hamiltonian path
//! is NP-hard, so we use the paper-faithful practical reading: try a
//! greedy DFS Hamiltonian-path heuristic first, fall back to a BFS
//! spanning tree. Both give |P| = d = N-1, which is what Algorithm 2
//! consumes (an epoch = d iterations, one P-link established per
//! iteration). The choice is documented in DESIGN.md §DTUR.

use super::Graph;

/// BFS distances from `src`; `usize::MAX` marks unreachable nodes.
pub fn bfs_dist(g: &Graph, src: usize) -> Vec<usize> {
    let mut dist = vec![usize::MAX; g.n()];
    let mut queue = std::collections::VecDeque::new();
    dist[src] = 0;
    queue.push_back(src);
    while let Some(v) = queue.pop_front() {
        for u in g.neighbors(v) {
            if dist[u] == usize::MAX {
                dist[u] = dist[v] + 1;
                queue.push_back(u);
            }
        }
    }
    dist
}

/// Graph diameter (max shortest-path distance); None if disconnected.
pub fn diameter(g: &Graph) -> Option<usize> {
    let mut best = 0;
    for v in 0..g.n() {
        let d = bfs_dist(g, v);
        for &x in &d {
            if x == usize::MAX {
                return None;
            }
            best = best.max(x);
        }
    }
    Some(best)
}

/// Shortest path (as a node list) between two nodes, if any.
pub fn shortest_path(g: &Graph, src: usize, dst: usize) -> Option<Vec<usize>> {
    let mut prev = vec![usize::MAX; g.n()];
    let mut dist = vec![usize::MAX; g.n()];
    let mut queue = std::collections::VecDeque::new();
    dist[src] = 0;
    queue.push_back(src);
    while let Some(v) = queue.pop_front() {
        if v == dst {
            break;
        }
        for u in g.neighbors(v) {
            if dist[u] == usize::MAX {
                dist[u] = dist[v] + 1;
                prev[u] = v;
                queue.push_back(u);
            }
        }
    }
    if dist[dst] == usize::MAX {
        return None;
    }
    let mut path = vec![dst];
    let mut cur = dst;
    while cur != src {
        cur = prev[cur];
        path.push(cur);
    }
    path.reverse();
    Some(path)
}

/// The DTUR connecting path P: a minimal set of d = N-1 links spanning all
/// workers, as an ordered edge list (the order DTUR establishes them in).
///
/// Strategy: greedy DFS longest-simple-path from the max-degree node; if
/// it visits every node we have a true Hamiltonian path, otherwise we
/// return a BFS spanning tree's edges in discovery order.
pub fn connecting_path(g: &Graph) -> Vec<(usize, usize)> {
    assert!(g.is_connected(), "DTUR requires a connected graph");
    let n = g.n();
    if n <= 1 {
        return Vec::new();
    }
    // Greedy Hamiltonian-path attempt from each of a few start nodes.
    let mut starts: Vec<usize> = (0..n).collect();
    starts.sort_by_key(|&v| std::cmp::Reverse(g.degree(v)));
    for &s in starts.iter().take(4.min(n)) {
        if let Some(path) = greedy_ham_path(g, s) {
            return path.windows(2).map(|w| ord(w[0], w[1])).collect();
        }
    }
    // Fallback: BFS spanning tree in discovery order.
    let mut seen = vec![false; n];
    let mut queue = std::collections::VecDeque::new();
    let mut edges = Vec::with_capacity(n - 1);
    seen[starts[0]] = true;
    queue.push_back(starts[0]);
    while let Some(v) = queue.pop_front() {
        for u in g.neighbors(v) {
            if !seen[u] {
                seen[u] = true;
                edges.push(ord(v, u));
                queue.push_back(u);
            }
        }
    }
    edges
}

fn ord(a: usize, b: usize) -> (usize, usize) {
    if a < b {
        (a, b)
    } else {
        (b, a)
    }
}

/// Greedy simple path: always step to the unvisited neighbour with fewest
/// unvisited neighbours (Warnsdorff-style). Returns the node order when it
/// covers all of G.
fn greedy_ham_path(g: &Graph, start: usize) -> Option<Vec<usize>> {
    let n = g.n();
    let mut visited = vec![false; n];
    let mut path = vec![start];
    visited[start] = true;
    let mut cur = start;
    while path.len() < n {
        let next = g
            .neighbors(cur)
            .filter(|&u| !visited[u])
            .min_by_key(|&u| g.neighbors(u).filter(|&w| !visited[w]).count())?;
        visited[next] = true;
        path.push(next);
        cur = next;
    }
    Some(path)
}

/// Check that an edge list spans all n nodes and is connected as a subgraph.
pub fn spans_all(n: usize, edges: &[(usize, usize)]) -> bool {
    if n == 0 {
        return true;
    }
    let sub = Graph::from_edges(n, edges);
    // spanning connectivity: the edge-induced subgraph plus isolated nodes
    // must be connected, i.e. every node touched and one component.
    let mut touched = vec![false; n];
    for &(a, b) in edges {
        touched[a] = true;
        touched[b] = true;
    }
    (n == 1 || touched.iter().all(|&t| t)) && sub.is_connected()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topology;
    use crate::util::rng::Rng;

    #[test]
    fn bfs_on_ring() {
        let g = topology::ring(6);
        let d = bfs_dist(&g, 0);
        assert_eq!(d, vec![0, 1, 2, 3, 2, 1]);
        assert_eq!(diameter(&g), Some(3));
    }

    #[test]
    fn shortest_path_endpoints() {
        let g = topology::ring(8);
        let p = shortest_path(&g, 0, 4).unwrap();
        assert_eq!(p.len(), 5);
        assert_eq!(p[0], 0);
        assert_eq!(*p.last().unwrap(), 4);
        for w in p.windows(2) {
            assert!(g.has_edge(w[0], w[1]));
        }
    }

    #[test]
    fn connecting_path_spans_everything() {
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            for &n in &[2usize, 3, 6, 10, 15] {
                let g = topology::random_connected(n, 0.3, &mut rng);
                let p = connecting_path(&g);
                assert_eq!(p.len(), n - 1, "n={n} seed={seed}");
                assert!(spans_all(n, &p), "n={n} seed={seed}");
                for &(a, b) in &p {
                    assert!(g.has_edge(a, b));
                }
            }
        }
    }

    #[test]
    fn connecting_path_on_ring_is_hamiltonian() {
        let g = topology::ring(10);
        let p = connecting_path(&g);
        assert_eq!(p.len(), 9);
        // ring has a Hamiltonian path; each node appears <= 2 times
        let mut count = vec![0usize; 10];
        for &(a, b) in &p {
            count[a] += 1;
            count[b] += 1;
        }
        assert!(count.iter().all(|&c| c <= 2));
    }

    #[test]
    fn connecting_path_star_is_tree() {
        let g = topology::star(6);
        let p = connecting_path(&g);
        assert_eq!(p.len(), 5);
        assert!(spans_all(6, &p));
    }

    #[test]
    fn diameter_disconnected_none() {
        let g = Graph::from_edges(4, &[(0, 1), (2, 3)]);
        assert_eq!(diameter(&g), None);
    }
}
