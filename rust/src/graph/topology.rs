//! Topology generators for the consensus graph.
//!
//! The paper evaluates on "a randomly generated connected graph" with 6
//! and 10 workers; we also provide the standard decentralised-SGD
//! topologies (ring, complete, 2D torus/grid, star) so ablations can probe
//! the topology dependence of the convergence bound (the β^{NB} term in
//! Theorem 1 depends on connectivity).

use super::Graph;
use crate::util::parse::ParseError;
use crate::util::rng::Rng;

/// Named topology kinds, parsed from config / CLI.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Topology {
    Ring,
    Complete,
    Star,
    Grid,
    /// Erdős–Rényi G(n, p) conditioned on connectivity (paper's setup).
    RandomConnected,
    /// Hierarchical rack-of-rings: `r` racks, each an internal ring,
    /// whose gateway nodes form an inter-rack ring (datacenter-style
    /// two-level hierarchy; parsed from `racks:<r>`).
    Racks(usize),
}

impl Topology {
    /// Parse a topology spec. Round-trip contract:
    /// `parse(&t.name()) == Ok(t)` for every topology; anything else is
    /// a typed [`ParseError`].
    pub fn parse(s: &str) -> Result<Topology, ParseError> {
        let err = || {
            ParseError::new("topology", s, "ring | complete | star | grid | random | racks:<r>")
        };
        if let Some(r) = s.strip_prefix("racks:") {
            let r = r.parse::<usize>().map_err(|_| err())?;
            if r == 0 {
                return Err(err());
            }
            return Ok(Topology::Racks(r));
        }
        Ok(match s {
            "ring" => Topology::Ring,
            "complete" | "full" => Topology::Complete,
            "star" => Topology::Star,
            "grid" | "torus" => Topology::Grid,
            "random" | "random_connected" => Topology::RandomConnected,
            _ => return Err(err()),
        })
    }

    /// The spec string [`Self::parse`] accepts back:
    /// `parse(&t.name()) == Ok(t)`.
    pub fn name(&self) -> String {
        match self {
            Topology::Ring => "ring".into(),
            Topology::Complete => "complete".into(),
            Topology::Star => "star".into(),
            Topology::Grid => "grid".into(),
            Topology::RandomConnected => "random".into(),
            Topology::Racks(r) => format!("racks:{r}"),
        }
    }
}

pub fn build(kind: Topology, n: usize, rng: &mut Rng) -> Graph {
    match kind {
        Topology::Ring => ring(n),
        Topology::Complete => complete(n),
        Topology::Star => star(n),
        Topology::Grid => grid(n),
        Topology::RandomConnected => random_connected(n, 0.4, rng),
        Topology::Racks(r) => rack_of_rings(n, r),
    }
}

pub fn ring(n: usize) -> Graph {
    let mut g = Graph::empty(n);
    if n < 2 {
        return g;
    }
    for i in 0..n {
        g.add_edge(i, (i + 1) % n);
    }
    g
}

pub fn complete(n: usize) -> Graph {
    let mut g = Graph::empty(n);
    for a in 0..n {
        for b in (a + 1)..n {
            g.add_edge(a, b);
        }
    }
    g
}

pub fn star(n: usize) -> Graph {
    let mut g = Graph::empty(n);
    for i in 1..n {
        g.add_edge(0, i);
    }
    g
}

/// Near-square 2D grid (torus wrap only when a dimension >= 3).
pub fn grid(n: usize) -> Graph {
    let rows = (n as f64).sqrt().floor() as usize;
    let rows = rows.max(1);
    let cols = n.div_ceil(rows);
    let mut g = Graph::empty(n);
    let id = |r: usize, c: usize| r * cols + c;
    for r in 0..rows {
        for c in 0..cols {
            let v = id(r, c);
            if v >= n {
                continue;
            }
            if c + 1 < cols && id(r, c + 1) < n {
                g.add_edge(v, id(r, c + 1));
            }
            if r + 1 < rows && id(r + 1, c) < n {
                g.add_edge(v, id(r + 1, c));
            }
        }
    }
    // Ensure connectivity for ragged last rows.
    if n > 1 && !g.is_connected() {
        for i in 1..n {
            if !g.is_connected() {
                g.add_edge(i - 1, i);
            }
        }
    }
    g
}

/// G(n, p) resampled until connected, then guaranteed by adding a random
/// spanning-tree fallback after a bounded number of rejections.
pub fn random_connected(n: usize, p: f64, rng: &mut Rng) -> Graph {
    assert!(n >= 1);
    for _attempt in 0..64 {
        let mut g = Graph::empty(n);
        for a in 0..n {
            for b in (a + 1)..n {
                if rng.uniform() < p {
                    g.add_edge(a, b);
                }
            }
        }
        if g.is_connected() {
            return g;
        }
    }
    // Fallback: random spanning tree + extra random edges (always connected).
    let mut g = Graph::empty(n);
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    for i in 1..n {
        let j = rng.below(i);
        g.add_edge(order[i], order[j]);
    }
    for a in 0..n {
        for b in (a + 1)..n {
            if !g.has_edge(a, b) && rng.uniform() < p {
                g.add_edge(a, b);
            }
        }
    }
    g
}

/// Two-level hierarchy: `racks` near-equal contiguous racks, a ring
/// inside each rack, and the first node of every rack (its "gateway" /
/// top-of-rack switch) joined into an inter-rack ring. Degree stays
/// O(1) — at most 4 (two intra-rack + two inter-rack on gateways) — so
/// million-worker instances stay sparse, while the diameter drops from
/// O(n) (flat ring) to O(n/r + r).
pub fn rack_of_rings(n: usize, racks: usize) -> Graph {
    let racks = racks.clamp(1, n.max(1));
    if racks <= 1 {
        return ring(n);
    }
    let mut g = Graph::empty(n);
    let slices = rack_slices(n, racks);
    for s in &slices {
        let (lo, m) = (s.start, s.len());
        if m >= 2 {
            for i in 0..m {
                g.add_edge(lo + i, lo + (i + 1) % m);
            }
        }
    }
    for r in 0..racks {
        g.add_edge(slices[r].start, slices[(r + 1) % racks].start);
    }
    g
}

/// The contiguous member ranges of each rack in a [`rack_of_rings`]
/// topology — the first `n % racks` racks get one extra member. Exposed
/// so fault injection can expand a rack-level outage window into the
/// exact per-worker membership events the topology implies.
pub fn rack_slices(n: usize, racks: usize) -> Vec<std::ops::Range<usize>> {
    let racks = racks.clamp(1, n.max(1));
    let base = n / racks;
    let extra = n % racks;
    let mut slices = Vec::with_capacity(racks);
    let mut at = 0;
    for r in 0..racks {
        let hi = at + base + usize::from(r < extra);
        slices.push(at..hi);
        at = hi;
    }
    slices
}

/// The fixed 10-worker network from the paper's Figure 2 (approximate
/// reconstruction — the exact edge list is not published; we build a
/// random connected 10-node graph with comparable average degree and pin
/// its seed so every experiment sees the same network).
pub fn paper_fig2(rng_seed: u64) -> Graph {
    let mut rng = Rng::new(rng_seed);
    random_connected(10, 0.35, &mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_degrees() {
        let g = ring(6);
        assert!(g.is_connected());
        for v in 0..6 {
            assert_eq!(g.degree(v), 2);
        }
    }

    #[test]
    fn ring_of_two() {
        let g = ring(2);
        assert_eq!(g.edge_count(), 1);
        assert!(g.is_connected());
    }

    #[test]
    fn complete_edge_count() {
        let g = complete(7);
        assert_eq!(g.edge_count(), 21);
        for v in 0..7 {
            assert_eq!(g.degree(v), 6);
        }
    }

    #[test]
    fn star_center() {
        let g = star(5);
        assert_eq!(g.degree(0), 4);
        for v in 1..5 {
            assert_eq!(g.degree(v), 1);
        }
        assert!(g.is_connected());
    }

    #[test]
    fn grid_connected_for_many_sizes() {
        for n in 1..30 {
            let g = grid(n);
            assert!(g.is_connected(), "grid({n}) not connected");
        }
    }

    #[test]
    fn random_connected_always_connected() {
        for seed in 0..25 {
            let mut rng = Rng::new(seed);
            for &n in &[2usize, 3, 6, 10, 17] {
                let g = random_connected(n, 0.15, &mut rng);
                assert!(g.is_connected(), "n={n} seed={seed}");
                assert_eq!(g.n(), n);
            }
        }
    }

    #[test]
    fn random_connected_deterministic_per_seed() {
        let g1 = random_connected(8, 0.3, &mut Rng::new(9));
        let g2 = random_connected(8, 0.3, &mut Rng::new(9));
        assert_eq!(g1, g2);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Topology::parse("ring"), Ok(Topology::Ring));
        assert_eq!(Topology::parse("full"), Ok(Topology::Complete));
        assert_eq!(Topology::parse("racks:8"), Ok(Topology::Racks(8)));
        for bad in ["racks:0", "racks:x", "racks:", "nope", "", "Ring", "ring "] {
            let err = Topology::parse(bad).unwrap_err();
            assert_eq!(err.what, "topology");
            assert_eq!(err.input, bad);
            assert!(err.to_string().contains("racks:<r>"), "{err}");
        }
    }

    #[test]
    fn name_roundtrips_through_parse() {
        for t in [
            Topology::Ring,
            Topology::Complete,
            Topology::Star,
            Topology::Grid,
            Topology::RandomConnected,
            Topology::Racks(12),
        ] {
            assert_eq!(Topology::parse(&t.name()), Ok(t), "name: {}", t.name());
        }
    }

    #[test]
    fn rack_slices_match_the_built_topology() {
        for &(n, r) in &[(12usize, 3usize), (10, 4), (50, 7), (3, 10), (8, 1)] {
            let slices = rack_slices(n, r);
            // cover 0..n exactly, contiguously
            let mut at = 0;
            for s in &slices {
                assert_eq!(s.start, at);
                assert!(!s.is_empty() || n < r, "empty rack in ({n},{r})");
                at = s.end;
            }
            assert_eq!(at, n);
            // rack sizes differ by at most one
            let sizes: Vec<usize> = slices.iter().map(|s| s.len()).collect();
            let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "({n},{r}): {sizes:?}");
            // gateways (slice starts) really are the inter-rack ring
            if r >= 2 && n >= r {
                let g = rack_of_rings(n, r);
                for w in 0..slices.len() {
                    let (a, b) = (slices[w].start, slices[(w + 1) % slices.len()].start);
                    if a != b {
                        assert!(g.has_edge(a, b), "({n},{r}): gateway edge {a}-{b} missing");
                    }
                }
            }
        }
    }

    #[test]
    fn rack_of_rings_connected_sparse_for_many_shapes() {
        for &(n, r) in &[(2usize, 2usize), (5, 2), (9, 3), (10, 4), (24, 6), (50, 7), (100, 10)] {
            let g = rack_of_rings(n, r);
            assert_eq!(g.n(), n);
            assert!(g.is_connected(), "racks({n},{r}) not connected");
            for v in 0..n {
                assert!(g.degree(v) <= 4, "racks({n},{r}): degree({v}) = {}", g.degree(v));
            }
        }
    }

    #[test]
    fn rack_of_rings_degenerates_to_ring() {
        assert_eq!(rack_of_rings(8, 1), ring(8));
        // more racks than workers: clamped, still connected
        let g = rack_of_rings(3, 10);
        assert!(g.is_connected());
        assert_eq!(g.n(), 3);
    }

    #[test]
    fn rack_of_rings_gateways_link_racks() {
        // 12 workers, 3 racks of 4: gateways 0, 4, 8 form the top ring
        let g = rack_of_rings(12, 3);
        assert!(g.has_edge(0, 4) && g.has_edge(4, 8) && g.has_edge(8, 0));
        // intra-rack ring intact
        assert!(g.has_edge(0, 1) && g.has_edge(1, 2) && g.has_edge(2, 3) && g.has_edge(3, 0));
        // no stray cross-rack edges off the gateways
        assert!(!g.has_edge(1, 5) && !g.has_edge(3, 4));
    }

    #[test]
    fn paper_fig2_is_10_nodes_connected() {
        let g = paper_fig2(2021);
        assert_eq!(g.n(), 10);
        assert!(g.is_connected());
    }
}
