//! Coordinator/worker transports: in-process channels and real TCP.
//!
//! The coordinator drives workers through the [`Transport`] trait — send
//! a [`Msg`] to worker `j`, receive `(j, Msg)` events from any worker —
//! and each worker holds the matching [`WorkerPort`]. Two
//! implementations:
//!
//! - [`ChannelTransport`] — the degenerate transport: plain `mpsc`
//!   channels between threads of one process. No serialisation, no
//!   sockets; what the in-process live driver and the unit tests run on.
//! - [`TcpTransport`] — persistent per-worker TCP connections (localhost
//!   or otherwise). One reader thread per peer decodes frames off the
//!   socket and feeds the same event channel, so the coordinator's
//!   receive path is identical on both transports — a single
//!   `recv_timeout` park, no polling.
//!
//! Handshake: a connecting worker sends [`Msg::Hello`] (a requested slot
//! id, or [`ANY_WORKER`] to be assigned one), the coordinator answers
//! [`Msg::Init`] with the assigned id and the experiment setup JSON.
//! Every failure is a typed [`TransportError`].

use std::collections::VecDeque;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::codec::{read_frame, read_frame_opt, write_frame, CodecError, Msg};

pub use super::codec::ANY_WORKER;

/// Typed transport failure.
#[derive(Debug)]
pub enum TransportError {
    /// Worker `worker`'s connection/channel is gone (send side).
    Closed { worker: usize },
    /// The event stream is gone: every peer hung up.
    Disconnected,
    /// No event arrived within the timeout.
    Timeout { secs: f64 },
    /// Worker `worker` sent bytes the codec rejected.
    Codec { worker: usize, err: CodecError },
    /// Connection setup / Hello-Init exchange failed.
    Handshake(String),
    Io(std::io::Error),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Closed { worker } => write!(f, "worker {worker} connection closed"),
            TransportError::Disconnected => write!(f, "all peers disconnected"),
            TransportError::Timeout { secs } => write!(f, "no message within {secs:.1}s"),
            TransportError::Codec { worker, err } => {
                write!(f, "bad frame from worker {worker}: {err}")
            }
            TransportError::Handshake(what) => write!(f, "handshake failed: {what}"),
            TransportError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for TransportError {}

/// One received event: `(worker id, decoded message or codec failure)`.
type Event = (usize, Result<Msg, CodecError>);

/// Coordinator-side message fabric.
pub trait Transport {
    /// Number of worker endpoints.
    fn workers(&self) -> usize;
    /// Send `msg` to worker `to`.
    fn send(&mut self, to: usize, msg: Msg) -> Result<(), TransportError>;
    /// Block for the next event from any worker (up to `timeout`).
    fn recv(&mut self, timeout: Duration) -> Result<(usize, Msg), TransportError>;
}

fn map_event(ev: Event) -> Result<(usize, Msg), TransportError> {
    match ev {
        (j, Ok(msg)) => Ok((j, msg)),
        (j, Err(err)) => Err(TransportError::Codec { worker: j, err }),
    }
}

fn map_recv_timeout(
    r: Result<Event, RecvTimeoutError>,
    timeout: Duration,
) -> Result<(usize, Msg), TransportError> {
    match r {
        Ok(ev) => map_event(ev),
        Err(RecvTimeoutError::Timeout) => {
            Err(TransportError::Timeout { secs: timeout.as_secs_f64() })
        }
        Err(RecvTimeoutError::Disconnected) => Err(TransportError::Disconnected),
    }
}

// --------------------------------------------------------- worker side

enum PortTx {
    /// In-process: push straight into the coordinator's event channel.
    Chan { tx: Sender<Event>, id: usize },
    /// TCP: encode onto the socket.
    Tcp(TcpStream),
}

/// A worker's endpoint: receive coordinator commands, send answers.
pub struct WorkerPort {
    id: usize,
    rx: Receiver<Event>,
    tx: PortTx,
    pending: VecDeque<Msg>,
}

impl WorkerPort {
    /// The worker slot this port belongs to.
    pub fn id(&self) -> usize {
        self.id
    }

    /// Re-queue a message so the next `recv` returns it first (used by
    /// the worker's interruptible straggler wait when a non-Terminate
    /// command arrives mid-sleep).
    pub fn push_back(&mut self, msg: Msg) {
        self.pending.push_back(msg);
    }

    /// Blocking receive.
    pub fn recv(&mut self) -> Result<Msg, TransportError> {
        if let Some(m) = self.pending.pop_front() {
            return Ok(m);
        }
        match self.rx.recv() {
            Ok(ev) => map_event(ev).map(|(_, m)| m),
            Err(_) => Err(TransportError::Disconnected),
        }
    }

    /// Receive with a timeout; `Ok(None)` means the timeout elapsed.
    /// This park (not a poll) is what the worker's straggler sleep and
    /// the old busy-wait loops were replaced with.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Msg>, TransportError> {
        if let Some(m) = self.pending.pop_front() {
            return Ok(Some(m));
        }
        match self.rx.recv_timeout(timeout) {
            Ok(ev) => map_event(ev).map(|(_, m)| Some(m)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(TransportError::Disconnected),
        }
    }

    /// Send a message to the coordinator.
    pub fn send(&mut self, msg: Msg) -> Result<(), TransportError> {
        let id = self.id;
        match &mut self.tx {
            PortTx::Chan { tx, id: from } => tx
                .send((*from, Ok(msg)))
                .map_err(|_| TransportError::Disconnected),
            PortTx::Tcp(stream) => write_frame(stream, &msg).map_err(|e| match e {
                CodecError::Io(io) => TransportError::Io(io),
                other => TransportError::Codec { worker: id, err: other },
            }),
        }
    }
}

impl Drop for WorkerPort {
    fn drop(&mut self) {
        // Shutdown (not just drop) so the reader thread's blocked read —
        // which holds its own clone of the socket — unblocks too.
        if let PortTx::Tcp(stream) = &self.tx {
            let _ = stream.shutdown(Shutdown::Both);
        }
    }
}

// ------------------------------------------------------ channel fabric

/// The degenerate transport: `mpsc` channels inside one process.
pub struct ChannelTransport {
    txs: Vec<Sender<Event>>,
    rx: Receiver<Event>,
}

impl ChannelTransport {
    /// Build a coordinator handle plus `n` worker ports.
    pub fn pair(n: usize) -> (ChannelTransport, Vec<WorkerPort>) {
        let (evt_tx, evt_rx) = channel::<Event>();
        let mut txs = Vec::with_capacity(n);
        let mut ports = Vec::with_capacity(n);
        for j in 0..n {
            let (tx, rx) = channel::<Event>();
            txs.push(tx);
            ports.push(WorkerPort {
                id: j,
                rx,
                tx: PortTx::Chan { tx: evt_tx.clone(), id: j },
                pending: VecDeque::new(),
            });
        }
        // evt_tx is NOT retained here: once every port is gone the
        // coordinator's recv reports Disconnected instead of hanging.
        (ChannelTransport { txs, rx: evt_rx }, ports)
    }
}

impl Transport for ChannelTransport {
    fn workers(&self) -> usize {
        self.txs.len()
    }

    fn send(&mut self, to: usize, msg: Msg) -> Result<(), TransportError> {
        match self.txs.get(to) {
            Some(tx) => tx
                .send((to, Ok(msg)))
                .map_err(|_| TransportError::Closed { worker: to }),
            None => Err(TransportError::Closed { worker: to }),
        }
    }

    fn recv(&mut self, timeout: Duration) -> Result<(usize, Msg), TransportError> {
        map_recv_timeout(self.rx.recv_timeout(timeout), timeout)
    }
}

// ---------------------------------------------------------- tcp fabric

/// Decode frames off one peer's socket into the shared event channel.
fn reader_loop(id: usize, mut stream: TcpStream, tx: Sender<Event>) {
    loop {
        match read_frame_opt(&mut stream) {
            Ok(Some(msg)) => {
                if tx.send((id, Ok(msg))).is_err() {
                    return; // coordinator gone
                }
            }
            Ok(None) => return, // peer closed cleanly
            Err(err) => {
                let _ = tx.send((id, Err(err)));
                return;
            }
        }
    }
}

/// Real-socket transport: one persistent connection per worker.
pub struct TcpTransport {
    streams: Vec<TcpStream>,
    rx: Receiver<Event>,
    readers: Vec<JoinHandle<()>>,
}

impl TcpTransport {
    /// Accept exactly `n` workers on `listener`, performing the
    /// Hello/Init handshake with each (`setup` is the experiment JSON
    /// handed to every worker). Slot ids: a worker may claim a specific
    /// id in its Hello (duplicates and out-of-range ids are handshake
    /// errors), or send [`ANY_WORKER`] to get the lowest free slot.
    pub fn accept(
        listener: &TcpListener,
        n: usize,
        setup: &str,
        handshake_timeout: Duration,
    ) -> Result<TcpTransport, TransportError> {
        let (tx, rx) = channel::<Event>();
        let mut slots: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        let mut accepted = 0usize;
        while accepted < n {
            let (mut stream, _peer) = listener.accept().map_err(TransportError::Io)?;
            stream.set_nodelay(true).map_err(TransportError::Io)?;
            stream
                .set_read_timeout(Some(handshake_timeout))
                .map_err(TransportError::Io)?;
            let hello = read_frame(&mut stream)
                .map_err(|err| TransportError::Codec { worker: accepted, err })?;
            let Msg::Hello { worker } = hello else {
                return Err(TransportError::Handshake(format!(
                    "expected Hello, got {}",
                    hello.name()
                )));
            };
            let id = if worker == ANY_WORKER {
                slots
                    .iter()
                    .position(|s| s.is_none())
                    .ok_or_else(|| TransportError::Handshake("no free worker slot".into()))?
            } else {
                let id = worker as usize;
                if id >= n {
                    return Err(TransportError::Handshake(format!(
                        "worker id {id} out of range (n = {n})"
                    )));
                }
                if slots[id].is_some() {
                    return Err(TransportError::Handshake(format!(
                        "worker id {id} claimed twice"
                    )));
                }
                id
            };
            write_frame(
                &mut stream,
                &Msg::Init { worker: id as u32, setup: setup.to_string() },
            )
            .map_err(|err| match err {
                CodecError::Io(io) => TransportError::Io(io),
                other => TransportError::Codec { worker: id, err: other },
            })?;
            stream.set_read_timeout(None).map_err(TransportError::Io)?;
            slots[id] = Some(stream);
            accepted += 1;
        }
        let streams: Vec<TcpStream> = slots.into_iter().flatten().collect();
        let mut readers = Vec::with_capacity(n);
        for (id, s) in streams.iter().enumerate() {
            let clone = s.try_clone().map_err(TransportError::Io)?;
            let tx = tx.clone();
            readers.push(
                std::thread::Builder::new()
                    .name(format!("dybw-net-{id}"))
                    .spawn(move || reader_loop(id, clone, tx))
                    .map_err(TransportError::Io)?,
            );
        }
        Ok(TcpTransport { streams, rx, readers })
    }
}

impl Transport for TcpTransport {
    fn workers(&self) -> usize {
        self.streams.len()
    }

    fn send(&mut self, to: usize, msg: Msg) -> Result<(), TransportError> {
        match self.streams.get_mut(to) {
            Some(stream) => write_frame(stream, &msg).map_err(|e| match e {
                CodecError::Io(_) => TransportError::Closed { worker: to },
                other => TransportError::Codec { worker: to, err: other },
            }),
            None => Err(TransportError::Closed { worker: to }),
        }
    }

    fn recv(&mut self, timeout: Duration) -> Result<(usize, Msg), TransportError> {
        map_recv_timeout(self.rx.recv_timeout(timeout), timeout)
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        // Shutdown unblocks each reader thread's in-flight read (the
        // readers own clones of these sockets), then join them so no
        // thread outlives the transport.
        for s in &self.streams {
            let _ = s.shutdown(Shutdown::Both);
        }
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Connect with retry/backoff until `timeout` elapses (the coordinator
/// may come up after its workers in a launch script).
pub fn connect_retry(addr: &str, timeout: Duration) -> Result<TcpStream, TransportError> {
    let deadline = Instant::now() + timeout;
    let mut backoff = Duration::from_millis(50);
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                let now = Instant::now();
                if now >= deadline {
                    return Err(TransportError::Handshake(format!(
                        "cannot connect to {addr} within {:.1}s: {e}",
                        timeout.as_secs_f64()
                    )));
                }
                std::thread::sleep(backoff.min(deadline - now));
                backoff = (backoff * 2).min(Duration::from_millis(500));
            }
        }
    }
}

/// Worker-process entry: connect to the coordinator, run the Hello/Init
/// handshake (claiming slot `requested` if given), and return
/// `(assigned id, setup JSON, port)` with the reader thread running.
pub fn connect_worker(
    addr: &str,
    requested: Option<u32>,
    timeout: Duration,
) -> Result<(u32, String, WorkerPort), TransportError> {
    let mut stream = connect_retry(addr, timeout)?;
    stream.set_nodelay(true).map_err(TransportError::Io)?;
    write_frame(&mut stream, &Msg::Hello { worker: requested.unwrap_or(ANY_WORKER) }).map_err(
        |e| match e {
            CodecError::Io(io) => TransportError::Io(io),
            other => TransportError::Codec { worker: 0, err: other },
        },
    )?;
    stream
        .set_read_timeout(Some(timeout))
        .map_err(TransportError::Io)?;
    let init = read_frame(&mut stream).map_err(|err| {
        TransportError::Handshake(format!("no Init from coordinator at {addr}: {err}"))
    })?;
    let Msg::Init { worker, setup } = init else {
        return Err(TransportError::Handshake(format!(
            "expected Init, got {}",
            init.name()
        )));
    };
    stream.set_read_timeout(None).map_err(TransportError::Io)?;
    let id = worker as usize;
    let (evt_tx, rx) = channel::<Event>();
    let clone = stream.try_clone().map_err(TransportError::Io)?;
    std::thread::Builder::new()
        .name(format!("dybw-net-{id}"))
        .spawn(move || reader_loop(id, clone, evt_tx))
        .map_err(TransportError::Io)?;
    let port = WorkerPort {
        id,
        rx,
        tx: PortTx::Tcp(stream),
        pending: VecDeque::new(),
    };
    Ok((worker, setup, port))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_pair_round_trips_both_directions() {
        let (mut t, mut ports) = ChannelTransport::pair(2);
        assert_eq!(t.workers(), 2);
        t.send(0, Msg::Ping { nonce: 10 }).unwrap();
        t.send(1, Msg::Ping { nonce: 11 }).unwrap();
        for port in ports.iter_mut() {
            let Msg::Ping { nonce } = port.recv().unwrap() else {
                panic!("expected Ping");
            };
            assert_eq!(nonce, 10 + port.id() as u64);
            port.send(Msg::Pong { nonce }).unwrap();
        }
        let mut seen = [false; 2];
        for _ in 0..2 {
            let (j, msg) = t.recv(Duration::from_secs(5)).unwrap();
            assert_eq!(msg, Msg::Pong { nonce: 10 + j as u64 });
            seen[j] = true;
        }
        assert!(seen[0] && seen[1]);
    }

    #[test]
    fn channel_recv_times_out_as_typed_error() {
        let (mut t, _ports) = ChannelTransport::pair(1);
        match t.recv(Duration::from_millis(30)) {
            Err(TransportError::Timeout { secs }) => assert!(secs > 0.0),
            other => panic!("expected Timeout, got {other:?}"),
        }
    }

    #[test]
    fn channel_send_to_dropped_port_is_closed() {
        let (mut t, mut ports) = ChannelTransport::pair(2);
        ports.remove(0); // worker 0 dies
        assert!(matches!(
            t.send(0, Msg::Stop),
            Err(TransportError::Closed { worker: 0 })
        ));
        // worker 1 still reachable
        t.send(1, Msg::Stop).unwrap();
        assert_eq!(ports[0].recv().unwrap(), Msg::Stop);
    }

    #[test]
    fn channel_recv_disconnects_when_all_ports_dropped() {
        let (mut t, ports) = ChannelTransport::pair(2);
        drop(ports);
        assert!(matches!(
            t.recv(Duration::from_secs(1)),
            Err(TransportError::Disconnected)
        ));
    }

    #[test]
    fn push_back_is_returned_first() {
        let (mut t, mut ports) = ChannelTransport::pair(1);
        t.send(0, Msg::Stop).unwrap();
        ports[0].push_back(Msg::Ping { nonce: 1 });
        assert_eq!(ports[0].recv().unwrap(), Msg::Ping { nonce: 1 });
        assert_eq!(ports[0].recv().unwrap(), Msg::Stop);
    }

    #[test]
    fn tcp_loopback_handshake_and_round_trip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let timeout = Duration::from_secs(10);
        let mut joins = Vec::new();
        for j in [1u32, 0u32] {
            let addr = addr.clone();
            joins.push(std::thread::spawn(move || {
                let (id, setup, mut port) = connect_worker(&addr, Some(j), timeout).unwrap();
                assert_eq!(id, j);
                assert_eq!(setup, "SETUP");
                let Msg::Ping { nonce } = port.recv().unwrap() else {
                    panic!("expected Ping");
                };
                port.send(Msg::Pong { nonce: nonce + 1 }).unwrap();
                // coordinator closes; clean shutdown
                assert!(matches!(port.recv(), Err(TransportError::Disconnected)));
            }));
        }
        let mut t = TcpTransport::accept(&listener, 2, "SETUP", timeout).unwrap();
        t.send(0, Msg::Ping { nonce: 100 }).unwrap();
        t.send(1, Msg::Ping { nonce: 200 }).unwrap();
        for _ in 0..2 {
            let (j, msg) = t.recv(timeout).unwrap();
            assert_eq!(msg, Msg::Pong { nonce: 101 + 100 * j as u64 });
        }
        drop(t);
        for h in joins {
            h.join().unwrap();
        }
    }

    #[test]
    fn tcp_any_worker_gets_distinct_slots() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let timeout = Duration::from_secs(10);
        let joins: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let (id, _setup, port) = connect_worker(&addr, None, timeout).unwrap();
                    drop(port);
                    id
                })
            })
            .collect();
        let t = TcpTransport::accept(&listener, 2, "", timeout).unwrap();
        let mut ids: Vec<u32> = joins.into_iter().map(|h| h.join().unwrap()).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1]);
        drop(t);
    }

    #[test]
    fn tcp_handshake_rejects_out_of_range_id() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let timeout = Duration::from_secs(10);
        let h = std::thread::spawn(move || {
            // the coordinator drops the socket on rejection; either a
            // handshake error or an io error is acceptable here
            let _ = connect_worker(&addr, Some(7), timeout);
        });
        match TcpTransport::accept(&listener, 2, "", timeout) {
            Err(TransportError::Handshake(msg)) => assert!(msg.contains("out of range")),
            other => panic!("expected Handshake error, got {:?}", other.err()),
        }
        h.join().unwrap();
    }

    #[test]
    fn connect_retry_gives_up_with_typed_error() {
        // grab a port, then free it so nothing listens there
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let t0 = Instant::now();
        let err = connect_retry(&addr, Duration::from_millis(250)).unwrap_err();
        assert!(matches!(err, TransportError::Handshake(_)), "{err}");
        assert!(t0.elapsed() >= Duration::from_millis(200));
    }
}
