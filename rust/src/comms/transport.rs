//! Coordinator/worker transports: in-process channels and real TCP.
//!
//! The coordinator drives workers through the [`Transport`] trait — send
//! a [`Msg`] to worker `j`, receive `(j, Msg)` events from any worker —
//! and each worker holds the matching [`WorkerPort`]. Two
//! implementations:
//!
//! - [`ChannelTransport`] — the degenerate transport: plain `mpsc`
//!   channels between threads of one process. No serialisation, no
//!   sockets; what the in-process live driver and the unit tests run on.
//! - [`TcpTransport`] — persistent per-worker TCP connections (localhost
//!   or otherwise). One reader thread per peer decodes frames off the
//!   socket and feeds the same event channel, so the coordinator's
//!   receive path is identical on both transports — a single
//!   `recv_timeout` park, no polling.
//!
//! Handshake: a connecting worker sends [`Msg::Hello`] (a requested slot
//! id, or [`ANY_WORKER`] to be assigned one), the coordinator answers
//! [`Msg::Init`] with the assigned id and the experiment setup JSON.
//! Every failure is a typed [`TransportError`].
//!
//! Peers are mortal. A peer whose connection drops surfaces as a typed
//! [`TransportError::PeerDisconnected`] on the coordinator's event
//! stream (never a silently-dead reader thread), and the coordinator can
//! cut a peer itself with [`Transport::sever`]. After the initial accept
//! phase a [`TcpTransport`] keeps accepting: a worker that lost its
//! connection re-claims its slot with [`Msg::Rejoin`] (or a restarted
//! process re-handshakes with a specific-slot [`Msg::Hello`]), and the
//! new connection *takes over* the slot. Every slot carries a
//! generation counter, bumped on takeover/sever, and events from a
//! replaced connection are dropped as stale — a half-open old socket can
//! never speak for the slot's new owner.

use std::collections::VecDeque;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::codec::{
    read_frame, read_frame_opt_counted, write_frame, write_frame_counted, CodecError, Msg,
};
use crate::util::rng::Rng;

pub use super::codec::ANY_WORKER;

/// Typed transport failure.
#[derive(Debug)]
pub enum TransportError {
    /// Worker `worker`'s connection/channel is gone (send side).
    Closed { worker: usize },
    /// The event stream is gone: every peer hung up.
    Disconnected,
    /// Worker `worker`'s connection dropped (EOF / reset mid-recv).
    PeerDisconnected { worker: usize },
    /// No event arrived within the timeout.
    Timeout { secs: f64 },
    /// Worker `worker` sent bytes the codec rejected.
    Codec { worker: usize, err: CodecError },
    /// Connection setup / Hello-Init exchange failed.
    Handshake(String),
    Io(std::io::Error),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Closed { worker } => write!(f, "worker {worker} connection closed"),
            TransportError::Disconnected => write!(f, "all peers disconnected"),
            TransportError::PeerDisconnected { worker } => {
                write!(f, "worker {worker} disconnected")
            }
            TransportError::Timeout { secs } => write!(f, "no message within {secs:.1}s"),
            TransportError::Codec { worker, err } => {
                write!(f, "bad frame from worker {worker}: {err}")
            }
            TransportError::Handshake(what) => write!(f, "handshake failed: {what}"),
            TransportError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for TransportError {}

/// What a reader observed on one connection.
enum EventKind {
    Msg(Msg),
    Codec(CodecError),
    /// The connection closed (clean EOF or reset).
    Gone,
}

/// One event: `(worker id, connection generation, payload)`. The
/// generation lets receivers drop events from a connection that has
/// since been replaced by a rejoin takeover or cut by `sever`.
type Event = (usize, u64, EventKind);

/// Coordinator-side message fabric.
pub trait Transport {
    /// Number of worker endpoints.
    fn workers(&self) -> usize;
    /// Send `msg` to worker `to`.
    fn send(&mut self, to: usize, msg: Msg) -> Result<(), TransportError>;
    /// Block for the next event from any worker (up to `timeout`).
    fn recv(&mut self, timeout: Duration) -> Result<(usize, Msg), TransportError>;
    /// Cut worker `worker`'s connection. Subsequent events from the old
    /// connection are dropped as stale; sends to the slot fail `Closed`
    /// until (on TCP) a rejoin installs a new connection.
    fn sever(&mut self, worker: usize);
}

fn resolve_event(j: usize, kind: EventKind) -> Result<(usize, Msg), TransportError> {
    match kind {
        EventKind::Msg(m) => Ok((j, m)),
        EventKind::Codec(err) => Err(TransportError::Codec { worker: j, err }),
        EventKind::Gone => Err(TransportError::PeerDisconnected { worker: j }),
    }
}

// --------------------------------------------------------- worker side

enum PortTx {
    /// In-process: push straight into the coordinator's event channel.
    Chan { tx: Sender<Event>, id: usize },
    /// TCP: encode onto the socket.
    Tcp(TcpStream),
}

/// A worker's endpoint: receive coordinator commands, send answers.
pub struct WorkerPort {
    id: usize,
    rx: Receiver<Event>,
    tx: PortTx,
    pending: VecDeque<Msg>,
}

/// Map an event on the worker side: the only peer is the coordinator,
/// so a dropped connection is `Disconnected` (the leader is gone).
fn port_event(ev: Event) -> Result<Msg, TransportError> {
    match ev.2 {
        EventKind::Msg(m) => Ok(m),
        EventKind::Codec(err) => Err(TransportError::Codec { worker: ev.0, err }),
        EventKind::Gone => Err(TransportError::Disconnected),
    }
}

impl WorkerPort {
    /// The worker slot this port belongs to.
    pub fn id(&self) -> usize {
        self.id
    }

    /// Re-queue a message so the next `recv` returns it first (used by
    /// the worker's interruptible straggler wait when a non-Terminate
    /// command arrives mid-sleep).
    pub fn push_back(&mut self, msg: Msg) {
        self.pending.push_back(msg);
    }

    /// Blocking receive.
    pub fn recv(&mut self) -> Result<Msg, TransportError> {
        if let Some(m) = self.pending.pop_front() {
            return Ok(m);
        }
        match self.rx.recv() {
            Ok(ev) => port_event(ev),
            Err(_) => Err(TransportError::Disconnected),
        }
    }

    /// Receive with a timeout; `Ok(None)` means the timeout elapsed.
    /// This park (not a poll) is what the worker's straggler sleep and
    /// the old busy-wait loops were replaced with.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<Msg>, TransportError> {
        if let Some(m) = self.pending.pop_front() {
            return Ok(Some(m));
        }
        match self.rx.recv_timeout(timeout) {
            Ok(ev) => port_event(ev).map(Some),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(TransportError::Disconnected),
        }
    }

    /// Send a message to the coordinator.
    pub fn send(&mut self, msg: Msg) -> Result<(), TransportError> {
        let id = self.id;
        match &mut self.tx {
            PortTx::Chan { tx, id: from } => tx
                .send((*from, 0, EventKind::Msg(msg)))
                .map_err(|_| TransportError::Disconnected),
            PortTx::Tcp(stream) => write_frame(stream, &msg).map_err(|e| match e {
                CodecError::Io(io) => TransportError::Io(io),
                other => TransportError::Codec { worker: id, err: other },
            }),
        }
    }
}

impl Drop for WorkerPort {
    fn drop(&mut self) {
        match &self.tx {
            // Tell the coordinator this peer is gone — the channel
            // transport has no socket EOF to observe.
            PortTx::Chan { tx, id } => {
                let _ = tx.send((*id, 0, EventKind::Gone));
            }
            // Shutdown (not just drop) so the reader thread's blocked
            // read — which holds its own clone of the socket — unblocks.
            PortTx::Tcp(stream) => {
                let _ = stream.shutdown(Shutdown::Both);
            }
        }
    }
}

// ------------------------------------------------------ channel fabric

/// The degenerate transport: `mpsc` channels inside one process.
pub struct ChannelTransport {
    txs: Vec<Option<Sender<Event>>>,
    rx: Receiver<Event>,
    /// Per-slot generation; ports always stamp 0, so a sever (bump to
    /// >= 1) makes every later event from that port stale.
    gens: Vec<u64>,
}

impl ChannelTransport {
    /// Build a coordinator handle plus `n` worker ports.
    pub fn pair(n: usize) -> (ChannelTransport, Vec<WorkerPort>) {
        let (evt_tx, evt_rx) = channel::<Event>();
        let mut txs = Vec::with_capacity(n);
        let mut ports = Vec::with_capacity(n);
        for j in 0..n {
            let (tx, rx) = channel::<Event>();
            txs.push(Some(tx));
            ports.push(WorkerPort {
                id: j,
                rx,
                tx: PortTx::Chan { tx: evt_tx.clone(), id: j },
                pending: VecDeque::new(),
            });
        }
        // evt_tx is NOT retained here: once every port is gone the
        // coordinator's recv reports Disconnected instead of hanging.
        (ChannelTransport { txs, rx: evt_rx, gens: vec![0; n] }, ports)
    }
}

impl Transport for ChannelTransport {
    fn workers(&self) -> usize {
        self.txs.len()
    }

    fn send(&mut self, to: usize, msg: Msg) -> Result<(), TransportError> {
        if crate::obs::enabled() {
            if let Some(o) = crate::obs::active() {
                o.registry.counter(&format!("net/peer-{to}/tx_frames")).inc();
            }
        }
        match self.txs.get(to) {
            Some(Some(tx)) => tx
                .send((to, 0, EventKind::Msg(msg)))
                .map_err(|_| TransportError::Closed { worker: to }),
            _ => Err(TransportError::Closed { worker: to }),
        }
    }

    fn recv(&mut self, timeout: Duration) -> Result<(usize, Msg), TransportError> {
        let deadline = Instant::now() + timeout;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            match self.rx.recv_timeout(remaining) {
                Ok((j, gen, kind)) => {
                    if gen < self.gens[j] {
                        continue; // stale: slot was severed
                    }
                    return resolve_event(j, kind);
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(TransportError::Timeout { secs: timeout.as_secs_f64() })
                }
                Err(RecvTimeoutError::Disconnected) => return Err(TransportError::Disconnected),
            }
        }
    }

    fn sever(&mut self, worker: usize) {
        if worker < self.txs.len() {
            self.txs[worker] = None;
            self.gens[worker] += 1;
        }
    }
}

// ---------------------------------------------------------- tcp fabric

/// How long a late (post-start) connection gets to produce its
/// Rejoin/Hello frame before the acceptor drops it.
const REJOIN_HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

/// Acceptor poll interval (nonblocking accept + stop-flag check).
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Decode frames off one peer's socket into the shared event channel.
/// Every exit posts a `Gone` event: EOF, reset, or shutdown from our
/// own side all surface instead of a reader dying silently (stale-
/// generation `Gone`s are dropped by the receiver).
fn reader_loop(id: usize, gen: u64, mut stream: TcpStream, tx: Sender<Event>) {
    // Telemetry names resolved once per connection; instruments are
    // fetched per frame only while an observer is installed.
    let rx_frames = format!("net/peer-{id}/rx_frames");
    let rx_bytes = format!("net/peer-{id}/rx_bytes");
    loop {
        match read_frame_opt_counted(&mut stream) {
            Ok(Some((msg, bytes))) => {
                if crate::obs::enabled() {
                    if let Some(o) = crate::obs::active() {
                        o.registry.counter(&rx_frames).inc();
                        o.registry.counter(&rx_bytes).add(bytes as u64);
                    }
                }
                if tx.send((id, gen, EventKind::Msg(msg))).is_err() {
                    return; // coordinator gone
                }
            }
            Ok(None) => {
                let _ = tx.send((id, gen, EventKind::Gone));
                return;
            }
            // An io-level failure is connection death, not a protocol
            // violation: report the peer gone. Real codec violations
            // (bad magic/checksum/payload) stay typed.
            Err(CodecError::Io(_)) => {
                let _ = tx.send((id, gen, EventKind::Gone));
                return;
            }
            Err(err) => {
                let _ = tx.send((id, gen, EventKind::Codec(err)));
                return;
            }
        }
    }
}

/// Per-slot connection table shared between the transport handle, its
/// reader threads, and the background acceptor.
struct TcpShared {
    streams: Vec<Option<TcpStream>>,
    gens: Vec<u64>,
    readers: Vec<JoinHandle<()>>,
}

impl TcpShared {
    /// Install `stream` as slot `id`'s connection: bump the generation
    /// (staling the old connection's events), shut the old socket down,
    /// and spawn a reader for the new one. Returns the new generation.
    fn install(
        shared: &Arc<Mutex<TcpShared>>,
        tx: &Sender<Event>,
        id: usize,
        stream: TcpStream,
    ) -> std::io::Result<u64> {
        let clone = stream.try_clone()?;
        let mut sh = shared.lock().unwrap();
        sh.gens[id] += 1;
        let gen = sh.gens[id];
        if let Some(old) = sh.streams[id].take() {
            let _ = old.shutdown(Shutdown::Both);
        }
        sh.streams[id] = Some(stream);
        let tx = tx.clone();
        let handle = std::thread::Builder::new()
            .name(format!("dybw-net-{id}-g{gen}"))
            .spawn(move || reader_loop(id, gen, clone, tx))?;
        sh.readers.push(handle);
        // gen 1 is the slot's first connection; anything later is a
        // replacement — the reconnect counter the obs report surfaces.
        if gen > 1 && crate::obs::enabled() {
            if let Some(o) = crate::obs::active() {
                o.registry.counter("net/reconnects").inc();
                o.registry.counter(&format!("net/peer-{id}/reconnects")).inc();
            }
        }
        Ok(gen)
    }
}

/// Real-socket transport: one persistent connection per worker slot,
/// with a background acceptor that lets dead workers rejoin.
pub struct TcpTransport {
    n: usize,
    shared: Arc<Mutex<TcpShared>>,
    rx: Receiver<Event>,
    acceptor: Option<JoinHandle<()>>,
    stop: Arc<AtomicBool>,
}

/// One late connection: either a live worker re-claiming its slot after
/// a connection loss (`Rejoin`) or a restarted process running the full
/// handshake again (`Hello` with a specific slot id — it gets the setup
/// JSON back via `Init`). Both forward a `Rejoin` event so the driver
/// can answer with `StateSync`. Anything else is dropped.
fn handle_late_connection(
    mut stream: TcpStream,
    n: usize,
    setup: &str,
    shared: &Arc<Mutex<TcpShared>>,
    tx: &Sender<Event>,
) {
    if stream.set_nodelay(true).is_err()
        || stream.set_read_timeout(Some(REJOIN_HANDSHAKE_TIMEOUT)).is_err()
    {
        return;
    }
    let (id, draws, needs_init) = match read_frame(&mut stream) {
        Ok(Msg::Rejoin { worker, draws }) if (worker as usize) < n => {
            (worker as usize, draws, false)
        }
        Ok(Msg::Hello { worker }) if worker != ANY_WORKER && (worker as usize) < n => {
            (worker as usize, 0, true)
        }
        // out-of-range claim, ANY_WORKER after start, wrong message, or
        // garbage: drop the connection (the peer sees EOF, a typed
        // handshake error on its side — never a hang)
        _ => {
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
    };
    if needs_init
        && write_frame(&mut stream, &Msg::Init { worker: id as u32, setup: setup.to_string() })
            .is_err()
    {
        return;
    }
    if stream.set_read_timeout(None).is_err() {
        return;
    }
    let Ok(gen) = TcpShared::install(shared, tx, id, stream) else {
        return;
    };
    // the driver answers this with StateSync before sending anything
    // else to the slot
    let _ = tx.send((id, gen, EventKind::Msg(Msg::Rejoin { worker: id as u32, draws })));
}

fn acceptor_loop(
    listener: TcpListener,
    n: usize,
    setup: String,
    shared: Arc<Mutex<TcpShared>>,
    tx: Sender<Event>,
    stop: Arc<AtomicBool>,
) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => handle_late_connection(stream, n, &setup, &shared, &tx),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => std::thread::sleep(ACCEPT_POLL),
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

impl TcpTransport {
    /// Accept exactly `n` workers on `listener`, performing the
    /// Hello/Init handshake with each (`setup` is the experiment JSON
    /// handed to every worker). Slot ids: a worker may claim a specific
    /// id in its Hello (out-of-range ids are handshake errors; a repeat
    /// claim for a held slot is a takeover — the newer connection wins),
    /// or send [`ANY_WORKER`] to get the lowest free slot. Once all `n`
    /// slots are filled a background acceptor keeps the listener open so
    /// workers can rejoin mid-run.
    pub fn accept(
        listener: &TcpListener,
        n: usize,
        setup: &str,
        handshake_timeout: Duration,
    ) -> Result<TcpTransport, TransportError> {
        let (tx, rx) = channel::<Event>();
        let mut slots: Vec<Option<TcpStream>> = (0..n).map(|_| None).collect();
        while slots.iter().any(|s| s.is_none()) {
            let (mut stream, _peer) = listener.accept().map_err(TransportError::Io)?;
            stream.set_nodelay(true).map_err(TransportError::Io)?;
            stream
                .set_read_timeout(Some(handshake_timeout))
                .map_err(TransportError::Io)?;
            let hello = read_frame(&mut stream)
                .map_err(|err| TransportError::Codec { worker: 0, err })?;
            let Msg::Hello { worker } = hello else {
                return Err(TransportError::Handshake(format!(
                    "expected Hello, got {}",
                    hello.name()
                )));
            };
            let id = if worker == ANY_WORKER {
                slots
                    .iter()
                    .position(|s| s.is_none())
                    .ok_or_else(|| TransportError::Handshake("no free worker slot".into()))?
            } else {
                let id = worker as usize;
                if id >= n {
                    return Err(TransportError::Handshake(format!(
                        "worker id {id} out of range (n = {n})"
                    )));
                }
                id
            };
            write_frame(
                &mut stream,
                &Msg::Init { worker: id as u32, setup: setup.to_string() },
            )
            .map_err(|err| match err {
                CodecError::Io(io) => TransportError::Io(io),
                other => TransportError::Codec { worker: id, err: other },
            })?;
            stream.set_read_timeout(None).map_err(TransportError::Io)?;
            // duplicate claim during startup: clean takeover, the old
            // connection is cut and its (future) events are stale
            if let Some(old) = slots[id].replace(stream) {
                let _ = old.shutdown(Shutdown::Both);
            }
        }
        let shared = Arc::new(Mutex::new(TcpShared {
            streams: (0..n).map(|_| None).collect(),
            gens: vec![0; n],
            readers: Vec::with_capacity(n),
        }));
        for (id, s) in slots.into_iter().enumerate() {
            let s = s.expect("all slots filled");
            TcpShared::install(&shared, &tx, id, s).map_err(TransportError::Io)?;
        }
        let stop = Arc::new(AtomicBool::new(false));
        let accept_clone = listener.try_clone().map_err(TransportError::Io)?;
        accept_clone.set_nonblocking(true).map_err(TransportError::Io)?;
        let acceptor = std::thread::Builder::new()
            .name("dybw-accept".into())
            .spawn({
                let shared = Arc::clone(&shared);
                let tx = tx.clone();
                let stop = Arc::clone(&stop);
                let setup = setup.to_string();
                move || acceptor_loop(accept_clone, n, setup, shared, tx, stop)
            })
            .map_err(TransportError::Io)?;
        Ok(TcpTransport { n, shared, rx, acceptor: Some(acceptor), stop })
    }
}

impl Transport for TcpTransport {
    fn workers(&self) -> usize {
        self.n
    }

    fn send(&mut self, to: usize, msg: Msg) -> Result<(), TransportError> {
        let obs = if crate::obs::enabled() { crate::obs::active() } else { None };
        let t0 = obs.as_ref().map(|_| Instant::now());
        let mut sh = self.shared.lock().unwrap();
        let sent = match sh.streams.get_mut(to) {
            Some(Some(stream)) => write_frame_counted(stream, &msg).map_err(|e| match e {
                CodecError::Io(_) => TransportError::Closed { worker: to },
                other => TransportError::Codec { worker: to, err: other },
            }),
            _ => Err(TransportError::Closed { worker: to }),
        };
        drop(sh);
        let bytes = sent?;
        if let (Some(o), Some(t0)) = (&obs, t0) {
            o.registry.counter(&format!("net/peer-{to}/tx_frames")).inc();
            o.registry.counter(&format!("net/peer-{to}/tx_bytes")).add(bytes as u64);
            o.registry
                .histogram("net/send_secs")
                .record_secs(t0.elapsed().as_secs_f64());
        }
        Ok(())
    }

    fn recv(&mut self, timeout: Duration) -> Result<(usize, Msg), TransportError> {
        let deadline = Instant::now() + timeout;
        loop {
            let remaining = deadline.saturating_duration_since(Instant::now());
            match self.rx.recv_timeout(remaining) {
                Ok((j, gen, kind)) => {
                    let current = self.shared.lock().unwrap().gens[j];
                    if gen < current {
                        continue; // stale: connection was replaced or severed
                    }
                    return resolve_event(j, kind);
                }
                Err(RecvTimeoutError::Timeout) => {
                    return Err(TransportError::Timeout { secs: timeout.as_secs_f64() })
                }
                Err(RecvTimeoutError::Disconnected) => return Err(TransportError::Disconnected),
            }
        }
    }

    fn sever(&mut self, worker: usize) {
        let mut sh = self.shared.lock().unwrap();
        if worker < self.n {
            sh.gens[worker] += 1;
            if let Some(s) = sh.streams[worker].take() {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }
}

impl Drop for TcpTransport {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        // Shutdown unblocks each reader thread's in-flight read (the
        // readers own clones of these sockets), then join them so no
        // thread outlives the transport.
        let handles: Vec<JoinHandle<()>> = {
            let mut sh = self.shared.lock().unwrap();
            for s in sh.streams.iter().flatten() {
                let _ = s.shutdown(Shutdown::Both);
            }
            sh.readers.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }
}

// ------------------------------------------------------------- backoff

/// Decorrelated-jitter backoff: each delay is drawn uniformly from
/// `[base, 3 * previous]` and clamped to `cap`. A rack of workers that
/// all lost the same leader therefore spreads its reconnect attempts
/// out instead of thundering in lockstep (plain doubling keeps every
/// client on the same schedule; jittering around it breaks the herd).
pub struct Backoff {
    base: Duration,
    cap: Duration,
    prev: Duration,
    rng: Rng,
}

impl Backoff {
    pub fn new(base: Duration, cap: Duration, seed: u64) -> Backoff {
        Backoff { base, cap, prev: base, rng: Rng::new(seed) }
    }

    /// Next sleep. Always within `[base, cap]` and at most
    /// `3 * previous delay`.
    pub fn next_delay(&mut self) -> Duration {
        let lo = self.base.as_secs_f64();
        let hi = (self.prev.as_secs_f64() * 3.0).max(lo);
        let secs = self.rng.uniform_in(lo, hi);
        let d = Duration::from_secs_f64(secs).min(self.cap).max(self.base);
        self.prev = d;
        d
    }
}

/// Per-process backoff seed: wall-clock nanos XOR pid, so concurrently
/// launched workers start from different points of the jitter stream.
fn jitter_seed() -> u64 {
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0x9E37_79B9);
    t ^ ((std::process::id() as u64) << 32)
}

/// Connect with retry/backoff until `timeout` elapses (the coordinator
/// may come up after its workers in a launch script, or be restarting).
pub fn connect_retry(addr: &str, timeout: Duration) -> Result<TcpStream, TransportError> {
    let deadline = Instant::now() + timeout;
    let mut backoff =
        Backoff::new(Duration::from_millis(50), Duration::from_millis(500), jitter_seed());
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                let now = Instant::now();
                if now >= deadline {
                    return Err(TransportError::Handshake(format!(
                        "cannot connect to {addr} within {:.1}s: {e}",
                        timeout.as_secs_f64()
                    )));
                }
                std::thread::sleep(backoff.next_delay().min(deadline - now));
            }
        }
    }
}

// ----------------------------------------------------- worker connects

fn spawn_port_reader(
    id: usize,
    stream: &TcpStream,
) -> Result<Receiver<Event>, TransportError> {
    let (evt_tx, rx) = channel::<Event>();
    let clone = stream.try_clone().map_err(TransportError::Io)?;
    std::thread::Builder::new()
        .name(format!("dybw-net-{id}"))
        .spawn(move || reader_loop(id, 0, clone, evt_tx))
        .map_err(TransportError::Io)?;
    Ok(rx)
}

/// Worker-process entry: connect to the coordinator, run the Hello/Init
/// handshake (claiming slot `requested` if given), and return
/// `(assigned id, setup JSON, port)` with the reader thread running.
pub fn connect_worker(
    addr: &str,
    requested: Option<u32>,
    timeout: Duration,
) -> Result<(u32, String, WorkerPort), TransportError> {
    let mut stream = connect_retry(addr, timeout)?;
    stream.set_nodelay(true).map_err(TransportError::Io)?;
    write_frame(&mut stream, &Msg::Hello { worker: requested.unwrap_or(ANY_WORKER) }).map_err(
        |e| match e {
            CodecError::Io(io) => TransportError::Io(io),
            other => TransportError::Codec { worker: 0, err: other },
        },
    )?;
    stream
        .set_read_timeout(Some(timeout))
        .map_err(TransportError::Io)?;
    let init = read_frame(&mut stream).map_err(|err| {
        TransportError::Handshake(format!("no Init from coordinator at {addr}: {err}"))
    })?;
    let Msg::Init { worker, setup } = init else {
        return Err(TransportError::Handshake(format!(
            "expected Init, got {}",
            init.name()
        )));
    };
    stream.set_read_timeout(None).map_err(TransportError::Io)?;
    let id = worker as usize;
    let rx = spawn_port_reader(id, &stream)?;
    let port = WorkerPort {
        id,
        rx,
        tx: PortTx::Tcp(stream),
        pending: VecDeque::new(),
    };
    Ok((worker, setup, port))
}

/// Worker-process re-entry after a lost leader connection: reconnect,
/// re-claim `slot` with [`Msg::Rejoin`] (`draws` = training batches
/// already drawn), and block for the leader's [`Msg::StateSync`]
/// answer. Returns the sync message and a fresh port. Every failure —
/// including the leader rejecting the claim by dropping the connection
/// — is a typed error, never a hang (`timeout` bounds both the connect
/// retries and the StateSync wait).
pub fn rejoin_worker(
    addr: &str,
    slot: u32,
    draws: u64,
    timeout: Duration,
) -> Result<(Msg, WorkerPort), TransportError> {
    let mut stream = connect_retry(addr, timeout)?;
    stream.set_nodelay(true).map_err(TransportError::Io)?;
    write_frame(&mut stream, &Msg::Rejoin { worker: slot, draws }).map_err(|e| match e {
        CodecError::Io(io) => TransportError::Io(io),
        other => TransportError::Codec { worker: slot as usize, err: other },
    })?;
    stream
        .set_read_timeout(Some(timeout))
        .map_err(TransportError::Io)?;
    let sync = read_frame(&mut stream).map_err(|err| {
        TransportError::Handshake(format!("no StateSync from coordinator at {addr}: {err}"))
    })?;
    if !matches!(sync, Msg::StateSync { .. }) {
        return Err(TransportError::Handshake(format!(
            "expected StateSync, got {}",
            sync.name()
        )));
    }
    stream.set_read_timeout(None).map_err(TransportError::Io)?;
    let id = slot as usize;
    let rx = spawn_port_reader(id, &stream)?;
    let port = WorkerPort {
        id,
        rx,
        tx: PortTx::Tcp(stream),
        pending: VecDeque::new(),
    };
    Ok((sync, port))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_pair_round_trips_both_directions() {
        let (mut t, mut ports) = ChannelTransport::pair(2);
        assert_eq!(t.workers(), 2);
        t.send(0, Msg::Ping { nonce: 10 }).unwrap();
        t.send(1, Msg::Ping { nonce: 11 }).unwrap();
        for port in ports.iter_mut() {
            let Msg::Ping { nonce } = port.recv().unwrap() else {
                panic!("expected Ping");
            };
            assert_eq!(nonce, 10 + port.id() as u64);
            port.send(Msg::Pong { nonce }).unwrap();
        }
        let mut seen = [false; 2];
        for _ in 0..2 {
            let (j, msg) = t.recv(Duration::from_secs(5)).unwrap();
            assert_eq!(msg, Msg::Pong { nonce: 10 + j as u64 });
            seen[j] = true;
        }
        assert!(seen[0] && seen[1]);
    }

    #[test]
    fn channel_recv_times_out_as_typed_error() {
        let (mut t, _ports) = ChannelTransport::pair(1);
        match t.recv(Duration::from_millis(30)) {
            Err(TransportError::Timeout { secs }) => assert!(secs > 0.0),
            other => panic!("expected Timeout, got {other:?}"),
        }
    }

    #[test]
    fn channel_send_to_dropped_port_is_closed() {
        let (mut t, mut ports) = ChannelTransport::pair(2);
        ports.remove(0); // worker 0 dies
        assert!(matches!(
            t.send(0, Msg::Stop),
            Err(TransportError::Closed { worker: 0 })
        ));
        // worker 1 still reachable
        t.send(1, Msg::Stop).unwrap();
        assert_eq!(ports[0].recv().unwrap(), Msg::Stop);
    }

    #[test]
    fn channel_port_drop_surfaces_as_peer_disconnected() {
        let (mut t, mut ports) = ChannelTransport::pair(2);
        ports.remove(0); // worker 0 dies
        match t.recv(Duration::from_secs(1)) {
            Err(TransportError::PeerDisconnected { worker: 0 }) => {}
            other => panic!("expected PeerDisconnected, got {other:?}"),
        }
        // worker 1 unaffected
        ports[0].send(Msg::Pong { nonce: 1 }).unwrap();
        assert_eq!(t.recv(Duration::from_secs(1)).unwrap(), (1, Msg::Pong { nonce: 1 }));
    }

    #[test]
    fn channel_recv_disconnects_when_all_ports_dropped() {
        let (mut t, ports) = ChannelTransport::pair(2);
        drop(ports);
        // each port's death is reported first, in drop order ...
        for expect in 0..2usize {
            match t.recv(Duration::from_secs(1)) {
                Err(TransportError::PeerDisconnected { worker }) => assert_eq!(worker, expect),
                other => panic!("expected PeerDisconnected, got {other:?}"),
            }
        }
        // ... and only then is the stream itself gone
        assert!(matches!(
            t.recv(Duration::from_secs(1)),
            Err(TransportError::Disconnected)
        ));
    }

    #[test]
    fn channel_sever_drops_stale_events() {
        let (mut t, mut ports) = ChannelTransport::pair(2);
        ports[0].send(Msg::Pong { nonce: 7 }).unwrap();
        t.sever(0);
        // the pre-sever Pong and worker 0's eventual Gone are both stale
        ports[1].send(Msg::Pong { nonce: 8 }).unwrap();
        assert_eq!(t.recv(Duration::from_secs(1)).unwrap(), (1, Msg::Pong { nonce: 8 }));
        assert!(matches!(
            t.send(0, Msg::Stop),
            Err(TransportError::Closed { worker: 0 })
        ));
        drop(ports);
        // worker 1's Gone is live; worker 0's is filtered
        assert!(matches!(
            t.recv(Duration::from_secs(1)),
            Err(TransportError::PeerDisconnected { worker: 1 })
        ));
        assert!(matches!(
            t.recv(Duration::from_secs(1)),
            Err(TransportError::Disconnected)
        ));
    }

    #[test]
    fn push_back_is_returned_first() {
        let (mut t, mut ports) = ChannelTransport::pair(1);
        t.send(0, Msg::Stop).unwrap();
        ports[0].push_back(Msg::Ping { nonce: 1 });
        assert_eq!(ports[0].recv().unwrap(), Msg::Ping { nonce: 1 });
        assert_eq!(ports[0].recv().unwrap(), Msg::Stop);
    }

    #[test]
    fn backoff_delays_stay_within_bounds() {
        let base = Duration::from_millis(50);
        let cap = Duration::from_millis(500);
        for seed in [1u64, 7, 0xDEAD_BEEF] {
            let mut b = Backoff::new(base, cap, seed);
            let mut prev = base;
            for step in 0..64 {
                let d = b.next_delay();
                assert!(d >= base, "seed {seed} step {step}: {d:?} below base");
                assert!(d <= cap, "seed {seed} step {step}: {d:?} above cap");
                // decorrelated-jitter bound: at most 3x the previous sleep
                let limit = prev.mul_f64(3.0).max(base).min(cap);
                assert!(
                    d <= limit + Duration::from_micros(1),
                    "seed {seed} step {step}: {d:?} exceeds 3x prev {prev:?}"
                );
                prev = d;
            }
        }
    }

    #[test]
    fn backoff_jitters_rather_than_doubling_in_lockstep() {
        let base = Duration::from_millis(50);
        let cap = Duration::from_secs(60); // high cap: watch the spread
        let a: Vec<Duration> =
            (0..16).scan(Backoff::new(base, cap, 11), |b, _| Some(b.next_delay())).collect();
        let b: Vec<Duration> =
            (0..16).scan(Backoff::new(base, cap, 22), |b, _| Some(b.next_delay())).collect();
        assert_ne!(a, b, "two seeds produced identical sleep schedules");
    }

    #[test]
    fn tcp_loopback_handshake_and_round_trip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let timeout = Duration::from_secs(10);
        let mut joins = Vec::new();
        for j in [1u32, 0u32] {
            let addr = addr.clone();
            joins.push(std::thread::spawn(move || {
                let (id, setup, mut port) = connect_worker(&addr, Some(j), timeout).unwrap();
                assert_eq!(id, j);
                assert_eq!(setup, "SETUP");
                let Msg::Ping { nonce } = port.recv().unwrap() else {
                    panic!("expected Ping");
                };
                port.send(Msg::Pong { nonce: nonce + 1 }).unwrap();
                // coordinator closes; clean shutdown
                assert!(matches!(port.recv(), Err(TransportError::Disconnected)));
            }));
        }
        let mut t = TcpTransport::accept(&listener, 2, "SETUP", timeout).unwrap();
        t.send(0, Msg::Ping { nonce: 100 }).unwrap();
        t.send(1, Msg::Ping { nonce: 200 }).unwrap();
        for _ in 0..2 {
            let (j, msg) = t.recv(timeout).unwrap();
            assert_eq!(msg, Msg::Pong { nonce: 101 + 100 * j as u64 });
        }
        drop(t);
        for h in joins {
            h.join().unwrap();
        }
    }

    #[test]
    fn tcp_any_worker_gets_distinct_slots() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let timeout = Duration::from_secs(10);
        let joins: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let (id, _setup, port) = connect_worker(&addr, None, timeout).unwrap();
                    drop(port);
                    id
                })
            })
            .collect();
        let t = TcpTransport::accept(&listener, 2, "", timeout).unwrap();
        let mut ids: Vec<u32> = joins.into_iter().map(|h| h.join().unwrap()).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1]);
        drop(t);
    }

    #[test]
    fn tcp_handshake_rejects_out_of_range_id() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let timeout = Duration::from_secs(10);
        let h = std::thread::spawn(move || {
            // the coordinator drops the socket on rejection; either a
            // handshake error or an io error is acceptable here
            let _ = connect_worker(&addr, Some(7), timeout);
        });
        match TcpTransport::accept(&listener, 2, "", timeout) {
            Err(TransportError::Handshake(msg)) => assert!(msg.contains("out of range")),
            other => panic!("expected Handshake error, got {:?}", other.err()),
        }
        h.join().unwrap();
    }

    #[test]
    fn tcp_worker_death_mid_recv_is_peer_disconnected() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let timeout = Duration::from_secs(10);
        let h = std::thread::spawn(move || {
            let (_, _, mut port) = connect_worker(&addr, Some(0), timeout).unwrap();
            // wait for the go signal, then die with the leader mid-recv
            assert_eq!(port.recv().unwrap(), Msg::Ping { nonce: 1 });
            drop(port);
        });
        let mut t = TcpTransport::accept(&listener, 1, "", timeout).unwrap();
        t.send(0, Msg::Ping { nonce: 1 }).unwrap();
        // leader is parked in recv when the peer's socket dies
        match t.recv(timeout) {
            Err(TransportError::PeerDisconnected { worker: 0 }) => {}
            other => panic!("expected PeerDisconnected, got {other:?}"),
        }
        h.join().unwrap();
        drop(t);
    }

    /// Satellite: duplicate claims at startup are a clean takeover.
    /// Worker A claims slot 0 and completes its handshake; worker B then
    /// claims slot 0 too. B wins, A's connection is cut (it observes
    /// Disconnected, i.e. "go rejoin"), and nobody hangs.
    #[test]
    fn tcp_duplicate_startup_claim_is_clean_takeover() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let timeout = Duration::from_secs(10);
        let (a_done_tx, a_done_rx) = channel::<()>();
        let a = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let (_, _, mut port) = connect_worker(&addr, Some(0), timeout).unwrap();
                a_done_tx.send(()).unwrap();
                // the takeover cuts this connection
                assert!(matches!(port.recv(), Err(TransportError::Disconnected)));
            })
        };
        let b = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                // strictly after A finished its handshake
                a_done_rx.recv().unwrap();
                std::thread::sleep(Duration::from_millis(20));
                let (_, _, mut port) = connect_worker(&addr, Some(0), timeout).unwrap();
                let (_, _, mut p1) = connect_worker(&addr, Some(1), timeout).unwrap();
                assert_eq!(port.recv().unwrap(), Msg::Ping { nonce: 5 });
                port.send(Msg::Pong { nonce: 5 }).unwrap();
                drop(p1.recv()); // leader teardown
            })
        };
        let mut t = TcpTransport::accept(&listener, 2, "", timeout).unwrap();
        // slot 0 now belongs to B
        t.send(0, Msg::Ping { nonce: 5 }).unwrap();
        assert_eq!(t.recv(timeout).unwrap(), (0, Msg::Pong { nonce: 5 }));
        drop(t);
        a.join().unwrap();
        b.join().unwrap();
    }

    /// Satellite: a worker rejoining while the leader still holds the
    /// old (half-open) connection takes the slot over; the old
    /// connection's events are stale and the new one round-trips.
    #[test]
    fn tcp_rejoin_half_open_takeover() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let timeout = Duration::from_secs(10);
        let old = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let (_, _, mut port) = connect_worker(&addr, Some(0), timeout).unwrap();
                // keep the old connection half-open until it is cut
                assert!(matches!(port.recv(), Err(TransportError::Disconnected)));
            })
        };
        let mut t = TcpTransport::accept(&listener, 1, "", timeout).unwrap();
        let rejoiner = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let (sync, mut port) = rejoin_worker(&addr, 0, 3, timeout).unwrap();
                assert_eq!(sync, Msg::StateSync { draws: 9, w: vec![1.0], wtilde: vec![2.0] });
                assert_eq!(port.recv().unwrap(), Msg::Stop);
            })
        };
        // leader: the rejoin surfaces as an event; answer with StateSync
        let (j, msg) = t.recv(timeout).unwrap();
        assert_eq!((j, &msg), (0, &Msg::Rejoin { worker: 0, draws: 3 }));
        t.send(0, Msg::StateSync { draws: 9, w: vec![1.0], wtilde: vec![2.0] }).unwrap();
        t.send(0, Msg::Stop).unwrap();
        rejoiner.join().unwrap();
        old.join().unwrap();
        drop(t);
    }

    /// Satellite: a stale/out-of-range slot claim on rejoin is a typed
    /// handshake error on the worker side, never a hang.
    #[test]
    fn tcp_rejoin_out_of_range_claim_is_typed_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let timeout = Duration::from_secs(10);
        let w = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let (_, _, port) = connect_worker(&addr, Some(0), timeout).unwrap();
                port
            })
        };
        let t = TcpTransport::accept(&listener, 1, "", timeout).unwrap();
        let _port = w.join().unwrap();
        let err = rejoin_worker(&addr, 9, 0, Duration::from_secs(3)).unwrap_err();
        assert!(
            matches!(err, TransportError::Handshake(_)),
            "expected Handshake error, got {err:?}"
        );
        drop(t);
    }

    /// Satellite: duplicate simultaneous rejoin claims for one slot —
    /// the last installed connection wins, the loser sees a typed
    /// error/EOF, and neither side hangs.
    #[test]
    fn tcp_duplicate_rejoin_claims_never_hang() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let timeout = Duration::from_secs(10);
        let w = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let (_, _, port) = connect_worker(&addr, Some(0), timeout).unwrap();
                drop(port); // dies immediately: slot 0 is now claimable
            })
        };
        let mut t = TcpTransport::accept(&listener, 1, "", timeout).unwrap();
        w.join().unwrap();
        let claims: Vec<_> = (0..2)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || rejoin_worker(&addr, 0, 0, Duration::from_secs(5)))
            })
            .collect();
        // answer every surviving Rejoin event until both claimants
        // resolved; ignore the dead worker's PeerDisconnected
        let deadline = Instant::now() + timeout;
        loop {
            let finished = claims.iter().filter(|h| h.is_finished()).count();
            if finished == 2 || Instant::now() >= deadline {
                break;
            }
            match t.recv(Duration::from_millis(200)) {
                Ok((0, Msg::Rejoin { .. })) => {
                    let _ = t.send(
                        0,
                        Msg::StateSync { draws: 0, w: vec![0.0], wtilde: vec![0.0] },
                    );
                }
                Ok(other) => panic!("unexpected event {other:?}"),
                Err(TransportError::PeerDisconnected { .. })
                | Err(TransportError::Timeout { .. }) => {}
                Err(other) => panic!("unexpected error {other:?}"),
            }
        }
        let results: Vec<_> = claims.into_iter().map(|h| h.join().unwrap()).collect();
        let won = results.iter().filter(|r| r.is_ok()).count();
        assert!(won >= 1, "no rejoin claim succeeded: {results:?}");
        for r in results {
            if let Err(e) = r {
                assert!(
                    matches!(
                        e,
                        TransportError::Handshake(_)
                            | TransportError::Io(_)
                            | TransportError::Disconnected
                    ),
                    "loser got untyped failure: {e:?}"
                );
            }
        }
        drop(t);
    }

    /// Leader-initiated sever cuts the connection (the worker observes
    /// Disconnected) and stales any in-flight events from it.
    #[test]
    fn tcp_sever_cuts_worker_and_stales_events() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let timeout = Duration::from_secs(10);
        let w = {
            let addr = addr.clone();
            std::thread::spawn(move || {
                let (_, _, mut port) = connect_worker(&addr, Some(0), timeout).unwrap();
                port.send(Msg::Pong { nonce: 1 }).unwrap();
                assert!(matches!(port.recv(), Err(TransportError::Disconnected)));
            })
        };
        let mut t = TcpTransport::accept(&listener, 1, "", timeout).unwrap();
        // let the worker's Pong land in the event channel first
        std::thread::sleep(Duration::from_millis(100));
        t.sever(0);
        w.join().unwrap();
        // pre-sever Pong and the reader's Gone are both stale now
        assert!(matches!(
            t.recv(Duration::from_millis(300)),
            Err(TransportError::Timeout { .. })
        ));
        assert!(matches!(
            t.send(0, Msg::Stop),
            Err(TransportError::Closed { worker: 0 })
        ));
        drop(t);
    }

    #[test]
    fn connect_retry_gives_up_with_typed_error() {
        // grab a port, then free it so nothing listens there
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let t0 = Instant::now();
        let err = connect_retry(&addr, Duration::from_millis(250)).unwrap_err();
        assert!(matches!(err, TransportError::Handshake(_)), "{err}");
        assert!(t0.elapsed() >= Duration::from_millis(200));
    }
}
