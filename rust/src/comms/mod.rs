//! Wire layer for real multi-process deployment.
//!
//! The live driver historically ran every worker as a thread in one
//! process over `mpsc` channels. This module is what lets those workers
//! become real OS processes talking over TCP without changing a single
//! training semantic:
//!
//! - [`codec`] — a length-prefixed, checksummed binary framing for the
//!   protocol messages (parameter and gradient vectors included). Every
//!   malformed input is a typed [`CodecError`]; no decode path panics.
//! - [`transport`] — the [`transport::Transport`] trait the coordinator
//!   drives, with two implementations: the in-process
//!   [`transport::ChannelTransport`] (the degenerate transport — plain
//!   channels, zero serialisation) and the [`transport::TcpTransport`]
//!   (persistent per-worker connections, one reader thread per peer).
//! - [`heartbeat`] — the failure-detection layer: per-peer probe and
//!   expiry deadlines ([`heartbeat::Liveness`]) the live driver uses to
//!   declare a silent peer dead. Peer death surfaces as a typed
//!   [`TransportError::PeerDisconnected`] event, a dead worker rejoins
//!   through the transport's background acceptor
//!   ([`transport::rejoin_worker`]), and connection generations make
//!   takeovers race-free.
//!
//! The equivalence guarantee: recorded training history is computed from
//! virtual times on the coordinator (see `coordinator::live`), so a
//! seeded run produces **bit-identical** history over either transport —
//! asserted by `live_tcp_bit_identical_to_in_process` and the
//! `socket-smoke` CI job. Fault tolerance preserves it: while a worker
//! is down the coordinator computes that slot's contribution itself
//! (same seeded batches, same f32 arithmetic), so a run that loses and
//! regains a worker still exports the same bytes — asserted by the
//! `reconnect-smoke` CI job.

pub mod codec;
pub mod heartbeat;
pub mod transport;

pub use codec::{CodecError, Msg};
pub use heartbeat::Liveness;
pub use transport::{Transport, TransportError};
