//! Wire layer for real multi-process deployment.
//!
//! The live driver historically ran every worker as a thread in one
//! process over `mpsc` channels. This module is what lets those workers
//! become real OS processes talking over TCP without changing a single
//! training semantic:
//!
//! - [`codec`] — a length-prefixed, checksummed binary framing for the
//!   protocol messages (parameter and gradient vectors included). Every
//!   malformed input is a typed [`CodecError`]; no decode path panics.
//! - [`transport`] — the [`transport::Transport`] trait the coordinator
//!   drives, with two implementations: the in-process
//!   [`transport::ChannelTransport`] (the degenerate transport — plain
//!   channels, zero serialisation) and the [`transport::TcpTransport`]
//!   (persistent per-worker connections, one reader thread per peer).
//!
//! The equivalence guarantee: recorded training history is computed from
//! virtual times on the coordinator (see `coordinator::live`), so a
//! seeded run produces **bit-identical** history over either transport —
//! asserted by `live_tcp_bit_identical_to_in_process` and the
//! `socket-smoke` CI job.

pub mod codec;
pub mod transport;

pub use codec::{CodecError, Msg};
pub use transport::{Transport, TransportError};
