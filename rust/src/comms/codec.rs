//! Framed binary wire codec for the live-driver protocol.
//!
//! Frame layout (all integers little-endian):
//!
//! | offset | size | field                         |
//! |--------|------|-------------------------------|
//! | 0      | 4    | magic `"DYBW"`                |
//! | 4      | 1    | version (currently 1)         |
//! | 5      | 1    | message type                  |
//! | 6      | 4    | payload length `L` (u32)      |
//! | 10     | L    | payload                       |
//! | 10+L   | 4    | FNV-1a-32 checksum of payload |
//!
//! Decoding is hardened: every failure mode — short buffer, bad magic or
//! version, oversized length prefix, corrupted checksum, malformed or
//! trailing payload bytes — is a typed [`CodecError`]. No decode path
//! indexes unchecked or panics; the adversarial tests flip every byte of
//! valid frames and truncate at every prefix to hold that line.

use std::fmt;
use std::io::{Read, Write};

/// Frame magic: first bytes of every message on the wire.
pub const MAGIC: [u8; 4] = *b"DYBW";

/// Wire-format version byte.
pub const VERSION: u8 = 1;

/// Hard cap on a frame's payload (256 MiB) — rejects absurd length
/// prefixes before any allocation happens.
pub const MAX_PAYLOAD: u32 = 1 << 28;

/// Frame overhead: header (magic + version + type + length) + checksum.
pub const HEADER_LEN: usize = 10;
const TRAILER_LEN: usize = 4;

/// `Hello.worker` value meaning "assign me any free slot".
pub const ANY_WORKER: u32 = u32::MAX;

/// Every message the coordinator and workers exchange.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Worker -> coordinator: first message on a fresh connection.
    /// `worker` is a requested slot, or [`ANY_WORKER`].
    Hello { worker: u32 },
    /// Coordinator -> worker handshake answer: the assigned slot and the
    /// experiment setup JSON the worker rebuilds its shard from.
    Init { worker: u32, setup: String },
    /// Start iteration `k`; sleep the straggler delay out (real seconds).
    Start { k: u64, delay_s: f64 },
    /// Abort iteration `k`'s wait (the paper's termination command).
    Terminate { k: u64 },
    /// Mix phase: this worker's Metropolis row and, in row order, the
    /// peers' post-update parameter vectors.
    Mix {
        k: u64,
        active: bool,
        row: Vec<(u32, f64)>,
        peers: Vec<Vec<f32>>,
    },
    /// Worker -> coordinator: local update done (w̃_j(k) attached).
    Done {
        k: u64,
        loss: f32,
        terminated: bool,
        failed: bool,
        wtilde: Vec<f32>,
    },
    /// Worker -> coordinator: mix applied; post-mix w_j(k) attached.
    MixAck { k: u64, w: Vec<f32> },
    /// Latency probe (link measurement).
    Ping { nonce: u64 },
    /// Probe answer.
    Pong { nonce: u64 },
    /// Shut the worker down cleanly.
    Stop,
    /// Coordinator -> worker liveness probe; the worker echoes it back
    /// immediately (even while sleeping out a straggler delay).
    Heartbeat { seq: u64 },
    /// Worker -> coordinator on a *re*connection: re-claim slot `worker`.
    /// `draws` is how many training batches the worker has already drawn
    /// from its shard, so the leader can tell how far behind it is.
    Rejoin { worker: u32, draws: u64 },
    /// Coordinator -> worker rejoin answer: fast-forward your batch
    /// source to `draws` total draws and overwrite local state with the
    /// authoritative `w` / `wtilde` snapshots.
    StateSync { draws: u64, w: Vec<f32>, wtilde: Vec<f32> },
}

impl Msg {
    /// Wire type byte.
    fn type_byte(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::Init { .. } => 2,
            Msg::Start { .. } => 3,
            Msg::Terminate { .. } => 4,
            Msg::Mix { .. } => 5,
            Msg::Done { .. } => 6,
            Msg::MixAck { .. } => 7,
            Msg::Ping { .. } => 8,
            Msg::Pong { .. } => 9,
            Msg::Stop => 10,
            Msg::Heartbeat { .. } => 11,
            Msg::Rejoin { .. } => 12,
            Msg::StateSync { .. } => 13,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "Hello",
            Msg::Init { .. } => "Init",
            Msg::Start { .. } => "Start",
            Msg::Terminate { .. } => "Terminate",
            Msg::Mix { .. } => "Mix",
            Msg::Done { .. } => "Done",
            Msg::MixAck { .. } => "MixAck",
            Msg::Ping { .. } => "Ping",
            Msg::Pong { .. } => "Pong",
            Msg::Stop => "Stop",
            Msg::Heartbeat { .. } => "Heartbeat",
            Msg::Rejoin { .. } => "Rejoin",
            Msg::StateSync { .. } => "StateSync",
        }
    }
}

/// Typed decode/IO failure. Decoding never panics: malformed bytes from
/// the network always surface as one of these.
#[derive(Debug)]
pub enum CodecError {
    BadMagic { got: [u8; 4] },
    BadVersion { got: u8 },
    BadMsgType { got: u8 },
    Oversized { len: u32, max: u32 },
    Truncated { need: usize, have: usize },
    BadChecksum { want: u32, got: u32 },
    BadPayload(&'static str),
    Io(std::io::Error),
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::BadMagic { got } => write!(f, "bad frame magic {got:?}"),
            CodecError::BadVersion { got } => {
                write!(f, "unsupported wire version {got} (want {VERSION})")
            }
            CodecError::BadMsgType { got } => write!(f, "unknown message type {got}"),
            CodecError::Oversized { len, max } => {
                write!(f, "payload length {len} exceeds maximum {max}")
            }
            CodecError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            CodecError::BadChecksum { want, got } => {
                write!(f, "payload checksum mismatch: want {want:#010x}, got {got:#010x}")
            }
            CodecError::BadPayload(what) => write!(f, "malformed payload: {what}"),
            CodecError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// FNV-1a 32-bit hash — the frame's payload checksum.
fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

// ------------------------------------------------------------- encoding

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_vec_f32(out: &mut Vec<u8>, v: &[f32]) {
    put_u32(out, v.len() as u32);
    for &x in v {
        put_f32(out, x);
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn encode_payload(msg: &Msg) -> Vec<u8> {
    let mut p = Vec::new();
    match msg {
        Msg::Hello { worker } => put_u32(&mut p, *worker),
        Msg::Init { worker, setup } => {
            put_u32(&mut p, *worker);
            put_str(&mut p, setup);
        }
        Msg::Start { k, delay_s } => {
            put_u64(&mut p, *k);
            put_f64(&mut p, *delay_s);
        }
        Msg::Terminate { k } => put_u64(&mut p, *k),
        Msg::Mix { k, active, row, peers } => {
            put_u64(&mut p, *k);
            p.push(*active as u8);
            put_u32(&mut p, row.len() as u32);
            for &(i, wt) in row {
                put_u32(&mut p, i);
                put_f64(&mut p, wt);
            }
            // one vector per row entry, in row order — the count is the
            // row length by construction, so decode can't desynchronise
            for peer in peers {
                put_vec_f32(&mut p, peer);
            }
        }
        Msg::Done { k, loss, terminated, failed, wtilde } => {
            put_u64(&mut p, *k);
            put_f32(&mut p, *loss);
            p.push(*terminated as u8);
            p.push(*failed as u8);
            put_vec_f32(&mut p, wtilde);
        }
        Msg::MixAck { k, w } => {
            put_u64(&mut p, *k);
            put_vec_f32(&mut p, w);
        }
        Msg::Ping { nonce } | Msg::Pong { nonce } => put_u64(&mut p, *nonce),
        Msg::Stop => {}
        Msg::Heartbeat { seq } => put_u64(&mut p, *seq),
        Msg::Rejoin { worker, draws } => {
            put_u32(&mut p, *worker);
            put_u64(&mut p, *draws);
        }
        Msg::StateSync { draws, w, wtilde } => {
            put_u64(&mut p, *draws);
            put_vec_f32(&mut p, w);
            put_vec_f32(&mut p, wtilde);
        }
    }
    p
}

/// Encode one message as a complete frame.
pub fn encode(msg: &Msg) -> Vec<u8> {
    let payload = encode_payload(msg);
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len() + TRAILER_LEN);
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    out.push(msg.type_byte());
    put_u32(&mut out, payload.len() as u32);
    let sum = fnv1a(&payload);
    out.extend_from_slice(&payload);
    put_u32(&mut out, sum);
    out
}

// ------------------------------------------------------------- decoding

/// Bounds-checked cursor over a payload slice.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        let end = self
            .pos
            .checked_add(n)
            .ok_or(CodecError::BadPayload("length overflow"))?;
        if end > self.buf.len() {
            return Err(CodecError::Truncated { need: end, have: self.buf.len() });
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    fn bool(&mut self) -> Result<bool, CodecError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(CodecError::BadPayload("bool byte not 0/1")),
        }
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, CodecError> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    fn f32(&mut self) -> Result<f32, CodecError> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn vec_f32(&mut self) -> Result<Vec<f32>, CodecError> {
        let len = self.u32()? as usize;
        // sanity before allocating: the elements must actually be here
        let need = len
            .checked_mul(4)
            .ok_or(CodecError::BadPayload("vector length overflow"))?;
        if need > self.remaining() {
            return Err(CodecError::Truncated {
                need: self.pos.saturating_add(need),
                have: self.buf.len(),
            });
        }
        let mut v = Vec::with_capacity(len);
        for _ in 0..len {
            v.push(self.f32()?);
        }
        Ok(v)
    }

    fn string(&mut self) -> Result<String, CodecError> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| CodecError::BadPayload("non-UTF-8 string"))
    }
}

fn decode_payload(msg_type: u8, payload: &[u8]) -> Result<Msg, CodecError> {
    let mut r = Reader::new(payload);
    let msg = match msg_type {
        1 => Msg::Hello { worker: r.u32()? },
        2 => Msg::Init { worker: r.u32()?, setup: r.string()? },
        3 => Msg::Start { k: r.u64()?, delay_s: r.f64()? },
        4 => Msg::Terminate { k: r.u64()? },
        5 => {
            let k = r.u64()?;
            let active = r.bool()?;
            let row_len = r.u32()? as usize;
            // each row entry is >= 12 payload bytes; reject impossible
            // counts before reserving anything
            if row_len.saturating_mul(12) > r.remaining() {
                return Err(CodecError::Truncated {
                    need: r.pos.saturating_add(row_len.saturating_mul(12)),
                    have: payload.len(),
                });
            }
            let mut row = Vec::with_capacity(row_len);
            for _ in 0..row_len {
                row.push((r.u32()?, r.f64()?));
            }
            let mut peers = Vec::with_capacity(row_len);
            for _ in 0..row_len {
                peers.push(r.vec_f32()?);
            }
            Msg::Mix { k, active, row, peers }
        }
        6 => Msg::Done {
            k: r.u64()?,
            loss: r.f32()?,
            terminated: r.bool()?,
            failed: r.bool()?,
            wtilde: r.vec_f32()?,
        },
        7 => Msg::MixAck { k: r.u64()?, w: r.vec_f32()? },
        8 => Msg::Ping { nonce: r.u64()? },
        9 => Msg::Pong { nonce: r.u64()? },
        10 => Msg::Stop,
        11 => Msg::Heartbeat { seq: r.u64()? },
        12 => Msg::Rejoin { worker: r.u32()?, draws: r.u64()? },
        13 => Msg::StateSync { draws: r.u64()?, w: r.vec_f32()?, wtilde: r.vec_f32()? },
        other => return Err(CodecError::BadMsgType { got: other }),
    };
    if r.remaining() != 0 {
        return Err(CodecError::BadPayload("trailing bytes after message"));
    }
    Ok(msg)
}

/// Parse and validate a frame header. Returns `(msg_type, payload_len)`.
fn parse_header(h: &[u8; HEADER_LEN]) -> Result<(u8, u32), CodecError> {
    if h[0..4] != MAGIC {
        return Err(CodecError::BadMagic { got: [h[0], h[1], h[2], h[3]] });
    }
    if h[4] != VERSION {
        return Err(CodecError::BadVersion { got: h[4] });
    }
    let len = u32::from_le_bytes([h[6], h[7], h[8], h[9]]);
    if len > MAX_PAYLOAD {
        return Err(CodecError::Oversized { len, max: MAX_PAYLOAD });
    }
    Ok((h[5], len))
}

/// Decode one frame from the front of `buf`. Returns the message and the
/// number of bytes consumed.
pub fn decode(buf: &[u8]) -> Result<(Msg, usize), CodecError> {
    if buf.len() < HEADER_LEN {
        return Err(CodecError::Truncated { need: HEADER_LEN, have: buf.len() });
    }
    let mut h = [0u8; HEADER_LEN];
    h.copy_from_slice(&buf[..HEADER_LEN]);
    let (msg_type, len) = parse_header(&h)?;
    let total = HEADER_LEN + len as usize + TRAILER_LEN;
    if buf.len() < total {
        return Err(CodecError::Truncated { need: total, have: buf.len() });
    }
    let payload = &buf[HEADER_LEN..HEADER_LEN + len as usize];
    let stored = u32::from_le_bytes([
        buf[total - 4],
        buf[total - 3],
        buf[total - 2],
        buf[total - 1],
    ]);
    let computed = fnv1a(payload);
    if stored != computed {
        return Err(CodecError::BadChecksum { want: computed, got: stored });
    }
    Ok((decode_payload(msg_type, payload)?, total))
}

/// Write one message as a frame.
pub fn write_frame<W: Write>(w: &mut W, msg: &Msg) -> Result<(), CodecError> {
    write_frame_counted(w, msg).map(|_| ())
}

/// Write one message as a frame, returning the frame size in bytes
/// (telemetry: per-peer wire-byte counters).
pub fn write_frame_counted<W: Write>(w: &mut W, msg: &Msg) -> Result<usize, CodecError> {
    let buf = encode(msg);
    w.write_all(&buf).map_err(CodecError::Io)?;
    Ok(buf.len())
}

/// Read one frame, returning `Ok(None)` on a clean EOF at a frame
/// boundary (the peer closed the connection between messages).
pub fn read_frame_opt<R: Read>(r: &mut R) -> Result<Option<Msg>, CodecError> {
    Ok(read_frame_opt_counted(r)?.map(|(msg, _)| msg))
}

/// [`read_frame_opt`] plus the frame size in bytes.
pub fn read_frame_opt_counted<R: Read>(r: &mut R) -> Result<Option<(Msg, usize)>, CodecError> {
    let mut header = [0u8; HEADER_LEN];
    let mut filled = 0usize;
    while filled < HEADER_LEN {
        match r.read(&mut header[filled..]) {
            Ok(0) => {
                if filled == 0 {
                    return Ok(None);
                }
                return Err(CodecError::Truncated { need: HEADER_LEN, have: filled });
            }
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(CodecError::Io(e)),
        }
    }
    let (msg_type, len) = parse_header(&header)?;
    let mut rest = vec![0u8; len as usize + TRAILER_LEN];
    r.read_exact(&mut rest).map_err(CodecError::Io)?;
    let payload = &rest[..len as usize];
    let stored = u32::from_le_bytes([
        rest[rest.len() - 4],
        rest[rest.len() - 3],
        rest[rest.len() - 2],
        rest[rest.len() - 1],
    ]);
    let computed = fnv1a(payload);
    if stored != computed {
        return Err(CodecError::BadChecksum { want: computed, got: stored });
    }
    let total = HEADER_LEN + len as usize + TRAILER_LEN;
    Ok(Some((decode_payload(msg_type, payload)?, total)))
}

/// Read one frame; EOF before a complete frame is an error.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Msg, CodecError> {
    match read_frame_opt(r)? {
        Some(msg) => Ok(msg),
        None => Err(CodecError::Truncated { need: HEADER_LEN, have: 0 }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_messages() -> Vec<Msg> {
        vec![
            Msg::Hello { worker: 3 },
            Msg::Hello { worker: ANY_WORKER },
            Msg::Init { worker: 0, setup: r#"{"workers": 4, "seed": 7}"#.into() },
            Msg::Init { worker: 1, setup: String::new() },
            Msg::Start { k: 12, delay_s: 0.125 },
            Msg::Terminate { k: 12 },
            Msg::Mix {
                k: 3,
                active: true,
                row: vec![(0, 0.5), (2, 0.25), (3, 0.25)],
                peers: vec![vec![1.0, -2.5], vec![0.0, 3.25], vec![-0.125, 4.0]],
            },
            Msg::Mix { k: 4, active: false, row: Vec::new(), peers: Vec::new() },
            Msg::Done {
                k: 9,
                loss: 0.75,
                terminated: true,
                failed: false,
                wtilde: vec![0.5, -0.5, 1.5],
            },
            Msg::Done { k: 1, loss: 2.0, terminated: false, failed: true, wtilde: Vec::new() },
            Msg::MixAck { k: 9, w: vec![1.0; 17] },
            Msg::Ping { nonce: u64::MAX },
            Msg::Pong { nonce: 0 },
            Msg::Stop,
            Msg::Heartbeat { seq: 42 },
            Msg::Rejoin { worker: 2, draws: 17 },
            Msg::Rejoin { worker: ANY_WORKER, draws: 0 },
            Msg::StateSync { draws: 9, w: vec![0.5, -1.5], wtilde: vec![2.0, 0.0] },
            Msg::StateSync { draws: 0, w: Vec::new(), wtilde: Vec::new() },
        ]
    }

    #[test]
    fn round_trip_all_message_types() {
        for msg in sample_messages() {
            let frame = encode(&msg);
            let (back, used) = decode(&frame).unwrap();
            assert_eq!(used, frame.len(), "{}", msg.name());
            assert_eq!(back, msg, "{}", msg.name());
        }
    }

    #[test]
    fn round_trip_preserves_float_bits_including_nan() {
        let msg = Msg::Done {
            k: 2,
            loss: f32::NAN,
            terminated: false,
            failed: true,
            wtilde: vec![f32::INFINITY, -0.0, f32::from_bits(0x7fc0_1234)],
        };
        let (back, _) = decode(&encode(&msg)).unwrap();
        let Msg::Done { loss, wtilde, .. } = back else {
            panic!("wrong variant");
        };
        assert_eq!(loss.to_bits(), f32::NAN.to_bits());
        assert_eq!(wtilde[0].to_bits(), f32::INFINITY.to_bits());
        assert_eq!(wtilde[1].to_bits(), (-0.0f32).to_bits());
        assert_eq!(wtilde[2].to_bits(), 0x7fc0_1234);
    }

    /// Property-style sweep: randomly sized vector payloads round-trip.
    #[test]
    fn round_trip_random_payloads() {
        let mut rng = Rng::new(0xC0DEC);
        for trial in 0..200 {
            let dim = rng.below(64);
            let deg = rng.below(6);
            let msg = match trial % 4 {
                0 => Msg::Done {
                    k: rng.below(1 << 20) as u64,
                    loss: rng.uniform() as f32,
                    terminated: rng.uniform() < 0.5,
                    failed: false,
                    wtilde: (0..dim).map(|_| rng.uniform() as f32 - 0.5).collect(),
                },
                1 => Msg::MixAck {
                    k: rng.below(1 << 20) as u64,
                    w: (0..dim).map(|_| rng.uniform() as f32 * 8.0).collect(),
                },
                2 => Msg::Mix {
                    k: rng.below(1 << 20) as u64,
                    active: true,
                    row: (0..deg).map(|i| (i as u32, rng.uniform())).collect(),
                    peers: (0..deg)
                        .map(|_| (0..dim).map(|_| rng.uniform() as f32).collect())
                        .collect(),
                },
                _ => Msg::Init {
                    worker: rng.below(1 << 16) as u32,
                    setup: "x".repeat(rng.below(300)),
                },
            };
            let (back, _) = decode(&encode(&msg)).unwrap();
            assert_eq!(back, msg, "trial {trial}");
        }
    }

    #[test]
    fn truncation_at_every_prefix_is_typed_never_a_panic() {
        for msg in sample_messages() {
            let frame = encode(&msg);
            for cut in 0..frame.len() {
                match decode(&frame[..cut]) {
                    Err(_) => {}
                    Ok((m, used)) => {
                        panic!("decoded {} from a {cut}-byte prefix (used {used})", m.name())
                    }
                }
            }
        }
    }

    #[test]
    fn bad_magic_is_typed() {
        let mut frame = encode(&Msg::Stop);
        frame[0] = b'X';
        assert!(matches!(decode(&frame), Err(CodecError::BadMagic { .. })));
    }

    #[test]
    fn bad_version_is_typed() {
        let mut frame = encode(&Msg::Stop);
        frame[4] = 99;
        assert!(matches!(decode(&frame), Err(CodecError::BadVersion { got: 99 })));
    }

    #[test]
    fn bad_msg_type_is_typed() {
        let mut frame = encode(&Msg::Stop);
        frame[5] = 200;
        assert!(matches!(decode(&frame), Err(CodecError::BadMsgType { got: 200 })));
    }

    #[test]
    fn oversized_length_prefix_rejected_without_allocating() {
        let mut frame = encode(&Msg::Stop);
        frame[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(matches!(decode(&frame), Err(CodecError::Oversized { .. })));
    }

    #[test]
    fn corrupted_payload_fails_the_checksum() {
        let msg = Msg::MixAck { k: 5, w: vec![1.0, 2.0, 3.0] };
        let mut frame = encode(&msg);
        frame[HEADER_LEN + 9] ^= 0x40; // flip one payload bit
        assert!(matches!(decode(&frame), Err(CodecError::BadChecksum { .. })));
    }

    #[test]
    fn inner_vector_length_cannot_overrun() {
        // hand-build a MixAck whose inner vector claims more floats than
        // the payload holds; re-checksum so only the length lies
        let mut payload = Vec::new();
        put_u64(&mut payload, 1);
        put_u32(&mut payload, 1000); // claims 1000 f32s, provides 1
        put_f32(&mut payload, 1.0);
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC);
        frame.push(VERSION);
        frame.push(7);
        put_u32(&mut frame, payload.len() as u32);
        let sum = fnv1a(&payload);
        frame.extend_from_slice(&payload);
        put_u32(&mut frame, sum);
        assert!(matches!(decode(&frame), Err(CodecError::Truncated { .. })));
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut payload = Vec::new();
        put_u64(&mut payload, 7); // Terminate payload ...
        payload.push(0); // ... plus one stray byte
        let mut frame = Vec::new();
        frame.extend_from_slice(&MAGIC);
        frame.push(VERSION);
        frame.push(4);
        put_u32(&mut frame, payload.len() as u32);
        let sum = fnv1a(&payload);
        frame.extend_from_slice(&payload);
        put_u32(&mut frame, sum);
        assert!(matches!(decode(&frame), Err(CodecError::BadPayload(_))));
    }

    /// Flip every single byte of every sample frame: decode must return
    /// (any) typed result — never panic, never loop.
    #[test]
    fn every_single_byte_flip_never_panics() {
        for msg in sample_messages() {
            let frame = encode(&msg);
            for i in 0..frame.len() {
                let mut bad = frame.clone();
                bad[i] ^= 0xFF;
                let _ = decode(&bad);
            }
        }
    }

    #[test]
    fn stream_read_write_round_trip() {
        let msgs = sample_messages();
        let mut wire = Vec::new();
        for m in &msgs {
            write_frame(&mut wire, m).unwrap();
        }
        let mut cursor = std::io::Cursor::new(wire);
        for m in &msgs {
            assert_eq!(&read_frame(&mut cursor).unwrap(), m);
        }
        // clean EOF at the frame boundary
        assert!(read_frame_opt(&mut cursor).unwrap().is_none());
        // but a hard read reports it as truncation
        assert!(matches!(
            read_frame(&mut cursor),
            Err(CodecError::Truncated { .. })
        ));
    }

    #[test]
    fn stream_mid_frame_eof_is_an_error() {
        let frame = encode(&Msg::Ping { nonce: 3 });
        let mut cursor = std::io::Cursor::new(frame[..frame.len() - 2].to_vec());
        assert!(read_frame_opt(&mut cursor).is_err());
    }

    #[test]
    fn decode_reports_bytes_consumed_for_concatenated_frames() {
        let a = encode(&Msg::Ping { nonce: 1 });
        let b = encode(&Msg::Stop);
        let mut wire = a.clone();
        wire.extend_from_slice(&b);
        let (m1, used1) = decode(&wire).unwrap();
        assert_eq!(m1, Msg::Ping { nonce: 1 });
        assert_eq!(used1, a.len());
        let (m2, used2) = decode(&wire[used1..]).unwrap();
        assert_eq!(m2, Msg::Stop);
        assert_eq!(used2, b.len());
    }
}
