//! Heartbeat-driven failure detection for the live driver.
//!
//! The coordinator probes every live peer with [`Msg::Heartbeat`] at a
//! fixed interval; workers echo each probe immediately (even while
//! sleeping out a straggler delay). [`Liveness`] tracks, per peer, when
//! it was last heard from and when the next probe is due. A peer is
//! reported *expired* only once [`TIMEOUT_INTERVALS`] probes have gone
//! unanswered **and** its silence exceeds the timeout — gating expiry on
//! probes actually sent means a leader that was itself busy (a long
//! held-out eval, say) cannot condemn peers it never asked after. The
//! driver then severs the expired peer's connection, which collapses
//! "suspended", "wedged", and "network-dead" into the single down-peer
//! path that [`crate::comms::transport::TcpTransport`]'s rejoin flow
//! recovers from.
//!
//! [`Msg::Heartbeat`]: crate::comms::codec::Msg::Heartbeat

use std::time::{Duration, Instant};

/// Unanswered probes (equivalently, silence as a multiple of the probe
/// interval) tolerated before a peer is declared dead. Must exceed the
/// worker's longest blocking gradient computation divided by the probe
/// interval.
pub const TIMEOUT_INTERVALS: u32 = 4;

struct PeerState {
    alive: bool,
    last_seen: Instant,
    next_probe: Instant,
    /// Probes sent since the peer last spoke.
    unanswered: u32,
    /// Latest probe awaiting its echo: `(seq, sent_at)`. Telemetry-only
    /// bookkeeping — [`Liveness::probe_rtt`] matches echoes against it
    /// to measure round-trip time; expiry never reads it.
    inflight: Option<(u64, Instant)>,
}

/// Per-peer liveness deadlines. Purely a bookkeeping structure: the
/// caller feeds in message arrivals (`touch`) and membership changes
/// (`mark_down` / `mark_up`), and asks which peers to probe
/// (`due_probes`) and which have gone silent (`expired`).
pub struct Liveness {
    interval: Duration,
    timeout: Duration,
    seq: u64,
    peers: Vec<PeerState>,
}

impl Liveness {
    /// A tracker probing every `interval`. `Duration::ZERO` disables
    /// tracking entirely (the in-process default: threads don't die
    /// silently, so no probes, no deadlines).
    pub fn new(n: usize, interval: Duration, now: Instant) -> Liveness {
        Liveness {
            interval,
            timeout: interval * TIMEOUT_INTERVALS,
            seq: 0,
            peers: (0..n)
                .map(|_| PeerState {
                    alive: true,
                    last_seen: now,
                    next_probe: now + interval,
                    unanswered: 0,
                    inflight: None,
                })
                .collect(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.interval > Duration::ZERO
    }

    /// Any message from `j` proves it alive — heartbeat echoes are not
    /// special, a Done counts just as well.
    pub fn touch(&mut self, j: usize, now: Instant) {
        if let Some(p) = self.peers.get_mut(j) {
            p.last_seen = now;
            p.unanswered = 0;
        }
    }

    /// Stop tracking `j` (its connection is down; no probes, no expiry).
    pub fn mark_down(&mut self, j: usize) {
        if let Some(p) = self.peers.get_mut(j) {
            p.alive = false;
            p.inflight = None;
        }
    }

    /// Resume tracking `j` with a fresh deadline (it just rejoined).
    pub fn mark_up(&mut self, j: usize, now: Instant) {
        if let Some(p) = self.peers.get_mut(j) {
            p.alive = true;
            p.last_seen = now;
            p.next_probe = now + self.interval;
            p.unanswered = 0;
            p.inflight = None;
        }
    }

    /// Round-trip time of an answered probe: matches an echoed `seq`
    /// against the peer's in-flight probe and consumes it. `None` for
    /// stale echoes (a newer probe superseded the one echoed). Pure
    /// measurement — expiry and probing never depend on it.
    pub fn probe_rtt(&mut self, j: usize, seq: u64, now: Instant) -> Option<Duration> {
        let p = self.peers.get_mut(j)?;
        if let Some((s, sent)) = p.inflight {
            if s == seq {
                p.inflight = None;
                return Some(now.duration_since(sent));
            }
        }
        None
    }

    /// Peers whose probe is due, paired with the sequence number to
    /// stamp into the Heartbeat. Schedules each one's next probe.
    pub fn due_probes(&mut self, now: Instant) -> Vec<(usize, u64)> {
        if !self.enabled() {
            return Vec::new();
        }
        let mut due = Vec::new();
        for (j, p) in self.peers.iter_mut().enumerate() {
            if p.alive && p.next_probe <= now {
                self.seq += 1;
                p.next_probe = now + self.interval;
                p.unanswered += 1;
                p.inflight = Some((self.seq, now));
                due.push((j, self.seq));
            }
        }
        due
    }

    /// Live peers that ignored [`TIMEOUT_INTERVALS`] probes and stayed
    /// silent past the timeout.
    pub fn expired(&self, now: Instant) -> Vec<usize> {
        if !self.enabled() {
            return Vec::new();
        }
        self.peers
            .iter()
            .enumerate()
            .filter(|(_, p)| {
                p.alive
                    && p.unanswered >= TIMEOUT_INTERVALS
                    && now.duration_since(p.last_seen) > self.timeout
            })
            .map(|(j, _)| j)
            .collect()
    }

    /// How long the driver may park in `recv` before the next probe or
    /// expiry deadline. `None` when tracking is disabled (park for the
    /// full watchdog slice).
    pub fn next_deadline(&self, now: Instant) -> Option<Duration> {
        if !self.enabled() {
            return None;
        }
        self.peers
            .iter()
            .filter(|p| p.alive)
            .map(|p| {
                let probe = p.next_probe.saturating_duration_since(now);
                let expiry = (p.last_seen + self.timeout).saturating_duration_since(now);
                probe.min(expiry)
            })
            .min()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TICK: Duration = Duration::from_millis(100);

    #[test]
    fn disabled_tracker_never_probes_or_expires() {
        let t0 = Instant::now();
        let mut lv = Liveness::new(3, Duration::ZERO, t0);
        assert!(!lv.enabled());
        assert!(lv.due_probes(t0 + Duration::from_secs(3600)).is_empty());
        assert!(lv.expired(t0 + Duration::from_secs(3600)).is_empty());
        assert!(lv.next_deadline(t0).is_none());
    }

    #[test]
    fn probes_come_due_per_interval_with_fresh_seqs() {
        let t0 = Instant::now();
        let mut lv = Liveness::new(2, TICK, t0);
        assert!(lv.due_probes(t0).is_empty(), "nothing due immediately");
        let due = lv.due_probes(t0 + TICK);
        assert_eq!(due.iter().map(|&(j, _)| j).collect::<Vec<_>>(), vec![0, 1]);
        let seqs: Vec<u64> = due.iter().map(|&(_, s)| s).collect();
        assert_eq!(seqs.len(), 2);
        assert_ne!(seqs[0], seqs[1], "each probe gets its own seq");
        // not due again until another interval passes
        assert!(lv.due_probes(t0 + TICK).is_empty());
        assert_eq!(lv.due_probes(t0 + 2 * TICK).len(), 2);
    }

    #[test]
    fn silence_past_timeout_expires_only_the_silent_peer() {
        let t0 = Instant::now();
        let mut lv = Liveness::new(2, TICK, t0);
        for s in 1..=TIMEOUT_INTERVALS {
            lv.due_probes(t0 + s * TICK);
        }
        let late = t0 + TIMEOUT_INTERVALS * TICK + Duration::from_millis(1);
        lv.touch(1, late); // peer 1 answered
        assert_eq!(lv.expired(late), vec![0]);
    }

    /// Expiry is probe-gated: a leader that was away (long eval) and
    /// sent no probes must not condemn peers on re-entry, no matter how
    /// stale `last_seen` looks.
    #[test]
    fn leader_absence_alone_does_not_expire_peers() {
        let t0 = Instant::now();
        let mut lv = Liveness::new(1, TICK, t0);
        let back = t0 + 100 * TICK;
        assert!(lv.expired(back).is_empty());
        // re-entry fires one probe, not a verdict
        assert_eq!(lv.due_probes(back).len(), 1);
        assert!(lv.expired(back + Duration::from_millis(1)).is_empty());
    }

    #[test]
    fn down_peers_are_not_probed_or_expired_until_marked_up() {
        let t0 = Instant::now();
        let mut lv = Liveness::new(2, TICK, t0);
        lv.mark_down(0);
        for s in 1..=TIMEOUT_INTERVALS {
            let due = lv.due_probes(t0 + s * TICK);
            assert_eq!(due.iter().map(|&(j, _)| j).collect::<Vec<_>>(), vec![1], "round {s}");
        }
        let late = t0 + (TIMEOUT_INTERVALS + 1) * TICK;
        assert_eq!(lv.expired(late), vec![1]);
        // the rejoined peer gets a fresh deadline, not the stale one
        lv.mark_up(0, late);
        assert!(!lv.expired(late + TICK).contains(&0));
        for s in 1..=TIMEOUT_INTERVALS {
            lv.due_probes(late + s * TICK);
        }
        assert!(lv.expired(late + (TIMEOUT_INTERVALS + 1) * TICK).contains(&0));
    }

    #[test]
    fn probe_rtt_matches_echoes_and_rejects_stale_seqs() {
        let t0 = Instant::now();
        let mut lv = Liveness::new(1, TICK, t0);
        let due = lv.due_probes(t0 + TICK);
        let (j, seq) = due[0];
        // echo of the live probe: RTT is echo time minus probe time
        let echo_at = t0 + TICK + Duration::from_millis(7);
        assert_eq!(lv.probe_rtt(j, seq, echo_at), Some(Duration::from_millis(7)));
        // consumed: a duplicate echo measures nothing
        assert_eq!(lv.probe_rtt(j, seq, echo_at), None);
        // a superseded probe's echo is stale
        let due2 = lv.due_probes(t0 + 2 * TICK);
        let due3 = lv.due_probes(t0 + 3 * TICK);
        assert_eq!(lv.probe_rtt(j, due2[0].1, t0 + 3 * TICK), None);
        assert!(lv.probe_rtt(j, due3[0].1, t0 + 3 * TICK + TICK / 2).is_some());
        // out-of-range peer is a no-op
        assert_eq!(lv.probe_rtt(99, 1, echo_at), None);
    }

    #[test]
    fn next_deadline_is_the_soonest_probe_or_expiry() {
        let t0 = Instant::now();
        let mut lv = Liveness::new(2, TICK, t0);
        // soonest event is the first probe, one interval out
        assert_eq!(lv.next_deadline(t0), Some(TICK));
        let t1 = t0 + TICK / 2;
        assert_eq!(lv.next_deadline(t1), Some(TICK / 2));
        // with every peer down there is no deadline to honour
        lv.mark_down(0);
        lv.mark_down(1);
        assert_eq!(lv.next_deadline(t1), None);
    }
}
