//! Per-link message-latency model for the event-driven simulator.
//!
//! The lockstep drivers never needed one: a global θ(k) cut absorbs all
//! communication time into the iteration duration. The DES runs workers
//! on their own clocks, so the time a parameter estimate spends on the
//! wire between two neighbours becomes a first-class quantity: it decides
//! *which* n_i − b_i estimates arrive first, and therefore the whole
//! asynchronous schedule.
//!
//! Latency is a **pure function** of (src, dst, k): the jitter draw comes
//! from a [`stream_seed`]-keyed throwaway RNG, not from a shared stream,
//! so the sampled value never depends on the order events fire in — the
//! property the DES determinism tests lean on.

use crate::util::rng::{stream_seed, Rng};

use super::Dist;

/// Tag for link-latency streams (decorrelates them from compute-time
/// streams keyed on the same seed).
const LINK_TAG: u64 = 0x4C49_4E4B; // "LINK"

/// Message latency over one edge: fixed propagation base + random jitter,
/// optionally degraded per edge (heterogeneous links: a slow WAN hop, a
/// congested rack uplink).
#[derive(Debug, Clone)]
pub struct LinkModel {
    /// Fixed per-message latency floor (seconds).
    pub base: f64,
    /// Additional random per-message latency.
    pub jitter: Option<Dist>,
    /// Per-edge multipliers `(a, b, factor)` applied to BOTH directions
    /// of the (a, b) edge — heterogeneous-link injection.
    pub slow_links: Vec<(usize, usize, f64)>,
    /// Seed of the jitter streams.
    pub seed: u64,
}

impl LinkModel {
    /// Zero-latency network: messages arrive the instant they are sent.
    pub fn zero() -> Self {
        LinkModel {
            base: 0.0,
            jitter: None,
            slow_links: Vec::new(),
            seed: 0,
        }
    }

    pub fn new(base: f64, jitter: Option<Dist>, seed: u64) -> Self {
        LinkModel {
            base,
            jitter,
            slow_links: Vec::new(),
            seed,
        }
    }

    /// Mark the (a, b) edge `factor`x slower in both directions.
    pub fn with_slow_link(mut self, a: usize, b: usize, factor: f64) -> Self {
        self.slow_links.push((a, b, factor));
        self
    }

    /// Latency of worker `src`'s iteration-`k` message to `dst`.
    /// Pure in (src, dst, k); directions draw independent jitter.
    pub fn latency(&self, src: usize, dst: usize, k: usize) -> f64 {
        let mut l = self.base;
        if let Some(d) = &self.jitter {
            let key = stream_seed(
                self.seed,
                LINK_TAG,
                ((src as u64) << 32) | dst as u64,
                k as u64,
            );
            l += d.sample(&mut Rng::new(key));
        }
        for &(a, b, f) in &self.slow_links {
            if (src == a && dst == b) || (src == b && dst == a) {
                l *= f;
            }
        }
        l
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_model_is_zero() {
        let m = LinkModel::zero();
        assert_eq!(m.latency(0, 1, 5), 0.0);
        assert_eq!(m.latency(3, 2, 0), 0.0);
    }

    #[test]
    fn latency_is_pure_in_coordinates() {
        let m = LinkModel::new(0.002, Some(Dist::ShiftedExp { base: 0.0, rate: 500.0 }), 7);
        let a = m.latency(1, 2, 10);
        assert_eq!(m.latency(1, 2, 10), a, "same tuple must resample identically");
        assert_ne!(m.latency(2, 1, 10), a, "directions draw independent jitter");
        assert_ne!(m.latency(1, 2, 11), a, "iterations draw independent jitter");
        assert!(a >= 0.002);
    }

    #[test]
    fn slow_link_applies_both_directions_only_there() {
        let m = LinkModel::new(0.01, None, 0).with_slow_link(0, 1, 5.0);
        assert_eq!(m.latency(0, 1, 3), 0.05);
        assert_eq!(m.latency(1, 0, 3), 0.05);
        assert_eq!(m.latency(1, 2, 3), 0.01);
    }

    #[test]
    fn jitter_mean_roughly_matches_dist() {
        let d = Dist::ShiftedExp { base: 0.001, rate: 200.0 };
        let m = LinkModel::new(0.0, Some(d), 3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|k| m.latency(0, 1, k)).sum::<f64>() / n as f64;
        assert!((mean - d.mean()).abs() < 0.001, "mean {mean} want {}", d.mean());
    }
}
