//! Per-link message-latency model for the event-driven simulator.
//!
//! The lockstep drivers never needed one: a global θ(k) cut absorbs all
//! communication time into the iteration duration. The DES runs workers
//! on their own clocks, so the time a parameter estimate spends on the
//! wire between two neighbours becomes a first-class quantity: it decides
//! *which* n_i − b_i estimates arrive first, and therefore the whole
//! asynchronous schedule.
//!
//! Latency is a **pure function** of (src, dst, k): the jitter draw comes
//! from a [`stream_seed`]-keyed throwaway RNG, not from a shared stream,
//! so the sampled value never depends on the order events fire in — the
//! property the DES determinism tests lean on.
//!
//! [`LinkMeasure`] closes the loop with reality: the live driver's
//! `measure` mode (see `coordinator::live::measure_links`) records real
//! per-worker latencies over the deployed transport, and
//! [`LinkMeasure::calibrated`] fits them into a [`LinkModel`] the DES can
//! replay — the model stops being an uncalibrated assumption.

use crate::util::rng::{stream_seed, Rng};

use super::Dist;

/// Tag for link-latency streams (decorrelates them from compute-time
/// streams keyed on the same seed).
const LINK_TAG: u64 = 0x4C49_4E4B; // "LINK"

/// A rejected `slow_links` configuration.
#[derive(Debug, Clone, PartialEq)]
pub enum LinkConfigError {
    /// An endpoint index does not name a worker.
    EdgeOutOfRange { a: usize, b: usize, n: usize },
    /// The same (undirected) edge appears more than once — factors would
    /// silently compound.
    DuplicateEdge { a: usize, b: usize },
    /// A non-finite or negative slowdown factor.
    BadFactor { a: usize, b: usize, factor: f64 },
}

impl std::fmt::Display for LinkConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            LinkConfigError::EdgeOutOfRange { a, b, n } => {
                write!(f, "slow_links edge ({a},{b}) outside 0..{n}")
            }
            LinkConfigError::DuplicateEdge { a, b } => {
                write!(f, "slow_links lists edge ({a},{b}) more than once")
            }
            LinkConfigError::BadFactor { a, b, factor } => {
                write!(
                    f,
                    "slow_links factor {factor} for edge ({a},{b}) must be finite and >= 0"
                )
            }
        }
    }
}

impl std::error::Error for LinkConfigError {}

/// Message latency over one edge: fixed propagation base + random jitter,
/// optionally degraded per edge (heterogeneous links: a slow WAN hop, a
/// congested rack uplink).
#[derive(Debug, Clone)]
pub struct LinkModel {
    /// Fixed per-message latency floor (seconds).
    pub base: f64,
    /// Additional random per-message latency.
    pub jitter: Option<Dist>,
    /// Per-edge multipliers `(a, b, factor)` applied to BOTH directions
    /// of the (a, b) edge — heterogeneous-link injection. At most one
    /// entry per undirected edge ([`Self::validate`] enforces this).
    pub slow_links: Vec<(usize, usize, f64)>,
    /// Seed of the jitter streams.
    pub seed: u64,
}

impl LinkModel {
    /// Zero-latency network: messages arrive the instant they are sent.
    pub fn zero() -> Self {
        LinkModel {
            base: 0.0,
            jitter: None,
            slow_links: Vec::new(),
            seed: 0,
        }
    }

    pub fn new(base: f64, jitter: Option<Dist>, seed: u64) -> Self {
        LinkModel {
            base,
            jitter,
            slow_links: Vec::new(),
            seed,
        }
    }

    /// Mark the (a, b) edge `factor`x slower in both directions.
    pub fn with_slow_link(mut self, a: usize, b: usize, factor: f64) -> Self {
        self.slow_links.push((a, b, factor));
        self
    }

    /// Check the `slow_links` table against a network of `n` workers:
    /// every endpoint must name a worker, every factor must be a sane
    /// multiplier, and no (undirected) edge may appear twice — a
    /// duplicate would otherwise apply its factor multiplicatively, and
    /// an out-of-range index would silently never match.
    pub fn validate(&self, n: usize) -> Result<(), LinkConfigError> {
        let mut seen = std::collections::BTreeSet::new();
        for &(a, b, factor) in &self.slow_links {
            if a >= n || b >= n {
                return Err(LinkConfigError::EdgeOutOfRange { a, b, n });
            }
            if !(factor.is_finite() && factor >= 0.0) {
                return Err(LinkConfigError::BadFactor { a, b, factor });
            }
            if !seen.insert((a.min(b), a.max(b))) {
                return Err(LinkConfigError::DuplicateEdge { a, b });
            }
        }
        Ok(())
    }

    /// Latency of worker `src`'s iteration-`k` message to `dst`.
    /// Pure in (src, dst, k); directions draw independent jitter.
    pub fn latency(&self, src: usize, dst: usize, k: usize) -> f64 {
        let mut l = self.base;
        if let Some(d) = &self.jitter {
            let key = stream_seed(
                self.seed,
                LINK_TAG,
                ((src as u64) << 32) | dst as u64,
                k as u64,
            );
            l += d.sample(&mut Rng::new(key));
        }
        for &(a, b, f) in &self.slow_links {
            if (src == a && dst == b) || (src == b && dst == a) {
                l *= f;
                // an edge has ONE factor; even if a duplicate entry
                // slipped past validation it must not compound
                break;
            }
        }
        l
    }
}

/// Real per-worker latency samples recorded over a live transport
/// (coordinator <-> worker one-way estimates, RTT/2).
#[derive(Debug, Clone)]
pub struct LinkMeasure {
    samples: Vec<Vec<f64>>,
}

impl LinkMeasure {
    pub fn new(n: usize) -> Self {
        LinkMeasure {
            samples: vec![Vec::new(); n],
        }
    }

    pub fn n(&self) -> usize {
        self.samples.len()
    }

    /// Record one one-way latency estimate (seconds) for `worker`.
    pub fn record(&mut self, worker: usize, seconds: f64) {
        self.samples[worker].push(seconds.max(0.0));
    }

    /// Total number of recorded samples.
    pub fn count(&self) -> usize {
        self.samples.iter().map(|s| s.len()).sum()
    }

    /// The global latency floor across all samples (0 when empty).
    pub fn base(&self) -> f64 {
        let min = self
            .samples
            .iter()
            .flatten()
            .copied()
            .fold(f64::INFINITY, f64::min);
        if min.is_finite() {
            min
        } else {
            0.0
        }
    }

    /// Fit a [`LinkModel`] to the measurements: the observed floor
    /// becomes `base`, the mean excess over the floor becomes an
    /// exponential jitter (the classic shifted-exponential link model).
    /// With no samples (or no spread) the model is deterministic.
    pub fn calibrated(&self, seed: u64) -> LinkModel {
        if self.count() == 0 {
            return LinkModel::zero();
        }
        let base = self.base();
        let total = self.count() as f64;
        let mean_excess =
            self.samples.iter().flatten().map(|&s| s - base).sum::<f64>() / total;
        let jitter = if mean_excess > 1e-9 {
            Some(Dist::ShiftedExp {
                base: 0.0,
                rate: 1.0 / mean_excess,
            })
        } else {
            None
        };
        LinkModel {
            base,
            jitter,
            slow_links: Vec::new(),
            seed,
        }
    }

    /// Human-readable per-worker summary (count / min / mean / max, ms).
    pub fn summary(&self) -> String {
        let mut out = String::new();
        for (j, s) in self.samples.iter().enumerate() {
            if s.is_empty() {
                out.push_str(&format!("  worker {j}: no samples\n"));
                continue;
            }
            let min = s.iter().copied().fold(f64::INFINITY, f64::min);
            let max = s.iter().copied().fold(0.0f64, f64::max);
            let mean = s.iter().sum::<f64>() / s.len() as f64;
            out.push_str(&format!(
                "  worker {j}: {} samples, min {:.3}ms / mean {:.3}ms / max {:.3}ms\n",
                s.len(),
                min * 1e3,
                mean * 1e3,
                max * 1e3
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_model_is_zero() {
        let m = LinkModel::zero();
        assert_eq!(m.latency(0, 1, 5), 0.0);
        assert_eq!(m.latency(3, 2, 0), 0.0);
    }

    #[test]
    fn latency_is_pure_in_coordinates() {
        let m = LinkModel::new(0.002, Some(Dist::ShiftedExp { base: 0.0, rate: 500.0 }), 7);
        let a = m.latency(1, 2, 10);
        assert_eq!(m.latency(1, 2, 10), a, "same tuple must resample identically");
        assert_ne!(m.latency(2, 1, 10), a, "directions draw independent jitter");
        assert_ne!(m.latency(1, 2, 11), a, "iterations draw independent jitter");
        assert!(a >= 0.002);
    }

    #[test]
    fn slow_link_applies_both_directions_only_there() {
        let m = LinkModel::new(0.01, None, 0).with_slow_link(0, 1, 5.0);
        assert_eq!(m.latency(0, 1, 3), 0.05);
        assert_eq!(m.latency(1, 0, 3), 0.05);
        assert_eq!(m.latency(1, 2, 3), 0.01);
    }

    #[test]
    fn duplicate_slow_link_entries_apply_once() {
        // the old code compounded duplicates: 0.01 * 5 * 5 = 0.25
        let m = LinkModel::new(0.01, None, 0)
            .with_slow_link(0, 1, 5.0)
            .with_slow_link(0, 1, 5.0);
        assert!((m.latency(0, 1, 3) - 0.05).abs() < 1e-12);
        // same for a duplicate written in the reversed direction
        let m = LinkModel::new(0.01, None, 0)
            .with_slow_link(0, 1, 5.0)
            .with_slow_link(1, 0, 3.0);
        assert!((m.latency(0, 1, 3) - 0.05).abs() < 1e-12);
        assert!((m.latency(1, 0, 3) - 0.05).abs() < 1e-12);
    }

    #[test]
    fn validate_rejects_duplicates() {
        let m = LinkModel::new(0.01, None, 0)
            .with_slow_link(0, 1, 5.0)
            .with_slow_link(1, 0, 3.0);
        assert_eq!(
            m.validate(4),
            Err(LinkConfigError::DuplicateEdge { a: 1, b: 0 })
        );
        let ok = LinkModel::new(0.01, None, 0)
            .with_slow_link(0, 1, 5.0)
            .with_slow_link(1, 2, 3.0);
        assert_eq!(ok.validate(4), Ok(()));
    }

    #[test]
    fn validate_rejects_out_of_range_indices() {
        let m = LinkModel::new(0.01, None, 0).with_slow_link(0, 7, 2.0);
        assert_eq!(
            m.validate(4),
            Err(LinkConfigError::EdgeOutOfRange { a: 0, b: 7, n: 4 })
        );
        assert_eq!(m.validate(8), Ok(()));
    }

    #[test]
    fn validate_rejects_bad_factors() {
        for bad in [f64::NAN, f64::INFINITY, -2.0] {
            let m = LinkModel::new(0.01, None, 0).with_slow_link(0, 1, bad);
            assert!(
                matches!(m.validate(4), Err(LinkConfigError::BadFactor { .. })),
                "factor {bad} accepted"
            );
        }
    }

    #[test]
    fn config_errors_mention_slow_links() {
        // scenario-load errors surface these through anyhow; grepping
        // for "slow_links" in the message is the documented contract
        for e in [
            LinkConfigError::EdgeOutOfRange { a: 0, b: 9, n: 4 },
            LinkConfigError::DuplicateEdge { a: 1, b: 2 },
            LinkConfigError::BadFactor { a: 0, b: 1, factor: f64::NAN },
        ] {
            assert!(e.to_string().contains("slow_links"), "{e}");
        }
    }

    #[test]
    fn jitter_mean_roughly_matches_dist() {
        let d = Dist::ShiftedExp { base: 0.001, rate: 200.0 };
        let m = LinkModel::new(0.0, Some(d), 3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|k| m.latency(0, 1, k)).sum::<f64>() / n as f64;
        assert!((mean - d.mean()).abs() < 0.001, "mean {mean} want {}", d.mean());
    }

    #[test]
    fn measure_calibrates_to_a_sane_model() {
        let mut m = LinkMeasure::new(2);
        for i in 0..50 {
            m.record(0, 0.001 + (i % 5) as f64 * 1e-4);
            m.record(1, 0.0012 + (i % 3) as f64 * 1e-4);
        }
        assert_eq!(m.count(), 100);
        assert!((m.base() - 0.001).abs() < 1e-12);
        let model = m.calibrated(11);
        assert!((model.base - 0.001).abs() < 1e-12);
        let d = model.jitter.expect("spread should produce jitter");
        assert!(d.nonnegative());
        // mean of the fitted model tracks the sample mean
        let sample_mean = 0.001 + (0.0 + 1.0 + 2.0 + 3.0 + 4.0) / 5.0 * 1e-4 / 2.0
            + (0.0002 + (0.0 + 1.0 + 2.0) / 3.0 * 1e-4) / 2.0;
        assert!((model.base + d.mean() - sample_mean).abs() < 1e-5);
        let s = m.summary();
        assert!(s.contains("worker 0") && s.contains("worker 1"));
    }

    #[test]
    fn empty_measure_is_the_zero_model() {
        let m = LinkMeasure::new(3);
        assert_eq!(m.count(), 0);
        assert_eq!(m.base(), 0.0);
        let model = m.calibrated(0);
        assert_eq!(model.latency(0, 1, 0), 0.0);
        assert!(model.jitter.is_none());
    }

    #[test]
    fn constant_measure_has_no_jitter() {
        let mut m = LinkMeasure::new(1);
        for _ in 0..10 {
            m.record(0, 0.002);
        }
        let model = m.calibrated(1);
        assert!(model.jitter.is_none());
        assert_eq!(model.latency(0, 0, 0), 0.002);
    }
}
