//! Compute-time traces: record, save, replay (production-trace stand-in).
//!
//! Real deployments tune straggler policies against recorded cluster
//! traces; none are available offline, so this module closes the loop
//! synthetically: record t_j(k) matrices from any [`StragglerModel`]
//! (or import one written by hand), persist as CSV, and replay it
//! deterministically — so cb-DyBW and every baseline can be compared on
//! the *identical* timing realisation (variance-free A/B, the strongest
//! form of the paper's Fig. 1c comparison).

use std::path::Path;

use super::StragglerModel;
use crate::util::rng::Rng;

/// A recorded timing trace: `times[k][j]` = t_j(k).
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub workers: usize,
    pub times: Vec<Vec<f64>>,
}

impl Trace {
    /// Record `iters` iterations from a model. The real iteration index
    /// is threaded through, so k-dependent effects (outage windows,
    /// diurnal swing) land in the trace; models without them record
    /// exactly what they always did (the index costs no RNG draws).
    pub fn record(model: &StragglerModel, iters: usize, rng: &mut Rng) -> Trace {
        Trace {
            workers: model.n(),
            times: (0..iters).map(|k| model.sample_iteration_at(k, rng)).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.times.len()
    }

    pub fn is_empty(&self) -> bool {
        self.times.is_empty()
    }

    /// CSV: header `k,w0,w1,...`, one row per iteration. Times are
    /// written with f64 Display (shortest-roundtrip), so a save→load
    /// cycle reproduces every time bit for bit.
    pub fn save_csv(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut out = String::from("k");
        for j in 0..self.workers {
            out.push_str(&format!(",w{j}"));
        }
        out.push('\n');
        for (k, row) in self.times.iter().enumerate() {
            out.push_str(&k.to_string());
            for t in row {
                out.push_str(&format!(",{t}"));
            }
            out.push('\n');
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    /// Load a trace, validating rather than panicking on malformed
    /// input: the header must read `k,w0,w1,...` (each column named for
    /// its index — a header/worker-count mismatch is an error), every
    /// data row must have exactly one cell per column (no ragged rows),
    /// and every time must parse as a finite positive number.
    pub fn load_csv(path: &Path) -> anyhow::Result<Trace> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read trace {}: {e}", path.display()))?;
        let mut lines = text.lines();
        let header = lines.next().ok_or_else(|| anyhow::anyhow!("empty trace"))?;
        let cols: Vec<&str> = header.split(',').map(str::trim).collect();
        anyhow::ensure!(
            cols.first() == Some(&"k"),
            "trace header must start with 'k' (got '{header}')"
        );
        let workers = cols.len() - 1;
        anyhow::ensure!(workers > 0, "trace has no worker columns");
        for (j, col) in cols[1..].iter().enumerate() {
            anyhow::ensure!(
                *col == format!("w{j}"),
                "trace header column {} is '{col}', want 'w{j}' — \
                 header does not match its own worker count",
                j + 1
            );
        }
        let mut times = Vec::new();
        for (lineno, line) in lines.enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let cells: Vec<&str> = line.split(',').collect();
            anyhow::ensure!(
                cells.len() == workers + 1,
                "trace line {}: ragged row ({} cells, want {})",
                lineno + 2,
                cells.len(),
                workers + 1
            );
            let row: Vec<f64> = cells[1..]
                .iter()
                .map(|c| c.trim().parse::<f64>())
                .collect::<Result<_, _>>()
                .map_err(|e| anyhow::anyhow!("trace line {}: non-numeric cell: {e}", lineno + 2))?;
            anyhow::ensure!(
                row.iter().all(|&t| t.is_finite() && t > 0.0),
                "trace line {}: non-positive time",
                lineno + 2
            );
            times.push(row);
        }
        anyhow::ensure!(!times.is_empty(), "trace has a header but no data rows");
        Ok(Trace { workers, times })
    }

    /// Per-worker mean compute time.
    pub fn worker_means(&self) -> Vec<f64> {
        let mut m = vec![0.0f64; self.workers];
        for row in &self.times {
            for (acc, t) in m.iter_mut().zip(row) {
                *acc += t;
            }
        }
        let n = self.len().max(1) as f64;
        m.iter_mut().for_each(|v| *v /= n);
        m
    }
}

/// Replays a trace as an iteration-time source (wraps around at the end).
#[derive(Debug, Clone)]
pub struct TraceReplay {
    trace: Trace,
    pos: usize,
}

impl TraceReplay {
    pub fn new(trace: Trace) -> anyhow::Result<Self> {
        anyhow::ensure!(!trace.is_empty(), "cannot replay empty trace");
        Ok(TraceReplay { trace, pos: 0 })
    }

    pub fn next_iteration(&mut self) -> Vec<f64> {
        let row = self.trace.times[self.pos].clone();
        self.pos = (self.pos + 1) % self.trace.len();
        row
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::straggler::Dist;

    fn model(n: usize) -> StragglerModel {
        StragglerModel::homogeneous(n, Dist::ShiftedExp { base: 0.05, rate: 20.0 })
    }

    #[test]
    fn record_shapes() {
        let mut rng = Rng::new(0);
        let t = Trace::record(&model(5), 40, &mut rng);
        assert_eq!(t.workers, 5);
        assert_eq!(t.len(), 40);
        assert!(t.times.iter().flatten().all(|&x| x > 0.0));
    }

    #[test]
    fn record_threads_the_iteration_index() {
        // a diurnal model's trace must actually swing with k
        let mut m = StragglerModel::homogeneous(2, Dist::Deterministic { base: 1.0 });
        m.diurnal_amp = 0.5;
        m.diurnal_period = 4.0;
        let mut rng = Rng::new(4);
        let t = Trace::record(&m, 4, &mut rng);
        assert!((t.times[1][0] - 1.5).abs() < 1e-9, "{:?}", t.times);
        assert!((t.times[3][0] - 0.5).abs() < 1e-9, "{:?}", t.times);
    }

    #[test]
    fn csv_roundtrip_is_bit_exact() {
        let mut rng = Rng::new(1);
        let t = Trace::record(&model(3), 10, &mut rng);
        let dir = std::env::temp_dir().join("dybw_trace_test");
        let path = dir.join("t.csv");
        t.save_csv(&path).unwrap();
        let l = Trace::load_csv(&path).unwrap();
        assert_eq!(t.workers, l.workers);
        assert_eq!(t.len(), l.len());
        // f64 Display is shortest-roundtrip: every time survives exactly
        for (a, b) in t.times.iter().flatten().zip(l.times.iter().flatten()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // and a second save of the loaded trace is byte-identical
        let path2 = dir.join("t2.csv");
        l.save_csv(&path2).unwrap();
        assert_eq!(
            std::fs::read(&path).unwrap(),
            std::fs::read(&path2).unwrap()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_rejects_garbage_with_errors_not_panics() {
        let dir = std::env::temp_dir().join("dybw_trace_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        let cases: &[(&str, &str)] = &[
            ("k,w0,w1\n0,0.5\n", "ragged"),                 // ragged (short) row
            ("k,w0\n0,0.5,0.6\n", "ragged"),                // ragged (long) row
            ("k,w0\n0,-1.0\n", "non-positive"),             // negative time
            ("k,w0\n0,inf\n", "non-positive"),              // non-finite time
            ("k,w0,w1\n0,0.5,abc\n", "non-numeric"),        // non-numeric cell
            ("time,w0\n0,0.5\n", "start with 'k'"),         // bad leading column
            ("k,w0,w5\n0,0.5,0.6\n", "worker count"),       // header/count mismatch
            ("k,w1,w0\n0,0.5,0.6\n", "worker count"),       // shuffled header
            ("k\n0\n", "no worker columns"),                // no workers
            ("k,w0\n", "no data rows"),                     // header only
        ];
        for (text, want) in cases {
            std::fs::write(&path, text).unwrap();
            let err = Trace::load_csv(&path).unwrap_err().to_string();
            assert!(err.contains(want), "input {text:?}: error {err:?} missing {want:?}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn load_missing_file_errors() {
        let p = std::env::temp_dir().join("dybw_trace_definitely_missing.csv");
        assert!(Trace::load_csv(&p).is_err());
    }

    #[test]
    fn replay_wraps_and_is_deterministic() {
        let mut rng = Rng::new(2);
        let t = Trace::record(&model(2), 3, &mut rng);
        let mut r = TraceReplay::new(t.clone()).unwrap();
        let seq: Vec<Vec<f64>> = (0..7).map(|_| r.next_iteration()).collect();
        assert_eq!(seq[0], t.times[0]);
        assert_eq!(seq[3], t.times[0]); // wrapped
        assert_eq!(seq[6], t.times[0]);
    }

    #[test]
    fn worker_means_sane() {
        let mut rng = Rng::new(3);
        let mut m = model(4);
        m.persistent[1] = 10.0;
        let t = Trace::record(&m, 400, &mut rng);
        let means = t.worker_means();
        assert!(means[1] > 5.0 * means[0], "{means:?}");
    }
}
