//! Straggler substrate: per-worker compute-time model t_j(k) (paper §3.2.2).
//!
//! The paper treats the time worker j takes to compute its local update at
//! iteration k as a random variable t_j(k), heterogeneous across workers
//! ("different amount of time due to the different sizes of available
//! local training data") and guarantees "at least one straggler in each
//! iteration" in the experiments (Appendix B). The authors' testbed got
//! this for free from real cluster noise; we simulate it (see DESIGN.md
//! §Substitutions): a per-worker base distribution plus persistent and
//! transient slowdown multipliers.

pub mod link;
pub mod trace;

use crate::util::parse::ParseError;
use crate::util::rng::Rng;

/// A scheduled degradation window: worker `worker` runs `factor`x slower
/// for iterations `from..to` (failure injection for tests/ablations —
/// models a co-located job, thermal throttle, or partial outage).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Outage {
    pub worker: usize,
    pub from: usize,
    pub to: usize,
    pub factor: f64,
}

/// Base compute-time distribution families.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Dist {
    /// Always exactly `base` seconds.
    Deterministic { base: f64 },
    /// Uniform in [lo, hi).
    Uniform { lo: f64, hi: f64 },
    /// base + Exponential(rate) — the classic shifted-exponential
    /// straggler model (Lee et al., coded computation literature).
    ShiftedExp { base: f64, rate: f64 },
    /// Pareto(xm, alpha) — heavy-tailed ("tail at scale").
    Pareto { xm: f64, alpha: f64 },
    /// LogNormal(mu, sigma) of the underlying normal.
    LogNormal { mu: f64, sigma: f64 },
}

impl Dist {
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            Dist::Deterministic { base } => base,
            Dist::Uniform { lo, hi } => rng.uniform_in(lo, hi),
            Dist::ShiftedExp { base, rate } => base + rng.exponential(rate),
            Dist::Pareto { xm, alpha } => rng.pareto(xm, alpha),
            Dist::LogNormal { mu, sigma } => rng.lognormal(mu, sigma),
        }
    }

    pub fn mean(&self) -> f64 {
        match *self {
            Dist::Deterministic { base } => base,
            Dist::Uniform { lo, hi } => 0.5 * (lo + hi),
            Dist::ShiftedExp { base, rate } => base + 1.0 / rate,
            Dist::Pareto { xm, alpha } => {
                if alpha > 1.0 {
                    alpha * xm / (alpha - 1.0)
                } else {
                    f64::INFINITY
                }
            }
            Dist::LogNormal { mu, sigma } => (mu + 0.5 * sigma * sigma).exp(),
        }
    }

    /// Are this distribution's parameters sane AND all samples
    /// guaranteed >= 0? Time-like quantities (compute durations, link
    /// latencies) must never go negative — a negative sample would
    /// schedule simulator events into the past.
    pub fn nonnegative(&self) -> bool {
        match *self {
            Dist::Deterministic { base } => base.is_finite() && base >= 0.0,
            Dist::Uniform { lo, hi } => lo.is_finite() && hi.is_finite() && lo >= 0.0 && hi >= lo,
            Dist::ShiftedExp { base, rate } => {
                base.is_finite() && base >= 0.0 && rate.is_finite() && rate > 0.0
            }
            Dist::Pareto { xm, alpha } => {
                xm.is_finite() && xm > 0.0 && alpha.is_finite() && alpha > 0.0
            }
            Dist::LogNormal { mu, sigma } => mu.is_finite() && sigma.is_finite(),
        }
    }

    /// The spec string [`Self::parse`] accepts back — `parse(spec(d)) ==
    /// Ok(d)` (f64 Display is shortest-roundtrip, so no precision loss).
    pub fn spec(&self) -> String {
        match *self {
            Dist::Deterministic { base } => format!("det:{base}"),
            Dist::Uniform { lo, hi } => format!("uniform:{lo},{hi}"),
            Dist::ShiftedExp { base, rate } => format!("sexp:{base},{rate}"),
            Dist::Pareto { xm, alpha } => format!("pareto:{xm},{alpha}"),
            Dist::LogNormal { mu, sigma } => format!("lognormal:{mu},{sigma}"),
        }
    }

    /// Parse `"det:0.1"`, `"uniform:0.05,0.2"`, `"sexp:0.1,20"`,
    /// `"pareto:0.1,2.5"`, `"lognormal:-2,0.5"`.
    pub fn parse(s: &str) -> Result<Dist, ParseError> {
        const EXPECTED: &str = concat!(
            "det:<base> | uniform:<lo>,<hi> | sexp:<base>,<rate> | ",
            "pareto:<xm>,<alpha> | lognormal:<mu>,<sigma>"
        );
        let err = || ParseError::new("distribution", s, EXPECTED);
        let (kind, rest) = s.split_once(':').ok_or_else(err)?;
        let nums: Vec<f64> = rest
            .split(',')
            .map(|x| x.trim().parse::<f64>())
            .collect::<Result<_, _>>()
            .map_err(|_| err())?;
        Ok(match (kind, nums.as_slice()) {
            ("det", [b]) => Dist::Deterministic { base: *b },
            ("uniform", [lo, hi]) => Dist::Uniform { lo: *lo, hi: *hi },
            ("sexp", [b, r]) => Dist::ShiftedExp { base: *b, rate: *r },
            ("pareto", [xm, a]) => Dist::Pareto { xm: *xm, alpha: *a },
            ("lognormal", [mu, s]) => Dist::LogNormal { mu: *mu, sigma: *s },
            _ => return Err(err()),
        })
    }
}

/// The full per-worker straggler model.
#[derive(Debug, Clone)]
pub struct StragglerModel {
    /// Base distribution, common shape for all workers.
    pub base: Dist,
    /// Per-worker speed multiplier (data-size heterogeneity). 1.0 = nominal.
    pub worker_scale: Vec<f64>,
    /// Persistent stragglers: worker -> extra multiplier (e.g. 4x slower).
    pub persistent: Vec<f64>,
    /// Probability that any given worker transiently straggles this iteration.
    pub transient_prob: f64,
    /// Multiplier applied to a transient straggler's draw.
    pub transient_factor: f64,
    /// Force at least one transient straggler every iteration (Appendix B:
    /// "we assume that there exists at least one straggler in each
    /// iteration").
    pub force_one_straggler: bool,
    /// Scheduled degradation windows (failure injection).
    pub outages: Vec<Outage>,
    /// Diurnal load swing: every draw at iteration k is multiplied by
    /// `1 + diurnal_amp · sin(2πk / diurnal_period)` (shared-cluster
    /// day/night interference). Amplitude must stay in [0, 1) so times
    /// remain positive; 0 disables. Applies only when the iteration
    /// index is known ([`Self::sample_iteration_at`]).
    pub diurnal_amp: f64,
    /// Period of the diurnal swing in iterations (0 disables).
    pub diurnal_period: f64,
}

impl StragglerModel {
    /// Homogeneous model: same distribution everywhere, no injection.
    pub fn homogeneous(n: usize, base: Dist) -> Self {
        StragglerModel {
            base,
            worker_scale: vec![1.0; n],
            persistent: vec![1.0; n],
            transient_prob: 0.0,
            transient_factor: 1.0,
            force_one_straggler: false,
            outages: Vec::new(),
            diurnal_amp: 0.0,
            diurnal_period: 0.0,
        }
    }

    /// The paper-like default: mild heterogeneity + forced transient
    /// straggler each iteration with `factor`x slowdown.
    pub fn paper_default(n: usize, rng: &mut Rng) -> Self {
        let worker_scale: Vec<f64> = (0..n).map(|_| rng.uniform_in(0.8, 1.25)).collect();
        StragglerModel {
            base: Dist::ShiftedExp { base: 0.08, rate: 25.0 },
            worker_scale,
            persistent: vec![1.0; n],
            transient_prob: 0.15,
            transient_factor: 4.0,
            force_one_straggler: true,
            outages: Vec::new(),
            diurnal_amp: 0.0,
            diurnal_period: 0.0,
        }
    }

    pub fn n(&self) -> usize {
        self.worker_scale.len()
    }

    /// Mark worker `w` as persistently `factor`x slower.
    pub fn with_persistent(mut self, w: usize, factor: f64) -> Self {
        self.persistent[w] = factor;
        self
    }

    /// Draw the compute-time vector t_·(k) for one iteration (no outage
    /// windows applied — use [`Self::sample_iteration_at`] when the
    /// iteration index matters).
    pub fn sample_iteration(&self, rng: &mut Rng) -> Vec<f64> {
        self.sample_iteration_at(usize::MAX, rng)
    }

    /// The multiplicative diurnal swing at iteration `k` (1.0 when the
    /// swing is disabled or the iteration index is unknown). Pure in
    /// `k` — no RNG draws — so enabling it never shifts the stream.
    pub fn diurnal_factor(&self, k: usize) -> f64 {
        if self.diurnal_amp <= 0.0 || self.diurnal_period <= 0.0 || k == usize::MAX {
            return 1.0;
        }
        1.0 + self.diurnal_amp * (std::f64::consts::TAU * k as f64 / self.diurnal_period).sin()
    }

    /// Draw t_·(k) for iteration `k`, applying any scheduled [`Outage`]
    /// whose window contains `k`, plus the diurnal swing.
    pub fn sample_iteration_at(&self, k: usize, rng: &mut Rng) -> Vec<f64> {
        let n = self.n();
        let diurnal = self.diurnal_factor(k);
        let mut transient = vec![false; n];
        for t in transient.iter_mut() {
            *t = rng.uniform() < self.transient_prob;
        }
        if self.force_one_straggler && !transient.iter().any(|&t| t) && n > 0 {
            transient[rng.below(n)] = true;
        }
        (0..n)
            .map(|j| {
                let mut t = self.base.sample(rng) * self.worker_scale[j] * self.persistent[j];
                if transient[j] {
                    t *= self.transient_factor;
                }
                for o in &self.outages {
                    if o.worker == j && (o.from..o.to).contains(&k) {
                        t *= o.factor;
                    }
                }
                t * diurnal
            })
            .collect()
    }

    /// Expected nominal (non-straggling) compute time of worker j.
    pub fn nominal_mean(&self, j: usize) -> f64 {
        self.base.mean() * self.worker_scale[j] * self.persistent[j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        assert_eq!(Dist::parse("det:0.5"), Ok(Dist::Deterministic { base: 0.5 }));
        assert_eq!(
            Dist::parse("sexp:0.1,20"),
            Ok(Dist::ShiftedExp { base: 0.1, rate: 20.0 })
        );
        assert_eq!(
            Dist::parse("pareto:1,2"),
            Ok(Dist::Pareto { xm: 1.0, alpha: 2.0 })
        );
        for bad in ["bogus:1", "det:a", "det", "", "sexp:0.1", "det:1,2"] {
            let err = Dist::parse(bad).unwrap_err();
            assert_eq!(err.what, "distribution", "input: {bad}");
            assert_eq!(err.input, bad);
        }
    }

    #[test]
    fn nonnegative_flags_bad_time_dists() {
        assert!(Dist::Deterministic { base: 0.0 }.nonnegative());
        assert!(!Dist::Deterministic { base: -0.1 }.nonnegative());
        assert!(!Dist::Uniform { lo: -0.05, hi: 0.2 }.nonnegative());
        assert!(!Dist::Uniform { lo: 0.2, hi: 0.1 }.nonnegative());
        assert!(!Dist::ShiftedExp { base: 0.1, rate: 0.0 }.nonnegative());
        assert!(!Dist::Pareto { xm: 0.0, alpha: 2.0 }.nonnegative());
        assert!(!Dist::Pareto { xm: f64::INFINITY, alpha: 2.0 }.nonnegative());
        assert!(!Dist::ShiftedExp { base: 0.1, rate: f64::INFINITY }.nonnegative());
        assert!(Dist::LogNormal { mu: -2.0, sigma: 0.5 }.nonnegative());
        assert!(!Dist::LogNormal { mu: f64::NAN, sigma: 0.5 }.nonnegative());
    }

    #[test]
    fn spec_inverts_parse_for_every_family() {
        for d in [
            Dist::Deterministic { base: 0.125 },
            Dist::Uniform { lo: 0.05, hi: 0.2 },
            Dist::ShiftedExp { base: 0.08, rate: 25.0 },
            Dist::Pareto { xm: 0.1, alpha: 2.5 },
            Dist::LogNormal { mu: -2.0, sigma: 0.5 },
        ] {
            assert_eq!(Dist::parse(&d.spec()), Ok(d), "spec: {}", d.spec());
        }
    }

    #[test]
    fn shifted_exp_mean() {
        let d = Dist::ShiftedExp { base: 0.1, rate: 10.0 };
        let mut rng = Rng::new(0);
        let n = 100_000;
        let m: f64 = (0..n).map(|_| d.sample(&mut rng)).sum::<f64>() / n as f64;
        assert!((m - d.mean()).abs() < 0.005, "m={m} want={}", d.mean());
    }

    #[test]
    fn samples_positive() {
        let mut rng = Rng::new(1);
        for d in [
            Dist::Deterministic { base: 0.2 },
            Dist::Uniform { lo: 0.1, hi: 0.3 },
            Dist::ShiftedExp { base: 0.05, rate: 5.0 },
            Dist::Pareto { xm: 0.1, alpha: 2.0 },
            Dist::LogNormal { mu: -2.0, sigma: 0.5 },
        ] {
            for _ in 0..1000 {
                assert!(d.sample(&mut rng) > 0.0);
            }
        }
    }

    #[test]
    fn forced_straggler_present_every_iteration() {
        let mut rng = Rng::new(2);
        let mut model = StragglerModel::homogeneous(6, Dist::Deterministic { base: 0.1 });
        model.force_one_straggler = true;
        model.transient_factor = 5.0;
        for _ in 0..200 {
            let ts = model.sample_iteration(&mut rng);
            let slow = ts.iter().filter(|&&t| t > 0.4).count();
            assert!(slow >= 1, "no straggler injected: {ts:?}");
        }
    }

    #[test]
    fn persistent_straggler_slower_on_average() {
        let mut rng = Rng::new(3);
        let model = StragglerModel::homogeneous(4, Dist::Uniform { lo: 0.1, hi: 0.2 })
            .with_persistent(2, 6.0);
        let mut sums = vec![0.0f64; 4];
        for _ in 0..2000 {
            for (s, t) in sums.iter_mut().zip(model.sample_iteration(&mut rng)) {
                *s += t;
            }
        }
        assert!(sums[2] > 4.0 * sums[0]);
        assert!((model.nominal_mean(2) / model.nominal_mean(0) - 6.0).abs() < 1e-9);
    }

    #[test]
    fn heterogeneous_scales_respected() {
        let mut rng = Rng::new(4);
        let model = StragglerModel::paper_default(6, &mut rng);
        assert_eq!(model.n(), 6);
        for j in 0..6 {
            assert!(model.worker_scale[j] >= 0.8 && model.worker_scale[j] <= 1.25);
        }
    }

    #[test]
    fn deterministic_no_injection_constant() {
        let mut rng = Rng::new(5);
        let model = StragglerModel::homogeneous(3, Dist::Deterministic { base: 0.25 });
        let ts = model.sample_iteration(&mut rng);
        assert_eq!(ts, vec![0.25; 3]);
    }

    #[test]
    fn diurnal_swing_modulates_deterministically() {
        let mut model = StragglerModel::homogeneous(2, Dist::Deterministic { base: 1.0 });
        model.diurnal_amp = 0.5;
        model.diurnal_period = 4.0;
        let mut rng = Rng::new(7);
        // sin(2πk/4) over k = 0..4: 0, +1, 0, −1
        let want = [1.0, 1.5, 1.0, 0.5];
        for (k, w) in want.iter().enumerate() {
            let ts = model.sample_iteration_at(k, &mut rng);
            assert!((ts[0] - w).abs() < 1e-9, "k={k}: {} want {w}", ts[0]);
            assert!(ts.iter().all(|&t| t > 0.0));
        }
        // unknown iteration index (sample_iteration): swing off
        assert_eq!(model.sample_iteration(&mut rng), vec![1.0; 2]);
        assert_eq!(model.diurnal_factor(usize::MAX), 1.0);
        // disabled swing is exactly 1 at every k
        model.diurnal_amp = 0.0;
        assert_eq!(model.diurnal_factor(3), 1.0);
    }

    #[test]
    fn outage_window_applies_only_inside() {
        let mut rng = Rng::new(6);
        let mut model = StragglerModel::homogeneous(3, Dist::Deterministic { base: 0.1 });
        model.outages.push(Outage {
            worker: 1,
            from: 10,
            to: 20,
            factor: 50.0,
        });
        let before = model.sample_iteration_at(9, &mut rng);
        let during = model.sample_iteration_at(15, &mut rng);
        let after = model.sample_iteration_at(20, &mut rng);
        assert_eq!(before[1], 0.1);
        assert_eq!(during[1], 5.0);
        assert_eq!(after[1], 0.1);
        assert_eq!(during[0], 0.1); // others untouched
    }
}
