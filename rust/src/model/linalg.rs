//! Blocked single-precision GEMM kernels for the native engine.
//!
//! The native engine (rust/src/model/{lrm,mlp}.rs) is the pure-Rust oracle
//! and fallback for the PJRT artifacts; its hot loops are these three
//! GEMM variants (NN, TN, NT — all row-major). They use i-k-j loop order
//! with a register-blocked inner loop the autovectoriser lifts to AVX,
//! and shard the independent output-row ranges across scoped threads once
//! the problem passes `PAR_FLOPS` (perf pass, EXPERIMENTS.md §Perf: the
//! 2NN gradient went 16.4 ms → ~4 ms on this machine).

/// Parallelise above this many multiply-adds (empirically where thread
/// spawn cost is < 5% of the kernel).
const PAR_FLOPS: usize = 1 << 21;

thread_local! {
    /// Max scoped threads a GEMM issued from THIS thread may use
    /// (0 = uncapped). Engine-pool lanes set `cores / lanes` so
    /// lane-level and kernel-level parallelism compose to roughly the
    /// machine width instead of oversubscribing (T lanes × 8 kernel
    /// threads), while a 1-lane pool keeps the full pre-pool kernel
    /// parallelism.
    static INTRA_OP_CAP: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

/// Cap intra-kernel (scoped-thread) GEMM parallelism for the calling
/// thread; `0` removes the cap, `1` forces single-threaded kernels. Has
/// no effect on results — row shards are independent outputs, so the
/// kernels are bit-identical at any thread count.
pub fn set_intra_op_cap(cap: usize) {
    INTRA_OP_CAP.with(|f| f.set(cap));
}

fn threads_for(flops: usize) -> usize {
    if flops < PAR_FLOPS {
        return 1;
    }
    let t = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(8);
    match INTRA_OP_CAP.with(|f| f.get()) {
        0 => t,
        cap => t.min(cap),
    }
}

/// Split `c` into `parts` row-chunks of `row_len` and run `f(chunk_index_range, chunk)`.
fn par_rows<F>(c: &mut [f32], rows: usize, row_len: usize, parts: usize, f: F)
where
    F: Fn(std::ops::Range<usize>, &mut [f32]) + Sync,
{
    debug_assert_eq!(c.len(), rows * row_len);
    if parts <= 1 || rows < 2 * parts {
        f(0..rows, c);
        return;
    }
    let chunk_rows = rows.div_ceil(parts);
    std::thread::scope(|s| {
        let mut rest = c;
        let mut start = 0usize;
        while start < rows {
            let take = chunk_rows.min(rows - start);
            let (head, tail) = rest.split_at_mut(take * row_len);
            let range = start..start + take;
            let fref = &f;
            s.spawn(move || fref(range, head));
            rest = tail;
            start += take;
        }
    });
}

/// c[m,n] += a[m,k] · b[k,n]   (row-major, accumulate)
pub fn gemm_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let parts = threads_for(m * k * n);
    par_rows(c, m, n, parts, |rows, cc| {
        // 4-row register blocking: each pass over a B row feeds four
        // output rows, quartering B traffic (the kernel is B-bandwidth
        // bound once B falls out of L1).
        let mut iter = rows.clone();
        let base = rows.start;
        while iter.len() >= 4 {
            let i = iter.start;
            iter = (i + 4)..rows.end;
            let (a0, a1, a2, a3) = (
                &a[i * k..(i + 1) * k],
                &a[(i + 1) * k..(i + 2) * k],
                &a[(i + 2) * k..(i + 3) * k],
                &a[(i + 3) * k..(i + 4) * k],
            );
            let ci = i - base;
            let (c01, c23) = cc[ci * n..(ci + 4) * n].split_at_mut(2 * n);
            let (c0, c1) = c01.split_at_mut(n);
            let (c2, c3) = c23.split_at_mut(n);
            for l in 0..k {
                let (v0, v1, v2, v3) = (a0[l], a1[l], a2[l], a3[l]);
                let brow = &b[l * n..(l + 1) * n];
                // (a zip-based variant measured ~5% slower — see
                //  EXPERIMENTS.md §Perf iteration 4; indexed form kept)
                for j in 0..n {
                    let bv = brow[j];
                    c0[j] += v0 * bv;
                    c1[j] += v1 * bv;
                    c2[j] += v2 * bv;
                    c3[j] += v3 * bv;
                }
            }
        }
        for i in iter {
            let arow = &a[i * k..(i + 1) * k];
            let ci = i - base;
            let crow = &mut cc[ci * n..(ci + 1) * n];
            for (l, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[l * n..(l + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    });
}

/// c[k,n] += aᵀ[k,m] · b[m,n]  where a is [m,k] row-major (i.e. c = aᵀ·b)
///
/// Parallel over output rows l (columns of a): each shard rescans a and b
/// but writes a disjoint slice of c — b stays L2/L3-resident.
pub fn gemm_tn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(c.len(), k * n);
    let parts = threads_for(m * k * n);
    par_rows(c, k, n, parts, |lrange, cc| {
        let l0 = lrange.start;
        // 4-way blocking over input rows i: four (arow, brow) pairs per
        // sweep of the output, quartering C read/write traffic (the TN
        // bound — C is revisited once per input row otherwise).
        let mut i = 0usize;
        while i + 4 <= m {
            let (a0, a1, a2, a3) = (
                &a[i * k..(i + 1) * k],
                &a[(i + 1) * k..(i + 2) * k],
                &a[(i + 2) * k..(i + 3) * k],
                &a[(i + 3) * k..(i + 4) * k],
            );
            let (b0, b1, b2, b3) = (
                &b[i * n..(i + 1) * n],
                &b[(i + 1) * n..(i + 2) * n],
                &b[(i + 2) * n..(i + 3) * n],
                &b[(i + 3) * n..(i + 4) * n],
            );
            for l in lrange.clone() {
                let (v0, v1, v2, v3) = (a0[l], a1[l], a2[l], a3[l]);
                let crow = &mut cc[(l - l0) * n..(l - l0 + 1) * n];
                for j in 0..n {
                    crow[j] += v0 * b0[j] + v1 * b1[j] + v2 * b2[j] + v3 * b3[j];
                }
            }
            i += 4;
        }
        for i in i..m {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * n..(i + 1) * n];
            for l in lrange.clone() {
                let av = arow[l];
                if av == 0.0 {
                    continue;
                }
                let crow = &mut cc[(l - l0) * n..(l - l0 + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    });
}

/// c[m,k] += a[m,n] · bᵀ[n,k]  where b is [k,n] row-major (i.e. c = a·bᵀ)
pub fn gemm_nt(m: usize, n: usize, k: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    debug_assert_eq!(a.len(), m * n);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * k);
    let parts = threads_for(m * k * n);
    par_rows(c, m, k, parts, |rows, cc| {
        // 4-way blocking over output rows i: each B row is dotted against
        // four A rows per load, quartering B traffic.
        let base = rows.start;
        let mut i = rows.start;
        while i + 4 <= rows.end {
            let (a0, a1, a2, a3) = (
                &a[i * n..(i + 1) * n],
                &a[(i + 1) * n..(i + 2) * n],
                &a[(i + 2) * n..(i + 3) * n],
                &a[(i + 3) * n..(i + 4) * n],
            );
            let ci = i - base;
            for l in 0..k {
                let brow = &b[l * n..(l + 1) * n];
                let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                for j in 0..n {
                    let bv = brow[j];
                    s0 += a0[j] * bv;
                    s1 += a1[j] * bv;
                    s2 += a2[j] * bv;
                    s3 += a3[j] * bv;
                }
                cc[ci * k + l] += s0;
                cc[(ci + 1) * k + l] += s1;
                cc[(ci + 2) * k + l] += s2;
                cc[(ci + 3) * k + l] += s3;
            }
            i += 4;
        }
        for i in i..rows.end {
            let arow = &a[i * n..(i + 1) * n];
            let ci = i - base;
            let crow = &mut cc[ci * k..(ci + 1) * k];
            for (l, cv) in crow.iter_mut().enumerate() {
                let brow = &b[l * n..(l + 1) * n];
                let mut acc = 0.0f32;
                for (av, bv) in arow.iter().zip(brow) {
                    acc += av * bv;
                }
                *cv += acc;
            }
        }
    });
}

/// Row-wise stable softmax in place over [rows, cols].
pub fn softmax_rows(rows: usize, cols: usize, z: &mut [f32]) {
    debug_assert_eq!(z.len(), rows * cols);
    for r in 0..rows {
        let row = &mut z[r * cols..(r + 1) * cols];
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_mat(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn naive_nn(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for l in 0..k {
                    acc += a[i * k + l] as f64 * b[l * n + j] as f64;
                }
                c[i * n + j] = acc as f32;
            }
        }
        c
    }

    #[test]
    fn gemm_nn_matches_naive() {
        let mut rng = Rng::new(0);
        let (m, k, n) = (13, 7, 9);
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, k * n);
        let mut c = vec![0.0f32; m * n];
        gemm_nn(m, k, n, &a, &b, &mut c);
        let want = naive_nn(m, k, n, &a, &b);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gemm_tn_matches_transposed_naive() {
        let mut rng = Rng::new(1);
        let (m, k, n) = (11, 6, 8);
        let a = rand_mat(&mut rng, m * k); // [m,k]
        let b = rand_mat(&mut rng, m * n); // [m,n]
        let mut c = vec![0.0f32; k * n];
        gemm_tn(m, k, n, &a, &b, &mut c);
        // naive: transpose a then nn
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for l in 0..k {
                at[l * m + i] = a[i * k + l];
            }
        }
        let want = naive_nn(k, m, n, &at, &b);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn gemm_nt_matches_transposed_naive() {
        let mut rng = Rng::new(2);
        let (m, n, k) = (10, 5, 7);
        let a = rand_mat(&mut rng, m * n); // [m,n]
        let b = rand_mat(&mut rng, k * n); // [k,n]
        let mut c = vec![0.0f32; m * k];
        gemm_nt(m, n, k, &a, &b, &mut c);
        let mut bt = vec![0.0f32; n * k];
        for i in 0..k {
            for l in 0..n {
                bt[l * k + i] = b[i * n + l];
            }
        }
        let want = naive_nn(m, n, k, &a, &bt);
        for (x, y) in c.iter().zip(&want) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn intra_op_toggle_is_bit_identical() {
        // Large enough to clear PAR_FLOPS so the parallel path engages.
        let (m, k, n) = (64, 64, 512);
        let mut rng = Rng::new(41);
        let a = rand_mat(&mut rng, m * k);
        let b = rand_mat(&mut rng, k * n);
        let mut c_par = vec![0.0f32; m * n];
        gemm_nn(m, k, n, &a, &b, &mut c_par);
        set_intra_op_cap(1);
        let mut c_seq = vec![0.0f32; m * n];
        gemm_nn(m, k, n, &a, &b, &mut c_seq);
        set_intra_op_cap(0);
        assert_eq!(c_par, c_seq);
    }

    #[test]
    fn gemm_accumulates() {
        let a = vec![1.0f32, 0.0, 0.0, 1.0];
        let b = vec![2.0f32, 0.0, 0.0, 2.0];
        let mut c = vec![1.0f32; 4];
        gemm_nn(2, 2, 2, &a, &b, &mut c);
        assert_eq!(c, vec![3.0, 1.0, 1.0, 3.0]);
    }

    #[test]
    fn softmax_rows_sum_to_one_and_stable() {
        let mut z = vec![1e4f32, 0.0, -1e4, 1.0, 2.0, 3.0];
        softmax_rows(2, 3, &mut z);
        for r in 0..2 {
            let s: f32 = z[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(z[r * 3..(r + 1) * 3].iter().all(|v| v.is_finite()));
        }
        assert!(z[0] > 0.999); // extreme logit wins
    }
}
