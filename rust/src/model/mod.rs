//! Model substrate: parameter layouts + native (pure-Rust) engines.
//!
//! Mirrors python/compile/model.py exactly: the same flat `f32[P]`
//! parameter vector, the same segment order, the same init rules. The
//! native LRM/MLP2 implementations serve three roles: (1) correctness
//! oracle for the PJRT artifacts (cross-checked in rust/tests), (2) fast
//! engine for simulation-heavy benches where PJRT dispatch would dominate,
//! (3) fallback when `artifacts/` has not been built.
//!
//! The transformer exists only as a PJRT artifact — re-deriving its
//! backward pass natively would duplicate the Layer-2 JAX autodiff it
//! exists to exercise (see DESIGN.md §Inventory).

pub mod linalg;
pub mod lrm;
pub mod mlp;

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Init kinds, matching python `Segment.init` strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Init {
    GlorotUniform,
    Zeros,
    NormalScaled,
}

impl Init {
    pub fn parse(s: &str) -> Option<Init> {
        Some(match s {
            "glorot_uniform" => Init::GlorotUniform,
            "zeros" => Init::Zeros,
            "normal_scaled" => Init::NormalScaled,
            _ => return None,
        })
    }
}

/// One named tensor inside the flat parameter vector.
#[derive(Debug, Clone, PartialEq)]
pub struct Segment {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
    pub init: Init,
}

/// Model kind tags.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ModelKind {
    Lrm,
    Mlp2,
    Transformer,
}

impl ModelKind {
    pub fn parse(s: &str) -> Option<ModelKind> {
        Some(match s {
            "lrm" => ModelKind::Lrm,
            "mlp2" => ModelKind::Mlp2,
            "transformer" => ModelKind::Transformer,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Lrm => "lrm",
            ModelKind::Mlp2 => "mlp2",
            ModelKind::Transformer => "transformer",
        }
    }
}

/// Static model description — the Rust mirror of python `ModelSpec` plus
/// its derived `ParamLayout`. Constructed directly or parsed from an
/// artifact `.meta.json`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub kind: ModelKind,
    pub batch: usize,
    pub dim: usize,
    pub classes: usize,
    pub hidden: usize,
    pub vocab: usize,
    pub seq: usize,
    pub param_count: usize,
    pub segments: Vec<Segment>,
}

impl ModelMeta {
    pub fn lrm(dim: usize, classes: usize, batch: usize) -> ModelMeta {
        let segments = layout(&[
            ("w", vec![dim, classes], Init::GlorotUniform),
            ("b", vec![classes], Init::Zeros),
        ]);
        ModelMeta {
            name: format!("lrm_d{dim}_c{classes}_b{batch}"),
            kind: ModelKind::Lrm,
            batch,
            dim,
            classes,
            hidden: 0,
            vocab: 0,
            seq: 0,
            param_count: segments.iter().map(|s| s.size).sum(),
            segments,
        }
    }

    pub fn mlp2(dim: usize, hidden: usize, classes: usize, batch: usize) -> ModelMeta {
        let segments = layout(&[
            ("w1", vec![dim, hidden], Init::GlorotUniform),
            ("b1", vec![hidden], Init::Zeros),
            ("w2", vec![hidden, hidden], Init::GlorotUniform),
            ("b2", vec![hidden], Init::Zeros),
            ("w3", vec![hidden, classes], Init::GlorotUniform),
            ("b3", vec![classes], Init::Zeros),
        ]);
        ModelMeta {
            name: format!("mlp2_d{dim}_h{hidden}_c{classes}_b{batch}"),
            kind: ModelKind::Mlp2,
            batch,
            dim,
            classes,
            hidden,
            vocab: 0,
            seq: 0,
            param_count: segments.iter().map(|s| s.size).sum(),
            segments,
        }
    }

    /// Parse an artifact `.meta.json` produced by python/compile/aot.py.
    pub fn from_json(j: &Json) -> anyhow::Result<ModelMeta> {
        let get_usize = |key: &str| -> usize {
            j.get(key).and_then(|v| v.as_usize()).unwrap_or(0)
        };
        let kind_s = j
            .get("kind")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("meta missing 'kind'"))?;
        let kind = ModelKind::parse(kind_s)
            .ok_or_else(|| anyhow::anyhow!("unknown model kind '{kind_s}'"))?;
        let mut segments = Vec::new();
        for seg in j
            .get("segments")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("meta missing 'segments'"))?
        {
            let name = seg
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("segment missing name"))?
                .to_string();
            let shape: Vec<usize> = seg
                .get("shape")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow::anyhow!("segment missing shape"))?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect();
            let init_s = seg.get("init").and_then(|v| v.as_str()).unwrap_or("zeros");
            segments.push(Segment {
                name,
                shape: shape.clone(),
                offset: seg.get("offset").and_then(|v| v.as_usize()).unwrap_or(0),
                size: seg.get("size").and_then(|v| v.as_usize()).unwrap_or(0),
                init: Init::parse(init_s)
                    .ok_or_else(|| anyhow::anyhow!("unknown init '{init_s}'"))?,
            });
        }
        let meta = ModelMeta {
            name: j
                .get("name")
                .and_then(|v| v.as_str())
                .unwrap_or("unnamed")
                .to_string(),
            kind,
            batch: get_usize("batch"),
            dim: get_usize("dim"),
            classes: get_usize("classes"),
            hidden: get_usize("hidden"),
            vocab: get_usize("vocab"),
            seq: get_usize("seq"),
            param_count: get_usize("param_count"),
            segments,
        };
        meta.validate()?;
        Ok(meta)
    }

    /// Internal consistency: segments tile [0, param_count) exactly.
    pub fn validate(&self) -> anyhow::Result<()> {
        let mut off = 0usize;
        for s in &self.segments {
            anyhow::ensure!(
                s.offset == off,
                "segment {} offset {} != expected {off}",
                s.name,
                s.offset
            );
            anyhow::ensure!(
                s.size == s.shape.iter().product::<usize>(),
                "segment {} size mismatch",
                s.name
            );
            off += s.size;
        }
        anyhow::ensure!(
            off == self.param_count,
            "segments tile {off} != param_count {}",
            self.param_count
        );
        Ok(())
    }

    pub fn segment(&self, name: &str) -> &Segment {
        self.segments
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("no segment '{name}'"))
    }

    /// View a named segment inside a flat parameter vector.
    pub fn slice<'a>(&self, flat: &'a [f32], name: &str) -> &'a [f32] {
        let s = self.segment(name);
        &flat[s.offset..s.offset + s.size]
    }

    pub fn slice_mut<'a>(&self, flat: &'a mut [f32], name: &str) -> &'a mut [f32] {
        let s = self.segment(name);
        &mut flat[s.offset..s.offset + s.size]
    }

    /// Initialise a fresh flat parameter vector (same rules as python).
    pub fn init_params(&self, rng: &mut Rng) -> Vec<f32> {
        let mut out = vec![0.0f32; self.param_count];
        for s in &self.segments {
            let span = &mut out[s.offset..s.offset + s.size];
            match s.init {
                Init::Zeros => {}
                Init::GlorotUniform => {
                    let fan_in = if s.shape.len() > 1 { s.shape[0] } else { s.size };
                    let fan_out = *s.shape.last().unwrap();
                    let lim = (6.0 / (fan_in + fan_out) as f64).sqrt();
                    for v in span.iter_mut() {
                        *v = rng.uniform_in(-lim, lim) as f32;
                    }
                }
                Init::NormalScaled => {
                    let scale = 1.0 / (*s.shape.last().unwrap() as f64).sqrt();
                    for v in span.iter_mut() {
                        *v = (rng.normal() * scale) as f32;
                    }
                }
            }
        }
        out
    }
}

fn layout(specs: &[(&str, Vec<usize>, Init)]) -> Vec<Segment> {
    let mut out = Vec::with_capacity(specs.len());
    let mut off = 0usize;
    for (name, shape, init) in specs {
        let size: usize = shape.iter().product();
        out.push(Segment {
            name: name.to_string(),
            shape: shape.clone(),
            offset: off,
            size,
            init: *init,
        });
        off += size;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lrm_layout_matches_python() {
        let m = ModelMeta::lrm(8, 4, 16);
        assert_eq!(m.param_count, 36);
        assert_eq!(m.segment("w").offset, 0);
        assert_eq!(m.segment("b").offset, 32);
        m.validate().unwrap();
    }

    #[test]
    fn mlp2_layout_matches_python() {
        // mirror of mlp2_d64_h256_c10: 64*256+256+256*256+256+256*10+10
        let m = ModelMeta::mlp2(64, 256, 10, 256);
        assert_eq!(m.param_count, 64 * 256 + 256 + 256 * 256 + 256 + 256 * 10 + 10);
        assert_eq!(m.param_count, 85002); // cross-checked against python
        m.validate().unwrap();
    }

    #[test]
    fn init_respects_kinds() {
        let m = ModelMeta::lrm(10, 5, 4);
        let p = m.init_params(&mut Rng::new(0));
        let w = m.slice(&p, "w");
        let b = m.slice(&p, "b");
        assert!(w.iter().any(|&v| v != 0.0));
        assert!(b.iter().all(|&v| v == 0.0));
        let lim = (6.0f64 / 15.0).sqrt() as f32;
        assert!(w.iter().all(|&v| v.abs() <= lim));
    }

    #[test]
    fn init_deterministic() {
        let m = ModelMeta::mlp2(6, 8, 3, 4);
        let a = m.init_params(&mut Rng::new(9));
        let b = m.init_params(&mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn from_json_parses_aot_meta() {
        let src = r#"{
            "name": "lrm_d8_c4_b16", "kind": "lrm", "batch": 16,
            "dim": 8, "classes": 4, "hidden": 0, "vocab": 0, "seq": 0,
            "d_model": 0, "n_heads": 0, "n_layers": 0,
            "param_count": 36,
            "segments": [
                {"name": "w", "shape": [8, 4], "offset": 0, "size": 32, "init": "glorot_uniform"},
                {"name": "b", "shape": [4], "offset": 32, "size": 4, "init": "zeros"}
            ],
            "x_shape": [16, 8], "x_dtype": "float32",
            "y_shape": [16, 4], "y_dtype": "float32"
        }"#;
        let j = Json::parse(src).unwrap();
        let m = ModelMeta::from_json(&j).unwrap();
        assert_eq!(m.kind, ModelKind::Lrm);
        assert_eq!(m.param_count, 36);
        assert_eq!(m.segments.len(), 2);
    }

    #[test]
    fn from_json_rejects_bad_offsets() {
        let src = r#"{
            "name": "x", "kind": "lrm", "batch": 1, "dim": 2, "classes": 2,
            "param_count": 6,
            "segments": [
                {"name": "w", "shape": [2, 2], "offset": 1, "size": 4, "init": "zeros"},
                {"name": "b", "shape": [2], "offset": 4, "size": 2, "init": "zeros"}
            ]
        }"#;
        let j = Json::parse(src).unwrap();
        assert!(ModelMeta::from_json(&j).is_err());
    }
}
