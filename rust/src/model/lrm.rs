//! Native logistic-regression engine (paper's LRM workload).
//!
//! Same math as the Layer-2 JAX model: z = xW + b, mean cross-entropy on
//! one-hot labels, gradient dz = (softmax(z) - y)/B. Exact agreement with
//! the PJRT artifact is asserted in rust/tests/runtime_pjrt.rs.

use super::{linalg, ModelMeta};
use crate::data::batch::Batch;

/// Reusable scratch buffers (no allocation on the grad hot path).
#[derive(Debug, Clone, Default)]
pub struct LrmScratch {
    z: Vec<f32>,
}

/// Compute mean loss and gradient into `grad` (len = param_count).
pub fn grad(
    meta: &ModelMeta,
    w_flat: &[f32],
    batch: &Batch,
    grad_out: &mut [f32],
    scratch: &mut LrmScratch,
) -> f32 {
    let (b, d, c) = (batch.bsz, meta.dim, meta.classes);
    debug_assert_eq!(batch.dim, d);
    debug_assert_eq!(w_flat.len(), meta.param_count);
    debug_assert_eq!(grad_out.len(), meta.param_count);
    let w = meta.slice(w_flat, "w");
    let bias = meta.slice(w_flat, "b");

    scratch.z.clear();
    scratch.z.resize(b * c, 0.0);
    let z = &mut scratch.z;
    // z = x·W + bias
    linalg::gemm_nn(b, d, c, &batch.x, w, z);
    for r in 0..b {
        for (zc, bc) in z[r * c..(r + 1) * c].iter_mut().zip(bias) {
            *zc += *bc;
        }
    }
    // loss before softmax overwrites z
    let loss = xent_loss(b, c, z, &batch.y1h);
    // dz = (softmax(z) - y)/B, computed in place
    linalg::softmax_rows(b, c, z);
    let inv_b = 1.0 / b as f32;
    for (zv, yv) in z.iter_mut().zip(&batch.y1h) {
        *zv = (*zv - *yv) * inv_b;
    }
    // gW = xᵀ·dz ; gb = Σ_rows dz
    grad_out.fill(0.0);
    {
        let (gw, gb) = grad_out.split_at_mut(meta.segment("b").offset);
        linalg::gemm_tn(b, d, c, &batch.x, z, gw);
        for r in 0..b {
            for (g, dzv) in gb.iter_mut().zip(&z[r * c..(r + 1) * c]) {
                *g += *dzv;
            }
        }
    }
    loss
}

/// Mean loss + correct-prediction count (no gradient).
pub fn eval(
    meta: &ModelMeta,
    w_flat: &[f32],
    batch: &Batch,
    scratch: &mut LrmScratch,
) -> (f32, usize) {
    let (b, d, c) = (batch.bsz, meta.dim, meta.classes);
    let w = meta.slice(w_flat, "w");
    let bias = meta.slice(w_flat, "b");
    scratch.z.clear();
    scratch.z.resize(b * c, 0.0);
    let z = &mut scratch.z;
    linalg::gemm_nn(b, d, c, &batch.x, w, z);
    for r in 0..b {
        for (zc, bc) in z[r * c..(r + 1) * c].iter_mut().zip(bias) {
            *zc += *bc;
        }
    }
    let loss = xent_loss(b, c, z, &batch.y1h);
    let mut correct = 0usize;
    for r in 0..b {
        let row = &z[r * c..(r + 1) * c];
        let pred = argmax(row);
        if pred == batch.y[r] as usize {
            correct += 1;
        }
    }
    (loss, correct)
}

/// Stable mean cross-entropy of raw logits against one-hot labels.
pub(crate) fn xent_loss(b: usize, c: usize, z: &[f32], y1h: &[f32]) -> f32 {
    let mut total = 0.0f64;
    for r in 0..b {
        let row = &z[r * c..(r + 1) * c];
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let lse: f32 = row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
        let picked: f32 = row
            .iter()
            .zip(&y1h[r * c..(r + 1) * c])
            .map(|(&zv, &yv)| zv * yv)
            .sum();
        total += (lse - picked) as f64;
    }
    (total / b as f64) as f32
}

pub(crate) fn argmax(row: &[f32]) -> usize {
    let mut best = (f32::NEG_INFINITY, 0usize);
    for (i, &v) in row.iter().enumerate() {
        if v > best.0 {
            best = (v, i);
        }
    }
    best.1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batch::BatchSampler;
    use crate::data::synthetic::{gaussian_mixture, MixtureSpec};
    use crate::util::rng::Rng;

    fn setup() -> (ModelMeta, Batch, Vec<f32>) {
        let meta = ModelMeta::lrm(8, 4, 16);
        let mut data = gaussian_mixture(&MixtureSpec::mnist_like(8, 200), &mut Rng::new(0));
        data.classes = 4;
        for y in data.y.iter_mut() {
            *y %= 4;
        }
        let batch = BatchSampler::new(1).sample(&data, 16);
        let w = meta.init_params(&mut Rng::new(2));
        (meta, batch, w)
    }

    #[test]
    fn zero_params_uniform_loss() {
        let (meta, batch, _) = setup();
        let w = vec![0.0f32; meta.param_count];
        let mut g = vec![0.0f32; meta.param_count];
        let loss = grad(&meta, &w, &batch, &mut g, &mut LrmScratch::default());
        assert!((loss - (4.0f32).ln()).abs() < 1e-5, "loss={loss}");
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (meta, batch, w) = setup();
        let mut g = vec![0.0f32; meta.param_count];
        let mut scratch = LrmScratch::default();
        let loss0 = grad(&meta, &w, &batch, &mut g, &mut scratch);
        let eps = 1e-3f32;
        // probe a spread of coordinates
        for &i in &[0usize, 5, 17, 31, 33, 35] {
            let mut wp = w.clone();
            wp[i] += eps;
            let mut gtmp = vec![0.0f32; meta.param_count];
            let lp = grad(&meta, &wp, &batch, &mut gtmp, &mut scratch);
            let mut wm = w.clone();
            wm[i] -= eps;
            let lm = grad(&meta, &wm, &batch, &mut gtmp, &mut scratch);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - g[i]).abs() < 2e-3,
                "coord {i}: fd={fd} analytic={} loss0={loss0}",
                g[i]
            );
        }
    }

    #[test]
    fn sgd_descends() {
        let (meta, batch, mut w) = setup();
        let mut g = vec![0.0f32; meta.param_count];
        let mut scratch = LrmScratch::default();
        let l0 = grad(&meta, &w, &batch, &mut g, &mut scratch);
        for _ in 0..20 {
            for (wv, gv) in w.iter_mut().zip(&g) {
                *wv -= 0.5 * gv;
            }
            grad(&meta, &w, &batch, &mut g, &mut scratch);
        }
        let l1 = grad(&meta, &w, &batch, &mut g, &mut scratch);
        assert!(l1 < l0 * 0.8, "l0={l0} l1={l1}");
    }

    #[test]
    fn eval_consistent_with_grad_loss() {
        let (meta, batch, w) = setup();
        let mut g = vec![0.0f32; meta.param_count];
        let mut scratch = LrmScratch::default();
        let lg = grad(&meta, &w, &batch, &mut g, &mut scratch);
        let (le, correct) = eval(&meta, &w, &batch, &mut scratch);
        assert!((lg - le).abs() < 1e-6);
        assert!(correct <= batch.bsz);
    }

    #[test]
    fn training_improves_accuracy() {
        let meta = ModelMeta::lrm(8, 10, 64);
        let data = gaussian_mixture(&MixtureSpec::mnist_like(8, 2000), &mut Rng::new(5));
        let mut sampler = BatchSampler::new(6);
        let mut w = meta.init_params(&mut Rng::new(7));
        let mut g = vec![0.0f32; meta.param_count];
        let mut scratch = LrmScratch::default();
        let test = BatchSampler::new(8).sample(&data, 512);
        let (_, c0) = eval(&meta, &w, &test, &mut scratch);
        for _ in 0..150 {
            let b = sampler.sample(&data, 64);
            grad(&meta, &w, &b, &mut g, &mut scratch);
            for (wv, gv) in w.iter_mut().zip(&g) {
                *wv -= 0.3 * gv;
            }
        }
        let (_, c1) = eval(&meta, &w, &test, &mut scratch);
        assert!(
            c1 as f64 > c0 as f64 + 0.2 * 512.0,
            "accuracy {}→{} of 512",
            c0,
            c1
        );
    }
}
