//! Native 2NN engine (paper Table 1: FC+ReLU 256 → FC+ReLU 256 → FC 10).
//!
//! Forward: h1 = relu(x·W1+b1), h2 = relu(h1·W2+b2), z = h2·W3+b3,
//! mean cross-entropy. Backward is the standard chain; all GEMMs through
//! model::linalg. Agreement with the PJRT artifact asserted in
//! rust/tests/runtime_pjrt.rs.

use super::lrm::{argmax, xent_loss};
use super::{linalg, ModelMeta};
use crate::data::batch::Batch;

/// Reusable forward/backward activations.
#[derive(Debug, Clone, Default)]
pub struct MlpScratch {
    h1: Vec<f32>,
    h2: Vec<f32>,
    z: Vec<f32>,
    dh1: Vec<f32>,
    dh2: Vec<f32>,
}

impl MlpScratch {
    fn reserve(&mut self, b: usize, h: usize, c: usize) {
        self.h1.clear();
        self.h1.resize(b * h, 0.0);
        self.h2.clear();
        self.h2.resize(b * h, 0.0);
        self.z.clear();
        self.z.resize(b * c, 0.0);
        self.dh1.clear();
        self.dh1.resize(b * h, 0.0);
        self.dh2.clear();
        self.dh2.resize(b * h, 0.0);
    }
}

fn forward(
    meta: &ModelMeta,
    w_flat: &[f32],
    batch: &Batch,
    s: &mut MlpScratch,
) {
    let (b, d, h, c) = (batch.bsz, meta.dim, meta.hidden, meta.classes);
    let w1 = meta.slice(w_flat, "w1");
    let b1 = meta.slice(w_flat, "b1");
    let w2 = meta.slice(w_flat, "w2");
    let b2 = meta.slice(w_flat, "b2");
    let w3 = meta.slice(w_flat, "w3");
    let b3 = meta.slice(w_flat, "b3");
    s.reserve(b, h, c);
    // h1 = relu(x·W1 + b1)
    linalg::gemm_nn(b, d, h, &batch.x, w1, &mut s.h1);
    add_bias_relu(b, h, &mut s.h1, b1);
    // h2 = relu(h1·W2 + b2)
    linalg::gemm_nn(b, h, h, &s.h1, w2, &mut s.h2);
    add_bias_relu(b, h, &mut s.h2, b2);
    // z = h2·W3 + b3
    linalg::gemm_nn(b, h, c, &s.h2, w3, &mut s.z);
    for r in 0..b {
        for (zc, bc) in s.z[r * c..(r + 1) * c].iter_mut().zip(b3) {
            *zc += *bc;
        }
    }
}

/// Mean loss + gradient into `grad_out`.
pub fn grad(
    meta: &ModelMeta,
    w_flat: &[f32],
    batch: &Batch,
    grad_out: &mut [f32],
    s: &mut MlpScratch,
) -> f32 {
    let (b, d, h, c) = (batch.bsz, meta.dim, meta.hidden, meta.classes);
    forward(meta, w_flat, batch, s);
    let loss = xent_loss(b, c, &s.z, &batch.y1h);

    // dz = (softmax - y)/B in place
    linalg::softmax_rows(b, c, &mut s.z);
    let inv_b = 1.0 / b as f32;
    for (zv, yv) in s.z.iter_mut().zip(&batch.y1h) {
        *zv = (*zv - *yv) * inv_b;
    }

    grad_out.fill(0.0);
    let w2 = meta.slice(w_flat, "w2").to_vec(); // copies avoid aliasing grad_out splits
    let w3 = meta.slice(w_flat, "w3").to_vec();

    // Layer 3 grads: gW3 = h2ᵀ·dz ; gb3 = Σ dz ; dh2 = dz·W3ᵀ ⊙ relu'(h2)
    {
        let off = meta.segment("w3").offset;
        let (head, tail) = grad_out.split_at_mut(off);
        let (gw3, gb3) = tail.split_at_mut(meta.segment("w3").size);
        linalg::gemm_tn(b, h, c, &s.h2, &s.z, gw3);
        sum_rows(b, c, &s.z, gb3);
        let _ = head;
    }
    linalg::gemm_nt(b, c, h, &s.z, &w3, &mut s.dh2);
    relu_mask(&s.h2, &mut s.dh2);

    // Layer 2 grads: gW2 = h1ᵀ·dh2 ; gb2 = Σ dh2 ; dh1 = dh2·W2ᵀ ⊙ relu'(h1)
    {
        let w2_off = meta.segment("w2").offset;
        let b2_off = meta.segment("b2").offset;
        let (_, tail) = grad_out.split_at_mut(w2_off);
        let (gw2, rest) = tail.split_at_mut(meta.segment("w2").size);
        let (gb2, _) = rest.split_at_mut(meta.segment("b2").size);
        debug_assert_eq!(w2_off + meta.segment("w2").size, b2_off);
        linalg::gemm_tn(b, h, h, &s.h1, &s.dh2, gw2);
        sum_rows(b, h, &s.dh2, gb2);
    }
    linalg::gemm_nt(b, h, h, &s.dh2, &w2, &mut s.dh1);
    relu_mask(&s.h1, &mut s.dh1);

    // Layer 1 grads: gW1 = xᵀ·dh1 ; gb1 = Σ dh1
    {
        let (head, _) = grad_out.split_at_mut(meta.segment("w2").offset);
        let (gw1, gb1) = head.split_at_mut(meta.segment("w1").size);
        linalg::gemm_tn(b, d, h, &batch.x, &s.dh1, gw1);
        sum_rows(b, h, &s.dh1, gb1);
    }
    loss
}

/// Mean loss + correct-prediction count.
pub fn eval(meta: &ModelMeta, w_flat: &[f32], batch: &Batch, s: &mut MlpScratch) -> (f32, usize) {
    let (b, c) = (batch.bsz, meta.classes);
    forward(meta, w_flat, batch, s);
    let loss = xent_loss(b, c, &s.z, &batch.y1h);
    let mut correct = 0usize;
    for r in 0..b {
        if argmax(&s.z[r * c..(r + 1) * c]) == batch.y[r] as usize {
            correct += 1;
        }
    }
    (loss, correct)
}

fn add_bias_relu(rows: usize, cols: usize, m: &mut [f32], bias: &[f32]) {
    for r in 0..rows {
        for (v, bc) in m[r * cols..(r + 1) * cols].iter_mut().zip(bias) {
            *v = (*v + *bc).max(0.0);
        }
    }
}

/// dx ⊙= 1[act > 0]  (activations already post-ReLU, so >0 is the mask)
fn relu_mask(act: &[f32], dx: &mut [f32]) {
    for (d, &a) in dx.iter_mut().zip(act) {
        if a <= 0.0 {
            *d = 0.0;
        }
    }
}

fn sum_rows(rows: usize, cols: usize, m: &[f32], out: &mut [f32]) {
    for r in 0..rows {
        for (o, v) in out.iter_mut().zip(&m[r * cols..(r + 1) * cols]) {
            *o += *v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batch::BatchSampler;
    use crate::data::synthetic::{gaussian_mixture, MixtureSpec};
    use crate::util::rng::Rng;

    fn setup() -> (ModelMeta, Batch, Vec<f32>) {
        let meta = ModelMeta::mlp2(10, 24, 4, 16);
        let mut data = gaussian_mixture(&MixtureSpec::mnist_like(10, 300), &mut Rng::new(0));
        data.classes = 4;
        for y in data.y.iter_mut() {
            *y %= 4;
        }
        let batch = BatchSampler::new(1).sample(&data, 16);
        let w = meta.init_params(&mut Rng::new(2));
        (meta, batch, w)
    }

    #[test]
    fn zero_params_uniform_loss() {
        let (meta, batch, _) = setup();
        let w = vec![0.0f32; meta.param_count];
        let mut g = vec![0.0f32; meta.param_count];
        let loss = grad(&meta, &w, &batch, &mut g, &mut MlpScratch::default());
        assert!((loss - (4.0f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let (meta, batch, w) = setup();
        let mut g = vec![0.0f32; meta.param_count];
        let mut s = MlpScratch::default();
        grad(&meta, &w, &batch, &mut g, &mut s);
        let eps = 1e-2f32;
        // one coordinate from every segment
        let coords: Vec<usize> = meta
            .segments
            .iter()
            .map(|seg| seg.offset + seg.size / 2)
            .collect();
        let mut gtmp = vec![0.0f32; meta.param_count];
        for &i in &coords {
            let mut wp = w.clone();
            wp[i] += eps;
            let lp = grad(&meta, &wp, &batch, &mut gtmp, &mut s);
            let mut wm = w.clone();
            wm[i] -= eps;
            let lm = grad(&meta, &wm, &batch, &mut gtmp, &mut s);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - g[i]).abs() < 5e-3 + 0.05 * fd.abs(),
                "coord {i}: fd={fd} analytic={}",
                g[i]
            );
        }
    }

    #[test]
    fn sgd_descends() {
        let (meta, batch, mut w) = setup();
        let mut g = vec![0.0f32; meta.param_count];
        let mut s = MlpScratch::default();
        let l0 = grad(&meta, &w, &batch, &mut g, &mut s);
        for _ in 0..30 {
            for (wv, gv) in w.iter_mut().zip(&g) {
                *wv -= 0.5 * gv;
            }
            grad(&meta, &w, &batch, &mut g, &mut s);
        }
        let l1 = grad(&meta, &w, &batch, &mut g, &mut s);
        assert!(l1 < l0 * 0.7, "l0={l0} l1={l1}");
    }

    #[test]
    fn eval_matches_grad_loss() {
        let (meta, batch, w) = setup();
        let mut g = vec![0.0f32; meta.param_count];
        let mut s = MlpScratch::default();
        let lg = grad(&meta, &w, &batch, &mut g, &mut s);
        let (le, _) = eval(&meta, &w, &batch, &mut s);
        assert!((lg - le).abs() < 1e-6);
    }

    #[test]
    fn beats_linear_model_on_nonlinear_task() {
        // XOR-ish labels: linear model stuck near 50%, 2NN should fit.
        let mut rng = Rng::new(3);
        let n = 1200;
        let mut x = vec![0.0f32; n * 2];
        let mut y = vec![0u32; n];
        for i in 0..n {
            let a = rng.normal() as f32;
            let b = rng.normal() as f32;
            x[i * 2] = a;
            x[i * 2 + 1] = b;
            y[i] = u32::from((a > 0.0) != (b > 0.0));
        }
        let data = crate::data::Dataset {
            dim: 2,
            classes: 2,
            x,
            y,
        };
        let meta = ModelMeta::mlp2(2, 32, 2, 64);
        let mut w = meta.init_params(&mut Rng::new(4));
        let mut g = vec![0.0f32; meta.param_count];
        let mut s = MlpScratch::default();
        let mut sampler = BatchSampler::new(5);
        for _ in 0..400 {
            let b = sampler.sample(&data, 64);
            grad(&meta, &w, &b, &mut g, &mut s);
            for (wv, gv) in w.iter_mut().zip(&g) {
                *wv -= 0.8 * gv;
            }
        }
        let test = BatchSampler::new(6).sample(&data, 512);
        let (_, correct) = eval(&meta, &w, &test, &mut s);
        assert!(correct > 440, "2NN should crack XOR: {correct}/512");
    }
}
