//! `dybw` — the launcher.
//!
//! Subcommands:
//! - `train`    — run one training job from flags / a JSON config
//! - `figure`   — regenerate a paper figure/table (or `all`)
//! - `topology` — inspect a consensus graph + its DTUR path
//! - `artifacts`— list and validate the AOT artifact set
//! - `analyze`  — consensus-theory numbers (λ₂, β, mixing forecast)
//! - `des`      — event-driven cluster simulator (async per-worker time)
//! - `live`     — real-worker driver: in-process threads or a TCP leader
//! - `worker`   — one worker process that joins a `live --listen` leader
//! - `bench`    — perf-trajectory tooling (regression gate vs baseline)
//! - `obs`      — inspect telemetry recorded with `--obs-dir` (straggler report)

// Same rationale as the crate-level allows in lib.rs (config structs are
// mutated field-by-field after `Default::default()`).
#![allow(clippy::field_reassign_with_default)]

use std::path::PathBuf;
use std::time::Duration;

use dybw::comms::transport::{connect_worker, rejoin_worker, ChannelTransport, TcpTransport};
use dybw::comms::Transport;
use dybw::coordinator::live::{self, LiveOptions, WorkerExit, WorkerOpts, WorkerState};
use dybw::coordinator::setup::{Backend, DatasetProfile, Setup};
use dybw::coordinator::Algorithm;
use dybw::data::partition::Partition;
use dybw::engine::BatchSource;
use dybw::experiments;
use dybw::graph::topology::{self, Topology};
use dybw::metrics::export;
use dybw::metrics::summary::Comparison;
use dybw::straggler::Dist;
use dybw::util::cli::{Args, CliError, Command};
use dybw::util::json::Json;
use dybw::util::rng::Rng;

fn main() {
    dybw::util::log::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("{e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> anyhow::Result<()> {
    let Some(sub) = argv.first() else {
        print_global_help();
        return Ok(());
    };
    let rest = &argv[1..];
    match sub.as_str() {
        "train" => cmd_train(rest),
        "figure" => cmd_figure(rest),
        "topology" => cmd_topology(rest),
        "artifacts" => cmd_artifacts(rest),
        "analyze" => cmd_analyze(rest),
        "trace" => cmd_trace(rest),
        "des" => cmd_des(rest),
        "live" => cmd_live(rest),
        "worker" => cmd_worker(rest),
        "bench" => cmd_bench(rest),
        "obs" => cmd_obs(rest),
        "help" | "--help" | "-h" => {
            print_global_help();
            Ok(())
        }
        other => anyhow::bail!("unknown subcommand '{other}' — try `dybw help`"),
    }
}

fn print_global_help() {
    println!(
        "dybw — straggler-resilient distributed training with dynamic backup workers\n\
         \n\
         USAGE: dybw <subcommand> [options]\n\
         \n\
         SUBCOMMANDS:\n\
         \x20 train      run one training job (cb-DyBW or a baseline)\n\
         \x20 figure     regenerate a paper figure: table1 fig1..fig7 speedup baselines topology severity compression async | all\n\
         \x20 topology   inspect a consensus graph and its DTUR connecting path\n\
         \x20 artifacts  list + validate AOT artifacts (built by `make artifacts`)\n\
         \x20 analyze    consensus-theory report (lambda2, beta, mixing forecast)\n\
         \x20 trace      record a straggler timing trace / A-B algorithms on one\n\
         \x20 des        event-driven simulator: async per-worker clocks, scenario sweeps\n\
         \x20 live       real-worker driver: in-process threads, or a TCP leader (--listen)\n\
         \x20 worker     one worker process: `dybw worker --connect <addr>`\n\
         \x20 bench      perf-trajectory gate: compare BENCH_speedup.json vs baseline\n\
         \x20 obs        straggler telemetry report from a --obs-dir recording\n\
         \n\
         Run `dybw <subcommand> --help` for options."
    );
}

fn setup_opts(cmd: Command) -> Command {
    cmd.opt("workers", "6", "number of workers N")
        .opt("topology", "random", "ring|grid|star|complete|random")
        .opt("algo", "cb-dybw", "cb-dybw|cb-full|cb-static:<b>|ps-sync|ps-backup:<b>")
        .opt("model", "lrm_d64_c10_b256", "model/artifact name")
        .opt("dataset", "mnist", "mnist|cifar synthetic profile")
        .opt("partition", "iid", "iid|shards|dirichlet:<alpha>")
        .opt("train-n", "12000", "training examples (total)")
        .opt("test-n", "2048", "test examples")
        .opt(
            "straggler",
            "sexp:0.08,25",
            "base compute-time dist (det|uniform|sexp|pareto|lognormal)",
        )
        .opt("straggler-factor", "4", "transient straggler slowdown factor")
        .opt("iters", "200", "training iterations K")
        .opt("lr0", "0.2", "initial learning rate")
        .opt("lr-decay", "0.95", "learning-rate decay")
        .opt("eval-every", "10", "evaluate every k iterations")
        .opt("seed", "2021", "master RNG seed")
        .opt("backend", "native", "native|pjrt[:dir]")
        .opt("threads", "0", "engine-pool lanes (0 = auto: all available cores, capped at N)")
        .flag("no-prefetch", "disable batch prefetch (bit-identical either way; debugging aid)")
        .opt("config", "", "JSON config file (flags override)")
}

fn setup_from_args(a: &Args) -> anyhow::Result<Setup> {
    let mut s = Setup::default();
    // config file first, flags override
    let cfg_path = a.get("config");
    if !cfg_path.is_empty() {
        let text = std::fs::read_to_string(cfg_path)
            .map_err(|e| anyhow::anyhow!("cannot read config {cfg_path}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("bad config: {e}"))?;
        s.apply_json(&j)?;
    }
    s.workers = a.get_usize("workers")?;
    s.topology = Topology::parse(a.get("topology"))?;
    s.algo = Algorithm::parse(a.get("algo")).ok_or_else(|| anyhow::anyhow!("bad --algo"))?;
    s.model = a.get("model").to_string();
    s.dataset = DatasetProfile::parse(a.get("dataset"))
        .ok_or_else(|| anyhow::anyhow!("bad --dataset"))?;
    s.partition = Partition::parse(a.get("partition"))?;
    s.train_n = a.get_usize("train-n")?;
    s.test_n = a.get_usize("test-n")?;
    s.straggler_base = Dist::parse(a.get("straggler"))?;
    s.straggler_factor = a.get_f64("straggler-factor")?;
    s.train.iters = a.get_usize("iters")?;
    s.train.lr0 = a.get_f64("lr0")?;
    s.train.lr_decay = a.get_f64("lr-decay")?;
    s.train.eval_every = a.get_usize("eval-every")?;
    s.train.seed = a.get_u64("seed")?;
    s.threads = a.get_usize("threads")?;
    if a.flag("no-prefetch") {
        s.train.prefetch = false;
    }
    s.backend = match a.get("backend") {
        "native" => Backend::Native,
        b if b.starts_with("pjrt") => Backend::Pjrt {
            artifacts_dir: PathBuf::from(b.strip_prefix("pjrt:").unwrap_or("artifacts")),
        },
        other => anyhow::bail!("bad --backend '{other}'"),
    };
    Ok(s)
}

fn cmd_train(argv: &[String]) -> anyhow::Result<()> {
    let cmd = setup_opts(Command::new("dybw train", "run one training job"))
        .opt("out-dir", "results", "where to write CSV/JSON histories")
        .flag("compare-full", "also run cb-Full and print the comparison")
        .opt("target-loss", "0.5", "target test loss for time-to-loss reporting")
        .opt("ckpt-dir", "", "checkpoint directory (enables periodic checkpointing)")
        .opt("ckpt-every", "0", "checkpoint every k iterations (needs --ckpt-dir)")
        .opt("ckpt-retain", "3", "keep only the newest k checkpoints (0 = keep all)")
        .opt("kill-at", "0", "abort right after checkpointing iteration k (fault injection)")
        .flag("resume", "restore the latest intact checkpoint in --ckpt-dir, then continue")
        .opt("obs-dir", "", "record telemetry (trace + metrics) under this directory");
    let a = parse_or_exit(&cmd, argv)?;
    let s = setup_from_args(&a)?;
    let obs = obs_from_args(&a)?;
    let out_dir = PathBuf::from(a.get("out-dir"));

    println!(
        "# dybw train: {} / {} / {} workers / {} backend / {} pool lanes",
        s.algo.name(),
        s.model,
        s.workers,
        match &s.backend {
            Backend::Native => "native",
            Backend::Pjrt { .. } => "pjrt",
        },
        s.resolve_threads()
    );
    let mut trainer = s.build_sim()?;
    let ckpt_dir = a.get("ckpt-dir");
    if !ckpt_dir.is_empty() {
        let every = a.get_usize("ckpt-every")?;
        anyhow::ensure!(every > 0, "--ckpt-dir needs --ckpt-every > 0");
        trainer.ckpt_mgr = Some(dybw::coordinator::ckpt_manager::CkptManager::new(
            &PathBuf::from(ckpt_dir),
            a.get_usize("ckpt-retain")?,
        )?);
        trainer.ckpt_every = every;
        trainer.ckpt_model = s.model.clone();
        if a.get_usize("kill-at")? > 0 {
            trainer.kill_at = Some(a.get_usize("kill-at")?);
        }
        if a.flag("resume") {
            if trainer.resume_latest()? {
                let done = trainer.start_k();
                // the remaining budget, so resumed + original runs end at
                // the same total iteration count
                trainer.cfg.iters = trainer.cfg.iters.saturating_sub(done);
                println!(
                    "# resumed from iteration {done} ({} iterations to go)",
                    trainer.cfg.iters
                );
            } else {
                println!("# --resume: no intact checkpoint under {ckpt_dir}; starting fresh");
            }
        }
    } else {
        anyhow::ensure!(
            !a.flag("resume") && a.get_usize("kill-at")? == 0,
            "--resume/--kill-at need --ckpt-dir"
        );
    }
    trainer.on_iter = Some(Box::new(|r| {
        if r.k % 50 == 0 {
            println!(
                "  k={:<5} T(k)={:.3}s clock={:.1}s loss={:.4} active={} backup={:.2}",
                r.k, r.duration, r.clock, r.train_loss, r.active, r.backup_avg
            );
        }
    }));
    let h = trainer.run()?;
    export::write_csv(&h, &out_dir, "train")?;
    export::write_json(&h, &out_dir, "train")?;
    print_history_summary(&h);

    if a.flag("compare-full") {
        let mut s2 = s.clone();
        s2.algo = Algorithm::CbFull;
        let hb = s2.build_sim()?.run()?;
        export::write_csv(&hb, &out_dir, "train.full")?;
        let c = Comparison::new(&h, &hb, a.get_f64("target-loss")?);
        println!("\n## comparison vs cb-Full\n{}", c.render());
    }
    obs_finish(&a, &obs)?;
    println!("(histories written under {})", out_dir.display());
    Ok(())
}

fn print_history_summary(h: &dybw::metrics::RunHistory) {
    println!("\n## summary: {}", h.algo);
    println!("  iterations          : {}", h.iters.len());
    println!("  total virtual time  : {:.1}s", h.total_time());
    println!("  mean iter duration  : {:.3}s", h.mean_iter_duration());
    println!("  mean backup workers : {:.2}", h.mean_backup_workers());
    if let Some(e) = h.final_eval() {
        println!(
            "  final test loss/err : {:.4} / {:.1}%  (consensus err {:.2e})",
            e.test_loss,
            e.test_error * 100.0,
            e.consensus_error
        );
    }
}

fn cmd_figure(argv: &[String]) -> anyhow::Result<()> {
    let cmd = setup_opts(Command::new(
        "dybw figure",
        "regenerate a paper figure/table",
    ))
    .positional("id", "table1|fig1..fig7|speedup|baselines|topology|severity|compression|async|all")
    .opt("out-dir", "results", "CSV/JSON output dir")
    .opt("cells", "0", "concurrent harness cells (0 = auto; 1 = sequential reference)")
    .flag("quick", "shrunk workloads (CI)");
    let a = parse_or_exit(&cmd, argv)?;
    let id = a.positionals.first().ok_or_else(|| {
        anyhow::anyhow!("which figure? (e.g. `dybw figure fig1`)\n\n{}", cmd.usage())
    })?;
    let base = setup_from_args(&a)?;
    experiments::set_cell_concurrency(a.get_usize("cells")?);
    let out_dir = PathBuf::from(a.get("out-dir"));
    let report = experiments::run(id, &base, &out_dir, a.flag("quick"))?;
    println!("{report}");
    Ok(())
}

fn cmd_topology(argv: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("dybw topology", "inspect a consensus graph")
        .opt("workers", "6", "number of workers")
        .opt("topology", "random", "ring|grid|star|complete|random")
        .opt("seed", "2021", "seed");
    let a = parse_or_exit(&cmd, argv)?;
    let kind = Topology::parse(a.get("topology"))?;
    let mut rng = Rng::new(a.get_u64("seed")?);
    let g = topology::build(kind, a.get_usize("workers")?, &mut rng);
    println!(
        "topology={} n={} edges={} connected={} diameter={:?}",
        kind.name(),
        g.n(),
        g.edge_count(),
        g.is_connected(),
        dybw::graph::paths::diameter(&g)
    );
    for v in 0..g.n() {
        let nbrs: Vec<String> = g.neighbors(v).map(|u| u.to_string()).collect();
        println!("  worker {v}: [{}]", nbrs.join(", "));
    }
    let p = dybw::graph::paths::connecting_path(&g);
    println!("DTUR path P (d={}): {:?}", p.len(), p);
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_artifacts(argv: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("dybw artifacts", "list + validate AOT artifacts")
        .opt("dir", "artifacts", "artifacts directory")
        .flag("compile", "also compile each artifact on the PJRT client");
    let a = parse_or_exit(&cmd, argv)?;
    let dir = PathBuf::from(a.get("dir"));
    let set = dybw::runtime::ArtifactSet::load(&dir)?;
    println!("{} artifact families in {}:", set.artifacts.len(), dir.display());
    for art in &set.artifacts {
        art.meta.validate()?;
        print!(
            "  {:<28} kind={:<11} P={:<8} batch={}",
            art.meta.name,
            art.meta.kind.name(),
            art.meta.param_count,
            art.meta.batch
        );
        if a.flag("compile") {
            let client = dybw::runtime::shared_client()?;
            let t0 = std::time::Instant::now();
            let _m = dybw::runtime::LoadedModel::compile(art, client)?;
            print!("  [compiled in {:.2}s]", t0.elapsed().as_secs_f64());
        }
        println!();
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_artifacts(_argv: &[String]) -> anyhow::Result<()> {
    anyhow::bail!(
        "`dybw artifacts` needs the PJRT runtime — rebuild with `cargo build --features pjrt`"
    )
}

fn cmd_analyze(argv: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("dybw analyze", "consensus-theory report")
        .opt("workers", "6", "number of workers")
        .opt("topology", "random", "graph kind")
        .opt("seed", "2021", "seed");
    let a = parse_or_exit(&cmd, argv)?;
    let kind = Topology::parse(a.get("topology"))?;
    let mut rng = Rng::new(a.get_u64("seed")?);
    let g = topology::build(kind, a.get_usize("workers")?, &mut rng);
    let p = dybw::consensus::ConsensusMatrix::metropolis_full(&g);
    p.check_doubly_stochastic(1e-9)
        .map_err(|e| anyhow::anyhow!(e))?;
    let l2 = dybw::consensus::matrix::lambda2(&p, 300);
    let beta = p.min_positive();
    println!("graph: {} n={} edges={}", kind.name(), g.n(), g.edge_count());
    println!("metropolis P(full): doubly stochastic OK");
    println!("  beta (min positive entry)   = {beta:.4}");
    println!("  lambda2 (mixing factor)     = {l2:.4}");
    println!("  rounds to halve disagreement = {:.1}", (0.5f64).ln() / l2.ln());
    let d = dybw::graph::paths::connecting_path(&g).len();
    println!("  DTUR epoch length d          = {d}  (Assumption 2: B = d)");
    Ok(())
}

fn cmd_trace(argv: &[String]) -> anyhow::Result<()> {
    let cmd = setup_opts(Command::new(
        "dybw trace",
        "record a compute-time trace, or A/B algorithms on a recorded one",
    ))
    .positional("action", "record | ab")
    .opt("trace-file", "results/trace.csv", "trace CSV path")
    .opt("trace-iters", "200", "iterations to record");
    let a = parse_or_exit(&cmd, argv)?;
    let action = a
        .positionals
        .first()
        .map(String::as_str)
        .ok_or_else(|| anyhow::anyhow!("action: record | ab\n\n{}", cmd.usage()))?;
    let s = setup_from_args(&a)?;
    let path = PathBuf::from(a.get("trace-file"));
    match action {
        "record" => {
            let mut rng = Rng::new(s.train.seed);
            let model = dybw::straggler::StragglerModel {
                base: s.straggler_base,
                worker_scale: (0..s.workers).map(|_| rng.uniform_in(0.8, 1.25)).collect(),
                persistent: vec![1.0; s.workers],
                transient_prob: 0.15,
                transient_factor: s.straggler_factor,
                force_one_straggler: s.force_straggler,
                outages: Vec::new(),
                diurnal_amp: 0.0,
                diurnal_period: 0.0,
            };
            let trace = dybw::straggler::trace::Trace::record(
                &model,
                a.get_usize("trace-iters")?,
                &mut rng,
            );
            trace.save_csv(&path)?;
            println!(
                "recorded {} iterations x {} workers -> {} (worker means: {:?})",
                trace.len(),
                trace.workers,
                path.display(),
                trace
                    .worker_means()
                    .iter()
                    .map(|m| format!("{m:.3}"))
                    .collect::<Vec<_>>()
            );
        }
        "ab" => {
            use dybw::straggler::trace::{Trace, TraceReplay};
            let trace = Trace::load_csv(&path)?;
            anyhow::ensure!(
                trace.workers == s.workers,
                "trace has {} workers, setup {}",
                trace.workers,
                s.workers
            );
            println!("A/B on identical timing trace ({} iters):", trace.len());
            let mut results = Vec::new();
            for algo in [Algorithm::CbDybw, Algorithm::CbFull] {
                let mut s2 = s.clone();
                s2.algo = algo;
                s2.train.iters = s2.train.iters.min(trace.len());
                let mut tr = s2.build_sim()?;
                tr.trace = Some(TraceReplay::new(trace.clone())?);
                let h = tr.run()?;
                println!(
                    "  {:<10} total {:.1}s  mean T(k) {:.3}s  final loss {:.4}",
                    h.algo,
                    h.total_time(),
                    h.mean_iter_duration(),
                    h.final_eval().map(|e| e.test_loss).unwrap_or(f64::NAN)
                );
                results.push(h);
            }
            let c = Comparison::new(&results[0], &results[1], 0.55);
            println!("\n{}", c.render());
        }
        other => anyhow::bail!("unknown trace action '{other}' (record | ab)"),
    }
    Ok(())
}

fn cmd_des(argv: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new(
        "dybw des",
        "event-driven cluster simulator: asynchronous per-worker time",
    )
    .positional("action", "run | template")
    .opt("scenario", "", "scenario JSON file (default: the built-in ring-1k sweep)")
    .opt("out-dir", "results", "summary JSON / history CSV output dir")
    .opt("export-events", "", "write the deterministic per-event log to this path")
    .opt("workers", "0", "override the scenario's worker count (0 = keep)")
    .opt("iters", "0", "override iterations per worker (0 = keep)")
    .opt("seed", "", "override the scenario's seed")
    .opt(
        "policies",
        "",
        "override the policy sweep, comma-separated: full|static:<b>|dybw",
    )
    .opt("ckpt-dir", "", "full fidelity: checkpoint directory (needs exactly one policy)")
    .opt("ckpt-every", "0", "checkpoint every k frontier iterations (needs --ckpt-dir)")
    .opt("ckpt-retain", "3", "keep only the newest k checkpoints (0 = keep all)")
    .opt("kill-at", "0", "abort right after the milestone-k checkpoint (fault injection)")
    .flag("resume", "verified replay against the latest checkpoint in --ckpt-dir")
    .opt("obs-dir", "", "record telemetry (trace + metrics) under this directory");
    let a = parse_or_exit(&cmd, argv)?;
    let action = a.positionals.first().map(String::as_str).unwrap_or("run");
    match action {
        "template" => {
            // a starting point for hand-written scenarios
            println!(
                "{}",
                dybw::des::Scenario::default().to_json().to_string_pretty()
            );
            Ok(())
        }
        "run" => {
            let mut scenario = match a.get("scenario") {
                "" => dybw::des::Scenario::default(),
                path => dybw::des::Scenario::load(&PathBuf::from(path))?,
            };
            if a.get_usize("workers")? > 0 {
                scenario.workers = a.get_usize("workers")?;
            }
            if a.get_usize("iters")? > 0 {
                scenario.iters = a.get_usize("iters")?;
            }
            if !a.get("seed").is_empty() {
                scenario.seed = a.get_u64("seed")?;
            }
            if !a.get("policies").is_empty() {
                scenario.policies = a
                    .get("policies")
                    .split(',')
                    .map(|p| Ok(dybw::des::WaitPolicy::parse(p.trim())?))
                    .collect::<anyhow::Result<_>>()?;
            }
            let events = match a.get("export-events") {
                "" => None,
                p => Some(PathBuf::from(p)),
            };
            let recovery = match a.get("ckpt-dir") {
                "" => {
                    anyhow::ensure!(
                        !a.flag("resume") && a.get_usize("kill-at")? == 0,
                        "--resume/--kill-at need --ckpt-dir"
                    );
                    None
                }
                dir => {
                    let every = a.get_usize("ckpt-every")?;
                    anyhow::ensure!(every > 0, "--ckpt-dir needs --ckpt-every > 0");
                    Some(dybw::des::RecoveryOpts {
                        dir: PathBuf::from(dir),
                        every,
                        retain: a.get_usize("ckpt-retain")?,
                        kill_at: match a.get_usize("kill-at")? {
                            0 => None,
                            k => Some(k),
                        },
                        resume: a.flag("resume"),
                    })
                }
            };
            let obs = obs_from_args(&a)?;
            let report = scenario.run_with_recovery(
                &PathBuf::from(a.get("out-dir")),
                events.as_deref(),
                recovery,
            )?;
            println!("{report}");
            obs_finish(&a, &obs)?;
            Ok(())
        }
        other => anyhow::bail!("unknown des action '{other}' (run | template)\n\n{}", cmd.usage()),
    }
}

fn cmd_live(argv: &[String]) -> anyhow::Result<()> {
    let cmd = setup_opts(Command::new(
        "dybw live",
        "real-worker driver: in-process threads, or a TCP leader",
    ))
    .opt("listen", "", "TCP listen address (e.g. 127.0.0.1:0); empty = in-process threads")
    .opt("addr-file", "", "write the bound listen address to this file (launch scripts)")
    .opt("time-scale", "1", "multiply injected straggler sleeps (0 = no real sleeping)")
    .opt("watchdog", "180", "seconds without protocol progress before the leader aborts")
    .opt("heartbeat", "", "liveness probe interval in seconds (empty = 2 over TCP, off in-process)")
    .opt("rejoin-timeout", "", "seconds a lost worker keeps retrying its rejoin (empty = 60)")
    .opt("chaos", "", "DES scenario JSON whose faults section injects worker kills/recoveries (TCP only)")
    .opt("measure-links", "0", "Ping/Pong rounds before training; calibrates a DES LinkModel")
    .opt("out-dir", "results", "where to write CSV/JSON histories")
    .opt("prefix", "live", "history file name prefix")
    .opt("obs-dir", "", "record telemetry (trace + metrics) under this directory");
    let a = parse_or_exit(&cmd, argv)?;
    let s = setup_from_args(&a)?;
    let obs = obs_from_args(&a)?;
    let tcp = !a.get("listen").is_empty();
    let n = s.workers;

    // Fault injection + liveness knobs. Precedence for the durations:
    // explicit flag > the --chaos scenario's cluster section > built-in
    // defaults (2s heartbeat over TCP, disabled in-process, 60s rejoin).
    let mut res = live::LiveResilience::default();
    let mut scenario_hb = None;
    let mut scenario_rj = None;
    let chaos_path = a.get("chaos");
    if !chaos_path.is_empty() {
        anyhow::ensure!(tcp, "--chaos injects faults on the TCP transport; add --listen");
        let sc = dybw::des::Scenario::load(&PathBuf::from(chaos_path))?;
        anyhow::ensure!(
            sc.workers == n,
            "chaos scenario is for {} workers, this run has {n}",
            sc.workers
        );
        let fp = sc.faults.compile(sc.topology, n)?;
        anyhow::ensure!(
            fp.link_downs.is_empty() && fp.link_ups.is_empty(),
            "live chaos supports worker churn only — drop the faults.partitions section"
        );
        res.chaos.downs = fp.downs;
        res.chaos.ups = fp.ups;
        // A worker that is down from t = 0 still connects (the leader
        // needs all n slots to start); model it as a kill at t = 0.
        for j in fp.initially_down {
            res.chaos.downs.push((j, 0.0));
        }
        if sc.heartbeat_secs > 0.0 {
            scenario_hb = Some(Duration::from_secs_f64(sc.heartbeat_secs));
        }
        scenario_rj = Some(Duration::from_secs_f64(sc.rejoin_timeout_secs));
    }
    let secs_flag = |key: &str| -> anyhow::Result<Option<Duration>> {
        match a.get(key) {
            "" => Ok(None),
            v => {
                let secs: f64 = v
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--{key} expects seconds, got '{v}'"))?;
                anyhow::ensure!(secs.is_finite() && secs >= 0.0, "--{key} must be >= 0");
                Ok(Some(Duration::from_secs_f64(secs)))
            }
        }
    };
    let opts = LiveOptions {
        time_scale: a.get_f64("time-scale")?,
        watchdog: Duration::from_secs(a.get_u64("watchdog")?),
        heartbeat: secs_flag("heartbeat")?.or(scenario_hb).unwrap_or(if tcp {
            Duration::from_secs(2)
        } else {
            Duration::ZERO
        }),
        rejoin_timeout: secs_flag("rejoin-timeout")?
            .or(scenario_rj)
            .unwrap_or(Duration::from_secs(60)),
    };
    let measure_rounds = a.get_usize("measure-links")?;
    let mut parts = s.build_live()?;
    let mode = if tcp { "tcp" } else { "in-process" };
    let algo = s.algo.name();
    let lanes = parts.server.lanes();
    println!("# dybw live: {algo} / {} / {n} workers / {lanes} pool lanes / {mode}", s.model);
    if !res.chaos.is_empty() {
        println!(
            "# chaos: {} kill / {} recovery events from {chaos_path}",
            res.chaos.downs.len(),
            res.chaos.ups.len()
        );
    }

    let outcome = if !tcp {
        let (mut transport, ports) = ChannelTransport::pair(n);
        let sources = std::mem::take(&mut parts.sources);
        let handles =
            live::spawn_workers(&parts.cfg, &parts.client, sources, &parts.init, ports)?;
        if measure_rounds > 0 {
            run_measure(&mut transport, measure_rounds, &opts, parts.cfg.seed)?;
        }
        let result = live::drive(
            &mut transport,
            &parts.graph,
            s.algo,
            &parts.cfg,
            &parts.straggler,
            &parts.client,
            &parts.eval_batches,
            parts.init.clone(),
            &opts,
        );
        // disconnect the ports so workers unblock even on a mid-run error
        drop(transport);
        for h in handles {
            let _ = h.join();
        }
        result?
    } else {
        let listener = std::net::TcpListener::bind(a.get("listen"))?;
        let addr = listener.local_addr()?;
        let addr_file = a.get("addr-file");
        if !addr_file.is_empty() {
            std::fs::write(addr_file, addr.to_string())?;
        }
        println!("listening on {addr} — waiting for {n} x `dybw worker --connect {addr}`");
        let setup_json = s.to_json().to_string_pretty();
        let mut transport = TcpTransport::accept(&listener, n, &setup_json, opts.watchdog)?;
        if measure_rounds > 0 {
            run_measure(&mut transport, measure_rounds, &opts, parts.cfg.seed)?;
        }
        // The leader's own copies of the seeded per-worker sources go
        // unused for dispatch over TCP (each worker rebuilds its own) —
        // they become the ghost sources, so a dead worker's slot is
        // computed locally, bit-exactly, until the worker rejoins.
        res.ghost_sources = std::mem::take(&mut parts.sources);
        live::drive_resilient(
            &mut transport,
            &parts.graph,
            s.algo,
            &parts.cfg,
            &parts.straggler,
            &parts.client,
            &parts.eval_batches,
            parts.init.clone(),
            &opts,
            &mut res,
        )?
    };

    let out_dir = PathBuf::from(a.get("out-dir"));
    let prefix = a.get("prefix");
    export::write_csv(&outcome.history, &out_dir, prefix)?;
    export::write_json(&outcome.history, &out_dir, prefix)?;
    print_history_summary(&outcome.history);
    println!("  wall-clock          : {:.1}s", outcome.wall_seconds);
    if outcome.ghost_dones > 0 || outcome.rejoins > 0 {
        println!(
            "  degraded mode       : {} ghosted worker-iterations / {} rejoins",
            outcome.ghost_dones, outcome.rejoins
        );
    }
    if let Some((min, med, max)) = outcome.term_ack_summary() {
        println!(
            "  term-ack latency    : min {:.1}ms / median {:.1}ms / max {:.1}ms",
            min * 1e3,
            med * 1e3,
            max * 1e3
        );
    }
    obs_finish(&a, &obs)?;
    println!("(histories written under {})", out_dir.display());
    Ok(())
}

/// Ping/Pong the fleet and print the calibrated DES link model.
fn run_measure(
    transport: &mut dyn Transport,
    rounds: usize,
    opts: &LiveOptions,
    seed: u64,
) -> anyhow::Result<()> {
    let m = live::measure_links(transport, rounds, opts)?;
    println!("## link measurement ({rounds} rounds)\n{}", m.summary());
    let model = m.calibrated(seed);
    let jitter = match model.jitter {
        Some(j) => format!(" + jitter {}", j.spec()),
        None => ", no jitter".to_string(),
    };
    println!("calibrated LinkModel: base {:.3}ms{jitter}", model.base * 1e3);
    Ok(())
}

fn cmd_worker(argv: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new(
        "dybw worker",
        "one worker process: connects to a `dybw live --listen` leader",
    )
    .req("connect", "leader address, e.g. 127.0.0.1:4040")
    .opt("worker-id", "", "claim a specific worker slot (empty = any free slot)")
    .opt("retry-secs", "30", "keep retrying the initial connection for this long")
    .opt("rejoin-secs", "0", "on leader loss, keep retrying a rejoin for this long (0 = exit)")
    .opt("ckpt-dir", "", "checkpoint directory (worker-side state snapshots)")
    .opt("ckpt-every", "0", "checkpoint every k iterations (needs --ckpt-dir)")
    .opt("ckpt-retain", "3", "keep only the newest k checkpoints (0 = keep all)")
    .flag("resume", "restore the latest checkpoint in --ckpt-dir (for relaunching into a live run)")
    .opt("threads", "0", "engine-pool lanes override (0 = keep the leader's setting)")
    .opt("obs-dir", "", "record telemetry (trace + metrics) under this directory");
    let a = parse_or_exit(&cmd, argv)?;
    let obs = obs_from_args(&a)?;
    let worker_id = a.get("worker-id");
    let requested = if worker_id.is_empty() {
        None
    } else {
        let id: u32 = worker_id
            .parse()
            .map_err(|_| anyhow::anyhow!("--worker-id expects an integer, got '{worker_id}'"))?;
        Some(id)
    };
    let addr = a.get("connect");
    let timeout = Duration::from_secs(a.get_u64("retry-secs")?);
    let (slot, setup_json, mut port) = connect_worker(addr, requested, timeout)?;
    anyhow::ensure!(
        !setup_json.trim().is_empty(),
        "leader sent an empty setup — is it a `dybw live --listen` process?"
    );
    // Rebuild the leader's exact Setup; build_live then replays the same
    // seeded construction, so this process holds bit-identical data/init.
    let mut s = Setup::default();
    let j = Json::parse(&setup_json).map_err(|e| anyhow::anyhow!("bad setup from leader: {e}"))?;
    s.apply_json(&j)?;
    let threads = a.get_usize("threads")?;
    if threads > 0 {
        s.threads = threads; // lane count never enters the math — safe to override
    }
    let id = slot as usize;
    let mut parts = s.build_live()?;
    anyhow::ensure!(
        id < parts.sources.len(),
        "leader assigned slot {id}, but the setup has only {} workers",
        parts.sources.len()
    );
    let mut source = std::mem::take(&mut parts.sources)
        .into_iter()
        .nth(id)
        .expect("bounds checked above");
    println!(
        "worker {id}: connected to {addr} ({} params, {} pool lanes)",
        parts.client.param_count(),
        parts.server.lanes()
    );

    let mut wopts = WorkerOpts::default();
    let ckpt_dir = a.get("ckpt-dir");
    if ckpt_dir.is_empty() {
        anyhow::ensure!(
            !a.flag("resume") && a.get_usize("ckpt-every")? == 0,
            "--resume/--ckpt-every need --ckpt-dir"
        );
    } else {
        let every = a.get_usize("ckpt-every")?;
        anyhow::ensure!(every > 0, "--ckpt-dir needs --ckpt-every > 0");
        wopts.ckpt = Some(dybw::coordinator::ckpt_manager::CkptManager::new(
            &PathBuf::from(ckpt_dir),
            a.get_usize("ckpt-retain")?,
        )?);
        wopts.ckpt_every = every;
        wopts.model = s.model.clone();
    }
    let mut state = WorkerState::fresh(parts.init.clone());
    if a.flag("resume") {
        let mgr = wopts.ckpt.as_ref().expect("ensured above");
        match mgr.latest()? {
            Some((ckpt, path)) => {
                anyhow::ensure!(
                    ckpt.params.len() == 2
                        && ckpt.params.iter().all(|p| p.len() == state.w.len()),
                    "checkpoint {} does not fit this setup",
                    path.display()
                );
                state.draws = ckpt.iteration as u64;
                // replay the seeded source up to the checkpoint so later
                // draws stay aligned with the uninterrupted run
                for _ in 0..state.draws {
                    let _ = source.next_train(parts.cfg.batch_size);
                }
                let mut params = ckpt.params;
                state.wtilde = params.pop().expect("len checked above");
                state.w = params.pop().expect("len checked above");
                println!(
                    "worker {id}: restored checkpoint k={} from {}",
                    ckpt.iteration,
                    path.display()
                );
            }
            None => {
                println!("worker {id}: --resume: no intact checkpoint under {ckpt_dir}; starting fresh")
            }
        }
    }

    // Leader loss is survivable: keep the training state, re-claim the
    // slot, reconcile with the leader's StateSync, and carry on.
    let rejoin = Duration::from_secs(a.get_u64("rejoin-secs")?);
    loop {
        match live::worker_loop_opts(
            id,
            &parts.cfg,
            &parts.client,
            source.as_mut(),
            state,
            port,
            &mut wopts,
        )? {
            WorkerExit::Stopped => break,
            WorkerExit::LeaderLost(st) => {
                state = st;
                if rejoin.is_zero() {
                    anyhow::bail!(
                        "worker {id}: leader connection lost (run with --rejoin-secs to retry)"
                    );
                }
                println!(
                    "worker {id}: leader connection lost at draw {} — rejoining for up to {}s",
                    state.draws,
                    rejoin.as_secs()
                );
                match rejoin_worker(addr, slot, state.draws, rejoin) {
                    Ok((sync, fresh)) => {
                        live::apply_state_sync(
                            &mut state,
                            source.as_mut(),
                            parts.cfg.batch_size,
                            &sync,
                            id,
                        )?;
                        println!("worker {id}: rejoined at draw {}", state.draws);
                        port = fresh;
                    }
                    Err(e) => {
                        // the run finished or the leader is gone for good —
                        // a clean exit, not a failure
                        println!("worker {id}: rejoin failed ({e}); exiting");
                        obs_finish(&a, &obs)?;
                        return Ok(());
                    }
                }
            }
        }
    }
    obs_finish(&a, &obs)?;
    println!("worker {id}: done");
    Ok(())
}

fn cmd_bench(argv: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("dybw bench", "perf-trajectory tooling")
        .positional("action", "gate")
        .opt(
            "current",
            "results/BENCH_speedup.json",
            "fresh bench JSON (written by `dybw figure speedup`)",
        )
        .opt("baseline", "BENCH_speedup.baseline.json", "committed baseline JSON")
        .opt("tolerance", "0.75", "fail if a speedup drops below tolerance x baseline")
        .flag("refresh", "overwrite the baseline with current, even if the gate fails");
    let a = parse_or_exit(&cmd, argv)?;
    match a.positionals.first().map(String::as_str) {
        Some("gate") => {
            let current = PathBuf::from(a.get("current"));
            let baseline = PathBuf::from(a.get("baseline"));
            let tol = a.get_f64("tolerance")?;
            if a.flag("refresh") {
                // Re-baselining is needed precisely when the honest new
                // measurement fails the OLD floor; `refresh` reports that
                // gate but installs anyway — unless the current file is
                // malformed or non-bit-identical (its self-gate).
                println!("{}", experiments::speedup::refresh(&current, &baseline, tol)?);
            } else {
                println!("{}", experiments::speedup::gate(&current, &baseline, tol)?);
            }
            Ok(())
        }
        _ => anyhow::bail!("bench action: gate\n\n{}", cmd.usage()),
    }
}

fn cmd_obs(argv: &[String]) -> anyhow::Result<()> {
    let cmd = Command::new("dybw obs", "inspect telemetry recorded with --obs-dir")
        .positional("action", "report")
        .positional("dir", "obs directory (the --obs-dir of a finished run)")
        .opt("top", "5", "stragglers to list in the report");
    let a = parse_or_exit(&cmd, argv)?;
    match a.positionals.first().map(String::as_str) {
        Some("report") => {
            let dir = a.positionals.get(1).ok_or_else(|| {
                anyhow::anyhow!(
                    "which directory? (e.g. `dybw obs report results/obs`)\n\n{}",
                    cmd.usage()
                )
            })?;
            print!(
                "{}",
                dybw::obs::report::report(&PathBuf::from(dir), a.get_usize("top")?)?
            );
            Ok(())
        }
        _ => anyhow::bail!("obs action: report <dir>\n\n{}", cmd.usage()),
    }
}

/// Honour `--obs-dir`: install a process-wide observer streaming a
/// trace + metric registry under the directory. Telemetry never touches
/// the RNG or the parameters, so the recorded history is byte-identical
/// with or without this flag.
fn obs_from_args(a: &Args) -> anyhow::Result<Option<std::sync::Arc<dybw::obs::Obs>>> {
    match a.get("obs-dir") {
        "" => Ok(None),
        dir => {
            let obs = dybw::obs::Obs::to_dir(&PathBuf::from(dir))?;
            dybw::obs::install(obs.clone());
            Ok(Some(obs))
        }
    }
}

/// Flush the `--obs-dir` observer: uninstall it, export the Chrome
/// trace, and write `metrics.json`.
fn obs_finish(
    a: &Args,
    obs: &Option<std::sync::Arc<dybw::obs::Obs>>,
) -> anyhow::Result<()> {
    if let Some(o) = obs {
        dybw::obs::uninstall();
        o.finish()?;
        let dir = a.get("obs-dir");
        println!("(telemetry written under {dir} — inspect with `dybw obs report {dir}`)");
    }
    Ok(())
}

fn parse_or_exit(cmd: &Command, argv: &[String]) -> anyhow::Result<Args> {
    match cmd.parse(argv) {
        Ok(a) => Ok(a),
        Err(CliError(msg)) => {
            anyhow::bail!("{msg}")
        }
    }
}
