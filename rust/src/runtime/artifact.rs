//! Artifact discovery: the contract with python/compile/aot.py.
//!
//! `artifacts/` holds, per model family `<name>`:
//! `<name>.grad.hlo.txt`, `<name>.eval.hlo.txt`, `<name>.meta.json`,
//! plus a `manifest.json` index. This module loads and validates that
//! layout without touching PJRT (so it is unit-testable without a client).

use std::path::{Path, PathBuf};

use crate::model::ModelMeta;
use crate::util::json::Json;

/// One artifact family on disk.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub meta: ModelMeta,
    pub grad_hlo: PathBuf,
    pub eval_hlo: PathBuf,
}

/// All artifacts under a directory.
#[derive(Debug, Clone)]
pub struct ArtifactSet {
    pub dir: PathBuf,
    pub artifacts: Vec<Artifact>,
}

impl ArtifactSet {
    /// Load `dir/manifest.json` and every referenced family.
    pub fn load(dir: &Path) -> anyhow::Result<ArtifactSet> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} — run `make artifacts` first ({e})",
                manifest_path.display()
            )
        })?;
        let manifest = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("bad manifest.json: {e}"))?;
        let mut artifacts = Vec::new();
        for entry in manifest
            .get("artifacts")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'artifacts'"))?
        {
            let name = entry
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow::anyhow!("manifest entry missing name"))?;
            artifacts.push(Self::load_family(dir, name)?);
        }
        Ok(ArtifactSet {
            dir: dir.to_path_buf(),
            artifacts,
        })
    }

    /// Load a single family by name (no manifest needed).
    pub fn load_family(dir: &Path, name: &str) -> anyhow::Result<Artifact> {
        let meta_path = dir.join(format!("{name}.meta.json"));
        let meta_text = std::fs::read_to_string(&meta_path)
            .map_err(|e| anyhow::anyhow!("cannot read {}: {e}", meta_path.display()))?;
        let meta_json = Json::parse(&meta_text)
            .map_err(|e| anyhow::anyhow!("bad {}: {e}", meta_path.display()))?;
        let meta = ModelMeta::from_json(&meta_json)?;
        let grad_hlo = dir.join(format!("{name}.grad.hlo.txt"));
        let eval_hlo = dir.join(format!("{name}.eval.hlo.txt"));
        for p in [&grad_hlo, &eval_hlo] {
            anyhow::ensure!(p.exists(), "missing artifact file {}", p.display());
        }
        Ok(Artifact {
            meta,
            grad_hlo,
            eval_hlo,
        })
    }

    pub fn get(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.meta.name == name)
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.iter().map(|a| a.meta.name.as_str()).collect()
    }
}

/// Default artifacts directory: `$DYBW_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("DYBW_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<ArtifactSet> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        ArtifactSet::load(&dir).ok()
    }

    #[test]
    fn loads_built_artifacts_when_present() {
        // Soft test: artifacts/ may not exist in a fresh checkout.
        let Some(set) = repo_artifacts() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        assert!(set.get("lrm_d8_c4_b16").is_some());
        for a in &set.artifacts {
            a.meta.validate().unwrap();
            assert!(a.grad_hlo.exists());
            assert!(a.eval_hlo.exists());
        }
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = ArtifactSet::load(Path::new("/nonexistent/nowhere")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn missing_family_errors() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.exists() {
            assert!(ArtifactSet::load_family(&dir, "no_such_model").is_err());
        }
    }
}
