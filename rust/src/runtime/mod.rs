//! PJRT runtime: load + execute the AOT artifacts from Rust.
//!
//! The production compute path: `python -m compile.aot` lowers the Layer-2
//! JAX models (with Layer-1 Pallas kernels inlined) to HLO **text**; this
//! module parses it (`HloModuleProto::from_text_file` — text, because the
//! serialized protos from jax ≥ 0.5 carry 64-bit instruction ids that
//! xla_extension 0.5.1 rejects), compiles it once on the PJRT CPU client,
//! and executes it per-iteration with zero Python anywhere near the loop.
//!
//! - [`artifact`] — discovery + metadata (`manifest.json`, `*.meta.json`)
//! - [`exec`] — compiled model executables (grad + eval entry points)
//! - [`PjrtEngine`] — [`crate::engine::GradEngine`] over a compiled model

pub mod artifact;
pub mod exec;

pub use artifact::ArtifactSet;
pub use exec::{LoadedModel, PjrtEngine};

use std::cell::RefCell;

// The `xla` crate's PJRT handles are Rc-backed (single-threaded). One
// client per thread; threads that need compute either own their engines or
// go through `engine::server::ComputeServer`.
thread_local! {
    static CLIENT: RefCell<Option<xla::PjRtClient>> = const { RefCell::new(None) };
}

/// This thread's PJRT CPU client (created on first use, then cached).
pub fn shared_client() -> anyhow::Result<xla::PjRtClient> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            *slot = Some(
                xla::PjRtClient::cpu()
                    .map_err(|e| anyhow::anyhow!("PJRT CPU client init failed: {e}"))?,
            );
        }
        Ok(slot.clone().unwrap())
    })
}
