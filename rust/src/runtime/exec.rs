//! Compiled model executables + the PJRT gradient engine.
//!
//! A [`LoadedModel`] holds two compiled PJRT executables per model family
//! (the `(loss, grad)` training entry point and the `(loss, n_correct)`
//! eval entry point) plus the parameter-layout metadata. Compilation
//! happens once; execution reuses host-side literals and is allocation-
//! light. [`PjrtEngine`] adapts a shared `LoadedModel` to the coordinator's
//! [`GradEngine`] interface — workers clone the `Arc`, so N workers share
//! one compiled executable (PJRT executables are immutable + thread-safe).

use std::path::Path;
use std::rc::Rc;

use crate::data::batch::{Batch, SeqBatch};
use crate::engine::{AnyBatch, GradEngine};
use crate::model::{ModelKind, ModelMeta};

use super::artifact::Artifact;

/// f32 slice -> xla literal with the given dims.
fn literal_f32(dims: &[usize], data: &[f32]) -> anyhow::Result<xla::Literal> {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )?)
}

/// i32 slice -> xla literal with the given dims.
fn literal_i32(dims: &[usize], data: &[i32]) -> anyhow::Result<xla::Literal> {
    debug_assert_eq!(dims.iter().product::<usize>(), data.len());
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, std::mem::size_of_val(data))
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        dims,
        bytes,
    )?)
}

/// A model family compiled onto the PJRT client.
///
/// PJRT handles in the `xla` crate are Rc-backed, so a `LoadedModel` is
/// pinned to the thread that compiled it. Share across workers on the same
/// thread with `Rc<LoadedModel>`; cross-thread access goes through
/// `engine::server::ComputeServer`.
pub struct LoadedModel {
    pub meta: ModelMeta,
    client: xla::PjRtClient,
    grad_exe: xla::PjRtLoadedExecutable,
    eval_exe: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    /// Parse HLO text, compile both entry points. One-time cost.
    pub fn compile(artifact: &Artifact, client: xla::PjRtClient) -> anyhow::Result<Self> {
        let grad_exe = compile_hlo(&client, &artifact.grad_hlo)?;
        let eval_exe = compile_hlo(&client, &artifact.eval_hlo)?;
        Ok(LoadedModel {
            meta: artifact.meta.clone(),
            client,
            grad_exe,
            eval_exe,
        })
    }

    pub fn client(&self) -> &xla::PjRtClient {
        &self.client
    }

    fn batch_literals(&self, batch: &AnyBatch) -> anyhow::Result<(xla::Literal, xla::Literal)> {
        match (self.meta.kind, batch) {
            (ModelKind::Transformer, AnyBatch::Seq(b)) => self.seq_literals(b),
            (ModelKind::Transformer, AnyBatch::Dense(_)) => {
                anyhow::bail!("transformer artifact fed a dense batch")
            }
            (_, AnyBatch::Dense(b)) => self.dense_literals(b),
            (_, AnyBatch::Seq(_)) => anyhow::bail!("dense artifact fed a token batch"),
        }
    }

    fn dense_literals(&self, b: &Batch) -> anyhow::Result<(xla::Literal, xla::Literal)> {
        anyhow::ensure!(
            b.bsz == self.meta.batch && b.dim == self.meta.dim && b.classes == self.meta.classes,
            "batch shape ({}, {}, c{}) != artifact shape ({}, {}, c{})",
            b.bsz,
            b.dim,
            b.classes,
            self.meta.batch,
            self.meta.dim,
            self.meta.classes
        );
        Ok((
            literal_f32(&[b.bsz, b.dim], &b.x)?,
            literal_f32(&[b.bsz, b.classes], &b.y1h)?,
        ))
    }

    fn seq_literals(&self, b: &SeqBatch) -> anyhow::Result<(xla::Literal, xla::Literal)> {
        anyhow::ensure!(
            b.bsz == self.meta.batch && b.seq == self.meta.seq && b.vocab == self.meta.vocab,
            "seq batch ({}, {}, v{}) != artifact ({}, {}, v{})",
            b.bsz,
            b.seq,
            b.vocab,
            self.meta.batch,
            self.meta.seq,
            self.meta.vocab
        );
        Ok((
            literal_i32(&[b.bsz, b.seq], &b.tokens)?,
            literal_f32(&[b.bsz, b.seq, b.vocab], &b.y1h)?,
        ))
    }

    /// (loss, grad) — writes the flat gradient into `grad_out`.
    pub fn grad_into(
        &self,
        w: &[f32],
        batch: &AnyBatch,
        grad_out: &mut [f32],
    ) -> anyhow::Result<f32> {
        anyhow::ensure!(w.len() == self.meta.param_count, "param length mismatch");
        anyhow::ensure!(grad_out.len() == self.meta.param_count);
        let pw = literal_f32(&[w.len()], w)?;
        let (x, y) = self.batch_literals(batch)?;
        let result = self.grad_exe.execute::<xla::Literal>(&[pw, x, y])?[0][0]
            .to_literal_sync()?;
        let (loss_lit, grad_lit) = result.to_tuple2()?;
        let loss = loss_lit.get_first_element::<f32>()?;
        grad_lit.copy_raw_to::<f32>(grad_out)?;
        Ok(loss)
    }

    /// (loss, n_correct) on one batch.
    pub fn eval(&self, w: &[f32], batch: &AnyBatch) -> anyhow::Result<(f32, usize)> {
        anyhow::ensure!(w.len() == self.meta.param_count, "param length mismatch");
        let pw = literal_f32(&[w.len()], w)?;
        let (x, y) = self.batch_literals(batch)?;
        let result = self.eval_exe.execute::<xla::Literal>(&[pw, x, y])?[0][0]
            .to_literal_sync()?;
        let (loss_lit, correct_lit) = result.to_tuple2()?;
        Ok((
            loss_lit.get_first_element::<f32>()?,
            correct_lit.get_first_element::<f32>()? as usize,
        ))
    }
}

fn compile_hlo(
    client: &xla::PjRtClient,
    path: &Path,
) -> anyhow::Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow::anyhow!("parse {} failed: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compile {} failed: {e}", path.display()))
}

/// GradEngine over a shared compiled model. Clone one per worker (same
/// thread — see [`LoadedModel`]).
pub struct PjrtEngine {
    model: Rc<LoadedModel>,
}

impl PjrtEngine {
    pub fn new(model: Rc<LoadedModel>) -> Self {
        PjrtEngine { model }
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.model.meta
    }
}

impl GradEngine for PjrtEngine {
    fn param_count(&self) -> usize {
        self.model.meta.param_count
    }

    fn grad_into(
        &mut self,
        w: &[f32],
        batch: &AnyBatch,
        grad_out: &mut [f32],
    ) -> anyhow::Result<f32> {
        self.model.grad_into(w, batch, grad_out)
    }

    fn eval(&mut self, w: &[f32], batch: &AnyBatch) -> anyhow::Result<(f32, usize)> {
        self.model.eval(w, batch)
    }

    fn backend(&self) -> &'static str {
        "pjrt"
    }
}
