//! Per-worker engine pool: the parallel compute path.
//!
//! The sim driver used to push every worker's gradient through ONE shared
//! engine; the live driver serialised all compute behind one channel and
//! cloned a full parameter vector per call. This module replaces both with
//! an executor built from std primitives only:
//!
//! - an [`EngineFactory`] closure builds one [`GradEngine`] *per lane
//!   thread, on that thread* — which is exactly what Rc-backed PJRT
//!   handles require, and costs nothing for the native engines;
//! - callers submit *borrowed* jobs (`&[f32]` params in, `&mut [f32]`
//!   gradient out) and block until every job has been answered, so the
//!   hot path never clones a parameter vector or allocates a gradient;
//! - jobs go through a **shared queue** (`Mutex<Receiver>` the lanes pull
//!   from), so uneven job sizes — the tail eval batch, a slow PJRT queue,
//!   a heavyweight mixing row — load-balance across lanes instead of
//!   idling behind a static `idx % threads` pin;
//! - results are returned **in job order**, and each job is a pure
//!   function of its inputs (engine scratch is reset per call), so a
//!   pooled run is bit-identical to a sequential one regardless of the
//!   number of lanes or how jobs land on them.
//!
//! Besides engine work the pool runs *borrowed closures* ([`run_tasks`]):
//! type-erased `FnMut` tasks that may point into the caller's frame. This
//! is what the parallel eq. (6) mixing phase rides on — each task computes
//! one worker's weighted row-sum into a disjoint output row.
//!
//! Lanes are persistent OS threads: engines (and their scratch / device
//! buffers) live for the pool's lifetime, giving per-worker buffer reuse
//! across iterations.
//!
//! [`run_tasks`]: EnginePool::run_tasks

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use super::{AnyBatch, GradEngine};

/// Builds one engine instance; invoked once on each lane thread. Must be
/// `Send + Sync` (shared across lanes), but the engine it builds need not
/// be `Send` — it never leaves its lane.
pub type EngineFactory = Arc<dyn Fn() -> anyhow::Result<Box<dyn GradEngine>> + Send + Sync>;

// ---------------------------------------------------------------------------
// job protocol
// ---------------------------------------------------------------------------

/// Raw view of caller-owned memory. Safe to send because every pool entry
/// point blocks until every job's reply sender has been dropped (i.e. the
/// job finished, or it was destroyed unprocessed), so the pointee strictly
/// outlives every dereference on the lane side.
struct RawSlice {
    ptr: *const f32,
    len: usize,
}
unsafe impl Send for RawSlice {}

impl RawSlice {
    fn of(s: &[f32]) -> Self {
        RawSlice { ptr: s.as_ptr(), len: s.len() }
    }
    /// SAFETY: caller (the pool) guarantees the borrow is still live.
    unsafe fn get<'a>(&self) -> &'a [f32] {
        std::slice::from_raw_parts(self.ptr, self.len)
    }
}

struct RawSliceMut {
    ptr: *mut f32,
    len: usize,
}
unsafe impl Send for RawSliceMut {}

impl RawSliceMut {
    fn of(s: &mut [f32]) -> Self {
        RawSliceMut { ptr: s.as_mut_ptr(), len: s.len() }
    }
    unsafe fn get<'a>(&self) -> &'a mut [f32] {
        std::slice::from_raw_parts_mut(self.ptr, self.len)
    }
}

struct RawBatch {
    ptr: *const AnyBatch,
}
unsafe impl Send for RawBatch {}

impl RawBatch {
    fn of(b: &AnyBatch) -> Self {
        RawBatch { ptr: b }
    }
    unsafe fn get<'a>(&self) -> &'a AnyBatch {
        &*self.ptr
    }
}

/// Type-erased borrowed closure: a thin data pointer plus a monomorphised
/// trampoline, so non-`'static` tasks cross the channel without boxing.
/// The lifetime argument is the same as [`RawSlice`]'s: the submitting
/// call blocks until the job is answered or provably destroyed.
struct RawTask {
    data: *mut u8,
    call: unsafe fn(*mut u8) -> anyhow::Result<()>,
}
unsafe impl Send for RawTask {}

impl RawTask {
    fn of<F>(f: &mut F) -> Self
    where
        F: FnMut() -> anyhow::Result<()> + Send,
    {
        unsafe fn trampoline<F>(p: *mut u8) -> anyhow::Result<()>
        where
            F: FnMut() -> anyhow::Result<()>,
        {
            (*(p as *mut F))()
        }
        RawTask { data: f as *mut F as *mut u8, call: trampoline::<F> }
    }

    /// SAFETY: caller (the pool) guarantees the closure is still live and
    /// that no other lane holds this same task.
    unsafe fn invoke(&self) -> anyhow::Result<()> {
        (self.call)(self.data)
    }
}

enum JobKind {
    /// Write the flat gradient into the leased buffer, return the loss.
    Grad {
        w: RawSlice,
        batch: RawBatch,
        out: RawSliceMut,
    },
    /// Loss + correct count, no gradient.
    Eval { w: RawSlice, batch: RawBatch },
    /// Generic non-engine work (e.g. one eq. (6) mixing row).
    Task(RawTask),
}

enum JobOut {
    Grad(f32),
    Eval(f32, usize),
    Unit,
}

/// One queued unit of work. Each job carries its own clone of the
/// submitting call's reply sender; the clone is dropped when the job has
/// been answered — or when the job is destroyed unprocessed (failed send,
/// queue torn down) — which is what lets the submitter prove no lane
/// still holds a pointer into its frame.
struct Job {
    idx: usize,
    kind: JobKind,
    reply: Sender<Done>,
    /// Submission timestamp, stamped only while a telemetry observer is
    /// installed ([`crate::obs`]) — feeds the queue-wait histogram.
    queued_at: Option<Instant>,
}

struct Done {
    idx: usize,
    out: anyhow::Result<JobOut>,
}

// ---------------------------------------------------------------------------
// the pool
// ---------------------------------------------------------------------------

/// Fixed set of lane threads pulling from one shared job queue; one
/// engine per lane.
pub struct EnginePool {
    /// Submission side of the shared queue (`None` only during drop).
    queue: Option<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
    param_count: usize,
    backend: &'static str,
}

impl EnginePool {
    /// Spawn `threads` lanes; the factory runs once on each. Fails (and
    /// joins already-spawned lanes) if any factory invocation fails.
    pub fn new(factory: EngineFactory, threads: usize) -> anyhow::Result<EnginePool> {
        anyhow::ensure!(threads > 0, "engine pool needs >= 1 thread");
        let (queue_tx, queue_rx) = channel::<Job>();
        let shared_rx = Arc::new(Mutex::new(queue_rx));
        let (init_tx, init_rx) = channel::<anyhow::Result<(usize, &'static str)>>();
        // Share the machine between lane-level and kernel-level
        // parallelism: each lane's GEMMs may use at most cores/T scoped
        // threads (so a 1-lane pool keeps full intra-op parallelism and a
        // wide pool doesn't oversubscribe to T × 8 kernel threads).
        let kernel_cap = (std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            / threads)
            .max(1);
        let mut handles = Vec::with_capacity(threads);
        for lane in 0..threads {
            let factory = Arc::clone(&factory);
            let init_tx = init_tx.clone();
            let shared_rx = Arc::clone(&shared_rx);
            let spawned = std::thread::Builder::new()
                .name(format!("dybw-lane-{lane}"))
                .spawn(move || lane_loop(lane, factory, init_tx, shared_rx, kernel_cap));
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    // Same no-orphaned-threads guarantee as the
                    // init-failure path below: hang up the queue and join
                    // the lanes that did spawn before surfacing the error.
                    drop(queue_tx);
                    for h in handles {
                        let _ = h.join();
                    }
                    anyhow::bail!("failed to spawn engine pool lane {lane}: {e}");
                }
            }
        }
        drop(init_tx);
        drop(shared_rx); // only the lanes hold the queue receiver now
        let mut param_count = 0usize;
        let mut backend: &'static str = "?";
        for _ in 0..threads {
            let init = init_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("engine pool lane crashed during init"))
                .and_then(|r| r);
            match init {
                Ok((p, b)) => {
                    param_count = p;
                    backend = b;
                }
                Err(e) => {
                    // hang up and join the lanes that did come up before
                    // surfacing the failure — no orphaned threads.
                    drop(queue_tx);
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(e);
                }
            }
        }
        Ok(EnginePool {
            queue: Some(queue_tx),
            handles,
            threads,
            param_count,
            backend,
        })
    }

    /// Lanes-only pool for borrowed-closure work ([`run_tasks`]): no real
    /// engine is built, and grad/eval jobs error. For harnesses and
    /// benches that need the shared-queue scheduler, not the engines.
    ///
    /// [`run_tasks`]: Self::run_tasks
    pub fn tasks_only(threads: usize) -> anyhow::Result<EnginePool> {
        struct NullEngine;
        impl GradEngine for NullEngine {
            fn param_count(&self) -> usize {
                0
            }
            fn grad_into(
                &mut self,
                _w: &[f32],
                _batch: &AnyBatch,
                _grad_out: &mut [f32],
            ) -> anyhow::Result<f32> {
                anyhow::bail!("tasks-only pool has no engine")
            }
            fn eval(&mut self, _w: &[f32], _batch: &AnyBatch) -> anyhow::Result<(f32, usize)> {
                anyhow::bail!("tasks-only pool has no engine")
            }
            fn backend(&self) -> &'static str {
                "tasks-only"
            }
        }
        let factory: EngineFactory = Arc::new(|| Ok(Box::new(NullEngine) as Box<dyn GradEngine>));
        EnginePool::new(factory, threads)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn param_count(&self) -> usize {
        self.param_count
    }

    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// Fan one gradient job per worker across the lanes: job `i` reads
    /// `ws[i]` and `batches[i]` and writes into its leased `grad_outs[i]`.
    /// Losses come back in job order, so reductions over them are
    /// deterministic no matter how lanes raced.
    pub fn grad_many(
        &self,
        ws: &[&[f32]],
        batches: &[AnyBatch],
        grad_outs: &mut [Vec<f32>],
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            ws.len() == batches.len() && ws.len() == grad_outs.len(),
            "grad_many: mismatched job arity"
        );
        let mut outs = grad_outs.iter_mut();
        let kinds = ws
            .iter()
            .zip(batches)
            .map(|(w, batch)| JobKind::Grad {
                w: RawSlice::of(w),
                batch: RawBatch::of(batch),
                out: RawSliceMut::of(outs.next().unwrap()),
            })
            .collect();
        let results = self.run_jobs(kinds)?;
        results
            .into_iter()
            .map(|out| match out {
                JobOut::Grad(loss) => Ok(loss),
                _ => unreachable!("grad job returned non-grad result"),
            })
            .collect()
    }

    /// Evaluate one parameter vector over many batches in parallel;
    /// `(loss, correct)` pairs come back in batch order.
    pub fn eval_many(&self, w: &[f32], batches: &[AnyBatch]) -> anyhow::Result<Vec<(f32, usize)>> {
        let kinds = batches
            .iter()
            .map(|batch| JobKind::Eval {
                w: RawSlice::of(w),
                batch: RawBatch::of(batch),
            })
            .collect();
        let results = self.run_jobs(kinds)?;
        results
            .into_iter()
            .map(|out| match out {
                JobOut::Eval(loss, correct) => Ok((loss, correct)),
                _ => unreachable!("eval job returned non-eval result"),
            })
            .collect()
    }

    /// Score one parameter vector on a full eval set: batches fan across
    /// the lanes via [`Self::eval_many`], the row-weighted reduction
    /// runs in batch order (so the result is independent of the pool
    /// size). Returns `(mean test loss, error fraction)` — the one
    /// definition of the eval metric shared by the lockstep and the
    /// event-driven trainers.
    pub fn score(&self, w: &[f32], eval_batches: &[AnyBatch]) -> anyhow::Result<(f64, f64)> {
        let scores = self.eval_many(w, eval_batches)?;
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;
        let mut rows = 0usize;
        for ((loss, corr), b) in scores.into_iter().zip(eval_batches) {
            let r = b.rows();
            loss_sum += loss as f64 * r as f64;
            correct += corr;
            rows += r;
        }
        anyhow::ensure!(rows > 0, "empty eval set");
        Ok((loss_sum / rows as f64, 1.0 - correct as f64 / rows as f64))
    }

    /// Fan per-worker gradient jobs AND generic borrowed-closure tasks in
    /// ONE queue submission: the gradients are enqueued first, the tasks
    /// drain on whatever lane capacity is spare. This is the
    /// batch-prefetch overlap — draw iteration k+1's batches while k's
    /// gradients run — without a second synchronisation barrier. Losses
    /// come back in grad-job order; every task runs exactly once; the
    /// call blocks until ALL jobs (grads and tasks) are answered, so the
    /// usual borrowed-pointer soundness invariant of [`run_jobs`] holds.
    ///
    /// [`run_jobs`]: Self::run_jobs
    pub fn grad_many_overlapped<F>(
        &self,
        ws: &[&[f32]],
        batches: &[AnyBatch],
        grad_outs: &mut [Vec<f32>],
        tasks: &mut [F],
    ) -> anyhow::Result<Vec<f32>>
    where
        F: FnMut() -> anyhow::Result<()> + Send,
    {
        anyhow::ensure!(
            ws.len() == batches.len() && ws.len() == grad_outs.len(),
            "grad_many_overlapped: mismatched job arity"
        );
        let n_grads = ws.len();
        let mut outs = grad_outs.iter_mut();
        let mut kinds: Vec<JobKind> = ws
            .iter()
            .zip(batches)
            .map(|(w, batch)| JobKind::Grad {
                w: RawSlice::of(w),
                batch: RawBatch::of(batch),
                out: RawSliceMut::of(outs.next().unwrap()),
            })
            .collect();
        kinds.extend(tasks.iter_mut().map(|f| JobKind::Task(RawTask::of(f))));
        let results = self.run_jobs(kinds)?;
        results
            .into_iter()
            .take(n_grads)
            .map(|out| match out {
                JobOut::Grad(loss) => Ok(loss),
                _ => unreachable!("grad job returned non-grad result"),
            })
            .collect()
    }

    /// Run independent borrowed closures across the lanes (non-engine
    /// work — e.g. the parallel eq. (6) mixing rows), blocking until all
    /// of them have finished. Task `i` runs exactly once, on whichever
    /// lane pulls it; errors surface lowest-index-first. Tasks may borrow
    /// caller state: the blocking-drain invariant of [`run_jobs`] is what
    /// makes handing their raw pointers to the lanes sound.
    ///
    /// [`run_jobs`]: Self::run_jobs
    pub fn run_tasks<F>(&self, tasks: &mut [F]) -> anyhow::Result<()>
    where
        F: FnMut() -> anyhow::Result<()> + Send,
    {
        let kinds = tasks
            .iter_mut()
            .map(|f| JobKind::Task(RawTask::of(f)))
            .collect();
        self.run_jobs(kinds).map(|_| ())
    }

    /// One gradient on whichever lane is free first; blocks until done.
    /// This is the live-mode entry point — many worker threads may call
    /// it concurrently, and the shared queue hands each request to the
    /// next idle lane (no static worker→lane affinity).
    pub fn grad_one(
        &self,
        w: &[f32],
        batch: &AnyBatch,
        grad_out: &mut [f32],
    ) -> anyhow::Result<f32> {
        let kind = JobKind::Grad {
            w: RawSlice::of(w),
            batch: RawBatch::of(batch),
            out: RawSliceMut::of(grad_out),
        };
        match self.run_jobs(vec![kind])?.pop() {
            Some(JobOut::Grad(loss)) => Ok(loss),
            _ => anyhow::bail!("engine pool returned no result"),
        }
    }

    /// One evaluation on whichever lane is free first; blocks until done.
    pub fn eval_one(&self, w: &[f32], batch: &AnyBatch) -> anyhow::Result<(f32, usize)> {
        let kind = JobKind::Eval {
            w: RawSlice::of(w),
            batch: RawBatch::of(batch),
        };
        match self.run_jobs(vec![kind])?.pop() {
            Some(JobOut::Eval(loss, correct)) => Ok((loss, correct)),
            _ => anyhow::bail!("engine pool returned no result"),
        }
    }

    /// Push jobs onto the shared queue (any lane may pull any job) and
    /// block for all replies, returned in job order.
    ///
    /// Soundness invariant: this function NEVER returns — not even on the
    /// error paths — until every job's reply sender is gone, i.e. every
    /// job either finished on some lane or was destroyed unprocessed. A
    /// failed send returns (and drops) its job without any lane having
    /// seen it; jobs stranded in the queue when the lanes die are dropped
    /// by the queue receiver's destructor. Either way [`collect`] observes
    /// the hang-up and no lane still holds a pointer into the caller's
    /// frame when this returns.
    ///
    /// [`collect`]: Self::collect
    fn run_jobs(&self, kinds: Vec<JobKind>) -> anyhow::Result<Vec<JobOut>> {
        let expected = kinds.len();
        if expected == 0 {
            return Ok(Vec::new());
        }
        let queue = self.queue.as_ref().expect("engine pool queue alive");
        let (reply, results_rx) = channel::<Done>();
        // Telemetry (observational only): stamp submission time and bump
        // the shared queue-depth gauge; each lane decrements on pull.
        let obs = crate::obs::active();
        let queued_at = obs.as_ref().map(|o| {
            o.registry.gauge("pool/queue_depth").add(expected as i64);
            Instant::now()
        });
        let mut all_sent = true;
        for (idx, kind) in kinds.into_iter().enumerate() {
            let job = Job { idx, kind, reply: reply.clone(), queued_at };
            if queue.send(job).is_err() {
                // every lane is gone; the failed send returned (and
                // dropped) this job, and the remaining kinds are dropped
                // with the iterator — none of them reached a lane.
                all_sent = false;
                break;
            }
        }
        drop(reply);
        let results = Self::collect(results_rx, expected);
        anyhow::ensure!(all_sent, "engine pool lanes are gone");
        results
    }

    /// Drain replies until every job is answered or every reply sender is
    /// gone. The recv loop only ends once no lane (and no queue slot) can
    /// still reach this call's jobs, which is what makes handing raw
    /// borrows to the lanes sound: when this returns, no pointer into the
    /// caller's frame survives outside it.
    fn collect(results_rx: Receiver<Done>, expected: usize) -> anyhow::Result<Vec<JobOut>> {
        let mut slots: Vec<Option<anyhow::Result<JobOut>>> = Vec::new();
        slots.resize_with(expected, || None);
        let mut received = 0usize;
        while received < expected {
            match results_rx.recv() {
                Ok(done) => {
                    slots[done.idx] = Some(done.out);
                    received += 1;
                }
                Err(_) => break, // a lane died mid-call; all senders gone
            }
        }
        anyhow::ensure!(
            received == expected,
            "engine pool lane died mid-call ({received}/{expected} jobs completed)"
        );
        // surface the lowest-index error (deterministic) or unwrap all
        let mut out = Vec::with_capacity(expected);
        for slot in slots {
            out.push(slot.expect("collect counted a missing slot")?);
        }
        Ok(out)
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        self.queue = None; // hang up -> lanes exit their recv loop
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn lane_loop(
    lane: usize,
    factory: EngineFactory,
    init_tx: Sender<anyhow::Result<(usize, &'static str)>>,
    queue: Arc<Mutex<Receiver<Job>>>,
    kernel_cap: usize,
) {
    // Bit-identical at any cap — this is purely a scheduling choice.
    crate::model::linalg::set_intra_op_cap(kernel_cap);
    let mut engine = match factory() {
        Ok(e) => {
            let _ = init_tx.send(Ok((e.param_count(), e.backend())));
            e
        }
        Err(e) => {
            let _ = init_tx.send(Err(e));
            return;
        }
    };
    drop(init_tx);
    // Telemetry names resolved once per lane; the instruments themselves
    // are fetched per job because an observer may be installed or torn
    // down while the pool is alive. With no observer the per-job cost is
    // one relaxed atomic load (`obs::enabled`).
    let track = format!("lane-{lane}");
    let busy_name = format!("pool/{track}/busy_us");
    let idle_name = format!("pool/{track}/idle_us");
    crate::obs::span::set_track(&track);
    loop {
        let idle_start = crate::obs::enabled().then(Instant::now);
        // Pull the next job from the shared queue. Holding the lock
        // across the blocking recv is deliberate: an idle lane parks
        // inside recv with the lock held, peers park on the mutex, and
        // each arriving job wakes exactly one lane. A poisoned lock (a
        // peer panicked mid-pull) still yields a usable receiver.
        let job = {
            let rx = match queue.lock() {
                Ok(guard) => guard,
                Err(poisoned) => poisoned.into_inner(),
            };
            rx.recv()
        };
        let Ok(Job { idx, kind, reply, queued_at }) = job else {
            break; // pool hung up
        };
        let obs = if crate::obs::enabled() { crate::obs::active() } else { None };
        if let Some(o) = &obs {
            if let Some(t0) = idle_start {
                o.registry.counter(&idle_name).add(t0.elapsed().as_micros() as u64);
            }
            o.registry.gauge("pool/queue_depth").add(-1);
            if let Some(t) = queued_at {
                o.registry
                    .histogram("pool/job_wait_secs")
                    .record_secs(t.elapsed().as_secs_f64());
            }
        }
        let busy_start = obs.as_ref().map(|o| (Instant::now(), o.now_us()));
        // SAFETY: the submitting pool call blocks until this job's
        // `reply` clone is dropped, so every raw pointer in `kind` is
        // live for the duration of this dereference.
        let out = unsafe {
            match kind {
                JobKind::Grad { w, batch, out } => {
                    engine.grad_into(w.get(), batch.get(), out.get()).map(JobOut::Grad)
                }
                JobKind::Eval { w, batch } => {
                    engine.eval(w.get(), batch.get()).map(|(l, c)| JobOut::Eval(l, c))
                }
                JobKind::Task(task) => task.invoke().map(|_| JobOut::Unit),
            }
        };
        if let (Some(o), Some((t0, start_us))) = (&obs, busy_start) {
            let busy = t0.elapsed();
            o.registry.counter(&busy_name).add(busy.as_micros() as u64);
            o.registry.histogram("pool/job_secs").record_secs(busy.as_secs_f64());
            if let Some(sink) = o.trace() {
                sink.complete(&track, "job", start_us, busy.as_micros() as u64, &[]);
            }
        }
        let _ = reply.send(Done { idx, out });
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batch::BatchSampler;
    use crate::data::synthetic::{gaussian_mixture, MixtureSpec};
    use crate::engine::{native_factory, NativeEngine};
    use crate::model::ModelMeta;
    use crate::util::rng::Rng;

    fn fixture(n_jobs: usize) -> (ModelMeta, Vec<f32>, Vec<AnyBatch>) {
        let meta = ModelMeta::lrm(8, 10, 16);
        let mut rng = Rng::new(0);
        let data = gaussian_mixture(&MixtureSpec::mnist_like(8, 400), &mut rng);
        let mut sampler = BatchSampler::new(1);
        let batches = (0..n_jobs)
            .map(|_| AnyBatch::Dense(sampler.sample(&data, 16)))
            .collect();
        let w = meta.init_params(&mut rng);
        (meta, w, batches)
    }

    #[test]
    fn pooled_grads_match_direct_engine() {
        let (meta, w, batches) = fixture(8);
        let pool = EnginePool::new(native_factory(meta.clone()), 3).unwrap();
        assert_eq!(pool.threads(), 3);
        assert_eq!(pool.param_count(), meta.param_count);
        assert_eq!(pool.backend(), "native");

        let ws: Vec<&[f32]> = (0..8).map(|_| w.as_slice()).collect();
        let mut outs = vec![vec![0.0f32; meta.param_count]; 8];
        let losses = pool.grad_many(&ws, &batches, &mut outs).unwrap();

        let mut eng = NativeEngine::new(meta.clone()).unwrap();
        let mut g = vec![0.0f32; meta.param_count];
        for (i, b) in batches.iter().enumerate() {
            let loss = eng.grad_into(&w, b, &mut g).unwrap();
            assert_eq!(loss.to_bits(), losses[i].to_bits(), "loss {i} differs");
            assert_eq!(g, outs[i], "gradient {i} differs");
        }
    }

    #[test]
    fn pool_size_does_not_change_results() {
        let (meta, w, batches) = fixture(7);
        let ws: Vec<&[f32]> = (0..7).map(|_| w.as_slice()).collect();
        let run = |threads: usize| {
            let pool = EnginePool::new(native_factory(meta.clone()), threads).unwrap();
            let mut outs = vec![vec![0.0f32; meta.param_count]; 7];
            let losses = pool.grad_many(&ws, &batches, &mut outs).unwrap();
            (losses, outs)
        };
        let (l1, g1) = run(1);
        let (l4, g4) = run(4);
        assert_eq!(
            l1.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            l4.iter().map(|l| l.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(g1, g4);
    }

    #[test]
    fn eval_many_matches_direct_engine() {
        let (meta, w, batches) = fixture(5);
        let pool = EnginePool::new(native_factory(meta.clone()), 2).unwrap();
        let got = pool.eval_many(&w, &batches).unwrap();
        let mut eng = NativeEngine::new(meta).unwrap();
        for (i, b) in batches.iter().enumerate() {
            let (l, c) = eng.eval(&w, b).unwrap();
            assert_eq!(l.to_bits(), got[i].0.to_bits());
            assert_eq!(c, got[i].1);
        }
    }

    #[test]
    fn single_job_entry_points_work_concurrently() {
        let (meta, w, batches) = fixture(4);
        let pool = Arc::new(EnginePool::new(native_factory(meta.clone()), 2).unwrap());
        let handles: Vec<_> = batches
            .into_iter()
            .map(|b| {
                let pool = Arc::clone(&pool);
                let w = w.clone();
                let p = meta.param_count;
                std::thread::spawn(move || {
                    let mut g = vec![0.0f32; p];
                    let loss = pool.grad_one(&w, &b, &mut g).unwrap();
                    let (le, _) = pool.eval_one(&w, &b).unwrap();
                    (loss, le, g)
                })
            })
            .collect();
        for h in handles {
            let (loss, le, g) = h.join().unwrap();
            assert!(loss.is_finite() && (le - loss).abs() < 1e-6);
            assert!(g.iter().any(|&v| v != 0.0));
        }
    }

    #[test]
    fn run_tasks_executes_every_closure_exactly_once() {
        let (meta, ..) = fixture(0);
        let pool = EnginePool::new(native_factory(meta), 3).unwrap();
        // Borrowed output slots: each task writes its own, none may race.
        let mut slots = vec![0u64; 17];
        {
            let mut tasks: Vec<_> = slots
                .iter_mut()
                .enumerate()
                .map(|(i, slot)| {
                    move || -> anyhow::Result<()> {
                        *slot += (i as u64 + 1) * 3;
                        Ok(())
                    }
                })
                .collect();
            pool.run_tasks(&mut tasks).unwrap();
        }
        for (i, &v) in slots.iter().enumerate() {
            assert_eq!(v, (i as u64 + 1) * 3, "task {i} ran {v} times the increment");
        }
    }

    #[test]
    fn grad_many_overlapped_runs_grads_and_tasks() {
        let (meta, w, batches) = fixture(6);
        let pool = EnginePool::new(native_factory(meta.clone()), 3).unwrap();
        let ws: Vec<&[f32]> = (0..6).map(|_| w.as_slice()).collect();
        let mut plain = vec![vec![0.0f32; meta.param_count]; 6];
        let expected = pool.grad_many(&ws, &batches, &mut plain).unwrap();

        let mut outs = vec![vec![0.0f32; meta.param_count]; 6];
        let mut hits = vec![0u32; 5];
        let losses = {
            let mut tasks: Vec<_> = hits
                .iter_mut()
                .map(|h| {
                    move || -> anyhow::Result<()> {
                        *h += 1;
                        Ok(())
                    }
                })
                .collect();
            let r = pool.grad_many_overlapped(&ws, &batches, &mut outs, &mut tasks);
            r.unwrap()
        };
        // gradients and losses are exactly those of the plain fan-out...
        assert_eq!(
            losses.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            expected.iter().map(|l| l.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(outs, plain);
        // ...and every overlapped task ran exactly once.
        assert!(hits.iter().all(|&h| h == 1), "{hits:?}");
    }

    #[test]
    fn grad_many_overlapped_with_no_tasks_matches_grad_many() {
        let (meta, w, batches) = fixture(3);
        let pool = EnginePool::new(native_factory(meta.clone()), 2).unwrap();
        let ws: Vec<&[f32]> = (0..3).map(|_| w.as_slice()).collect();
        let mut a = vec![vec![0.0f32; meta.param_count]; 3];
        let mut b = vec![vec![0.0f32; meta.param_count]; 3];
        let la = pool.grad_many(&ws, &batches, &mut a).unwrap();
        let mut none: Vec<fn() -> anyhow::Result<()>> = Vec::new();
        let lb = pool.grad_many_overlapped(&ws, &batches, &mut b, &mut none);
        assert_eq!(la, lb.unwrap());
        assert_eq!(a, b);
    }

    #[test]
    fn run_tasks_surfaces_lowest_index_error() {
        let (meta, w, batches) = fixture(2);
        let pool = EnginePool::new(native_factory(meta.clone()), 2).unwrap();
        let mut tasks: Vec<_> = (0..6)
            .map(|i| {
                move || -> anyhow::Result<()> {
                    anyhow::ensure!(i % 2 == 0, "task {i} failed");
                    Ok(())
                }
            })
            .collect();
        let err = pool.run_tasks(&mut tasks).unwrap_err();
        assert!(err.to_string().contains("task 1 failed"), "{err}");
        // the SAME pool survives task errors: its lanes still serve both
        // further tasks and engine work
        let mut again: Vec<_> = (0..3).map(|_| || -> anyhow::Result<()> { Ok(()) }).collect();
        assert!(pool.run_tasks(&mut again).is_ok());
        let ws: Vec<&[f32]> = (0..2).map(|_| w.as_slice()).collect();
        let mut outs = vec![vec![0.0f32; meta.param_count]; 2];
        assert!(pool.grad_many(&ws, &batches, &mut outs).is_ok());
    }

    #[test]
    fn uneven_tasks_load_balance_across_lanes() {
        // One deliberately slow task plus many fast ones: with a shared
        // queue the fast tasks drain on the other lane while the slow one
        // occupies its lane; with static pinning half of them would queue
        // behind the sleeper. Assert correctness (everything ran) — the
        // scheduling itself is what the wall-clock benches measure.
        let (meta, ..) = fixture(0);
        let pool = EnginePool::new(native_factory(meta), 2).unwrap();
        let mut hits = vec![0u32; 9];
        {
            let mut tasks: Vec<_> = hits
                .iter_mut()
                .enumerate()
                .map(|(i, h)| {
                    move || -> anyhow::Result<()> {
                        if i == 0 {
                            std::thread::sleep(std::time::Duration::from_millis(30));
                        }
                        *h += 1;
                        Ok(())
                    }
                })
                .collect();
            pool.run_tasks(&mut tasks).unwrap();
        }
        assert!(hits.iter().all(|&h| h == 1));
    }

    #[test]
    fn factory_failure_surfaces_at_construction() {
        let factory: EngineFactory = Arc::new(|| anyhow::bail!("no engine for you"));
        let err = EnginePool::new(factory, 2).unwrap_err();
        assert!(err.to_string().contains("no engine"), "{err}");
    }

    #[test]
    fn engine_error_mid_run_is_an_err_not_a_hang() {
        // An engine that computes fine but errors on transformer batches:
        // feed it a Seq batch to trigger the dense() type check.
        let (meta, w, mut batches) = fixture(3);
        batches[1] = AnyBatch::Seq(crate::data::batch::SeqBatch {
            bsz: 1,
            seq: 4,
            vocab: 2,
            tokens: vec![0; 4],
            y1h: vec![0.0; 8],
        });
        let pool = EnginePool::new(native_factory(meta.clone()), 2).unwrap();
        let ws: Vec<&[f32]> = (0..3).map(|_| w.as_slice()).collect();
        let mut outs = vec![vec![0.0f32; meta.param_count]; 3];
        let err = pool.grad_many(&ws, &batches, &mut outs).unwrap_err();
        assert!(err.to_string().contains("dense"), "{err}");
        // the pool survives a job error: subsequent calls still work
        batches[1] = batches[0].clone();
        assert!(pool.grad_many(&ws, &batches, &mut outs).is_ok());
    }

    #[test]
    fn zero_threads_rejected() {
        let (meta, ..) = fixture(0);
        assert!(EnginePool::new(native_factory(meta), 0).is_err());
        assert!(EnginePool::tasks_only(0).is_err());
    }

    #[test]
    fn tasks_only_pool_runs_closures_but_rejects_engine_work() {
        let pool = EnginePool::tasks_only(2).unwrap();
        assert_eq!(pool.backend(), "tasks-only");
        let mut total = vec![0u32; 5];
        let mut tasks: Vec<_> = total
            .iter_mut()
            .map(|t| {
                move || -> anyhow::Result<()> {
                    *t += 1;
                    Ok(())
                }
            })
            .collect();
        pool.run_tasks(&mut tasks).unwrap();
        drop(tasks);
        assert!(total.iter().all(|&t| t == 1));
        let (_, w, batches) = fixture(1);
        let mut g = vec![0.0f32; 1];
        let err = pool.grad_one(&w, &batches[0], &mut g).unwrap_err();
        assert!(err.to_string().contains("no engine"), "{err}");
    }
}
