//! Per-worker engine pool: the parallel compute path.
//!
//! The sim driver used to push every worker's gradient through ONE shared
//! engine; the live driver serialised all compute behind one channel and
//! cloned a full parameter vector per call. This module replaces both with
//! an executor built from std primitives only:
//!
//! - an [`EngineFactory`] closure builds one [`GradEngine`] *per lane
//!   thread, on that thread* — which is exactly what Rc-backed PJRT
//!   handles require, and costs nothing for the native engines;
//! - callers submit *borrowed* jobs (`&[f32]` params in, `&mut [f32]`
//!   gradient out) and block until every lane has replied, so the hot
//!   path never clones a parameter vector or allocates a gradient;
//! - results are returned **in job order**, and each job is a pure
//!   function of `(w, batch)` (engine scratch is reset per call), so a
//!   pooled run is bit-identical to a sequential one regardless of the
//!   number of lanes or how jobs land on them.
//!
//! Lanes are persistent OS threads: engines (and their scratch / device
//! buffers) live for the pool's lifetime, giving per-worker buffer reuse
//! across iterations.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use super::{AnyBatch, GradEngine};

/// Builds one engine instance; invoked once on each lane thread. Must be
/// `Send + Sync` (shared across lanes), but the engine it builds need not
/// be `Send` — it never leaves its lane.
pub type EngineFactory = Arc<dyn Fn() -> anyhow::Result<Box<dyn GradEngine>> + Send + Sync>;

// ---------------------------------------------------------------------------
// job protocol
// ---------------------------------------------------------------------------

/// Raw view of caller-owned memory. Safe to send because every pool entry
/// point blocks until all lanes serving the call have dropped their reply
/// sender (i.e. finished or died), so the pointee strictly outlives every
/// dereference on the lane side.
struct RawSlice {
    ptr: *const f32,
    len: usize,
}
unsafe impl Send for RawSlice {}

impl RawSlice {
    fn of(s: &[f32]) -> Self {
        RawSlice { ptr: s.as_ptr(), len: s.len() }
    }
    /// SAFETY: caller (the pool) guarantees the borrow is still live.
    unsafe fn get<'a>(&self) -> &'a [f32] {
        std::slice::from_raw_parts(self.ptr, self.len)
    }
}

struct RawSliceMut {
    ptr: *mut f32,
    len: usize,
}
unsafe impl Send for RawSliceMut {}

impl RawSliceMut {
    fn of(s: &mut [f32]) -> Self {
        RawSliceMut { ptr: s.as_mut_ptr(), len: s.len() }
    }
    unsafe fn get<'a>(&self) -> &'a mut [f32] {
        std::slice::from_raw_parts_mut(self.ptr, self.len)
    }
}

struct RawBatch {
    ptr: *const AnyBatch,
}
unsafe impl Send for RawBatch {}

impl RawBatch {
    fn of(b: &AnyBatch) -> Self {
        RawBatch { ptr: b }
    }
    unsafe fn get<'a>(&self) -> &'a AnyBatch {
        &*self.ptr
    }
}

enum JobKind {
    /// Write the flat gradient into the leased buffer, return the loss.
    Grad(RawSliceMut),
    /// Loss + correct count, no gradient.
    Eval,
}

struct Job {
    idx: usize,
    w: RawSlice,
    batch: RawBatch,
    kind: JobKind,
}

enum JobOut {
    Grad(f32),
    Eval(f32, usize),
}

struct Done {
    idx: usize,
    out: anyhow::Result<JobOut>,
}

struct RunMsg {
    jobs: Vec<Job>,
    reply: Sender<Done>,
}

// ---------------------------------------------------------------------------
// the pool
// ---------------------------------------------------------------------------

/// Fixed set of lane threads, one engine per lane.
pub struct EnginePool {
    lanes: Vec<Sender<RunMsg>>,
    handles: Vec<JoinHandle<()>>,
    param_count: usize,
    backend: &'static str,
    /// Round-robin cursor for single-job submissions (live mode).
    rr: AtomicUsize,
}

impl EnginePool {
    /// Spawn `threads` lanes; the factory runs once on each. Fails (and
    /// joins already-spawned lanes) if any factory invocation fails.
    pub fn new(factory: EngineFactory, threads: usize) -> anyhow::Result<EnginePool> {
        anyhow::ensure!(threads > 0, "engine pool needs >= 1 thread");
        let mut lanes = Vec::with_capacity(threads);
        let mut handles = Vec::with_capacity(threads);
        let (init_tx, init_rx) = channel::<anyhow::Result<(usize, &'static str)>>();
        // Share the machine between lane-level and kernel-level
        // parallelism: each lane's GEMMs may use at most cores/T scoped
        // threads (so a 1-lane pool keeps full intra-op parallelism and a
        // wide pool doesn't oversubscribe to T × 8 kernel threads).
        let kernel_cap = (std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            / threads)
            .max(1);
        for lane in 0..threads {
            let (tx, rx) = channel::<RunMsg>();
            let factory = Arc::clone(&factory);
            let init_tx = init_tx.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("dybw-lane-{lane}"))
                    .spawn(move || lane_loop(factory, init_tx, rx, kernel_cap))?,
            );
            lanes.push(tx);
        }
        drop(init_tx);
        let mut param_count = 0usize;
        let mut backend: &'static str = "?";
        for _ in 0..threads {
            let init = init_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("engine pool lane crashed during init"))
                .and_then(|r| r);
            match init {
                Ok((p, b)) => {
                    param_count = p;
                    backend = b;
                }
                Err(e) => {
                    // hang up and join the lanes that did come up before
                    // surfacing the failure — no orphaned threads.
                    drop(lanes);
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(e);
                }
            }
        }
        Ok(EnginePool {
            lanes,
            handles,
            param_count,
            backend,
            rr: AtomicUsize::new(0),
        })
    }

    pub fn threads(&self) -> usize {
        self.lanes.len()
    }

    pub fn param_count(&self) -> usize {
        self.param_count
    }

    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// Fan one gradient job per worker across the lanes: job `i` reads
    /// `ws[i]` and `batches[i]` and writes into its leased `grad_outs[i]`.
    /// Losses come back in job order, so reductions over them are
    /// deterministic no matter how lanes raced.
    pub fn grad_many(
        &self,
        ws: &[&[f32]],
        batches: &[AnyBatch],
        grad_outs: &mut [Vec<f32>],
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(
            ws.len() == batches.len() && ws.len() == grad_outs.len(),
            "grad_many: mismatched job arity"
        );
        let mut outs = grad_outs.iter_mut();
        let jobs = ws
            .iter()
            .zip(batches)
            .enumerate()
            .map(|(idx, (w, batch))| Job {
                idx,
                w: RawSlice::of(w),
                batch: RawBatch::of(batch),
                kind: JobKind::Grad(RawSliceMut::of(outs.next().unwrap())),
            })
            .collect();
        let results = self.run_jobs(jobs)?;
        results
            .into_iter()
            .map(|out| match out {
                JobOut::Grad(loss) => Ok(loss),
                JobOut::Eval(..) => unreachable!("grad job returned eval result"),
            })
            .collect()
    }

    /// Evaluate one parameter vector over many batches in parallel;
    /// `(loss, correct)` pairs come back in batch order.
    pub fn eval_many(
        &self,
        w: &[f32],
        batches: &[AnyBatch],
    ) -> anyhow::Result<Vec<(f32, usize)>> {
        let jobs = batches
            .iter()
            .enumerate()
            .map(|(idx, batch)| Job {
                idx,
                w: RawSlice::of(w),
                batch: RawBatch::of(batch),
                kind: JobKind::Eval,
            })
            .collect();
        let results = self.run_jobs(jobs)?;
        results
            .into_iter()
            .map(|out| match out {
                JobOut::Eval(loss, correct) => Ok((loss, correct)),
                JobOut::Grad(_) => unreachable!("eval job returned grad result"),
            })
            .collect()
    }

    /// One gradient on the next lane (round-robin); blocks until done.
    /// This is the live-mode entry point — many worker threads may call
    /// it concurrently.
    pub fn grad_one(
        &self,
        w: &[f32],
        batch: &AnyBatch,
        grad_out: &mut [f32],
    ) -> anyhow::Result<f32> {
        let job = Job {
            idx: 0,
            w: RawSlice::of(w),
            batch: RawBatch::of(batch),
            kind: JobKind::Grad(RawSliceMut::of(grad_out)),
        };
        match self.run_on_lane(self.next_lane(), vec![job])?.pop() {
            Some(JobOut::Grad(loss)) => Ok(loss),
            _ => anyhow::bail!("engine pool returned no result"),
        }
    }

    /// One evaluation on the next lane (round-robin); blocks until done.
    pub fn eval_one(&self, w: &[f32], batch: &AnyBatch) -> anyhow::Result<(f32, usize)> {
        let job = Job {
            idx: 0,
            w: RawSlice::of(w),
            batch: RawBatch::of(batch),
            kind: JobKind::Eval,
        };
        match self.run_on_lane(self.next_lane(), vec![job])?.pop() {
            Some(JobOut::Eval(loss, correct)) => Ok((loss, correct)),
            _ => anyhow::bail!("engine pool returned no result"),
        }
    }

    fn next_lane(&self) -> usize {
        self.rr.fetch_add(1, Ordering::Relaxed) % self.lanes.len()
    }

    /// Distribute jobs round-robin (job i -> lane i % T, so worker j gets
    /// a stable lane across iterations) and block for all replies.
    ///
    /// Soundness invariant: this function NEVER returns — not even on the
    /// error paths — until every lane that was handed jobs has dropped its
    /// reply sender, i.e. no lane still holds a raw pointer into the
    /// caller's frame. A send to a dead lane therefore does not return
    /// early; the jobs meant for it are dropped unused and the error is
    /// reported only after the surviving lanes have been drained.
    fn run_jobs(&self, jobs: Vec<Job>) -> anyhow::Result<Vec<JobOut>> {
        let expected = jobs.len();
        let threads = self.lanes.len();
        let mut per_lane: Vec<Vec<Job>> = (0..threads).map(|_| Vec::new()).collect();
        for job in jobs {
            per_lane[job.idx % threads].push(job);
        }
        let (reply, results_rx) = channel::<Done>();
        let mut sent = 0usize;
        let mut dead_lane = None;
        for (lane, batch) in per_lane.into_iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            let count = batch.len();
            match self.lanes[lane].send(RunMsg { jobs: batch, reply: reply.clone() }) {
                Ok(()) => sent += count,
                // the failed send returns (and drops) the jobs unused
                Err(_) => dead_lane = Some(lane),
            }
        }
        drop(reply);
        let results = Self::collect(results_rx, expected, sent);
        if let Some(lane) = dead_lane {
            anyhow::bail!("engine pool lane {lane} is gone");
        }
        results
    }

    fn run_on_lane(&self, lane: usize, jobs: Vec<Job>) -> anyhow::Result<Vec<JobOut>> {
        let expected = jobs.len();
        let (reply, results_rx) = channel::<Done>();
        // A failed send returns the jobs without any lane having seen
        // them, so returning immediately is sound here (single lane).
        self.lanes[lane]
            .send(RunMsg { jobs, reply })
            .map_err(|_| anyhow::anyhow!("engine pool lane {lane} is gone"))?;
        Self::collect(results_rx, expected, expected)
    }

    /// Drain up to `expected` replies into `slots_len` job slots. The
    /// recv loop only ends once every lane serving this call has dropped
    /// its reply sender, which is what makes handing raw borrows to the
    /// lanes sound: when this returns, no lane still holds a pointer into
    /// the caller's frame.
    fn collect(
        results_rx: Receiver<Done>,
        slots_len: usize,
        expected: usize,
    ) -> anyhow::Result<Vec<JobOut>> {
        let mut slots: Vec<Option<anyhow::Result<JobOut>>> =
            (0..slots_len).map(|_| None).collect();
        let mut received = 0usize;
        while received < expected {
            match results_rx.recv() {
                Ok(done) => {
                    slots[done.idx] = Some(done.out);
                    received += 1;
                }
                Err(_) => break, // a lane died mid-call; all senders gone
            }
        }
        anyhow::ensure!(
            received == expected && expected == slots_len,
            "engine pool lane died mid-call ({received}/{slots_len} jobs completed)"
        );
        // surface the lowest-index error (deterministic) or unwrap all
        let mut out = Vec::with_capacity(slots_len);
        for slot in slots {
            out.push(slot.expect("collect counted a missing slot")?);
        }
        Ok(out)
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        self.lanes.clear(); // hang up -> lanes exit their recv loop
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn lane_loop(
    factory: EngineFactory,
    init_tx: Sender<anyhow::Result<(usize, &'static str)>>,
    rx: Receiver<RunMsg>,
    kernel_cap: usize,
) {
    // Bit-identical at any cap — this is purely a scheduling choice.
    crate::model::linalg::set_intra_op_cap(kernel_cap);
    let mut engine = match factory() {
        Ok(e) => {
            let _ = init_tx.send(Ok((e.param_count(), e.backend())));
            e
        }
        Err(e) => {
            let _ = init_tx.send(Err(e));
            return;
        }
    };
    drop(init_tx);
    for RunMsg { jobs, reply } in rx {
        for job in jobs {
            // SAFETY: the submitting pool call blocks until this lane's
            // `reply` clone is dropped, so `w`, `batch`, and the grad
            // buffer are live for the duration of this dereference.
            let out = unsafe {
                let w = job.w.get();
                let batch = job.batch.get();
                match job.kind {
                    JobKind::Grad(g) => engine.grad_into(w, batch, g.get()).map(JobOut::Grad),
                    JobKind::Eval => engine.eval(w, batch).map(|(l, c)| JobOut::Eval(l, c)),
                }
            };
            let _ = reply.send(Done { idx: job.idx, out });
        }
        // `reply` drops here: the caller sees this lane as done.
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batch::BatchSampler;
    use crate::data::synthetic::{gaussian_mixture, MixtureSpec};
    use crate::engine::{native_factory, NativeEngine};
    use crate::model::ModelMeta;
    use crate::util::rng::Rng;

    fn fixture(n_jobs: usize) -> (ModelMeta, Vec<f32>, Vec<AnyBatch>) {
        let meta = ModelMeta::lrm(8, 10, 16);
        let mut rng = Rng::new(0);
        let data = gaussian_mixture(&MixtureSpec::mnist_like(8, 400), &mut rng);
        let mut sampler = BatchSampler::new(1);
        let batches = (0..n_jobs)
            .map(|_| AnyBatch::Dense(sampler.sample(&data, 16)))
            .collect();
        let w = meta.init_params(&mut rng);
        (meta, w, batches)
    }

    #[test]
    fn pooled_grads_match_direct_engine() {
        let (meta, w, batches) = fixture(8);
        let pool = EnginePool::new(native_factory(meta.clone()), 3).unwrap();
        assert_eq!(pool.threads(), 3);
        assert_eq!(pool.param_count(), meta.param_count);
        assert_eq!(pool.backend(), "native");

        let ws: Vec<&[f32]> = (0..8).map(|_| w.as_slice()).collect();
        let mut outs = vec![vec![0.0f32; meta.param_count]; 8];
        let losses = pool.grad_many(&ws, &batches, &mut outs).unwrap();

        let mut eng = NativeEngine::new(meta.clone()).unwrap();
        let mut g = vec![0.0f32; meta.param_count];
        for (i, b) in batches.iter().enumerate() {
            let loss = eng.grad_into(&w, b, &mut g).unwrap();
            assert_eq!(loss.to_bits(), losses[i].to_bits(), "loss {i} differs");
            assert_eq!(g, outs[i], "gradient {i} differs");
        }
    }

    #[test]
    fn pool_size_does_not_change_results() {
        let (meta, w, batches) = fixture(7);
        let ws: Vec<&[f32]> = (0..7).map(|_| w.as_slice()).collect();
        let run = |threads: usize| {
            let pool = EnginePool::new(native_factory(meta.clone()), threads).unwrap();
            let mut outs = vec![vec![0.0f32; meta.param_count]; 7];
            let losses = pool.grad_many(&ws, &batches, &mut outs).unwrap();
            (losses, outs)
        };
        let (l1, g1) = run(1);
        let (l4, g4) = run(4);
        assert_eq!(
            l1.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
            l4.iter().map(|l| l.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(g1, g4);
    }

    #[test]
    fn eval_many_matches_direct_engine() {
        let (meta, w, batches) = fixture(5);
        let pool = EnginePool::new(native_factory(meta.clone()), 2).unwrap();
        let got = pool.eval_many(&w, &batches).unwrap();
        let mut eng = NativeEngine::new(meta).unwrap();
        for (i, b) in batches.iter().enumerate() {
            let (l, c) = eng.eval(&w, b).unwrap();
            assert_eq!(l.to_bits(), got[i].0.to_bits());
            assert_eq!(c, got[i].1);
        }
    }

    #[test]
    fn single_job_entry_points_work_concurrently() {
        let (meta, w, batches) = fixture(4);
        let pool = Arc::new(EnginePool::new(native_factory(meta.clone()), 2).unwrap());
        let handles: Vec<_> = batches
            .into_iter()
            .map(|b| {
                let pool = Arc::clone(&pool);
                let w = w.clone();
                let p = meta.param_count;
                std::thread::spawn(move || {
                    let mut g = vec![0.0f32; p];
                    let loss = pool.grad_one(&w, &b, &mut g).unwrap();
                    let (le, _) = pool.eval_one(&w, &b).unwrap();
                    (loss, le, g)
                })
            })
            .collect();
        for h in handles {
            let (loss, le, g) = h.join().unwrap();
            assert!(loss.is_finite() && (le - loss).abs() < 1e-6);
            assert!(g.iter().any(|&v| v != 0.0));
        }
    }

    #[test]
    fn factory_failure_surfaces_at_construction() {
        let factory: EngineFactory = Arc::new(|| anyhow::bail!("no engine for you"));
        let err = EnginePool::new(factory, 2).unwrap_err();
        assert!(err.to_string().contains("no engine"), "{err}");
    }

    #[test]
    fn engine_error_mid_run_is_an_err_not_a_hang() {
        // An engine that computes fine but errors on transformer batches:
        // feed it a Seq batch to trigger the dense() type check.
        let (meta, w, mut batches) = fixture(3);
        batches[1] = AnyBatch::Seq(crate::data::batch::SeqBatch {
            bsz: 1,
            seq: 4,
            vocab: 2,
            tokens: vec![0; 4],
            y1h: vec![0.0; 8],
        });
        let pool = EnginePool::new(native_factory(meta.clone()), 2).unwrap();
        let ws: Vec<&[f32]> = (0..3).map(|_| w.as_slice()).collect();
        let mut outs = vec![vec![0.0f32; meta.param_count]; 3];
        let err = pool.grad_many(&ws, &batches, &mut outs).unwrap_err();
        assert!(err.to_string().contains("dense"), "{err}");
        // the pool survives a job error: subsequent calls still work
        batches[1] = batches[0].clone();
        assert!(pool.grad_many(&ws, &batches, &mut outs).is_ok());
    }

    #[test]
    fn zero_threads_rejected() {
        let (meta, ..) = fixture(0);
        assert!(EnginePool::new(native_factory(meta), 0).is_err());
    }
}
