//! Compute server: cross-thread access to thread-pinned engines.
//!
//! The `xla` crate's PJRT handles are Rc-backed (thread-local), but the
//! live-mode coordinator runs one OS thread per worker. Historically this
//! was a single dedicated compute thread serving `(w, batch)` requests
//! over channels — which serialised every worker's gradient and cloned a
//! full parameter vector per call. It is now a thin facade over the
//! multi-lane [`EnginePool`](super::pool::EnginePool): each lane owns one
//! engine (built on the lane by the factory, so PJRT still works), calls
//! borrow the caller's parameter slice and write the gradient into the
//! caller's leased buffer, and independent workers really compute in
//! parallel — matching a deployment where workers share a pool of
//! accelerator queues instead of one.
//!
//! The server/client split is deliberately kept as a stable facade even
//! though both now delegate to the same `Arc<EnginePool>`: callers (live
//! driver, e2e example) depend on the spawn/clone surface, and the
//! facade is where live-mode policy (lane affinity, backpressure,
//! request priorities) will land without touching the pool.

use std::sync::Arc;

use super::pool::{EngineFactory, EnginePool};
use super::AnyBatch;

/// Handle workers use to submit compute. Clone freely across threads;
/// calls block until their job completes on some lane.
#[derive(Clone)]
pub struct ComputeClient {
    pool: Arc<EnginePool>,
}

impl ComputeClient {
    pub fn param_count(&self) -> usize {
        self.pool.param_count()
    }

    /// Compute mean loss and write the flat gradient into `grad_out`
    /// (zero-copy: no parameter clone, no per-call allocation).
    pub fn grad_into(
        &self,
        w: &[f32],
        batch: &AnyBatch,
        grad_out: &mut [f32],
    ) -> anyhow::Result<f32> {
        self.pool.grad_one(w, batch, grad_out)
    }

    /// Mean loss + correct predictions over one batch.
    pub fn eval(&self, w: &[f32], batch: &AnyBatch) -> anyhow::Result<(f32, usize)> {
        self.pool.eval_one(w, batch)
    }

    /// Evaluate one parameter vector over many batches, fanned across the
    /// pool's lanes; `(loss, correct)` pairs come back in batch order, so
    /// reductions over them are deterministic regardless of lane count.
    pub fn eval_many(&self, w: &[f32], batches: &[AnyBatch]) -> anyhow::Result<Vec<(f32, usize)>> {
        self.pool.eval_many(w, batches)
    }
}

/// The server; dropping it (after all clients) joins the lane threads.
pub struct ComputeServer {
    pool: Arc<EnginePool>,
}

impl ComputeServer {
    /// Spawn `lanes` compute lanes; `factory` runs ON each lane thread
    /// (so it may build Rc-backed PJRT engines).
    pub fn spawn(
        factory: EngineFactory,
        lanes: usize,
    ) -> anyhow::Result<(ComputeServer, ComputeClient)> {
        Ok(Self::from_pool(Arc::new(EnginePool::new(factory, lanes)?)))
    }

    /// Wrap an existing pool in the server/client facade — what lets a
    /// `Setup`-built [`EnginePool`] (data synthesis already fanned over
    /// it) be handed straight to the live driver without spinning up a
    /// second set of lanes.
    pub fn from_pool(pool: Arc<EnginePool>) -> (ComputeServer, ComputeClient) {
        let client = ComputeClient { pool: Arc::clone(&pool) };
        (ComputeServer { pool }, client)
    }

    pub fn param_count(&self) -> usize {
        self.pool.param_count()
    }

    pub fn lanes(&self) -> usize {
        self.pool.threads()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batch::BatchSampler;
    use crate::data::synthetic::{gaussian_mixture, MixtureSpec};
    use crate::engine::native_factory;
    use crate::model::ModelMeta;
    use crate::util::rng::Rng;

    fn batch() -> AnyBatch {
        let data = gaussian_mixture(&MixtureSpec::mnist_like(8, 100), &mut Rng::new(0));
        AnyBatch::Dense(BatchSampler::new(1).sample(&data, 16))
    }

    #[test]
    fn serves_grad_requests_from_many_threads() {
        let meta = ModelMeta::lrm(8, 10, 16);
        let (server, client) = ComputeServer::spawn(native_factory(meta.clone()), 2).unwrap();
        assert_eq!(client.param_count(), meta.param_count);
        assert_eq!(server.lanes(), 2);
        let w = meta.init_params(&mut Rng::new(2));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = client.clone();
                let w = w.clone();
                let b = batch();
                std::thread::spawn(move || {
                    let mut g = vec![0.0f32; c.param_count()];
                    let loss = c.grad_into(&w, &b, &mut g).unwrap();
                    (loss, g)
                })
            })
            .collect();
        for h in handles {
            let (loss, g) = h.join().unwrap();
            assert!(loss.is_finite() && loss > 0.0);
            assert_eq!(g.len(), meta.param_count);
            assert!(g.iter().any(|&v| v != 0.0));
        }
    }

    #[test]
    fn eval_works() {
        let meta = ModelMeta::lrm(8, 10, 16);
        let (_srv, client) = ComputeServer::spawn(native_factory(meta.clone()), 1).unwrap();
        let w = vec![0.0f32; meta.param_count];
        let (loss, correct) = client.eval(&w, &batch()).unwrap();
        assert!((loss - (10f32).ln()).abs() < 1e-4);
        assert!(correct <= 16);
    }

    #[test]
    fn from_pool_reuses_the_given_pool() {
        let meta = ModelMeta::lrm(8, 10, 16);
        let pool = crate::engine::EnginePool::new(native_factory(meta.clone()), 2).unwrap();
        let (server, client) = ComputeServer::from_pool(std::sync::Arc::new(pool));
        assert_eq!(server.lanes(), 2);
        assert_eq!(client.param_count(), meta.param_count);
        let w = meta.init_params(&mut Rng::new(4));
        let mut g = vec![0.0f32; client.param_count()];
        let loss = client.grad_into(&w, &batch(), &mut g).unwrap();
        assert!(loss.is_finite() && g.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn factory_failure_propagates() {
        let factory: crate::engine::EngineFactory = std::sync::Arc::new(|| anyhow::bail!("nope"));
        assert!(ComputeServer::spawn(factory, 2).is_err());
    }
}
