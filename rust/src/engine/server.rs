//! Compute server: cross-thread access to thread-pinned engines.
//!
//! The `xla` crate's PJRT handles are Rc-backed (thread-local), but the
//! live-mode coordinator runs one OS thread per worker. The standard fix
//! is an executor-service pattern: one dedicated compute thread owns the
//! engine (client + compiled executables) and serves `(w, batch) ->
//! (loss, grad)` requests over channels. XLA's CPU backend parallelises
//! each execution internally, so serialising the *dispatch* costs little;
//! it also mirrors a real deployment where workers share an accelerator.

use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use super::{AnyBatch, GradEngine};

enum Request {
    Grad {
        w: Vec<f32>,
        batch: AnyBatch,
        reply: Sender<anyhow::Result<(f32, Vec<f32>)>>,
    },
    Eval {
        w: Vec<f32>,
        batch: AnyBatch,
        reply: Sender<anyhow::Result<(f32, usize)>>,
    },
}

/// Handle workers use to submit compute. Clone freely across threads.
#[derive(Clone)]
pub struct ComputeClient {
    tx: Sender<Request>,
    param_count: usize,
}

impl ComputeClient {
    pub fn param_count(&self) -> usize {
        self.param_count
    }

    pub fn grad(&self, w: Vec<f32>, batch: AnyBatch) -> anyhow::Result<(f32, Vec<f32>)> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Grad { w, batch, reply })
            .map_err(|_| anyhow::anyhow!("compute server gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("compute server died"))?
    }

    pub fn eval(&self, w: Vec<f32>, batch: AnyBatch) -> anyhow::Result<(f32, usize)> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Eval { w, batch, reply })
            .map_err(|_| anyhow::anyhow!("compute server gone"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("compute server died"))?
    }
}

/// The server; dropping it (after all clients) stops the thread.
pub struct ComputeServer {
    handle: Option<JoinHandle<()>>,
    tx: Option<Sender<Request>>,
    param_count: usize,
}

impl ComputeServer {
    /// `factory` runs ON the compute thread (so it may build Rc-backed
    /// PJRT engines); it must be Send itself.
    pub fn spawn<F>(factory: F) -> anyhow::Result<(ComputeServer, ComputeClient)>
    where
        F: FnOnce() -> anyhow::Result<Box<dyn GradEngine>> + Send + 'static,
    {
        let (tx, rx) = channel::<Request>();
        let (init_tx, init_rx) = channel::<anyhow::Result<usize>>();
        let handle = std::thread::Builder::new()
            .name("dybw-compute".into())
            .spawn(move || {
                let mut engine = match factory() {
                    Ok(e) => {
                        let _ = init_tx.send(Ok(e.param_count()));
                        e
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(e));
                        return;
                    }
                };
                let mut grad_buf = vec![0.0f32; engine.param_count()];
                for req in rx {
                    match req {
                        Request::Grad { w, batch, reply } => {
                            let res = engine
                                .grad_into(&w, &batch, &mut grad_buf)
                                .map(|loss| (loss, grad_buf.clone()));
                            let _ = reply.send(res);
                        }
                        Request::Eval { w, batch, reply } => {
                            let _ = reply.send(engine.eval(&w, &batch));
                        }
                    }
                }
            })?;
        let param_count = init_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("compute thread crashed during init"))??;
        let client = ComputeClient {
            tx: tx.clone(),
            param_count,
        };
        Ok((
            ComputeServer {
                handle: Some(handle),
                tx: Some(tx),
                param_count,
            },
            client,
        ))
    }

    pub fn param_count(&self) -> usize {
        self.param_count
    }
}

impl Drop for ComputeServer {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batch::BatchSampler;
    use crate::data::synthetic::{gaussian_mixture, MixtureSpec};
    use crate::engine::NativeEngine;
    use crate::model::ModelMeta;
    use crate::util::rng::Rng;

    fn batch() -> AnyBatch {
        let data = gaussian_mixture(&MixtureSpec::mnist_like(8, 100), &mut Rng::new(0));
        AnyBatch::Dense(BatchSampler::new(1).sample(&data, 16))
    }

    #[test]
    fn serves_grad_requests_from_many_threads() {
        let meta = ModelMeta::lrm(8, 10, 16);
        let m2 = meta.clone();
        let (_server, client) =
            ComputeServer::spawn(move || Ok(Box::new(NativeEngine::new(m2)?) as _)).unwrap();
        assert_eq!(client.param_count(), meta.param_count);
        let w = meta.init_params(&mut Rng::new(2));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let c = client.clone();
                let w = w.clone();
                let b = batch();
                std::thread::spawn(move || c.grad(w, b).unwrap())
            })
            .collect();
        for h in handles {
            let (loss, g) = h.join().unwrap();
            assert!(loss.is_finite() && loss > 0.0);
            assert_eq!(g.len(), meta.param_count);
        }
    }

    #[test]
    fn eval_works() {
        let meta = ModelMeta::lrm(8, 10, 16);
        let m2 = meta.clone();
        let (_server, client) =
            ComputeServer::spawn(move || Ok(Box::new(NativeEngine::new(m2)?) as _)).unwrap();
        let w = vec![0.0f32; meta.param_count];
        let (loss, correct) = client.eval(w, batch()).unwrap();
        assert!((loss - (10f32).ln()).abs() < 1e-4);
        assert!(correct <= 16);
    }

    #[test]
    fn factory_failure_propagates() {
        let res = ComputeServer::spawn(|| anyhow::bail!("nope"));
        assert!(res.is_err());
    }
}
