//! Gradient engines: the interface between the coordinator and compute.
//!
//! A [`GradEngine`] turns (flat params, batch) into (loss, flat gradient)
//! — eq. (4)-(5)'s local computation. Two implementations:
//!
//! - [`NativeEngine`] — pure Rust (model::{lrm,mlp}); oracle + fallback.
//! - [`crate::runtime::PjrtEngine`] — the production path: executes the
//!   AOT-compiled JAX/Pallas artifact through the PJRT C API.
//!
//! Engines are stateful (`&mut self`) so implementations can reuse
//! scratch/device buffers across iterations without allocating on the hot
//! path. Parallel execution goes through [`pool::EnginePool`]: one engine
//! per lane thread, each built on its lane by an [`pool::EngineFactory`]
//! (so thread-pinned PJRT handles work unchanged).

pub mod pool;
pub mod server;

pub use pool::{EngineFactory, EnginePool};

use std::sync::Arc;

use crate::data::batch::{Batch, BatchSampler, SeqBatch};
use crate::data::{Dataset, SeqDataset};
use crate::model::{lrm, mlp, ModelKind, ModelMeta};

/// A batch of either workload family, in artifact input layout.
#[derive(Debug, Clone)]
pub enum AnyBatch {
    Dense(Batch),
    Seq(SeqBatch),
}

impl AnyBatch {
    pub fn dense(&self) -> anyhow::Result<&Batch> {
        match self {
            AnyBatch::Dense(b) => Ok(b),
            AnyBatch::Seq(_) => anyhow::bail!("expected dense batch, got token batch"),
        }
    }

    pub fn rows(&self) -> usize {
        match self {
            AnyBatch::Dense(b) => b.bsz,
            AnyBatch::Seq(b) => b.bsz * b.seq, // per-token predictions
        }
    }
}

pub trait GradEngine {
    /// Total flat parameter count P.
    fn param_count(&self) -> usize;

    /// Compute mean loss and write the flat gradient into `grad_out`.
    fn grad_into(
        &mut self,
        w: &[f32],
        batch: &AnyBatch,
        grad_out: &mut [f32],
    ) -> anyhow::Result<f32>;

    /// Mean loss + number of correct predictions over the batch.
    fn eval(&mut self, w: &[f32], batch: &AnyBatch) -> anyhow::Result<(f32, usize)>;

    /// Human-readable backend tag (for logs/reports).
    fn backend(&self) -> &'static str;
}

/// A per-worker source of training batches + a shared eval set.
pub trait BatchSource: Send {
    /// Draw the next training mini-batch C_j(k) from this worker's shard.
    fn next_train(&mut self, bsz: usize) -> AnyBatch;
    /// Number of examples in this worker's shard.
    fn shard_len(&self) -> usize;
}

/// Dense classification source over a worker's local shard D_j.
pub struct DenseSource {
    shard: Dataset,
    sampler: BatchSampler,
}

impl DenseSource {
    pub fn new(shard: Dataset, seed: u64) -> Self {
        DenseSource {
            shard,
            sampler: BatchSampler::new(seed),
        }
    }
}

impl BatchSource for DenseSource {
    fn next_train(&mut self, bsz: usize) -> AnyBatch {
        AnyBatch::Dense(self.sampler.sample(&self.shard, bsz))
    }

    fn shard_len(&self) -> usize {
        self.shard.n()
    }
}

/// Token-sequence source (transformer workload).
pub struct SeqSource {
    shard: SeqDataset,
    sampler: BatchSampler,
}

impl SeqSource {
    pub fn new(shard: SeqDataset, seed: u64) -> Self {
        SeqSource {
            shard,
            sampler: BatchSampler::new(seed),
        }
    }
}

impl BatchSource for SeqSource {
    fn next_train(&mut self, bsz: usize) -> AnyBatch {
        AnyBatch::Seq(self.sampler.sample_seq(&self.shard, bsz))
    }

    fn shard_len(&self) -> usize {
        self.shard.n()
    }
}

/// Pure-Rust engine for LRM and MLP2.
pub struct NativeEngine {
    meta: ModelMeta,
    lrm_scratch: lrm::LrmScratch,
    mlp_scratch: mlp::MlpScratch,
}

impl NativeEngine {
    pub fn new(meta: ModelMeta) -> anyhow::Result<Self> {
        anyhow::ensure!(
            matches!(meta.kind, ModelKind::Lrm | ModelKind::Mlp2),
            "native engine supports lrm/mlp2 only (got {}); use the PJRT engine",
            meta.kind.name()
        );
        Ok(NativeEngine {
            meta,
            lrm_scratch: Default::default(),
            mlp_scratch: Default::default(),
        })
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }
}

/// Factory producing independent [`NativeEngine`]s (one per pool lane).
pub fn native_factory(meta: ModelMeta) -> EngineFactory {
    Arc::new(move || Ok(Box::new(NativeEngine::new(meta.clone())?) as Box<dyn GradEngine>))
}

impl GradEngine for NativeEngine {
    fn param_count(&self) -> usize {
        self.meta.param_count
    }

    fn grad_into(
        &mut self,
        w: &[f32],
        batch: &AnyBatch,
        grad_out: &mut [f32],
    ) -> anyhow::Result<f32> {
        let batch = batch.dense()?;
        Ok(match self.meta.kind {
            ModelKind::Lrm => lrm::grad(&self.meta, w, batch, grad_out, &mut self.lrm_scratch),
            ModelKind::Mlp2 => mlp::grad(&self.meta, w, batch, grad_out, &mut self.mlp_scratch),
            ModelKind::Transformer => unreachable!("checked in new()"),
        })
    }

    fn eval(&mut self, w: &[f32], batch: &AnyBatch) -> anyhow::Result<(f32, usize)> {
        let batch = batch.dense()?;
        Ok(match self.meta.kind {
            ModelKind::Lrm => lrm::eval(&self.meta, w, batch, &mut self.lrm_scratch),
            ModelKind::Mlp2 => mlp::eval(&self.meta, w, batch, &mut self.mlp_scratch),
            ModelKind::Transformer => unreachable!("checked in new()"),
        })
    }

    fn backend(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batch::BatchSampler;
    use crate::data::synthetic::{gaussian_mixture, MixtureSpec};
    use crate::util::rng::Rng;

    #[test]
    fn native_engine_lrm_roundtrip() {
        let meta = ModelMeta::lrm(8, 10, 32);
        let data = gaussian_mixture(&MixtureSpec::mnist_like(8, 100), &mut Rng::new(0));
        let batch = BatchSampler::new(1).sample(&data, 32);
        let batch = AnyBatch::Dense(batch);
        let mut eng = NativeEngine::new(meta.clone()).unwrap();
        let w = meta.init_params(&mut Rng::new(2));
        let mut g = vec![0.0f32; eng.param_count()];
        let loss = eng.grad_into(&w, &batch, &mut g).unwrap();
        assert!(loss > 0.0 && loss.is_finite());
        assert!(g.iter().any(|&v| v != 0.0));
        let (le, correct) = eng.eval(&w, &batch).unwrap();
        assert!((le - loss).abs() < 1e-6);
        assert!(correct <= 32);
        assert_eq!(eng.backend(), "native");
    }

    #[test]
    fn native_engine_rejects_transformer() {
        let mut meta = ModelMeta::lrm(4, 2, 8);
        meta.kind = ModelKind::Transformer;
        assert!(NativeEngine::new(meta).is_err());
    }
}
