//! DTUR — Distributed Threshold-based Update Rule (paper §4.1, Alg. 2).
//!
//! cb-DyBW needs, at every iteration k, a threshold θ(k): workers whose
//! local update lands within θ(k) join S(k) and mix; the rest become
//! backup workers for the round. DTUR picks θ(k) as the *earliest* moment
//! at which some not-yet-established link of the connecting path P
//! completes (both endpoints done), which simultaneously (a) makes θ as
//! small as the topology allows — minimising per-iteration time, eq. (21)
//! — and (b) guarantees that after each d-iteration epoch every link of P
//! has been established at least once, i.e. the union graph
//! E_{md+1} ∪ … ∪ E_{md+d} ⊇ P is connected: exactly Assumption 2's
//! B-bounded-connectivity with B = d, which the convergence proof needs.
//!
//! Epoch bookkeeping: P' collects established P-links; it resets every d
//! iterations. If the epoch's remaining iterations are exactly the
//! remaining unestablished links, DTUR must establish a *new* link each
//! round (the paper's "iteration k continues until one such link is
//! established").

use crate::graph::{paths, Graph};

/// Decision for one iteration.
#[derive(Debug, Clone)]
pub struct DturDecision {
    /// θ(k): the iteration's cut-off time (= the iteration duration).
    pub theta: f64,
    /// active[j] ⇔ t_j(k) ≤ θ(k) — worker j participates in eq. (6).
    pub active: Vec<bool>,
    /// Path links newly established this iteration (indices into `path`).
    pub established_now: Vec<usize>,
    /// Epoch position l ∈ [1, d] AFTER this iteration.
    pub epoch_pos: usize,
}

#[derive(Debug, Clone)]
pub struct Dtur {
    /// The connecting path P (d = path.len() links spanning all workers).
    path: Vec<(usize, usize)>,
    /// P': established[i] ⇔ path[i] ∈ P' this epoch.
    established: Vec<bool>,
    /// Iterations completed in the current epoch (0..d).
    epoch_pos: usize,
}

impl Dtur {
    pub fn new(g: &Graph) -> Self {
        let path = paths::connecting_path(g);
        let established = vec![false; path.len()];
        Dtur {
            path,
            established,
            epoch_pos: 0,
        }
    }

    /// d — the epoch length (= |P|).
    pub fn d(&self) -> usize {
        self.path.len()
    }

    pub fn path(&self) -> &[(usize, usize)] {
        &self.path
    }

    /// Is path link `idx` already in P' this epoch?
    pub fn is_established(&self, idx: usize) -> bool {
        self.established[idx]
    }

    /// One iteration of Algorithm 2 given the compute times t_j(k).
    pub fn step(&mut self, t: &[f64]) -> DturDecision {
        assert!(!self.path.is_empty(), "DTUR needs >= 2 workers");
        // θ(k) = min over unestablished P-links of the link completion time
        // max(t_i, t_j) — the first moment a desired link exists.
        let mut theta = f64::INFINITY;
        for (idx, &(a, b)) in self.path.iter().enumerate() {
            if !self.established[idx] {
                theta = theta.min(t[a].max(t[b]));
            }
        }
        // Degenerate case (possible when a caller feeds +inf for workers
        // that never finished): no unestablished link can complete. Fall
        // back to waiting out all finite finishers — the iteration makes
        // no path progress, the epoch simply continues next round.
        if !theta.is_finite() {
            theta = t
                .iter()
                .copied()
                .filter(|x| x.is_finite())
                .fold(0.0, f64::max);
            let active: Vec<bool> = t.iter().map(|&tj| tj <= theta).collect();
            self.epoch_pos += 1;
            if self.epoch_pos >= self.d() {
                self.established.iter_mut().for_each(|e| *e = false);
                self.epoch_pos = 0;
            }
            return DturDecision {
                theta,
                active,
                established_now: Vec::new(),
                epoch_pos: self.epoch_pos,
            };
        }
        // Everyone whose update beat θ participates.
        let active: Vec<bool> = t.iter().map(|&tj| tj <= theta).collect();
        // All P-links whose endpoints both beat θ establish now (at least
        // the argmin link).
        let mut established_now = Vec::new();
        for (idx, &(a, b)) in self.path.iter().enumerate() {
            if !self.established[idx] && t[a].max(t[b]) <= theta {
                self.established[idx] = true;
                established_now.push(idx);
            }
        }
        debug_assert!(!established_now.is_empty());
        self.epoch_pos += 1;
        // Epoch ends after d iterations; P' resets (paper: "P' is reset to
        // be empty at the end of this epoch"). Also reset early if every
        // link established — remaining iterations would have no target.
        if self.epoch_pos >= self.d() || self.established.iter().all(|&e| e) {
            self.established.iter_mut().for_each(|e| *e = false);
            self.epoch_pos = 0;
        }
        DturDecision {
            theta,
            active,
            established_now,
            epoch_pos: self.epoch_pos,
        }
    }
}

/// Per-worker DTUR state for the asynchronous (event-driven) setting.
///
/// The global [`Dtur`] needs the whole network's t_·(k) at once — exactly
/// what an asynchronous worker never has. `LocalDtur` is the paper's rule
/// restricted to what worker i *can* observe: its own star of links
/// {(i, j) : j ∈ N_i}. The iteration's threshold moment is the arrival of
/// the first estimate from a neighbour whose link is not yet established
/// this epoch (DTUR's "earliest not-yet-established link of P
/// completes", with P replaced by the local star); every estimate that
/// has arrived by then is counted, the rest become this round's backup
/// workers b_i(k). Epochs last d_i = deg(i) iterations, and because each
/// iteration establishes at least one new link, every neighbour is
/// counted at least once per epoch — the per-node analogue of Assumption
/// 2's B-bounded connectivity with B = d_i.
#[derive(Debug, Clone)]
pub struct LocalDtur {
    /// established[j] ⇔ neighbour j's link was counted this epoch.
    established: Vec<bool>,
    /// Iterations completed in the current epoch (0..deg).
    epoch_pos: usize,
}

impl LocalDtur {
    pub fn new(degree: usize) -> Self {
        LocalDtur {
            established: vec![false; degree],
            epoch_pos: 0,
        }
    }

    /// Epoch length d_i (= the node degree).
    pub fn d(&self) -> usize {
        self.established.len()
    }

    /// Churn: the neighbourhood changed, so the epoch length d_i changes
    /// with it. The current epoch is abandoned — established links of
    /// the old neighbour set say nothing about the new indexing — and a
    /// fresh epoch starts over the new degree. (The B-bounded
    /// connectivity guarantee then holds with B = new d_i from the next
    /// commit onward.)
    pub fn set_degree(&mut self, degree: usize) {
        self.established.clear();
        self.established.resize(degree, false);
        self.epoch_pos = 0;
    }

    pub fn is_established(&self, nbr: usize) -> bool {
        self.established[nbr]
    }

    /// May the worker stop waiting, given which neighbour estimates have
    /// arrived? True iff some not-yet-established link just completed.
    pub fn ready(&self, arrived: &[bool]) -> bool {
        debug_assert_eq!(arrived.len(), self.established.len());
        arrived
            .iter()
            .zip(&self.established)
            .any(|(&a, &e)| a && !e)
    }

    /// Commit the iteration with the arrived set as the counted set.
    /// Returns b_i(k) (= neighbours NOT counted). Panics (debug) if
    /// called when [`Self::ready`] is false — the caller must keep
    /// waiting until a new link establishes, exactly the paper's
    /// "iteration k continues until one such link is established".
    pub fn commit(&mut self, arrived: &[bool]) -> usize {
        debug_assert!(self.ready(arrived));
        for (e, &a) in self.established.iter_mut().zip(arrived) {
            *e |= a;
        }
        self.epoch_pos += 1;
        if self.epoch_pos >= self.d() || self.established.iter().all(|&e| e) {
            self.established.iter_mut().for_each(|e| *e = false);
            self.epoch_pos = 0;
        }
        arrived.iter().filter(|&&a| !a).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topology;
    use crate::straggler::{Dist, StragglerModel};
    use crate::util::rng::Rng;

    #[test]
    fn theta_is_min_link_completion() {
        let g = topology::ring(4); // path will span 4 nodes, 3 links
        let mut dtur = Dtur::new(&g);
        assert_eq!(dtur.d(), 3);
        let t = vec![0.1, 0.5, 0.2, 0.9];
        let dec = dtur.step(&t);
        // fastest possible P-link completion: the link among path links
        // with smallest max(t_i, t_j)
        let want = dtur
            .path()
            .iter()
            .map(|&(a, b)| t[a].max(t[b]))
            .fold(f64::INFINITY, f64::min);
        assert_eq!(dec.theta, want);
        // active = beat theta
        for (j, &a) in dec.active.iter().enumerate() {
            assert_eq!(a, t[j] <= dec.theta);
        }
        assert!(!dec.established_now.is_empty());
    }

    #[test]
    fn epoch_establishes_whole_path() {
        // Over one epoch (d iterations), every P-link must establish —
        // the Assumption-2 connectivity guarantee.
        let mut rng = Rng::new(1);
        for seed in 0..10 {
            let g = topology::random_connected(8, 0.35, &mut Rng::new(seed));
            let mut dtur = Dtur::new(&g);
            let d = dtur.d();
            let model = StragglerModel::homogeneous(8, Dist::Uniform { lo: 0.05, hi: 0.3 });
            let mut seen = vec![false; d];
            for _ in 0..d {
                let t = model.sample_iteration(&mut rng);
                let dec = dtur.step(&t);
                for idx in dec.established_now {
                    seen[idx] = true;
                }
                if dec.epoch_pos == 0 {
                    break; // epoch ended (possibly early)
                }
            }
            assert!(
                seen.iter().all(|&s| s),
                "seed {seed}: epoch ended without covering P: {seen:?}"
            );
        }
    }

    #[test]
    fn epoch_resets() {
        let g = topology::ring(5);
        let mut dtur = Dtur::new(&g);
        let d = dtur.d();
        let mut rng = Rng::new(3);
        let model = StragglerModel::homogeneous(5, Dist::Uniform { lo: 0.1, hi: 0.2 });
        let mut resets = 0;
        for _ in 0..3 * d {
            let t = model.sample_iteration(&mut rng);
            let dec = dtur.step(&t);
            if dec.epoch_pos == 0 {
                resets += 1;
            }
        }
        assert!(resets >= 3, "expected >= 3 epoch resets, got {resets}");
    }

    #[test]
    fn straggler_excluded_but_path_progresses() {
        let g = topology::complete(5);
        let mut dtur = Dtur::new(&g);
        // worker 4 is a massive straggler every iteration
        for _ in 0..dtur.d() {
            let t = vec![0.1, 0.12, 0.11, 0.13, 10.0];
            let dec = dtur.step(&t);
            // theta never waits for the straggler unless its link is the
            // only one left
            if dec.theta < 10.0 {
                assert!(!dec.active[4]);
            }
        }
    }

    #[test]
    fn infinite_times_degenerate_case_is_safe() {
        // Regression (live driver, debug builds): when every remaining
        // unestablished P-link touches a worker that never finished
        // (t = +inf), step must not panic and must not mark progress.
        let g = topology::ring(4); // path: 3 links
        let mut dtur = Dtur::new(&g);
        // establish exactly the links NOT touching worker w_inf first
        let t_all = vec![0.1, 0.1, 0.1, 0.1];
        let d1 = dtur.step(&t_all); // establishes all 3 links at once
        assert_eq!(d1.established_now.len(), 3);
        // new epoch; now feed +inf for two adjacent workers so SOME links
        // are uncompletable; run until only inf-links remain
        for _ in 0..dtur.d() * 2 {
            let mut t = vec![0.05, 0.06, f64::INFINITY, f64::INFINITY];
            let dec = dtur.step(&t);
            assert!(dec.theta.is_finite());
            assert!(!dec.active[2] || dec.theta == f64::INFINITY);
            t[2] = 0.05; // irrelevant; loop just exercises state
        }
    }

    #[test]
    fn backup_count_stays_within_node_degree() {
        // Algorithm 2 invariant: b_j(k) = |inactive neighbours of j| lies
        // in [0, deg(j)], AND the mask it derives from is exactly the
        // threshold rule (active ⇔ t_j ≤ θ), with every established
        // P-link's endpoints active — so the backups can never be "all of
        // N_j" on an iteration where j's link establishes.
        let mut rng = Rng::new(77);
        for seed in 0..20 {
            let g = topology::random_connected(9, 0.35, &mut Rng::new(seed));
            let mut dtur = Dtur::new(&g);
            let dist = Dist::ShiftedExp { base: 0.05, rate: 15.0 };
            let model = StragglerModel::homogeneous(9, dist);
            for _ in 0..30 {
                let t = model.sample_iteration(&mut rng);
                let dec = dtur.step(&t);
                // the mask IS the threshold decision, never all-backup
                for (j, &a) in dec.active.iter().enumerate() {
                    assert_eq!(
                        a,
                        t[j] <= dec.theta,
                        "seed {seed}: worker {j} mask disagrees with theta rule"
                    );
                }
                for &idx in &dec.established_now {
                    let (a, b) = dtur.path()[idx];
                    assert!(
                        dec.active[a] && dec.active[b],
                        "seed {seed}: established link ({a},{b}) has a backup endpoint"
                    );
                }
                for j in 0..g.n() {
                    let b_j = g.neighbors(j).filter(|&i| !dec.active[i]).count();
                    assert!(
                        b_j <= g.degree(j),
                        "seed {seed}: worker {j} backup count {b_j} > degree {}",
                        g.degree(j)
                    );
                }
            }
        }
    }

    #[test]
    fn threshold_monotone_in_observed_straggler_delay() {
        // θ(k) = min over unestablished P-links of max(t_a, t_b) is
        // monotone non-decreasing in every coordinate: inflating one
        // worker's observed delay (same epoch state) can only raise the
        // threshold, never lower it.
        let g = topology::random_connected(8, 0.4, &mut Rng::new(3));
        let warm = {
            // advance into mid-epoch so some links are already established
            let mut d = Dtur::new(&g);
            let mut rng = Rng::new(4);
            let model = StragglerModel::homogeneous(8, Dist::Uniform { lo: 0.05, hi: 0.3 });
            let t = model.sample_iteration(&mut rng);
            d.step(&t);
            d
        };
        let mut rng = Rng::new(5);
        let base: Vec<f64> = (0..8).map(|_| rng.uniform_in(0.05, 0.4)).collect();
        for w in 0..8 {
            let mut prev_theta = 0.0;
            for factor in [1.0, 2.0, 5.0, 20.0, 100.0] {
                let mut t = base.clone();
                t[w] *= factor;
                let dec = warm.clone().step(&t);
                assert!(
                    dec.theta + 1e-12 >= prev_theta,
                    "worker {w} x{factor}: theta {} < previous {prev_theta}",
                    dec.theta
                );
                prev_theta = dec.theta;
            }
        }
    }

    #[test]
    fn local_dtur_covers_every_neighbour_each_epoch() {
        // Each commit must establish >= 1 new link, so after d_i
        // iterations every neighbour has been counted at least once —
        // the local Assumption-2 guarantee the DES relies on.
        let mut rng = Rng::new(11);
        for deg in [1usize, 2, 3, 5, 8] {
            let mut d = LocalDtur::new(deg);
            let mut covered_in_epoch = vec![false; deg];
            for iter in 0..6 * deg {
                // random arrival pattern that always includes at least
                // one unestablished neighbour (the wait rule guarantees
                // this in the simulator)
                let mut arrived: Vec<bool> = (0..deg).map(|_| rng.uniform() < 0.5).collect();
                if !d.ready(&arrived) {
                    let fresh = (0..deg).find(|&j| !d.is_established(j)).unwrap();
                    arrived[fresh] = true;
                }
                assert!(d.ready(&arrived), "iter {iter}: commit without new link");
                for (c, &a) in covered_in_epoch.iter_mut().zip(&arrived) {
                    *c |= a;
                }
                let b = d.commit(&arrived);
                assert!(b <= deg);
                if d.epoch_pos == 0 {
                    assert!(
                        covered_in_epoch.iter().all(|&c| c),
                        "deg {deg}: epoch ended without covering all neighbours"
                    );
                    covered_in_epoch.iter_mut().for_each(|c| *c = false);
                }
            }
        }
    }

    #[test]
    fn local_dtur_not_ready_without_fresh_link() {
        let mut d = LocalDtur::new(3);
        assert!(!d.ready(&[false, false, false]));
        assert!(d.ready(&[false, true, false]));
        d.commit(&[false, true, false]); // neighbour 1 established
        assert!(!d.ready(&[false, true, false]), "stale link must not satisfy the wait");
        assert!(d.ready(&[true, true, false]));
        let b = d.commit(&[true, true, false]);
        assert_eq!(b, 1); // neighbour 2 was the backup
    }

    #[test]
    fn at_least_one_new_link_per_iteration() {
        let mut rng = Rng::new(5);
        let g = topology::random_connected(10, 0.3, &mut Rng::new(42));
        let mut dtur = Dtur::new(&g);
        let model = StragglerModel::homogeneous(10, Dist::ShiftedExp { base: 0.05, rate: 10.0 });
        for _ in 0..50 {
            let t = model.sample_iteration(&mut rng);
            let dec = dtur.step(&t);
            assert!(!dec.established_now.is_empty());
            assert!(dec.theta.is_finite() && dec.theta > 0.0);
        }
    }
}
