//! Layer-3 coordinator: the paper's training algorithms.
//!
//! - [`dtur`] — Algorithm 2, the threshold rule choosing backup workers
//!   (global form for the lockstep drivers, plus the per-worker
//!   [`dtur::LocalDtur`] the asynchronous [`des`](crate::des) layer runs
//!   on locally observed arrival times).
//! - [`algorithm`] — cb-DyBW (Algorithm 1), the cb-Full baseline, and the
//!   static-backup / parameter-server comparison points.
//! - [`sim`] — the deterministic discrete-event driver: real gradients
//!   fanned out over the per-worker engine pool (native or PJRT), virtual
//!   compute times from the straggler model. Regenerates every figure
//!   reproducibly from one seed, bit-identically at any pool size.
//! - [`live`] — the wall-clock driver: REAL workers (one OS thread per
//!   worker in-process, or one OS *process* per worker over the framed
//!   TCP transport in [`comms`](crate::comms)), real sleeps for
//!   stragglers, gradients in parallel through the multi-lane compute
//!   server. The recorded history is a pure function of the seed, so
//!   every transport produces bit-identical runs.
//! - [`setup`] — config -> trainer wiring shared by CLI/experiments.

pub mod algorithm;
pub mod checkpoint;
pub mod ckpt_manager;
pub mod dtur;
pub mod live;
pub mod setup;
pub mod sim;

pub use algorithm::Algorithm;
pub use sim::{SimTrainer, TrainConfig};
