//! Layer-3 coordinator: the paper's training algorithms.
//!
//! - [`dtur`] — Algorithm 2, the threshold rule choosing backup workers
//!   (global form for the lockstep drivers, plus the per-worker
//!   [`dtur::LocalDtur`] the asynchronous [`des`](crate::des) layer runs
//!   on locally observed arrival times).
//! - [`algorithm`] — cb-DyBW (Algorithm 1), the cb-Full baseline, and the
//!   static-backup / parameter-server comparison points.
//! - [`sim`] — the deterministic discrete-event driver: real gradients
//!   fanned out over the per-worker engine pool (native or PJRT), virtual
//!   compute times from the straggler model. Regenerates every figure
//!   reproducibly from one seed, bit-identically at any pool size.
//! - [`live`] — the wall-clock driver: one OS thread per worker, real
//!   sleeps for stragglers, gradients computed in parallel through the
//!   multi-lane compute server. Used by the e2e example to prove the
//!   stack composes.
//! - [`setup`] — config -> trainer wiring shared by CLI/experiments.

pub mod algorithm;
pub mod checkpoint;
pub mod dtur;
pub mod live;
pub mod setup;
pub mod sim;

pub use algorithm::Algorithm;
pub use sim::{SimTrainer, TrainConfig};
