//! Checkpointing: save/resume training state (framework feature).
//!
//! A checkpoint captures iteration counter, virtual clock, every worker's
//! parameter vector, and (optionally) the run history recorded so far —
//! the last part is what makes a killed-and-replayed run export
//! byte-identical series to an uninterrupted one. Format: a JSON header
//! (versioned, with a content checksum) followed by raw little-endian f32
//! data — readable from numpy with a two-line loader, cheap to write from
//! the hot loop. History floats are stored as `{:016x}` bit patterns
//! (`f64::to_bits`) because the JSON writer cannot represent NaN (θ is
//! NaN for the non-DyBW baselines) and because resume must reproduce
//! every recorded f64 bit-for-bit, not merely to printed precision.
//!
//! Every decode failure is a typed [`CkptError`] — the adversarial tests
//! below truncate at each byte offset, flip checksum bytes, and append
//! trailing garbage, and each must surface as the right variant (never a
//! panic, never a silently-wrong checkpoint).

use std::fmt;
use std::io::Write;
use std::path::Path;

use crate::consensus::mixing::ParamBuffers;
use crate::metrics::{EvalRecord, IterRecord, RunHistory};
use crate::util::json::Json;

const MAGIC: &str = "dybw-ckpt-v1";
/// Header-length sanity bound (headers carry history, so they grow with
/// the iteration count; 256 MiB is far beyond any real run's header).
const MAX_HEADER: u64 = 1 << 28;

/// Typed checkpoint decode/IO failure.
#[derive(Debug)]
pub enum CkptError {
    Io(std::io::Error),
    /// File ends before the declared payload does.
    Truncated { need: usize, got: usize },
    /// Magic string missing or wrong — not a dybw checkpoint.
    BadMagic,
    /// Declared header length fails the sanity bound.
    AbsurdHeader(u64),
    /// Header present but not the JSON we wrote.
    BadHeader(String),
    /// Payload bytes do not hash to the header's checksum.
    BadChecksum { got: String, want: String },
    /// Extra bytes after the declared payload.
    TrailingGarbage { extra: usize },
}

impl fmt::Display for CkptError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CkptError::Io(e) => write!(f, "checkpoint io error: {e}"),
            CkptError::Truncated { need, got } => {
                write!(f, "checkpoint truncated: need {need} bytes, got {got}")
            }
            CkptError::BadMagic => write!(f, "not a dybw checkpoint (bad magic)"),
            CkptError::AbsurdHeader(n) => write!(f, "absurd header length {n}"),
            CkptError::BadHeader(msg) => write!(f, "bad checkpoint header: {msg}"),
            CkptError::BadChecksum { got, want } => {
                write!(f, "checkpoint corrupted: checksum {got} != {want}")
            }
            CkptError::TrailingGarbage { extra } => {
                write!(f, "checkpoint has {extra} trailing garbage bytes")
            }
        }
    }
}

impl std::error::Error for CkptError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            CkptError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for CkptError {
    fn from(e: std::io::Error) -> Self {
        CkptError::Io(e)
    }
}

#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub iteration: usize,
    pub clock: f64,
    pub model: String,
    pub params: Vec<Vec<f32>>,
    /// History recorded up to `iteration` (empty for bare snapshots).
    pub history: RunHistory,
}

/// Bit-exact equality: params byte-for-byte, clock via `to_bits`, and the
/// history through [`RunHistory::bits_eq`] — the same oracle the
/// determinism tests use, so two checkpoints compare equal iff a resumed
/// run is indistinguishable from the original.
impl PartialEq for Checkpoint {
    fn eq(&self, other: &Checkpoint) -> bool {
        self.iteration == other.iteration
            && self.clock.to_bits() == other.clock.to_bits()
            && self.model == other.model
            && self.params == other.params
            && self.history.algo == other.history.algo
            && self.history.model == other.history.model
            && self.history.dataset == other.history.dataset
            && self.history.bits_eq(&other.history)
    }
}

fn hex_bits(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

fn parse_bits(s: &str) -> Result<f64, CkptError> {
    u64::from_str_radix(s, 16)
        .map(f64::from_bits)
        .map_err(|_| CkptError::BadHeader(format!("bad f64 bit pattern '{s}'")))
}

fn iter_to_str(r: &IterRecord) -> String {
    format!(
        "{};{};{};{};{};{};{}",
        r.k,
        hex_bits(r.duration),
        hex_bits(r.clock),
        hex_bits(r.train_loss),
        r.active,
        hex_bits(r.backup_avg),
        hex_bits(r.theta)
    )
}

fn iter_from_str(s: &str) -> Result<IterRecord, CkptError> {
    let p: Vec<&str> = s.split(';').collect();
    if p.len() != 7 {
        return Err(CkptError::BadHeader(format!("bad iter record '{s}'")));
    }
    let int = |x: &str| {
        x.parse::<usize>()
            .map_err(|_| CkptError::BadHeader(format!("bad integer '{x}'")))
    };
    Ok(IterRecord {
        k: int(p[0])?,
        duration: parse_bits(p[1])?,
        clock: parse_bits(p[2])?,
        train_loss: parse_bits(p[3])?,
        active: int(p[4])?,
        backup_avg: parse_bits(p[5])?,
        theta: parse_bits(p[6])?,
    })
}

fn eval_to_str(r: &EvalRecord) -> String {
    format!(
        "{};{};{};{};{}",
        r.k,
        hex_bits(r.clock),
        hex_bits(r.test_loss),
        hex_bits(r.test_error),
        hex_bits(r.consensus_error)
    )
}

fn eval_from_str(s: &str) -> Result<EvalRecord, CkptError> {
    let p: Vec<&str> = s.split(';').collect();
    if p.len() != 5 {
        return Err(CkptError::BadHeader(format!("bad eval record '{s}'")));
    }
    Ok(EvalRecord {
        k: p[0]
            .parse::<usize>()
            .map_err(|_| CkptError::BadHeader(format!("bad integer '{}'", p[0])))?,
        clock: parse_bits(p[1])?,
        test_loss: parse_bits(p[2])?,
        test_error: parse_bits(p[3])?,
        consensus_error: parse_bits(p[4])?,
    })
}

impl Checkpoint {
    pub fn from_buffers(iteration: usize, clock: f64, model: &str, bufs: &ParamBuffers) -> Self {
        Checkpoint {
            iteration,
            clock,
            model: model.to_string(),
            params: (0..bufs.n()).map(|j| bufs.get(j).to_vec()).collect(),
            history: RunHistory::default(),
        }
    }

    /// FNV-1a over the raw parameter bytes (corruption check).
    fn checksum(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for row in &self.params {
            for v in row {
                for b in v.to_le_bytes() {
                    h ^= b as u64;
                    h = h.wrapping_mul(0x1000_0000_01b3);
                }
            }
        }
        h
    }

    fn header(&self) -> Json {
        let mut header = Json::obj();
        header
            .set("magic", MAGIC.into())
            .set("iteration", self.iteration.into())
            .set("clock", hex_bits(self.clock).into())
            .set("model", self.model.as_str().into())
            .set("workers", self.params.len().into())
            .set("dim", self.params.first().map(|p| p.len()).unwrap_or(0).into())
            .set("checksum", format!("{:016x}", self.checksum()).into());
        if !self.history.iters.is_empty() || !self.history.evals.is_empty() {
            let h = &self.history;
            header
                .set("algo", h.algo.as_str().into())
                .set("hmodel", h.model.as_str().into())
                .set("dataset", h.dataset.as_str().into())
                .set("hworkers", h.workers.into())
                .set("iters", h.iters.iter().map(iter_to_str).collect::<Vec<_>>().into())
                .set("evals", h.evals.iter().map(eval_to_str).collect::<Vec<_>>().into());
        }
        header
    }

    /// Serialise to the on-disk byte layout:
    /// `u64 LE header length | JSON header | workers*dim raw LE f32`.
    pub fn to_bytes(&self) -> Vec<u8> {
        let htext = self.header().to_string();
        let dim = self.params.first().map(|p| p.len()).unwrap_or(0);
        let mut out = Vec::with_capacity(8 + htext.len() + self.params.len() * dim * 4);
        out.extend_from_slice(&(htext.len() as u64).to_le_bytes());
        out.extend_from_slice(htext.as_bytes());
        for row in &self.params {
            for v in row {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Decode a full checkpoint image. The buffer must contain exactly
    /// the declared payload — short reads are [`CkptError::Truncated`],
    /// extra bytes are [`CkptError::TrailingGarbage`].
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint, CkptError> {
        if bytes.len() < 8 {
            return Err(CkptError::Truncated { need: 8, got: bytes.len() });
        }
        let hlen64 = u64::from_le_bytes(bytes[..8].try_into().unwrap());
        if hlen64 > MAX_HEADER {
            return Err(CkptError::AbsurdHeader(hlen64));
        }
        let hlen = hlen64 as usize;
        if bytes.len() < 8 + hlen {
            return Err(CkptError::Truncated { need: 8 + hlen, got: bytes.len() });
        }
        let htext = std::str::from_utf8(&bytes[8..8 + hlen])
            .map_err(|e| CkptError::BadHeader(e.to_string()))?;
        let header = Json::parse(htext).map_err(|e| CkptError::BadHeader(e.to_string()))?;
        if header.get("magic").and_then(|v| v.as_str()) != Some(MAGIC) {
            return Err(CkptError::BadMagic);
        }
        let workers = header.get("workers").and_then(|v| v.as_usize()).unwrap_or(0);
        let dim = header.get("dim").and_then(|v| v.as_usize()).unwrap_or(0);
        let need = 8 + hlen + workers * dim * 4;
        if bytes.len() < need {
            return Err(CkptError::Truncated { need, got: bytes.len() });
        }
        if bytes.len() > need {
            return Err(CkptError::TrailingGarbage { extra: bytes.len() - need });
        }
        let mut params = Vec::with_capacity(workers);
        let mut off = 8 + hlen;
        for _ in 0..workers {
            let mut row = vec![0.0f32; dim];
            for slot in row.iter_mut() {
                *slot = f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
                off += 4;
            }
            params.push(row);
        }
        // clock: hex bit-pattern since the history extension; plain JSON
        // number in older files.
        let clock = match header.get("clock") {
            Some(Json::Str(s)) => parse_bits(s)?,
            Some(v) => v.as_f64().ok_or_else(|| CkptError::BadHeader("bad clock".into()))?,
            None => 0.0,
        };
        let mut history = RunHistory::default();
        if header.get("iters").is_some() || header.get("evals").is_some() {
            let arr = |key: &str| -> Result<Vec<String>, CkptError> {
                match header.get(key) {
                    None => Ok(Vec::new()),
                    Some(v) => v
                        .as_arr()
                        .ok_or_else(|| CkptError::BadHeader(format!("'{key}' not an array")))?
                        .iter()
                        .map(|x| {
                            x.as_str().map(str::to_string).ok_or_else(|| {
                                CkptError::BadHeader(format!("'{key}' entry not a string"))
                            })
                        })
                        .collect(),
                }
            };
            history.algo = header
                .get("algo")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string();
            history.model = header
                .get("hmodel")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string();
            history.dataset = header
                .get("dataset")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string();
            history.workers = header.get("hworkers").and_then(|v| v.as_usize()).unwrap_or(0);
            history.iters = arr("iters")?
                .iter()
                .map(|s| iter_from_str(s))
                .collect::<Result<_, _>>()?;
            history.evals = arr("evals")?
                .iter()
                .map(|s| eval_from_str(s))
                .collect::<Result<_, _>>()?;
        }
        let ckpt = Checkpoint {
            iteration: header.get("iteration").and_then(|v| v.as_usize()).unwrap_or(0),
            clock,
            model: header
                .get("model")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            params,
            history,
        };
        let want = header
            .get("checksum")
            .and_then(|v| v.as_str())
            .unwrap_or("")
            .to_string();
        let got = format!("{:016x}", ckpt.checksum());
        if want != got {
            return Err(CkptError::BadChecksum { got, want });
        }
        Ok(ckpt)
    }

    pub fn save(&self, path: &Path) -> Result<(), CkptError> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(&self.to_bytes())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint, CkptError> {
        Checkpoint::from_bytes(&std::fs::read(path)?)
    }

    pub fn into_buffers(self) -> ParamBuffers {
        ParamBuffers::from_initial(self.params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample() -> Checkpoint {
        let mut rng = Rng::new(7);
        Checkpoint {
            iteration: 123,
            clock: 45.5,
            model: "lrm_d8_c4_b16".into(),
            params: (0..4)
                .map(|_| (0..36).map(|_| rng.normal() as f32).collect())
                .collect(),
            history: RunHistory::default(),
        }
    }

    fn sample_with_history() -> Checkpoint {
        let mut c = sample();
        let mut h = RunHistory::new("cb-dybw", "lrm", "synthetic", 4);
        let mut clock = 0.0;
        for k in 1..=6 {
            clock += 0.125;
            h.iters.push(IterRecord {
                k,
                duration: 0.125,
                clock,
                train_loss: 1.0 / k as f64,
                active: 3,
                backup_avg: 0.5,
                // NaN theta is the baseline-algorithm case the hex-bit
                // encoding exists for.
                theta: if k % 2 == 0 { f64::NAN } else { 0.125 },
            });
        }
        h.evals.push(EvalRecord {
            k: 5,
            clock: 0.625,
            test_loss: 0.5,
            test_error: 0.25,
            consensus_error: 1e-9,
        });
        c.history = h;
        c
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("dybw_ckpt_test");
        let path = dir.join("a.ckpt");
        let c = sample();
        c.save(&path).unwrap();
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(c, l);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn history_roundtrips_bit_exactly_including_nan_theta() {
        let c = sample_with_history();
        let l = Checkpoint::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(c, l);
        assert!(l.history.iters[1].theta.is_nan());
        assert_eq!(l.history.algo, "cb-dybw");
        assert_eq!(l.history.evals.len(), 1);
    }

    #[test]
    fn corruption_detected() {
        let dir = std::env::temp_dir().join("dybw_ckpt_corrupt");
        let path = dir.join("b.ckpt");
        sample().save(&path).unwrap();
        // flip one byte in the payload
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 3;
        bytes[last] ^= 0xFF;
        std::fs::write(&path, bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(matches!(err, CkptError::BadChecksum { .. }));
        assert!(err.to_string().contains("corrupted"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_non_checkpoints() {
        let dir = std::env::temp_dir().join("dybw_ckpt_reject");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        std::fs::write(&path, b"\x05\x00\x00\x00\x00\x00\x00\x00hello").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn buffers_roundtrip() {
        let c = sample();
        let bufs = c.clone().into_buffers();
        let c2 = Checkpoint::from_buffers(c.iteration, c.clock, &c.model, &bufs);
        assert_eq!(c.params, c2.params);
    }

    #[test]
    fn truncation_at_every_offset_is_a_typed_error() {
        // Mirror of the codec fuzz suite: every strict prefix must decode
        // to Truncated / BadHeader / BadChecksum — never panic, never Ok.
        let bytes = sample_with_history().to_bytes();
        for cut in 0..bytes.len() {
            let err = Checkpoint::from_bytes(&bytes[..cut]).unwrap_err();
            match err {
                CkptError::Truncated { .. }
                | CkptError::BadHeader(_)
                | CkptError::BadChecksum { .. }
                | CkptError::AbsurdHeader(_)
                | CkptError::BadMagic => {}
                other => panic!("cut {cut}: unexpected error {other:?}"),
            }
        }
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut bytes = sample().to_bytes();
        bytes.extend_from_slice(b"xx");
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(matches!(err, CkptError::TrailingGarbage { extra: 2 }));
    }

    #[test]
    fn checksum_flip_in_header_detected() {
        let c = sample();
        let bytes = c.to_bytes();
        let text = String::from_utf8_lossy(&bytes[8..]).into_owned();
        // find the checksum hex in the header and flip its first digit
        let pos = 8 + text.find("checksum").unwrap() + "checksum\":\"".len();
        let mut bad = bytes.clone();
        bad[pos] = if bad[pos] == b'0' { b'1' } else { b'0' };
        let err = Checkpoint::from_bytes(&bad).unwrap_err();
        assert!(matches!(err, CkptError::BadChecksum { .. }), "{err:?}");
    }

    #[test]
    fn absurd_header_length_rejected() {
        let mut bytes = vec![0u8; 16];
        bytes[..8].copy_from_slice(&u64::MAX.to_le_bytes());
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(matches!(err, CkptError::AbsurdHeader(_)));
    }
}
