//! Checkpointing: save/resume training state (framework feature).
//!
//! A checkpoint captures iteration counter, virtual clock, and every
//! worker's parameter vector. Format: a JSON header (versioned, with a
//! content checksum) followed by raw little-endian f32 data — readable
//! from numpy with a two-line loader, cheap to write from the hot loop.

use std::io::{Read, Write};
use std::path::Path;

use crate::consensus::mixing::ParamBuffers;
use crate::util::json::Json;

const MAGIC: &str = "dybw-ckpt-v1";

#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub iteration: usize,
    pub clock: f64,
    pub model: String,
    pub params: Vec<Vec<f32>>,
}

impl Checkpoint {
    pub fn from_buffers(iteration: usize, clock: f64, model: &str, bufs: &ParamBuffers) -> Self {
        Checkpoint {
            iteration,
            clock,
            model: model.to_string(),
            params: (0..bufs.n()).map(|j| bufs.get(j).to_vec()).collect(),
        }
    }

    /// FNV-1a over the raw parameter bytes (corruption check).
    fn checksum(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for row in &self.params {
            for v in row {
                for b in v.to_le_bytes() {
                    h ^= b as u64;
                    h = h.wrapping_mul(0x1000_0000_01b3);
                }
            }
        }
        h
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut header = Json::obj();
        header
            .set("magic", MAGIC.into())
            .set("iteration", self.iteration.into())
            .set("clock", self.clock.into())
            .set("model", self.model.as_str().into())
            .set("workers", self.params.len().into())
            .set("dim", self.params.first().map(|p| p.len()).unwrap_or(0).into())
            .set("checksum", format!("{:016x}", self.checksum()).into());
        let htext = header.to_string();
        let mut f = std::fs::File::create(path)?;
        f.write_all(&(htext.len() as u64).to_le_bytes())?;
        f.write_all(htext.as_bytes())?;
        for row in &self.params {
            // SAFETY: f32 slice -> bytes view of the same length*4
            let bytes = unsafe {
                std::slice::from_raw_parts(row.as_ptr() as *const u8, row.len() * 4)
            };
            f.write_all(bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let mut f = std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("cannot open checkpoint {}: {e}", path.display()))?;
        let mut len8 = [0u8; 8];
        f.read_exact(&mut len8)?;
        let hlen = u64::from_le_bytes(len8) as usize;
        anyhow::ensure!(hlen < 1 << 20, "absurd header length");
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = Json::parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow::anyhow!("bad checkpoint header: {e}"))?;
        anyhow::ensure!(
            header.get("magic").and_then(|v| v.as_str()) == Some(MAGIC),
            "not a dybw checkpoint"
        );
        let workers = header.get("workers").and_then(|v| v.as_usize()).unwrap_or(0);
        let dim = header.get("dim").and_then(|v| v.as_usize()).unwrap_or(0);
        let mut params = Vec::with_capacity(workers);
        let mut raw = vec![0u8; dim * 4];
        for _ in 0..workers {
            f.read_exact(&mut raw)?;
            let mut row = vec![0.0f32; dim];
            for (i, chunk) in raw.chunks_exact(4).enumerate() {
                row[i] = f32::from_le_bytes(chunk.try_into().unwrap());
            }
            params.push(row);
        }
        let ckpt = Checkpoint {
            iteration: header.get("iteration").and_then(|v| v.as_usize()).unwrap_or(0),
            clock: header.get("clock").and_then(|v| v.as_f64()).unwrap_or(0.0),
            model: header
                .get("model")
                .and_then(|v| v.as_str())
                .unwrap_or("")
                .to_string(),
            params,
        };
        let want = header.get("checksum").and_then(|v| v.as_str()).unwrap_or("");
        let got = format!("{:016x}", ckpt.checksum());
        anyhow::ensure!(want == got, "checkpoint corrupted: checksum {got} != {want}");
        Ok(ckpt)
    }

    pub fn into_buffers(self) -> ParamBuffers {
        ParamBuffers::from_initial(self.params)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample() -> Checkpoint {
        let mut rng = Rng::new(7);
        Checkpoint {
            iteration: 123,
            clock: 45.5,
            model: "lrm_d8_c4_b16".into(),
            params: (0..4)
                .map(|_| (0..36).map(|_| rng.normal() as f32).collect())
                .collect(),
        }
    }

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("dybw_ckpt_test");
        let path = dir.join("a.ckpt");
        let c = sample();
        c.save(&path).unwrap();
        let l = Checkpoint::load(&path).unwrap();
        assert_eq!(c, l);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corruption_detected() {
        let dir = std::env::temp_dir().join("dybw_ckpt_corrupt");
        let path = dir.join("b.ckpt");
        sample().save(&path).unwrap();
        // flip one byte in the payload
        let mut bytes = std::fs::read(&path).unwrap();
        let last = bytes.len() - 3;
        bytes[last] ^= 0xFF;
        std::fs::write(&path, bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert!(err.to_string().contains("corrupted"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_non_checkpoints() {
        let dir = std::env::temp_dir().join("dybw_ckpt_reject");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.ckpt");
        std::fs::write(&path, b"\x05\x00\x00\x00\x00\x00\x00\x00hello").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn buffers_roundtrip() {
        let c = sample();
        let bufs = c.clone().into_buffers();
        let c2 = Checkpoint::from_buffers(c.iteration, c.clock, &c.model, &bufs);
        assert_eq!(c.params, c2.params);
    }
}
