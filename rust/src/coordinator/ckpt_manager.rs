//! Retention-aware checkpoint manager: atomic writes, pruned history,
//! restart-from-latest discovery.
//!
//! [`Checkpoint`](super::checkpoint::Checkpoint) knows how to encode one
//! snapshot; the manager owns a *directory* of them:
//!
//! - **Atomic saves** — bytes go to a `.tmp-` file first, `fsync`, then
//!   a rename onto the final `ckpt-<iteration>.dybw` name (plus a
//!   best-effort directory sync). A kill mid-write can leave a stale tmp
//!   file but never a half-written checkpoint under the real name.
//! - **Retention** — after every save the oldest checkpoints beyond
//!   `retain` are deleted, deterministically (iteration order, not
//!   mtime, so two same-seed runs leave byte-identical directories).
//! - **`latest()`** — walks checkpoints newest-first and returns the
//!   first that decodes intact, skipping corrupt/truncated files and
//!   tmp leftovers. Recovery never trusts a file the codec rejects.
//!
//! Single-writer by design: one training process owns a directory. The
//! tmp name is derived from the iteration, so concurrent writers would
//! clobber each other — that is out of scope, same as for the event logs.

use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};

use super::checkpoint::{Checkpoint, CkptError};

const PREFIX: &str = "ckpt-";
const SUFFIX: &str = ".dybw";
const TMP_PREFIX: &str = ".tmp-";

#[derive(Debug, Clone)]
pub struct CkptManager {
    dir: PathBuf,
    /// Keep this many newest checkpoints; 0 = keep everything.
    retain: usize,
}

impl CkptManager {
    pub fn new(dir: &Path, retain: usize) -> Result<CkptManager, CkptError> {
        std::fs::create_dir_all(dir)?;
        Ok(CkptManager { dir: dir.to_path_buf(), retain })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn file_name(iteration: usize) -> String {
        // zero-padded so lexicographic order == iteration order
        format!("{PREFIX}{iteration:010}{SUFFIX}")
    }

    /// Atomically persist one checkpoint and prune beyond the retention
    /// limit. Returns the final path.
    pub fn save(&self, ckpt: &Checkpoint) -> Result<PathBuf, CkptError> {
        let final_path = self.dir.join(Self::file_name(ckpt.iteration));
        let tmp_path = self
            .dir
            .join(format!("{TMP_PREFIX}{}", Self::file_name(ckpt.iteration)));
        {
            let mut f = File::create(&tmp_path)?;
            f.write_all(&ckpt.to_bytes())?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp_path, &final_path)?;
        // Durability of the rename itself needs a directory sync; not
        // every platform lets you open a directory, so best effort.
        if let Ok(d) = File::open(&self.dir) {
            let _ = d.sync_all();
        }
        self.prune()?;
        Ok(final_path)
    }

    /// All checkpoint files, ascending by iteration. Non-checkpoint
    /// names (tmp leftovers, foreign files) are ignored.
    pub fn list(&self) -> Result<Vec<(usize, PathBuf)>, CkptError> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let path = entry?.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
                continue;
            };
            let Some(mid) = name.strip_prefix(PREFIX).and_then(|s| s.strip_suffix(SUFFIX))
            else {
                continue;
            };
            if let Ok(iter) = mid.parse::<usize>() {
                out.push((iter, path));
            }
        }
        out.sort_unstable();
        Ok(out)
    }

    fn prune(&self) -> Result<(), CkptError> {
        if self.retain == 0 {
            return Ok(());
        }
        let files = self.list()?;
        if files.len() > self.retain {
            for (_, path) in &files[..files.len() - self.retain] {
                std::fs::remove_file(path)?;
            }
        }
        Ok(())
    }

    /// Newest checkpoint that decodes intact, with its path. Corrupt or
    /// truncated files are skipped (recovery falls back to the next
    /// newest), stale tmp files never match the name filter.
    pub fn latest(&self) -> Result<Option<(Checkpoint, PathBuf)>, CkptError> {
        for (_, path) in self.list()?.into_iter().rev() {
            if let Ok(ckpt) = Checkpoint::load(&path) {
                return Ok(Some((ckpt, path)));
            }
        }
        Ok(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::RunHistory;
    use crate::util::rng::Rng;

    fn snap(iteration: usize) -> Checkpoint {
        let mut rng = Rng::new(iteration as u64);
        Checkpoint {
            iteration,
            clock: iteration as f64 * 0.5,
            model: "lrm".into(),
            params: (0..3)
                .map(|_| (0..8).map(|_| rng.normal() as f32).collect())
                .collect(),
            history: RunHistory::default(),
        }
    }

    fn fresh_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dybw_ckpt_mgr_{tag}"));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn save_list_latest() {
        let dir = fresh_dir("basic");
        let mgr = CkptManager::new(&dir, 0).unwrap();
        for k in [4usize, 8, 12] {
            mgr.save(&snap(k)).unwrap();
        }
        let iters: Vec<usize> = mgr.list().unwrap().into_iter().map(|(k, _)| k).collect();
        assert_eq!(iters, vec![4, 8, 12]);
        let (latest, path) = mgr.latest().unwrap().unwrap();
        assert_eq!(latest, snap(12));
        assert!(path.ends_with("ckpt-0000000012.dybw"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retention_prunes_oldest_deterministically() {
        let dir = fresh_dir("retain");
        let mgr = CkptManager::new(&dir, 2).unwrap();
        for k in [4usize, 8, 12, 16] {
            mgr.save(&snap(k)).unwrap();
        }
        let iters: Vec<usize> = mgr.list().unwrap().into_iter().map(|(k, _)| k).collect();
        assert_eq!(iters, vec![12, 16]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn latest_skips_corrupt_and_truncated_to_newest_intact() {
        let dir = fresh_dir("skip");
        let mgr = CkptManager::new(&dir, 0).unwrap();
        mgr.save(&snap(4)).unwrap();
        mgr.save(&snap(8)).unwrap();
        let p12 = mgr.save(&snap(12)).unwrap();
        let p16 = mgr.save(&snap(16)).unwrap();
        // newest truncated mid-payload, second-newest checksum-flipped
        let bytes = std::fs::read(&p16).unwrap();
        std::fs::write(&p16, &bytes[..bytes.len() / 2]).unwrap();
        let mut bytes = std::fs::read(&p12).unwrap();
        let last = bytes.len() - 1;
        bytes[last] ^= 0xFF;
        std::fs::write(&p12, bytes).unwrap();
        let (latest, _) = mgr.latest().unwrap().unwrap();
        assert_eq!(latest, snap(8), "latest() must fall back to the newest intact file");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stale_tmp_and_foreign_files_are_ignored() {
        let dir = fresh_dir("stale");
        let mgr = CkptManager::new(&dir, 0).unwrap();
        mgr.save(&snap(4)).unwrap();
        // a crash between write and rename leaves exactly this
        std::fs::write(dir.join(".tmp-ckpt-0000000099.dybw"), b"half-written").unwrap();
        std::fs::write(dir.join("notes.txt"), b"unrelated").unwrap();
        // garbage under a valid checkpoint name must be skipped, not fatal
        std::fs::write(dir.join("ckpt-0000000050.dybw"), b"garbage").unwrap();
        let iters: Vec<usize> = mgr.list().unwrap().into_iter().map(|(k, _)| k).collect();
        assert_eq!(iters, vec![4, 50]);
        let (latest, _) = mgr.latest().unwrap().unwrap();
        assert_eq!(latest, snap(4));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_directory_has_no_latest() {
        let dir = fresh_dir("empty");
        let mgr = CkptManager::new(&dir, 3).unwrap();
        assert!(mgr.latest().unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoints_with_history_survive_the_manager() {
        use crate::metrics::IterRecord;
        let dir = fresh_dir("hist");
        let mgr = CkptManager::new(&dir, 1).unwrap();
        let mut c = snap(20);
        c.history = RunHistory::new("cb-dybw", "lrm", "synthetic", 3);
        c.history.iters.push(IterRecord {
            k: 20,
            duration: 0.1,
            clock: 2.0,
            train_loss: 0.3,
            active: 3,
            backup_avg: 0.0,
            theta: f64::NAN,
        });
        mgr.save(&c).unwrap();
        let (l, _) = mgr.latest().unwrap().unwrap();
        assert_eq!(l, c);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
