//! Training algorithms: who participates, how long an iteration takes.
//!
//! Each algorithm maps the iteration's compute-time vector t_·(k) to an
//! [`IterPlan`]: the participation mask (⇒ the consensus matrix P(k)),
//! the iteration duration T(k), and whether mixing is gossip (eq. 6) or
//! exact parameter-server averaging.
//!
//! | name        | waits for                      | mixing    | paper role |
//! |-------------|--------------------------------|-----------|------------|
//! | cb-DyBW     | first P-link (DTUR θ(k))       | Metropolis| Alg. 1+2   |
//! | cb-Full     | all workers                    | Metropolis| §5 baseline|
//! | cb-Static b | fastest N-b workers (fixed b)  | Metropolis| §1 static  |
//! | PS-Sync     | all workers                    | exact avg | §1 related |
//! | PS-Backup b | fastest N-b workers            | exact avg | [34]-style |
//!
//! The static/PS variants use a *global* threshold (the (N-b)-th order
//! statistic of t) rather than per-node neighbour picks so the active set
//! stays symmetric and P(k) doubly stochastic — see DESIGN.md §Baselines.

use super::dtur::Dtur;
use crate::graph::Graph;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Algorithm {
    /// The paper's contribution: dynamic backup workers via DTUR.
    CbDybw,
    /// Conventional consensus with full participation.
    CbFull,
    /// Fixed number of backup workers b (manually configured, the
    /// stale-synchronous strawman the paper argues against).
    CbStaticBackup { b: usize },
    /// Synchronous parameter server (exact averaging, waits for all).
    PsSync,
    /// Parameter server with b backup workers (Chen et al. 2016).
    PsBackup { b: usize },
}

impl Algorithm {
    pub fn name(&self) -> String {
        match self {
            Algorithm::CbDybw => "cb-DyBW".into(),
            Algorithm::CbFull => "cb-Full".into(),
            Algorithm::CbStaticBackup { b } => format!("cb-Static(b={b})"),
            Algorithm::PsSync => "PS-Sync".into(),
            Algorithm::PsBackup { b } => format!("PS-Backup(b={b})"),
        }
    }

    pub fn parse(s: &str) -> Option<Algorithm> {
        match s {
            "cb-dybw" | "dybw" => Some(Algorithm::CbDybw),
            "cb-full" | "full" => Some(Algorithm::CbFull),
            "ps-sync" | "ps" => Some(Algorithm::PsSync),
            _ => {
                if let Some(b) = s.strip_prefix("cb-static:") {
                    b.parse().ok().map(|b| Algorithm::CbStaticBackup { b })
                } else if let Some(b) = s.strip_prefix("ps-backup:") {
                    b.parse().ok().map(|b| Algorithm::PsBackup { b })
                } else {
                    None
                }
            }
        }
    }

    pub fn is_ps(&self) -> bool {
        matches!(self, Algorithm::PsSync | Algorithm::PsBackup { .. })
    }

    pub fn needs_dtur(&self) -> bool {
        matches!(self, Algorithm::CbDybw)
    }
}

/// The per-iteration plan derived from compute times.
#[derive(Debug, Clone)]
pub struct IterPlan {
    /// T(k): the iteration's duration on the virtual clock.
    pub duration: f64,
    /// θ(k) when a threshold rule produced the plan (NaN otherwise).
    pub theta: f64,
    /// Participation mask (all true for full/PS-sync).
    pub active: Vec<bool>,
    /// Exact averaging (PS) instead of Metropolis gossip.
    pub ps_style: bool,
}

impl IterPlan {
    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// avg_j b_j(k): mean backup workers per node — Fig. 1(d)'s series.
    /// b_j(k) = |N_j| - |active neighbours of j| (0 for PS-style full).
    pub fn backup_avg(&self, g: &Graph) -> f64 {
        let n = g.n();
        let mut total = 0usize;
        for j in 0..n {
            total += g.neighbors(j).filter(|&i| !self.active[i]).count();
        }
        total as f64 / n as f64
    }
}

/// Compute the plan for iteration k.
pub fn plan(
    algo: Algorithm,
    t: &[f64],
    dtur: Option<&mut Dtur>,
) -> IterPlan {
    let n = t.len();
    match algo {
        Algorithm::CbDybw => {
            let dtur = dtur.expect("cb-DyBW requires DTUR state");
            let dec = dtur.step(t);
            IterPlan {
                duration: dec.theta,
                theta: dec.theta,
                active: dec.active,
                ps_style: false,
            }
        }
        Algorithm::CbFull | Algorithm::PsSync => IterPlan {
            duration: t.iter().copied().fold(0.0, f64::max),
            theta: f64::NAN,
            active: vec![true; n],
            ps_style: algo.is_ps(),
        },
        Algorithm::CbStaticBackup { b } | Algorithm::PsBackup { b } => {
            let wait = n.saturating_sub(b).max(1);
            let mut sorted: Vec<f64> = t.to_vec();
            sorted.sort_by(f64::total_cmp);
            let theta = sorted[wait - 1];
            IterPlan {
                duration: theta,
                theta,
                active: t.iter().map(|&tj| tj <= theta).collect(),
                ps_style: algo.is_ps(),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topology;

    #[test]
    fn parse_names() {
        assert_eq!(Algorithm::parse("cb-dybw"), Some(Algorithm::CbDybw));
        assert_eq!(Algorithm::parse("full"), Some(Algorithm::CbFull));
        assert_eq!(
            Algorithm::parse("cb-static:2"),
            Some(Algorithm::CbStaticBackup { b: 2 })
        );
        assert_eq!(
            Algorithm::parse("ps-backup:1"),
            Some(Algorithm::PsBackup { b: 1 })
        );
        assert_eq!(Algorithm::parse("wat"), None);
    }

    #[test]
    fn full_waits_for_slowest() {
        let t = vec![0.1, 0.9, 0.2];
        let p = plan(Algorithm::CbFull, &t, None);
        assert_eq!(p.duration, 0.9);
        assert_eq!(p.active_count(), 3);
        assert!(!p.ps_style);
    }

    #[test]
    fn static_backup_order_statistic() {
        let t = vec![0.5, 0.1, 0.9, 0.3];
        let p = plan(Algorithm::CbStaticBackup { b: 1 }, &t, None);
        // waits for fastest 3 -> threshold = 0.5
        assert_eq!(p.duration, 0.5);
        assert_eq!(p.active, vec![true, true, false, true]);
    }

    #[test]
    fn ps_backup_is_ps_style() {
        let t = vec![0.5, 0.1, 0.9, 0.3];
        let p = plan(Algorithm::PsBackup { b: 2 }, &t, None);
        assert!(p.ps_style);
        assert_eq!(p.active_count(), 2);
        assert_eq!(p.duration, 0.3);
    }

    #[test]
    fn backup_avg_counts_inactive_neighbours() {
        let g = topology::complete(4);
        let p = IterPlan {
            duration: 1.0,
            theta: 1.0,
            active: vec![true, true, true, false],
            ps_style: false,
        };
        // every node has 3 neighbours; nodes 0-2 see one inactive (node 3),
        // node 3 sees none inactive
        assert!((p.backup_avg(&g) - 3.0 / 4.0).abs() < 1e-12);
    }

    #[test]
    fn dybw_duration_leq_full() {
        // Corollary 4: E[T_p] <= E[T_full]. Check the per-draw analogue:
        // DTUR's theta never exceeds max(t).
        let g = topology::random_connected(8, 0.4, &mut crate::util::rng::Rng::new(0));
        let mut dtur = Dtur::new(&g);
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..100 {
            let t: Vec<f64> = (0..8).map(|_| rng.uniform_in(0.05, 1.0)).collect();
            let tmax = t.iter().copied().fold(0.0, f64::max);
            let p = plan(Algorithm::CbDybw, &t, Some(&mut dtur));
            assert!(p.duration <= tmax + 1e-12);
        }
    }
}
