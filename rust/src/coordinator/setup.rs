//! Experiment wiring: declarative spec -> ready-to-run trainer.
//!
//! Shared by the CLI (`dybw train`), the figure harnesses
//! (src/experiments), the examples, and the benches, so every entry point
//! builds runs the exact same way. Specs serialise to/from JSON (the
//! config-file format of `dybw train --config`).

use std::path::PathBuf;
#[cfg(feature = "pjrt")]
use std::rc::Rc;
#[cfg(feature = "pjrt")]
use std::sync::Arc;

use crate::coordinator::{Algorithm, SimTrainer, TrainConfig};
use crate::data::batch::BatchSampler;
use crate::data::partition::{split_pooled, Partition};
use crate::data::synthetic::{gaussian_mixture_pooled, markov_sequences_pooled, MixtureSpec};
use crate::engine::{
    native_factory, AnyBatch, BatchSource, DenseSource, EngineFactory, EnginePool, SeqSource,
};
#[cfg(feature = "pjrt")]
use crate::engine::GradEngine;
use crate::graph::topology::{self, Topology};
use crate::model::{ModelKind, ModelMeta};
#[cfg(feature = "pjrt")]
use crate::runtime::{shared_client, ArtifactSet, LoadedModel, PjrtEngine};
use crate::straggler::{Dist, StragglerModel};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Which dataset profile to synthesise (paper: MNIST / CIFAR-10).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetProfile {
    MnistLike,
    CifarLike,
}

impl DatasetProfile {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "mnist" | "mnist-like" => Some(DatasetProfile::MnistLike),
            "cifar" | "cifar-like" => Some(DatasetProfile::CifarLike),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetProfile::MnistLike => "mnist-like",
            DatasetProfile::CifarLike => "cifar-like",
        }
    }

    pub fn mixture(&self, dim: usize, n: usize) -> MixtureSpec {
        match self {
            DatasetProfile::MnistLike => MixtureSpec::mnist_like(dim, n),
            DatasetProfile::CifarLike => MixtureSpec::cifar_like(dim, n),
        }
    }
}

/// Compute backend.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust engines (lrm/mlp2 only).
    Native,
    /// AOT JAX/Pallas artifacts through PJRT.
    Pjrt { artifacts_dir: PathBuf },
}

/// Full experiment specification.
#[derive(Debug, Clone)]
pub struct Setup {
    pub workers: usize,
    pub topology: Topology,
    pub algo: Algorithm,
    /// Model selected by artifact-family name (e.g. "lrm_d64_c10_b256").
    /// Shapes are parsed out of the name's meta when PJRT, or rebuilt
    /// natively for lrm/mlp2.
    pub model: String,
    pub dataset: DatasetProfile,
    pub partition: Partition,
    pub train_n: usize,
    pub test_n: usize,
    pub straggler_base: Dist,
    pub straggler_factor: f64,
    pub force_straggler: bool,
    pub backend: Backend,
    /// Engine-pool lanes for parallel per-worker work — the gradient
    /// fan-out, eval batches, AND the eq. (6) mixing rows all ride the
    /// same pool (0 = auto: available hardware parallelism, capped at
    /// the worker count).
    pub threads: usize,
    pub train: TrainConfig,
}

impl Default for Setup {
    fn default() -> Self {
        Setup {
            workers: 6,
            topology: Topology::RandomConnected,
            algo: Algorithm::CbDybw,
            model: "lrm_d64_c10_b256".into(),
            dataset: DatasetProfile::MnistLike,
            partition: Partition::Iid,
            train_n: 12_000,
            test_n: 2_048,
            straggler_base: Dist::ShiftedExp { base: 0.08, rate: 25.0 },
            straggler_factor: 4.0,
            force_straggler: true,
            backend: Backend::Native,
            threads: 0,
            train: TrainConfig::default(),
        }
    }
}

impl Setup {
    /// Resolve the ModelMeta: from the artifact set when PJRT, otherwise
    /// reconstructed natively from the model name.
    pub fn resolve_meta(&self) -> anyhow::Result<ModelMeta> {
        match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Pjrt { artifacts_dir } => {
                let art = ArtifactSet::load_family(artifacts_dir, &self.model)?;
                Ok(art.meta)
            }
            #[cfg(not(feature = "pjrt"))]
            Backend::Pjrt { .. } => {
                anyhow::bail!("backend 'pjrt' requires building with `--features pjrt`")
            }
            Backend::Native => parse_model_name(&self.model),
        }
    }

    /// Engine factory for this setup: invoked once per pool lane, ON the
    /// lane thread (so Rc-backed PJRT engines work — each lane compiles
    /// its own executable, mirroring a per-device queue).
    pub fn engine_factory(&self, meta: &ModelMeta) -> anyhow::Result<EngineFactory> {
        match &self.backend {
            Backend::Native => Ok(native_factory(meta.clone())),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt { artifacts_dir } => {
                let dir = artifacts_dir.clone();
                let name = self.model.clone();
                Ok(Arc::new(move || {
                    let art = ArtifactSet::load_family(&dir, &name)?;
                    let model = LoadedModel::compile(&art, shared_client()?)?;
                    Ok(Box::new(PjrtEngine::new(Rc::new(model))) as Box<dyn GradEngine>)
                }))
            }
            #[cfg(not(feature = "pjrt"))]
            Backend::Pjrt { .. } => {
                anyhow::bail!("backend 'pjrt' requires building with `--features pjrt`")
            }
        }
    }

    /// Effective pool size: the explicit `threads` setting, or (when 0)
    /// the machine's available parallelism capped at the worker count —
    /// neither the gradient fan-out nor the mixing phase can ever use
    /// more lanes than there are workers in the sim driver.
    pub fn resolve_threads(&self) -> usize {
        if self.threads > 0 {
            return self.threads;
        }
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(self.workers.max(1))
    }

    /// Build the per-worker engine pool.
    pub fn build_pool(&self, meta: &ModelMeta) -> anyhow::Result<EnginePool> {
        EnginePool::new(self.engine_factory(meta)?, self.resolve_threads())
    }

    /// The shared build prefix of [`Self::build_sim`] and
    /// [`Self::build_des`]: graph, straggler model, pool, data sources,
    /// eval set, and initial parameters, all drawn from ONE seed-derived
    /// RNG in a fixed order. The order IS the reproducibility contract —
    /// both trainers replay the exact same data/model for a seed, which
    /// is what makes lockstep-vs-async (and policy-vs-policy) runs
    /// variance-free A/Bs.
    fn build_parts(&self) -> anyhow::Result<SimParts> {
        let meta = self.resolve_meta()?;
        let mut cfg = self.train.clone();
        // artifact batch shape is fixed; keep config consistent
        cfg.batch_size = meta.batch;

        let mut rng = Rng::new(self.train.seed);
        let graph = topology::build(self.topology, self.workers, &mut rng);
        let straggler = self.straggler_model(&mut rng);

        // The pool comes up first so data synthesis can fan over its
        // lanes (pool construction consumes no RNG, so the stream
        // reaching build_data — and everything after it — is unchanged).
        let pool = self.build_pool(&meta)?;
        let (sources, eval_batches) = self.build_data(&meta, &mut rng, &pool)?;
        let init = meta.init_params(&mut rng);
        Ok(SimParts {
            cfg,
            graph,
            straggler,
            pool,
            sources,
            eval_batches,
            init,
            rng,
        })
    }

    /// Build the simulation trainer.
    pub fn build_sim(&self) -> anyhow::Result<SimTrainer> {
        let p = self.build_parts()?;
        SimTrainer::new(
            p.graph,
            self.algo,
            p.cfg,
            p.straggler,
            p.pool,
            p.sources,
            p.eval_batches,
            p.init,
        )
    }

    /// Build the pieces of a live (real-worker) run from the same
    /// [`Self::build_parts`] substrate as the sim/DES trainers — same
    /// RNG order, so a seed means the same graph/model/data in every
    /// driver, and in every PROCESS: a `dybw worker` rebuilds identical
    /// parts from the setup JSON the coordinator hands it at handshake,
    /// which is what makes the TCP run bit-identical to the in-process
    /// one.
    pub fn build_live(&self) -> anyhow::Result<LiveParts> {
        let p = self.build_parts()?;
        let (server, client) =
            crate::engine::server::ComputeServer::from_pool(std::sync::Arc::new(p.pool));
        Ok(LiveParts {
            graph: p.graph,
            cfg: p.cfg,
            straggler: p.straggler,
            server,
            client,
            sources: p.sources,
            eval_batches: p.eval_batches,
            init: p.init,
        })
    }

    /// Build the asynchronous event-driven trainer (full-fidelity DES).
    ///
    /// Same model/data/pool wiring as [`Self::build_sim`] (one shared
    /// [`Self::build_parts`], so the RNG stream order is identical by
    /// construction), but compute times become a trace recorded up front
    /// from the straggler model and replayed per worker. Because the
    /// whole build is a pure function of the seed, every policy run at
    /// the same seed sees the *identical* timing realisation:
    /// `build_des(dybw, ..)` vs `build_des(full, ..)` is a
    /// variance-free A/B.
    pub fn build_des(
        &self,
        policy: crate::des::WaitPolicy,
        link: crate::straggler::link::LinkModel,
    ) -> anyhow::Result<crate::des::DesTrainer> {
        self.build_des_with_times(policy, link, None)
    }

    /// [`Self::build_des`] with an externally supplied compute-time
    /// source (e.g. a scenario's shared realisation or a CSV trace) —
    /// skips recording the internal trace entirely instead of building
    /// one just to throw it away.
    pub fn build_des_with_times(
        &self,
        policy: crate::des::WaitPolicy,
        link: crate::straggler::link::LinkModel,
        times: Option<crate::des::ComputeTimes>,
    ) -> anyhow::Result<crate::des::DesTrainer> {
        let mut p = self.build_parts()?;
        let times = match times {
            Some(t) => t,
            None => {
                let trace = crate::straggler::trace::Trace::record(
                    &p.straggler,
                    p.cfg.iters.max(1),
                    &mut p.rng,
                );
                crate::des::ComputeTimes::Replay(std::sync::Arc::new(trace))
            }
        };
        crate::des::DesTrainer::new(
            p.graph,
            policy,
            p.cfg,
            times,
            link,
            p.pool,
            p.sources,
            p.eval_batches,
            p.init,
            &self.model,
        )
    }

    /// The straggler model this setup trains under, with per-worker pace
    /// scales drawn from `rng` (consumes exactly `workers` draws — the
    /// stream position is part of [`Self::build_parts`]'s contract).
    fn straggler_model(&self, rng: &mut Rng) -> StragglerModel {
        let mut straggler = StragglerModel {
            base: self.straggler_base,
            worker_scale: (0..self.workers).map(|_| rng.uniform_in(0.8, 1.25)).collect(),
            persistent: vec![1.0; self.workers],
            transient_prob: 0.15,
            transient_factor: self.straggler_factor,
            force_one_straggler: self.force_straggler,
            outages: Vec::new(),
            diurnal_amp: 0.0,
            diurnal_period: 0.0,
        };
        if !self.force_straggler && self.straggler_factor <= 1.0 {
            straggler.transient_prob = 0.0;
        }
        straggler
    }

    /// Record one compute-time realisation for this setup's straggler
    /// model — the shareable half of a DES build.
    ///
    /// Drawn from a dedicated seed-derived stream (model scales, then
    /// the trace), so it is a pure function of (seed, workers, straggler
    /// knobs) and cheap: no data synthesis, no engine pool. Harnesses
    /// that sweep wait policies over one scenario should record this
    /// once and hand it to every [`Self::build_des_with_times`] cell, so
    /// the policies A/B on literally the same realisation instead of
    /// each cell re-recording its own. Note it is NOT the realisation
    /// [`Self::build_des`] records internally (that one continues the
    /// shared build-parts stream) — pick one source per comparison.
    pub fn record_des_trace(&self) -> std::sync::Arc<crate::straggler::trace::Trace> {
        let mut rng = Rng::new(self.train.seed);
        let model = self.straggler_model(&mut rng);
        std::sync::Arc::new(crate::straggler::trace::Trace::record(
            &model,
            self.train.iters.max(1),
            &mut rng,
        ))
    }

    /// Synthesize + partition data, build per-worker sources + eval set.
    ///
    /// Synthesis and sharding fan over `pool`'s lanes (the `*_pooled`
    /// generators are bit-identical to their sequential forms at any lane
    /// count, so the produced data never depends on `threads`); eval
    /// batch materialisation is a small sequential tail. Any pool works —
    /// harnesses that only need data can pass
    /// [`EnginePool::tasks_only`](crate::engine::EnginePool::tasks_only).
    pub fn build_data(
        &self,
        meta: &ModelMeta,
        rng: &mut Rng,
        pool: &EnginePool,
    ) -> anyhow::Result<(Vec<Box<dyn BatchSource>>, Vec<AnyBatch>)> {
        match meta.kind {
            ModelKind::Transformer => {
                let train = markov_sequences_pooled(meta.vocab, meta.seq, self.train_n, rng, pool)?;
                let test =
                    markov_sequences_pooled(meta.vocab, meta.seq, self.test_n.min(512), rng, pool)?;
                // contiguous even split of sequences
                let per = train.n() / self.workers;
                anyhow::ensure!(per > 0, "too few sequences per worker");
                let sources: Vec<Box<dyn BatchSource>> = (0..self.workers)
                    .map(|j| {
                        let shard = crate::data::SeqDataset {
                            vocab: train.vocab,
                            seq: train.seq,
                            tokens: train.tokens
                                [j * per * train.seq..(j + 1) * per * train.seq]
                                .to_vec(),
                        };
                        Box::new(SeqSource::new(shard, self.train.seed + 100 + j as u64))
                            as Box<dyn BatchSource>
                    })
                    .collect();
                // eval: fixed batches of artifact batch size
                let mut sampler = BatchSampler::new(self.train.seed + 999);
                let n_eval = (test.n() / meta.batch).max(1);
                let eval_batches: Vec<AnyBatch> = (0..n_eval)
                    .map(|_| AnyBatch::Seq(sampler.sample_seq(&test, meta.batch)))
                    .collect();
                Ok((sources, eval_batches))
            }
            _ => {
                let total = self.train_n + self.test_n;
                let data =
                    gaussian_mixture_pooled(&self.dataset.mixture(meta.dim, total), rng, pool)?;
                let (train, test) = data.split(self.train_n);
                anyhow::ensure!(
                    meta.classes == test.classes,
                    "model classes {} != dataset classes {}",
                    meta.classes,
                    test.classes
                );
                let shards = split_pooled(&train, self.workers, self.partition, rng, pool)?;
                let sources: Vec<Box<dyn BatchSource>> = shards
                    .into_iter()
                    .enumerate()
                    .map(|(j, s)| {
                        Box::new(DenseSource::new(s, self.train.seed + 100 + j as u64))
                            as Box<dyn BatchSource>
                    })
                    .collect();
                // truncate test to a multiple of the artifact batch
                let usable = (test.n() / meta.batch) * meta.batch;
                anyhow::ensure!(usable > 0, "test set smaller than one batch");
                let idx: Vec<usize> = (0..usable).collect();
                let eval_batches: Vec<AnyBatch> =
                    BatchSampler::full_batches(&test.subset(&idx), meta.batch)
                        .into_iter()
                        .map(AnyBatch::Dense)
                        .collect();
                Ok((sources, eval_batches))
            }
        }
    }

    // ---------------------------------------------------------------- JSON
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("workers", self.workers.into())
            .set("topology", self.topology.name().into())
            .set("algo", self.algo.name().to_lowercase().into())
            .set("model", self.model.as_str().into())
            .set("dataset", self.dataset.name().into())
            .set("partition", self.partition.name().into())
            .set("train_n", self.train_n.into())
            .set("test_n", self.test_n.into())
            .set("threads", self.threads.into())
            .set("straggler", self.straggler_base.spec().into())
            .set("straggler_factor", self.straggler_factor.into())
            .set("force_straggler", self.force_straggler.into())
            .set("iters", self.train.iters.into())
            .set("lr0", self.train.lr0.into())
            .set("lr_decay", self.train.lr_decay.into())
            .set("eval_every", self.train.eval_every.into())
            .set("prefetch", self.train.prefetch.into())
            .set("seed", (self.train.seed as i64).into())
            .set(
                "backend",
                match &self.backend {
                    Backend::Native => "native".into(),
                    Backend::Pjrt { artifacts_dir } => {
                        format!("pjrt:{}", artifacts_dir.display())
                    }
                }
                .into(),
            );
        o
    }

    /// Apply JSON fields over the current values (partial configs OK).
    pub fn apply_json(&mut self, j: &Json) -> anyhow::Result<()> {
        if let Some(v) = j.get("workers").and_then(|v| v.as_usize()) {
            self.workers = v;
        }
        if let Some(v) = j.get("topology").and_then(|v| v.as_str()) {
            self.topology = Topology::parse(v)?;
        }
        if let Some(v) = j.get("algo").and_then(|v| v.as_str()) {
            self.algo = Algorithm::parse(v).ok_or_else(|| anyhow::anyhow!("bad algo '{v}'"))?;
        }
        if let Some(v) = j.get("model").and_then(|v| v.as_str()) {
            self.model = v.to_string();
        }
        if let Some(v) = j.get("dataset").and_then(|v| v.as_str()) {
            self.dataset =
                DatasetProfile::parse(v).ok_or_else(|| anyhow::anyhow!("bad dataset '{v}'"))?;
        }
        if let Some(v) = j.get("partition").and_then(|v| v.as_str()) {
            self.partition = Partition::parse(v)?;
        }
        if let Some(v) = j.get("train_n").and_then(|v| v.as_usize()) {
            self.train_n = v;
        }
        if let Some(v) = j.get("test_n").and_then(|v| v.as_usize()) {
            self.test_n = v;
        }
        if let Some(v) = j.get("threads").and_then(|v| v.as_usize()) {
            self.threads = v;
        }
        if let Some(v) = j.get("straggler").and_then(|v| v.as_str()) {
            self.straggler_base = Dist::parse(v)?;
        }
        if let Some(v) = j.get("straggler_factor").and_then(|v| v.as_f64()) {
            self.straggler_factor = v;
        }
        if let Some(v) = j.get("force_straggler").and_then(|v| v.as_bool()) {
            self.force_straggler = v;
        }
        if let Some(v) = j.get("iters").and_then(|v| v.as_usize()) {
            self.train.iters = v;
        }
        if let Some(v) = j.get("lr0").and_then(|v| v.as_f64()) {
            self.train.lr0 = v;
        }
        if let Some(v) = j.get("lr_decay").and_then(|v| v.as_f64()) {
            self.train.lr_decay = v;
        }
        if let Some(v) = j.get("eval_every").and_then(|v| v.as_usize()) {
            self.train.eval_every = v;
        }
        if let Some(v) = j.get("prefetch").and_then(|v| v.as_bool()) {
            self.train.prefetch = v;
        }
        if let Some(v) = j.get("seed").and_then(|v| v.as_f64()) {
            self.train.seed = v as u64;
        }
        if let Some(v) = j.get("backend").and_then(|v| v.as_str()) {
            self.backend = match v {
                "native" => Backend::Native,
                s if s.starts_with("pjrt") => {
                    let dir = s.strip_prefix("pjrt:").unwrap_or("artifacts");
                    Backend::Pjrt {
                        artifacts_dir: PathBuf::from(dir),
                    }
                }
                _ => anyhow::bail!("bad backend '{v}'"),
            };
        }
        Ok(())
    }
}

/// Everything a live run needs (see [`Setup::build_live`]): the common
/// substrate plus the engine pool wrapped in the compute server/client
/// facade the live driver's workers share.
pub struct LiveParts {
    pub graph: crate::graph::Graph,
    pub cfg: TrainConfig,
    pub straggler: StragglerModel,
    pub server: crate::engine::server::ComputeServer,
    pub client: crate::engine::server::ComputeClient,
    pub sources: Vec<Box<dyn BatchSource>>,
    pub eval_batches: Vec<AnyBatch>,
    pub init: Vec<f32>,
}

/// Everything [`Setup::build_parts`] assembles before a trainer exists:
/// the common substrate both the lockstep and the event-driven trainers
/// are built on. `rng` is the stream state after initial-parameter
/// draws — `build_des` records its timing trace from it.
struct SimParts {
    cfg: TrainConfig,
    graph: crate::graph::Graph,
    straggler: StragglerModel,
    pool: EnginePool,
    sources: Vec<Box<dyn BatchSource>>,
    eval_batches: Vec<AnyBatch>,
    init: Vec<f32>,
    rng: Rng,
}

/// Reconstruct a ModelMeta from an artifact-style name, e.g.
/// `lrm_d64_c10_b256` or `mlp2_d256_h256_c10_b1024`.
pub fn parse_model_name(name: &str) -> anyhow::Result<ModelMeta> {
    let mut dim = 0usize;
    let mut classes = 0usize;
    let mut hidden = 0usize;
    let mut batch = 0usize;
    let parts: Vec<&str> = name.split('_').collect();
    anyhow::ensure!(!parts.is_empty(), "empty model name");
    for p in &parts[1..] {
        if let Some(v) = p.strip_prefix('d').and_then(|x| x.parse().ok()) {
            dim = v;
        } else if let Some(v) = p.strip_prefix('h').and_then(|x| x.parse().ok()) {
            hidden = v;
        } else if let Some(v) = p.strip_prefix('c').and_then(|x| x.parse().ok()) {
            classes = v;
        } else if let Some(v) = p.strip_prefix('b').and_then(|x| x.parse().ok()) {
            batch = v;
        }
    }
    anyhow::ensure!(
        dim > 0 && classes > 0 && batch > 0,
        "cannot parse model name '{name}' (want e.g. lrm_d64_c10_b256)"
    );
    match parts[0] {
        "lrm" => Ok(ModelMeta::lrm(dim, classes, batch)),
        "mlp2" => {
            anyhow::ensure!(hidden > 0, "mlp2 name needs h<hidden>");
            Ok(ModelMeta::mlp2(dim, hidden, classes, batch))
        }
        other => anyhow::bail!("native backend cannot build '{other}' (use --backend pjrt)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_model_names() {
        let m = parse_model_name("lrm_d64_c10_b256").unwrap();
        assert_eq!((m.dim, m.classes, m.batch), (64, 10, 256));
        let m = parse_model_name("mlp2_d256_h256_c10_b1024").unwrap();
        assert_eq!(m.hidden, 256);
        assert!(parse_model_name("tfm_v64_t32_d64_h4_l2_b16").is_err());
        assert!(parse_model_name("lrm_nonsense").is_err());
    }

    #[test]
    fn default_setup_builds_and_runs_briefly() {
        let mut s = Setup::default();
        s.model = "lrm_d16_c10_b64".into();
        s.train_n = 2000;
        s.test_n = 512;
        s.train.iters = 8;
        s.train.eval_every = 4;
        let mut trainer = s.build_sim().unwrap();
        let h = trainer.run().unwrap();
        assert_eq!(h.iters.len(), 8);
        assert_eq!(h.workers, 6);
    }

    #[test]
    fn json_roundtrip() {
        let mut s = Setup::default();
        s.workers = 10;
        s.algo = Algorithm::CbFull;
        s.partition = Partition::Dirichlet { alpha: 0.5 };
        let j = s.to_json();
        let mut s2 = Setup::default();
        s2.apply_json(&j).unwrap();
        assert_eq!(s2.workers, 10);
        assert_eq!(s2.algo, Algorithm::CbFull);
        assert_eq!(s2.partition, Partition::Dirichlet { alpha: 0.5 });
    }

    #[test]
    fn threads_roundtrip_and_resolution() {
        let mut s = Setup::default();
        assert!(s.resolve_threads() >= 1);
        assert!(s.resolve_threads() <= s.workers);
        s.threads = 3;
        let j = s.to_json();
        let mut s2 = Setup::default();
        s2.apply_json(&j).unwrap();
        assert_eq!(s2.threads, 3);
        assert_eq!(s2.resolve_threads(), 3);
    }

    #[test]
    fn straggler_base_json_roundtrip() {
        let mut s = Setup::default();
        s.straggler_base = Dist::Uniform { lo: 0.02, hi: 0.05 };
        let j = s.to_json();
        let mut s2 = Setup::default();
        s2.apply_json(&j).unwrap();
        assert_eq!(s2.straggler_base, s.straggler_base);
    }

    #[test]
    fn build_live_parts_smoke() {
        let mut s = Setup::default();
        s.model = "lrm_d16_c10_b64".into();
        s.workers = 3;
        s.train_n = 1500;
        s.test_n = 256;
        s.threads = 2;
        let p = s.build_live().unwrap();
        assert_eq!(p.graph.n(), 3);
        assert_eq!(p.sources.len(), 3);
        assert_eq!(p.straggler.n(), 3);
        assert_eq!(p.client.param_count(), p.init.len());
        assert!(!p.eval_batches.is_empty());
        assert_eq!(p.server.lanes(), 2);
    }

    #[test]
    fn record_des_trace_is_pure_in_the_seed() {
        let mut s = Setup::default();
        s.workers = 4;
        s.train.iters = 7;
        let a = s.record_des_trace();
        let b = s.record_des_trace();
        assert_eq!(a.workers, 4);
        assert_eq!(a.len(), 7);
        assert!(a.times.iter().flatten().all(|t| t.is_finite() && *t > 0.0));
        // pure function of the setup: same seed, same realisation
        assert_eq!(a.times, b.times);
        // different seed, different realisation
        s.train.seed ^= 0x9e37;
        let c = s.record_des_trace();
        assert_ne!(a.times, c.times);
    }

    #[test]
    fn bad_json_fields_error() {
        let mut s = Setup::default();
        let j = Json::parse(r#"{"topology": "dodecahedron"}"#).unwrap();
        assert!(s.apply_json(&j).is_err());
    }

    #[test]
    fn transformer_data_builds() {
        let s = Setup {
            model: "tfm_v64_t32_d64_h4_l2_b16".into(),
            train_n: 64,
            test_n: 32,
            ..Default::default()
        };
        // native backend can't build the transformer engine, but the data
        // path is exercised via a hand-made meta and a tasks-only pool
        let mut meta = ModelMeta::lrm(4, 2, 16);
        meta.kind = ModelKind::Transformer;
        meta.vocab = 64;
        meta.seq = 32;
        meta.batch = 16;
        let pool = crate::engine::EnginePool::tasks_only(2).unwrap();
        let mut rng = Rng::new(0);
        let (sources, evals) = s.build_data(&meta, &mut rng, &pool).unwrap();
        assert_eq!(sources.len(), 6);
        assert!(!evals.is_empty());
    }

    /// End-to-end pool-size invariance THROUGH `build_sim`: pooled data
    /// synthesis, pooled sharding, batch prefetch, and pooled mixing all
    /// ride the lane count — a 4-lane build must replay the 1-lane build
    /// bit for bit.
    #[test]
    fn setup_build_is_bit_identical_across_pool_sizes() {
        let run = |threads: usize| {
            let mut s = Setup::default();
            s.model = "lrm_d16_c10_b64".into();
            s.train_n = 2000;
            s.test_n = 512;
            s.threads = threads;
            s.train.iters = 10;
            s.train.eval_every = 5;
            let mut t = s.build_sim().unwrap();
            let h = t.run().unwrap();
            (h, t.average_params())
        };
        let (h1, p1) = run(1);
        let (h4, p4) = run(4);
        assert!(h1.bits_eq(&h4), "history diverged across pool sizes");
        assert_eq!(p1.len(), p4.len());
        for (a, b) in p1.iter().zip(&p4) {
            assert_eq!(a.to_bits(), b.to_bits(), "final params diverged");
        }
    }

    #[test]
    fn prefetch_json_roundtrip() {
        let mut s = Setup::default();
        assert!(s.train.prefetch, "prefetch defaults on");
        s.train.prefetch = false;
        let j = s.to_json();
        let mut s2 = Setup::default();
        s2.apply_json(&j).unwrap();
        assert!(!s2.train.prefetch);
    }
}
