//! Live driver: real workers, real clocks, real termination commands.
//!
//! Workers are independent peers behind a [`Transport`] — in-process
//! threads over channels ([`ChannelTransport`]), or real OS processes
//! over TCP ([`crate::comms::transport::TcpTransport`], see
//! `dybw worker --connect`). Gradient compute goes through the
//! multi-lane [`ComputeServer`](crate::engine::server) facade; straggler
//! slowness is injected as an interruptible wait on top of the real
//! compute time. The leader plays the paper's distributed protocol:
//!
//! 1. all workers start iteration k simultaneously (`Start`);
//! 2. as local updates complete, workers announce them (`Done`, carrying
//!    the updated parameters — the "network" is the message fabric, not
//!    shared memory);
//! 3. for cb-DyBW the leader *terminates the iteration network-wide*
//!    once every planned participant has reported (the paper's "send a
//!    command to the rest workers to terminate the current iteration");
//!    stragglers abort their wait, keep their local update, and sit the
//!    round out;
//! 4. participants receive their Metropolis row plus the neighbour
//!    parameters (`Mix`), apply eq. (6), and ack; everyone barriers
//!    into k+1.
//!
//! **Reproducibility contract.** Participation, θ(k), durations, and
//! every recorded metric are computed from the *virtual* straggler times
//! drawn on the leader before the iteration is dispatched — the real
//! clock only shapes `wall_seconds` and the termination-ack latencies.
//! A seeded run therefore produces bit-identical [`RunHistory`] over any
//! transport and any machine, which is what the `socket-smoke` CI job
//! and `live_tcp_bit_identical_to_in_process` assert.
//!
//! **Fault tolerance.** Workers are mortal: [`drive_resilient`] keeps
//! the same contract when peers die and rejoin. The leader detects a
//! dead peer (connection drop, or [`Liveness`] heartbeat expiry) and
//! *ghosts* the slot — it computes the Done/MixAck the worker would have
//! sent from its own copy of that worker's seeded batch source, with the
//! identical f32 arithmetic — so the surviving neighbours proceed under
//! the paper's dynamic-backup-worker rule and the recorded history never
//! notices. A rejoining worker re-claims its slot ([`Msg::Rejoin`]) and
//! is answered with [`Msg::StateSync`] (authoritative parameters plus
//! the draw count that realigns its source), re-entering at the current
//! iteration. A [`ChaosPlan`] injects kill/recover events on the virtual
//! clock, mirroring the DES `FaultPlan` kinds, which is what the
//! `reconnect-smoke` CI job and the `live_tcp_worker_*` tests drive.

use std::time::{Duration, Instant};

use crate::comms::transport::{ChannelTransport, Transport, TransportError, WorkerPort};
use crate::comms::{Liveness, Msg};
use crate::consensus::ConsensusMatrix;
use crate::engine::server::ComputeClient;
use crate::engine::{AnyBatch, BatchSource};
use crate::graph::Graph;
use crate::metrics::{EvalRecord, IterRecord, RunHistory};
use crate::straggler::link::LinkMeasure;
use crate::straggler::StragglerModel;
use crate::util::rng::Rng;

use super::algorithm::{plan, Algorithm};
use super::checkpoint::Checkpoint;
use super::ckpt_manager::CkptManager;
use super::dtur::Dtur;
use super::sim::TrainConfig;

/// Typed live-driver failure: one worker's problem surfaces as one
/// error on the leader instead of a cascade of mutex-poison panics.
#[derive(Debug)]
pub enum LiveError {
    /// Algorithm/shape combination the live driver does not implement.
    Unsupported(String),
    /// A worker's gradient engine errored (details on the worker's log).
    ComputeFailed { worker: usize, k: u64 },
    /// A worker thread panicked (in-process transport only).
    WorkerPanicked { worker: usize },
    /// Could not spawn a worker thread.
    Spawn(std::io::Error),
    /// No message within the configured watchdog window.
    Watchdog { secs: f64, at: String },
    /// A peer broke the protocol (wrong iteration, duplicate Done, bad
    /// vector length, unexpected message type).
    Protocol { worker: usize, detail: String },
    Transport(TransportError),
    /// Held-out evaluation failed on the leader.
    Eval(String),
}

impl std::fmt::Display for LiveError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LiveError::Unsupported(what) => f.write_str(what),
            LiveError::ComputeFailed { worker, k } => {
                write!(f, "worker {worker} compute failed at iteration {k} (see log)")
            }
            LiveError::WorkerPanicked { worker } => write!(f, "worker {worker} panicked"),
            LiveError::Spawn(e) => write!(f, "failed to spawn worker thread: {e}"),
            LiveError::Watchdog { secs, at } => {
                write!(f, "watchdog: no {at} message within {secs:.0}s")
            }
            LiveError::Protocol { worker, detail } => {
                write!(f, "protocol violation from worker {worker}: {detail}")
            }
            LiveError::Transport(e) => write!(f, "transport: {e}"),
            LiveError::Eval(what) => write!(f, "eval failed: {what}"),
        }
    }
}

impl std::error::Error for LiveError {}

impl From<TransportError> for LiveError {
    fn from(e: TransportError) -> LiveError {
        LiveError::Transport(e)
    }
}

/// Knobs that do not affect the recorded history.
#[derive(Debug, Clone)]
pub struct LiveOptions {
    /// Converts the straggler model's virtual seconds into real wait
    /// seconds (e.g. 0.05 makes a "2s" straggler a 100ms wait so the
    /// example finishes quickly).
    pub time_scale: f64,
    /// How long the leader waits for any worker message before declaring
    /// the run wedged (previously hardcoded to 180 s).
    pub watchdog: Duration,
    /// Heartbeat probe interval for liveness tracking; `Duration::ZERO`
    /// (the default) disables probing — right for in-process transports,
    /// whose peers cannot die silently. A peer that ignores
    /// [`TIMEOUT_INTERVALS`](crate::comms::heartbeat::TIMEOUT_INTERVALS)
    /// probes is severed and treated as down.
    pub heartbeat: Duration,
    /// How long a disconnected worker process keeps retrying its rejoin
    /// before giving up. A worker-side knob, carried here so a scenario
    /// configures both sides in one place.
    pub rejoin_timeout: Duration,
}

impl Default for LiveOptions {
    fn default() -> LiveOptions {
        LiveOptions {
            time_scale: 1.0,
            watchdog: Duration::from_secs(180),
            heartbeat: Duration::ZERO,
            rejoin_timeout: Duration::from_secs(60),
        }
    }
}

#[derive(Debug)]
pub struct LiveOutcome {
    pub history: RunHistory,
    /// Real seconds the whole run took (incl. eval overhead).
    pub wall_seconds: f64,
    /// Per-worker termination-command ack latency: real seconds from the
    /// leader firing the terminate command to each terminated worker's
    /// `Done{terminated}` answer (one entry per terminated worker per
    /// iteration; empty for algorithms that never terminate).
    pub term_ack_latencies: Vec<f64>,
    /// Done stand-ins the leader computed for down workers (gradient
    /// ghosts only, mix ghosts not counted; 0 in a fault-free run).
    pub ghost_dones: usize,
    /// Successful worker rejoins (StateSync answered).
    pub rejoins: usize,
}

impl LiveOutcome {
    /// (min, median, max) of the termination-ack latencies.
    pub fn term_ack_summary(&self) -> Option<(f64, f64, f64)> {
        if self.term_ack_latencies.is_empty() {
            return None;
        }
        let mut v = self.term_ack_latencies.clone();
        v.sort_by(f64::total_cmp);
        Some((v[0], v[v.len() / 2], v[v.len() - 1]))
    }
}

/// Run training in-process: one thread per worker over the channel
/// transport. Kept as the stable entry point (e2e example, tests);
/// [`run_live_opts`] exposes the watchdog, and [`drive`] +
/// [`spawn_workers`] are the pieces multi-process deployments compose
/// over TCP.
pub fn run_live(
    graph: Graph,
    algo: Algorithm,
    cfg: TrainConfig,
    straggler: StragglerModel,
    compute: ComputeClient,
    sources: Vec<Box<dyn BatchSource>>,
    eval_batches: Vec<AnyBatch>,
    initial: Vec<f32>,
    time_scale: f64,
) -> Result<LiveOutcome, LiveError> {
    let opts = LiveOptions {
        time_scale,
        ..Default::default()
    };
    run_live_opts(
        graph,
        algo,
        cfg,
        straggler,
        compute,
        sources,
        eval_batches,
        initial,
        &opts,
    )
}

/// [`run_live`] with explicit [`LiveOptions`].
pub fn run_live_opts(
    graph: Graph,
    algo: Algorithm,
    cfg: TrainConfig,
    straggler: StragglerModel,
    compute: ComputeClient,
    sources: Vec<Box<dyn BatchSource>>,
    eval_batches: Vec<AnyBatch>,
    initial: Vec<f32>,
    opts: &LiveOptions,
) -> Result<LiveOutcome, LiveError> {
    let n = graph.n();
    if sources.len() != n {
        return Err(LiveError::Unsupported(format!(
            "need one batch source per worker ({} != {n})",
            sources.len()
        )));
    }
    let (mut transport, ports) = ChannelTransport::pair(n);
    let handles = spawn_workers(&cfg, &compute, sources, &initial, ports)?;
    let result = drive(
        &mut transport,
        &graph,
        algo,
        &cfg,
        &straggler,
        &compute,
        &eval_batches,
        initial,
        opts,
    );
    // Dropping the transport disconnects every port, so workers that are
    // still waiting (e.g. after a mid-run error) unblock and exit.
    drop(transport);
    let mut panicked = None;
    for (j, h) in handles.into_iter().enumerate() {
        if h.join().is_err() {
            panicked = Some(j);
        }
    }
    match (result, panicked) {
        (_, Some(worker)) => Err(LiveError::WorkerPanicked { worker }),
        (r, None) => r,
    }
}

/// Spawn one in-process worker thread per port (`ports[i].id()` indexes
/// `sources`). Worker-side errors are logged, not panicked, so the
/// leader's typed error is the only failure surface.
pub fn spawn_workers(
    cfg: &TrainConfig,
    compute: &ComputeClient,
    sources: Vec<Box<dyn BatchSource>>,
    initial: &[f32],
    ports: Vec<WorkerPort>,
) -> Result<Vec<std::thread::JoinHandle<()>>, LiveError> {
    let mut handles = Vec::with_capacity(ports.len());
    for (port, source) in ports.into_iter().zip(sources) {
        let j = port.id();
        let cfg = cfg.clone();
        let compute = compute.clone();
        let init = initial.to_vec();
        handles.push(
            std::thread::Builder::new()
                .name(format!("dybw-worker-{j}"))
                .spawn(move || {
                    if let Err(e) = worker_loop(j, cfg, compute, source, init, port) {
                        crate::util::log::log(
                            crate::util::log::Level::Error,
                            "live",
                            &format!("worker {j} exited with error: {e}"),
                        );
                    }
                })
                .map_err(LiveError::Spawn)?,
        );
    }
    Ok(handles)
}

fn recv_watchdogged(
    transport: &mut dyn Transport,
    opts: &LiveOptions,
    at: &str,
) -> Result<(usize, Msg), LiveError> {
    match transport.recv(opts.watchdog) {
        Ok(ev) => Ok(ev),
        Err(TransportError::Timeout { secs }) => Err(LiveError::Watchdog {
            secs,
            at: at.to_string(),
        }),
        Err(e) => Err(e.into()),
    }
}

/// Fault schedule for the live driver, mirroring the DES `FaultPlan`
/// event kinds over *virtual* time: at `t` a worker is killed (its
/// connection severed, its slot held down) or allowed back. Events fire
/// at iteration boundaries once the virtual clock passes them — the
/// same discretisation the recorded history uses, so a chaos scenario
/// replays identically on the simulator and the live cluster.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChaosPlan {
    /// `(worker, virtual time)` kill events.
    pub downs: Vec<(usize, f64)>,
    /// `(worker, virtual time)` recovery events: the slot becomes
    /// admissible again (the worker still has to rejoin; a rejoin that
    /// arrived during the down-window is answered at this point).
    pub ups: Vec<(usize, f64)>,
}

impl ChaosPlan {
    pub fn is_empty(&self) -> bool {
        self.downs.is_empty() && self.ups.is_empty()
    }

    /// The merged schedule `(time, worker, is_down)`, time-ordered with
    /// downs before ups at equal times.
    fn schedule(&self) -> Vec<(f64, usize, bool)> {
        let mut ev: Vec<(f64, usize, bool)> = self
            .downs
            .iter()
            .map(|&(j, t)| (t, j, true))
            .chain(self.ups.iter().map(|&(j, t)| (t, j, false)))
            .collect();
        ev.sort_by(|a, b| a.0.total_cmp(&b.0).then(b.2.cmp(&a.2)).then(a.1.cmp(&b.1)));
        ev
    }
}

/// What [`drive_resilient`] needs beyond the no-fault driver: one ghost
/// batch source per worker, seeded identically to the real worker's, so
/// the leader can stand in for a down worker bit-exactly — plus an
/// optional chaos schedule. With no ghost sources a lost peer stays
/// fatal (the pre-fault-tolerance behavior [`drive`] keeps).
#[derive(Default)]
pub struct LiveResilience {
    pub ghost_sources: Vec<Box<dyn BatchSource>>,
    pub chaos: ChaosPlan,
}

/// What the resilient receive loop hands the driver: a worker message,
/// a peer-down verdict (connection dropped, codec-poisoned, or probe
/// deadline blown — the caller decides whether that is fatal), or a
/// rejoin claim forwarded by the transport's background acceptor.
enum LiveEvent {
    Msg(usize, Msg),
    Down(usize),
    Rejoin { worker: usize, draws: u64 },
}

/// One receive step with heartbeat upkeep: fire due probes, swallow
/// heartbeat echoes (they are pure liveness signal), translate liveness
/// expiry and connection loss into [`LiveEvent::Down`], and enforce the
/// watchdog. In a non-resilient run with heartbeats disabled this
/// reduces exactly to the old single `recv` park — no hot-path cost.
fn recv_live_event(
    transport: &mut dyn Transport,
    liveness: &mut Liveness,
    opts: &LiveOptions,
    resilient: bool,
    at: &str,
) -> Result<LiveEvent, LiveError> {
    let deadline = Instant::now() + opts.watchdog;
    loop {
        let now = Instant::now();
        for (j, seq) in liveness.due_probes(now) {
            if transport.send(j, Msg::Heartbeat { seq }).is_err() {
                liveness.mark_down(j);
                return Ok(LiveEvent::Down(j));
            }
        }
        if let Some(&j) = liveness.expired(now).first() {
            liveness.mark_down(j);
            return Ok(LiveEvent::Down(j));
        }
        if now >= deadline {
            return Err(LiveError::Watchdog {
                secs: opts.watchdog.as_secs_f64(),
                at: at.to_string(),
            });
        }
        let mut slice = deadline - now;
        if let Some(d) = liveness.next_deadline(now) {
            slice = slice.min(d.max(Duration::from_millis(1)));
        }
        match transport.recv(slice) {
            Ok((j, msg)) => {
                liveness.touch(j, Instant::now());
                match msg {
                    Msg::Heartbeat { seq } => {
                        // echo: pure liveness signal — but it closes the
                        // probe's round trip, which is the one clean RTT
                        // measurement the protocol gives us for free.
                        if crate::obs::enabled() {
                            if let (Some(obs), Some(rtt)) = (
                                crate::obs::active(),
                                liveness.probe_rtt(j, seq, Instant::now()),
                            ) {
                                obs.registry
                                    .histogram("net/heartbeat_rtt_secs")
                                    .record_secs(rtt.as_secs_f64());
                            }
                        }
                    }
                    Msg::Rejoin { worker, draws } => {
                        return Ok(LiveEvent::Rejoin { worker: worker as usize, draws })
                    }
                    m => return Ok(LiveEvent::Msg(j, m)),
                }
            }
            Err(TransportError::Timeout { .. }) => {} // probe/expiry recheck
            Err(TransportError::PeerDisconnected { worker }) => {
                liveness.mark_down(worker);
                return Ok(LiveEvent::Down(worker));
            }
            Err(TransportError::Codec { worker, err }) if resilient => {
                crate::util::log::log(
                    crate::util::log::Level::Warn,
                    "live",
                    &format!("worker {worker} poisoned its connection ({err}); severing"),
                );
                liveness.mark_down(worker);
                return Ok(LiveEvent::Down(worker));
            }
            Err(e) => return Err(e.into()),
        }
    }
}

/// Compute the Done a down worker would have sent, bit-exactly: fast-
/// forward the ghost source to this iteration's batch (same seed, same
/// draw count as the real worker), take the gradient at the slot's
/// board value (the worker's post-mix w), and apply eq. (5) with the
/// same f32 arithmetic the worker uses. Returns the training loss.
fn ghost_done(
    j: usize,
    ku: u64,
    eta: f32,
    cfg: &TrainConfig,
    compute: &ComputeClient,
    ghost_sources: &mut [Box<dyn BatchSource>],
    ghost_draws: &mut [u64],
    board_j: &mut Vec<f32>,
    grad: &mut [f32],
) -> Result<f32, LiveError> {
    let batch = loop {
        let b = ghost_sources[j].next_train(cfg.batch_size);
        ghost_draws[j] += 1;
        if ghost_draws[j] >= ku {
            break b;
        }
    };
    let loss = match compute.grad_into(board_j, &batch, grad) {
        Ok(l) => l,
        Err(e) => {
            crate::util::log::log(
                crate::util::log::Level::Error,
                "live",
                &format!("ghost compute for worker {j} failed: {e}"),
            );
            return Err(LiveError::ComputeFailed { worker: j, k: ku });
        }
    };
    let mut wt = board_j.clone();
    crate::util::vecmath::axpy(&mut wt, -eta, grad);
    *board_j = wt;
    Ok(loss)
}

/// The leader side of the protocol, generic over the transport.
/// Equivalent to [`drive_resilient`] with no ghost sources and no
/// chaos: any lost peer is a fatal
/// [`TransportError::PeerDisconnected`].
pub fn drive(
    transport: &mut dyn Transport,
    graph: &Graph,
    algo: Algorithm,
    cfg: &TrainConfig,
    straggler: &StragglerModel,
    compute: &ComputeClient,
    eval_batches: &[AnyBatch],
    initial: Vec<f32>,
    opts: &LiveOptions,
) -> Result<LiveOutcome, LiveError> {
    drive_resilient(
        transport,
        graph,
        algo,
        cfg,
        straggler,
        compute,
        eval_batches,
        initial,
        opts,
        &mut LiveResilience::default(),
    )
}

/// [`drive`] with fault tolerance. When `res.ghost_sources` is
/// populated (one per worker, seeded like the real ones) a down peer is
/// no longer fatal: the leader ghosts the slot — recomputing its Done
/// and mix updates locally, bit-exactly — until the worker rejoins and
/// is resynchronised with [`Msg::StateSync`]. The recorded history is
/// identical to the uninterrupted run. `res.chaos` additionally injects
/// kill/recover events on the virtual clock.
pub fn drive_resilient(
    transport: &mut dyn Transport,
    graph: &Graph,
    algo: Algorithm,
    cfg: &TrainConfig,
    straggler: &StragglerModel,
    compute: &ComputeClient,
    eval_batches: &[AnyBatch],
    initial: Vec<f32>,
    opts: &LiveOptions,
    res: &mut LiveResilience,
) -> Result<LiveOutcome, LiveError> {
    if !matches!(algo, Algorithm::CbDybw | Algorithm::CbFull) {
        return Err(LiveError::Unsupported(format!(
            "live driver implements the consensus algorithms (got {})",
            algo.name()
        )));
    }
    let n = graph.n();
    if transport.workers() != n || straggler.n() != n {
        return Err(LiveError::Unsupported(format!(
            "graph ({n}), transport ({}) and straggler model ({}) disagree on worker count",
            transport.workers(),
            straggler.n()
        )));
    }
    let resilient = !res.ghost_sources.is_empty();
    if resilient && res.ghost_sources.len() != n {
        return Err(LiveError::Unsupported(format!(
            "need one ghost source per worker ({} != {n})",
            res.ghost_sources.len()
        )));
    }
    if !resilient && !res.chaos.is_empty() {
        return Err(LiveError::Unsupported(
            "a chaos schedule needs ghost sources for degraded-mode continuation".to_string(),
        ));
    }
    if res
        .chaos
        .downs
        .iter()
        .chain(res.chaos.ups.iter())
        .any(|&(j, _)| j >= n)
    {
        return Err(LiveError::Unsupported(format!(
            "chaos schedule names a worker outside 0..{n}"
        )));
    }
    let run_start = Instant::now();
    crate::obs::span::set_track("leader");

    // Leader's view of the network: slot j holds worker j's latest
    // announced parameters (w̃_j after Done, w_j after MixAck). Plain
    // owned vectors — no shared-memory mutexes to poison.
    let mut board: Vec<Vec<f32>> = vec![initial; n];
    // Mix results stage here: ghost mixes read the *pre-mix* board, so
    // `board` must stay untouched until the whole phase has resolved.
    let mut new_board: Vec<Vec<f32>> = vec![Vec::new(); n];

    let mut history = RunHistory::new(&algo.name(), "live", "synthetic", n);
    let mut dtur = algo.needs_dtur().then(|| Dtur::new(graph));
    let mut rng = Rng::new(cfg.seed ^ 0x11FE);
    let mut clock = 0.0f64;
    let mut term_ack_latencies: Vec<f64> = Vec::new();

    // Membership. `live[j]`: connection believed usable. `excluded[j]`:
    // a chaos down-window holds the slot down regardless of rejoins;
    // rejoins that arrive meanwhile are `parked` and answered when the
    // window lifts. `draws[j]`: batches worker j has consumed (== its
    // iteration count); `ghost_draws[j]` tracks the leader's own copy of
    // that worker's source so a ghost can fast-forward to the right
    // batch.
    let mut liveness = Liveness::new(n, opts.heartbeat, run_start);
    let mut live = vec![true; n];
    let mut excluded = vec![false; n];
    let mut parked = vec![false; n];
    let mut draws: Vec<u64> = vec![0; n];
    let mut ghost_draws: Vec<u64> = vec![0; n];
    let mut ghost_grad: Vec<f32> = vec![0.0; compute.param_count()];
    let mut ghost_dones = 0usize;
    let mut rejoins = 0usize;
    let schedule = res.chaos.schedule();
    let mut chaos_at = 0usize;

    let ev0 = {
        let _s = crate::obs::span::enter(crate::obs::span::Phase::Eval);
        eval_board(&board, eval_batches, compute, 0, clock)?
    };
    history.evals.push(ev0);

    for k in 1..=cfg.iters {
        // Chaos events fire at iteration boundaries once the virtual
        // clock passes them: the same discretisation the DES uses, so
        // the schedule is transport- and wall-clock-independent.
        while chaos_at < schedule.len() && schedule[chaos_at].0 <= clock {
            let (_, cj, is_down) = schedule[chaos_at];
            chaos_at += 1;
            if is_down {
                transport.sever(cj);
                liveness.mark_down(cj);
                live[cj] = false;
                excluded[cj] = true;
                parked[cj] = false;
            } else {
                excluded[cj] = false;
                if parked[cj] {
                    parked[cj] = false;
                    let x = board[cj].clone();
                    let sync = Msg::StateSync {
                        draws: draws[cj],
                        w: x.clone(),
                        wtilde: x,
                    };
                    if transport.send(cj, sync).is_ok() {
                        live[cj] = true;
                        liveness.mark_up(cj, Instant::now());
                        rejoins += 1;
                    } else {
                        transport.sever(cj);
                    }
                }
            }
        }

        // Virtual plan first: participation and timing are sealed before
        // any real message is sent, so the history cannot depend on
        // scheduling, network jitter — or membership.
        let t = straggler.sample_iteration(&mut rng);
        let iter_plan = plan(algo, &t, dtur.as_mut());
        let ku = k as u64;
        let eta = cfg.lr(k as usize) as f32;

        for j in 0..n {
            if !live[j] {
                continue;
            }
            if let Err(e) = transport.send(
                j,
                Msg::Start {
                    k: ku,
                    delay_s: t[j] * opts.time_scale,
                },
            ) {
                if !resilient {
                    return Err(e.into());
                }
                transport.sever(j);
                liveness.mark_down(j);
                live[j] = false;
            }
        }

        // Collect every worker's Done. Once all planned participants
        // have reported, fire the real termination command at the
        // stragglers still waiting out their delay. Down workers are
        // ghosted up front so the barrier still resolves.
        let mut done = vec![false; n];
        let mut losses = vec![0.0f32; n];
        let mut active_pending = iter_plan.active_count();
        let mut fired = iter_plan.active.iter().all(|&a| a); // all active: nothing to cut
        let mut fired_at: Option<Instant> = None;
        let mut pending = n;

        // Stand in for a down worker's Done. A terminated straggler
        // keeps its local update, so the ghost Done is the same whether
        // the round would have cut it off or not.
        macro_rules! ghost_done_for {
            ($gj:expr) => {{
                let gj = $gj;
                if !done[gj] {
                    losses[gj] = ghost_done(
                        gj,
                        ku,
                        eta,
                        cfg,
                        compute,
                        &mut res.ghost_sources,
                        &mut ghost_draws,
                        &mut board[gj],
                        &mut ghost_grad,
                    )?;
                    done[gj] = true;
                    pending -= 1;
                    if iter_plan.active[gj] {
                        active_pending -= 1;
                    }
                    draws[gj] = ku;
                    ghost_dones += 1;
                }
            }};
        }
        macro_rules! fire_check {
            () => {
                if !fired && active_pending == 0 {
                    fired = true;
                    let waiting: Vec<usize> = (0..n).filter(|&i| !done[i]).collect();
                    if !waiting.is_empty() {
                        fired_at = Some(Instant::now());
                        for i in waiting {
                            if let Err(e) = transport.send(i, Msg::Terminate { k: ku }) {
                                if !resilient {
                                    return Err(e.into());
                                }
                                transport.sever(i);
                                liveness.mark_down(i);
                                live[i] = false;
                                ghost_done_for!(i);
                            }
                        }
                    }
                }
            };
        }

        for j in 0..n {
            if !live[j] {
                ghost_done_for!(j);
            }
        }
        fire_check!();

        let wait_span = crate::obs::span::enter(crate::obs::span::Phase::Wait);
        while pending > 0 {
            match recv_live_event(transport, &mut liveness, opts, resilient, "Done")? {
                LiveEvent::Msg(j, msg) => match msg {
                    Msg::Done {
                        k: mk,
                        loss,
                        terminated,
                        failed,
                        wtilde,
                    } => {
                        if mk != ku || done[j] {
                            return Err(LiveError::Protocol {
                                worker: j,
                                detail: format!("Done for iteration {mk} while collecting {ku}"),
                            });
                        }
                        if failed {
                            return Err(LiveError::ComputeFailed { worker: j, k: ku });
                        }
                        if wtilde.len() != board[j].len() {
                            return Err(LiveError::Protocol {
                                worker: j,
                                detail: format!(
                                    "Done carried {} params, expected {}",
                                    wtilde.len(),
                                    board[j].len()
                                ),
                            });
                        }
                        board[j] = wtilde;
                        losses[j] = loss;
                        done[j] = true;
                        pending -= 1;
                        draws[j] = ku;
                        if iter_plan.active[j] {
                            active_pending -= 1;
                        }
                        if terminated {
                            // shutdown-ack latency: command fired -> this ack
                            if let Some(t0) = fired_at {
                                term_ack_latencies.push(t0.elapsed().as_secs_f64());
                            }
                        }
                        fire_check!();
                    }
                    Msg::Pong { .. } => {} // stale measurement reply
                    other => {
                        return Err(LiveError::Protocol {
                            worker: j,
                            detail: format!("unexpected {} while collecting Done", other.name()),
                        })
                    }
                },
                LiveEvent::Down(j) => {
                    if !resilient {
                        return Err(TransportError::PeerDisconnected { worker: j }.into());
                    }
                    transport.sever(j);
                    parked[j] = false;
                    if live[j] {
                        live[j] = false;
                        ghost_done_for!(j);
                        fire_check!();
                    }
                }
                LiveEvent::Rejoin { worker: j, draws: wdraws } => {
                    if !resilient {
                        return Err(LiveError::Protocol {
                            worker: j,
                            detail: "rejoin without ghost sources configured".to_string(),
                        });
                    }
                    if j >= n {
                        return Err(LiveError::Protocol {
                            worker: j,
                            detail: format!("rejoin for unknown slot {j}"),
                        });
                    }
                    if wdraws > draws[j] {
                        return Err(LiveError::Protocol {
                            worker: j,
                            detail: format!(
                                "rejoin claims {wdraws} draws but the leader recorded {}",
                                draws[j]
                            ),
                        });
                    }
                    if excluded[j] {
                        parked[j] = true;
                    } else {
                        // The fresh connection supersedes whatever was
                        // there; finish the slot's round as a ghost, then
                        // hand the worker the authoritative state.
                        live[j] = false;
                        ghost_done_for!(j);
                        fire_check!();
                        let x = board[j].clone();
                        let sync = Msg::StateSync {
                            draws: draws[j],
                            w: x.clone(),
                            wtilde: x,
                        };
                        if transport.send(j, sync).is_ok() {
                            live[j] = true;
                            liveness.mark_up(j, Instant::now());
                            rejoins += 1;
                        } else {
                            transport.sever(j);
                        }
                    }
                }
            }
        }

        drop(wait_span);

        // Mixing: each participant gets its Metropolis row plus the
        // neighbour parameters in row order (the order fixes the f32
        // accumulation, keeping the result transport-independent).
        // Results stage into `new_board`: ghost mixes must read the
        // pre-mix board, so it may not change until the phase resolves.
        let mix_span = crate::obs::span::enter(crate::obs::span::Phase::Mix);
        let p = ConsensusMatrix::metropolis(graph, &iter_plan.active);
        let mut acked = vec![false; n];
        let mut pending = n;

        // Stand in for a down worker's MixAck: eq. (6) with the same
        // row-order f32 accumulation the worker uses.
        macro_rules! ghost_mix_for {
            ($gj:expr) => {{
                let gj = $gj;
                if !acked[gj] {
                    new_board[gj] = if iter_plan.active[gj] {
                        let mut buf = vec![0.0f32; board[gj].len()];
                        for &(i, wt) in p.row(gj) {
                            crate::util::vecmath::axpy(&mut buf, wt as f32, &board[i]);
                        }
                        buf
                    } else {
                        board[gj].clone()
                    };
                    acked[gj] = true;
                    pending -= 1;
                }
            }};
        }

        for j in 0..n {
            if !live[j] {
                ghost_mix_for!(j);
                continue;
            }
            let msg = if iter_plan.active[j] {
                let row = p.row(j);
                Msg::Mix {
                    k: ku,
                    active: true,
                    row: row.iter().map(|&(i, wt)| (i as u32, wt)).collect(),
                    peers: row.iter().map(|&(i, _)| board[i].clone()).collect(),
                }
            } else {
                Msg::Mix {
                    k: ku,
                    active: false,
                    row: Vec::new(),
                    peers: Vec::new(),
                }
            };
            if let Err(e) = transport.send(j, msg) {
                if !resilient {
                    return Err(e.into());
                }
                transport.sever(j);
                liveness.mark_down(j);
                live[j] = false;
                ghost_mix_for!(j);
            }
        }
        while pending > 0 {
            match recv_live_event(transport, &mut liveness, opts, resilient, "MixAck")? {
                LiveEvent::Msg(j, msg) => match msg {
                    Msg::MixAck { k: mk, w } => {
                        if mk != ku || acked[j] || w.len() != board[j].len() {
                            return Err(LiveError::Protocol {
                                worker: j,
                                detail: format!(
                                    "bad MixAck (iteration {mk}/{ku}, {} params)",
                                    w.len()
                                ),
                            });
                        }
                        new_board[j] = w;
                        acked[j] = true;
                        pending -= 1;
                    }
                    Msg::Pong { .. } => {}
                    other => {
                        return Err(LiveError::Protocol {
                            worker: j,
                            detail: format!("unexpected {} while collecting MixAck", other.name()),
                        })
                    }
                },
                LiveEvent::Down(j) => {
                    if !resilient {
                        return Err(TransportError::PeerDisconnected { worker: j }.into());
                    }
                    transport.sever(j);
                    parked[j] = false;
                    if live[j] {
                        live[j] = false;
                        ghost_mix_for!(j);
                    }
                }
                LiveEvent::Rejoin { worker: j, draws: wdraws } => {
                    if !resilient {
                        return Err(LiveError::Protocol {
                            worker: j,
                            detail: "rejoin without ghost sources configured".to_string(),
                        });
                    }
                    if j >= n {
                        return Err(LiveError::Protocol {
                            worker: j,
                            detail: format!("rejoin for unknown slot {j}"),
                        });
                    }
                    if wdraws > draws[j] {
                        return Err(LiveError::Protocol {
                            worker: j,
                            detail: format!(
                                "rejoin claims {wdraws} draws but the leader recorded {}",
                                draws[j]
                            ),
                        });
                    }
                    if excluded[j] {
                        parked[j] = true;
                    } else {
                        live[j] = false;
                        ghost_mix_for!(j);
                        let x = new_board[j].clone();
                        let sync = Msg::StateSync {
                            draws: draws[j],
                            w: x.clone(),
                            wtilde: x,
                        };
                        if transport.send(j, sync).is_ok() {
                            live[j] = true;
                            liveness.mark_up(j, Instant::now());
                            rejoins += 1;
                        } else {
                            transport.sever(j);
                        }
                    }
                }
            }
        }
        drop(mix_span);
        for j in 0..n {
            board[j] = std::mem::take(&mut new_board[j]);
        }

        clock += iter_plan.duration;
        history.iters.push(IterRecord {
            k,
            duration: iter_plan.duration,
            clock,
            train_loss: losses.iter().map(|&l| l as f64).sum::<f64>() / n as f64,
            active: iter_plan.active_count(),
            backup_avg: iter_plan.backup_avg(graph),
            theta: iter_plan.theta,
        });

        if cfg.eval_every > 0 && k % cfg.eval_every == 0 {
            let ev = {
                let _s = crate::obs::span::enter(crate::obs::span::Phase::Eval);
                eval_board(&board, eval_batches, compute, k, clock)?
            };
            history.evals.push(ev);
        }
    }

    for j in 0..n {
        let _ = transport.send(j, Msg::Stop);
    }
    if let Some(obs) = crate::obs::active() {
        obs.registry.counter("live/ghost_dones").add(ghost_dones as u64);
        obs.registry.counter("live/rejoins").add(rejoins as u64);
        let h = obs.registry.histogram("live/term_ack_secs");
        for &l in &term_ack_latencies {
            h.record_secs(l);
        }
    }
    Ok(LiveOutcome {
        history,
        wall_seconds: run_start.elapsed().as_secs_f64(),
        term_ack_latencies,
        ghost_dones,
        rejoins,
    })
}

/// The training state a worker carries across connections: rejoining
/// after a leader loss means handing this back to [`worker_loop_opts`]
/// after [`apply_state_sync`] reconciles it with the leader's view.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerState {
    /// Post-mix parameters (the point gradients are taken at).
    pub w: Vec<f32>,
    /// Post-local-update parameters (eq. (5) result).
    pub wtilde: Vec<f32>,
    /// Batches consumed from the seeded source so far.
    pub draws: u64,
}

impl WorkerState {
    pub fn fresh(initial: Vec<f32>) -> WorkerState {
        WorkerState {
            wtilde: initial.clone(),
            w: initial,
            draws: 0,
        }
    }
}

/// Why [`worker_loop_opts`] returned: a clean shutdown command, or the
/// leader connection died — in which case the worker gets its state
/// back to attempt a rejoin
/// ([`rejoin_worker`](crate::comms::transport::rejoin_worker) +
/// [`apply_state_sync`]).
#[derive(Debug)]
pub enum WorkerExit {
    Stopped,
    LeaderLost(WorkerState),
}

/// Worker-side knobs beyond the protocol itself.
#[derive(Default)]
pub struct WorkerOpts {
    /// Checkpoint sink; `None` disables checkpointing.
    pub ckpt: Option<CkptManager>,
    /// Save every this-many iterations (0 disables).
    pub ckpt_every: usize,
    /// Model tag stamped into saved checkpoints.
    pub model: String,
}

/// Reconcile a rejoining worker's state with the leader's
/// [`Msg::StateSync`]: fast-forward the seeded batch source to the
/// leader-recorded draw count (the draws the leader's ghost made on the
/// worker's behalf) and adopt the authoritative parameters.
pub fn apply_state_sync(
    state: &mut WorkerState,
    source: &mut dyn BatchSource,
    batch_size: usize,
    sync: &Msg,
    j: usize,
) -> Result<(), LiveError> {
    let Msg::StateSync { draws, w, wtilde } = sync else {
        return Err(LiveError::Protocol {
            worker: j,
            detail: format!("expected StateSync after rejoin, got {}", sync.name()),
        });
    };
    if *draws < state.draws {
        return Err(LiveError::Protocol {
            worker: j,
            detail: format!("StateSync rewinds draws ({} -> {draws})", state.draws),
        });
    }
    while state.draws < *draws {
        let _ = source.next_train(batch_size);
        state.draws += 1;
    }
    state.w = w.clone();
    state.wtilde = wtilde.clone();
    Ok(())
}

/// The worker side of the protocol: runs against a [`WorkerPort`] from
/// either transport (in a spawned thread, or as the whole body of a
/// `dybw worker` process). Leader loss is a clean exit here; use
/// [`worker_loop_opts`] to observe it and rejoin.
pub fn worker_loop(
    j: usize,
    cfg: TrainConfig,
    compute: ComputeClient,
    mut source: Box<dyn BatchSource>,
    initial: Vec<f32>,
    port: WorkerPort,
) -> Result<(), LiveError> {
    worker_loop_opts(
        j,
        &cfg,
        &compute,
        source.as_mut(),
        WorkerState::fresh(initial),
        port,
        &mut WorkerOpts::default(),
    )
    .map(|_| ())
}

/// [`worker_loop`] with explicit state and [`WorkerOpts`], returning a
/// typed [`WorkerExit`] so the caller can distinguish "leader said
/// stop" from "leader vanished" and drive the rejoin loop.
pub fn worker_loop_opts(
    j: usize,
    cfg: &TrainConfig,
    compute: &ComputeClient,
    source: &mut dyn BatchSource,
    state: WorkerState,
    mut port: WorkerPort,
    wopts: &mut WorkerOpts,
) -> Result<WorkerExit, LiveError> {
    let WorkerState {
        mut w,
        mut wtilde,
        mut draws,
    } = state;
    crate::obs::span::set_track(&format!("worker-{j}"));
    // Leased buffers: the gradient is written in place by the engine pool
    // every iteration, the mix accumulator swaps with `w` every round —
    // neither is ever reallocated.
    let mut grad: Vec<f32> = vec![0.0; compute.param_count()];
    let mut mix_buf: Vec<f32> = vec![0.0; w.len()];
    macro_rules! leader_lost {
        () => {
            return Ok(WorkerExit::LeaderLost(WorkerState { w, wtilde, draws }))
        };
    }
    loop {
        let cmd = match port.recv() {
            Ok(m) => m,
            Err(TransportError::Disconnected) => leader_lost!(),
            Err(e) => return Err(e.into()),
        };
        match cmd {
            Msg::Stop => return Ok(WorkerExit::Stopped),
            Msg::Start { k, delay_s } => {
                let start = Instant::now();
                let batch = source.next_train(cfg.batch_size);
                draws += 1;
                let compute_span = crate::obs::span::enter(crate::obs::span::Phase::Compute);
                let loss = match compute.grad_into(&w, &batch, &mut grad) {
                    Ok(r) => r,
                    Err(e) => {
                        crate::util::log::log(
                            crate::util::log::Level::Error,
                            "live",
                            &format!("worker {j} compute failed: {e}"),
                        );
                        let _ = port.send(Msg::Done {
                            k,
                            loss: f32::NAN,
                            terminated: false,
                            failed: true,
                            wtilde: Vec::new(),
                        });
                        return Ok(WorkerExit::Stopped);
                    }
                };
                drop(compute_span);
                // Straggler injection: wait out the remaining virtual
                // compute time parked on the port (no polling), abortable
                // by this iteration's termination command.
                let mut terminated = false;
                let mut stash: Vec<Msg> = Vec::new();
                let wait_span = crate::obs::span::enter(crate::obs::span::Phase::Wait);
                loop {
                    let elapsed = start.elapsed().as_secs_f64();
                    if delay_s.is_nan() || elapsed >= delay_s {
                        break;
                    }
                    let remaining = Duration::from_secs_f64((delay_s - elapsed).min(3600.0));
                    match port.recv_timeout(remaining) {
                        Ok(None) => {} // waited it out; re-check the clock
                        Ok(Some(Msg::Terminate { k: tk })) => {
                            if tk == k {
                                terminated = true;
                                break;
                            }
                            // stale command from an earlier iteration
                        }
                        Ok(Some(Msg::Heartbeat { seq })) => {
                            // echo immediately, never stash: a straggler
                            // sleeping out its delay must not look dead
                            if port.send(Msg::Heartbeat { seq }).is_err() {
                                leader_lost!();
                            }
                        }
                        Ok(Some(other)) => stash.push(other),
                        Err(TransportError::Disconnected) => leader_lost!(),
                        Err(e) => return Err(e.into()),
                    }
                }
                drop(wait_span);
                for m in stash {
                    port.push_back(m);
                }
                // eq. (5): local update (kept even when terminated).
                let eta = cfg.lr(k as usize) as f32;
                wtilde.copy_from_slice(&w);
                crate::util::vecmath::axpy(&mut wtilde, -eta, &grad);
                if port
                    .send(Msg::Done {
                        k,
                        loss,
                        terminated,
                        failed: false,
                        wtilde: wtilde.clone(),
                    })
                    .is_err()
                {
                    leader_lost!();
                }
            }
            Msg::Mix {
                k,
                active,
                row,
                peers,
            } => {
                if peers.len() != row.len() {
                    return Err(LiveError::Protocol {
                        worker: j,
                        detail: format!("Mix with {} rows but {} peers", row.len(), peers.len()),
                    });
                }
                let mix_span = crate::obs::span::enter(crate::obs::span::Phase::Mix);
                if active {
                    // eq. (6) over the active neighbourhood, accumulated
                    // in row order (deterministic) into the leased buffer.
                    mix_buf.fill(0.0);
                    for (&(_, wt), peer) in row.iter().zip(&peers) {
                        if peer.len() != w.len() {
                            return Err(LiveError::Protocol {
                                worker: j,
                                detail: format!(
                                    "Mix peer carried {} params, expected {}",
                                    peer.len(),
                                    w.len()
                                ),
                            });
                        }
                        crate::util::vecmath::axpy(&mut mix_buf, wt as f32, peer);
                    }
                    std::mem::swap(&mut w, &mut mix_buf);
                } else {
                    w.copy_from_slice(&wtilde);
                }
                drop(mix_span);
                if port.send(Msg::MixAck { k, w: w.clone() }).is_err() {
                    leader_lost!();
                }
                if wopts.ckpt_every > 0 && (k as usize) % wopts.ckpt_every == 0 {
                    if let Some(mgr) = &wopts.ckpt {
                        let _s = crate::obs::span::enter(crate::obs::span::Phase::Ckpt);
                        let ckpt = Checkpoint {
                            iteration: k as usize,
                            clock: 0.0,
                            model: wopts.model.clone(),
                            params: vec![w.clone(), wtilde.clone()],
                            history: RunHistory::default(),
                        };
                        if let Err(e) = mgr.save(&ckpt) {
                            crate::util::log::log(
                                crate::util::log::Level::Warn,
                                "live",
                                &format!("worker {j} checkpoint at k={k} failed: {e}"),
                            );
                        }
                    }
                }
            }
            Msg::Heartbeat { seq } => {
                // liveness probe: echo it straight back
                if port.send(Msg::Heartbeat { seq }).is_err() {
                    leader_lost!();
                }
            }
            Msg::StateSync {
                draws: synced,
                w: sw,
                wtilde: swt,
            } => {
                // The leader answers a mid-run (re)claim with its view of
                // this slot before anything else: a restarted process that
                // re-ran the full handshake lands here. Same reconciliation
                // as [`apply_state_sync`], on the loop's own state.
                if synced < draws {
                    return Err(LiveError::Protocol {
                        worker: j,
                        detail: format!("StateSync rewinds draws ({draws} -> {synced})"),
                    });
                }
                while draws < synced {
                    let _ = source.next_train(cfg.batch_size);
                    draws += 1;
                }
                if sw.len() != w.len() || swt.len() != w.len() {
                    return Err(LiveError::Protocol {
                        worker: j,
                        detail: format!(
                            "StateSync carried {}/{} params, expected {}",
                            sw.len(),
                            swt.len(),
                            w.len()
                        ),
                    });
                }
                w = sw;
                wtilde = swt;
            }
            Msg::Ping { nonce } => {
                if port.send(Msg::Pong { nonce }).is_err() {
                    leader_lost!();
                }
            }
            // a termination command that raced the Done we already sent
            Msg::Terminate { .. } => {}
            other => {
                return Err(LiveError::Protocol {
                    worker: j,
                    detail: format!("unexpected {} outside an iteration", other.name()),
                })
            }
        }
    }
}

/// Measure real per-worker round-trip latency with Ping/Pong (run
/// before or after training — it exchanges no RNG draws, so it never
/// perturbs the seeded history). One-way latency is estimated as RTT/2;
/// feed the result to [`LinkMeasure::calibrated`] to turn the deployed
/// network into a DES [`crate::straggler::link::LinkModel`].
pub fn measure_links(
    transport: &mut dyn Transport,
    rounds: usize,
    opts: &LiveOptions,
) -> Result<LinkMeasure, LiveError> {
    let n = transport.workers();
    let mut m = LinkMeasure::new(n);
    for r in 0..rounds {
        for j in 0..n {
            let nonce = (r * n + j) as u64;
            let t0 = Instant::now();
            transport.send(j, Msg::Ping { nonce })?;
            loop {
                let (from, msg) = recv_watchdogged(transport, opts, "Pong")?;
                match msg {
                    Msg::Pong { nonce: got } if from == j && got == nonce => {
                        m.record(j, t0.elapsed().as_secs_f64() / 2.0);
                        break;
                    }
                    Msg::Pong { .. } => {} // stale or cross-talk; keep waiting
                    other => {
                        return Err(LiveError::Protocol {
                            worker: from,
                            detail: format!("unexpected {} during link measurement", other.name()),
                        })
                    }
                }
            }
        }
    }
    Ok(m)
}

fn eval_board(
    board: &[Vec<f32>],
    eval_batches: &[AnyBatch],
    compute: &ComputeClient,
    k: usize,
    clock: f64,
) -> Result<EvalRecord, LiveError> {
    let n = board.len();
    let dim = board[0].len();
    let mut avg = vec![0.0f32; dim];
    for r in board {
        crate::util::vecmath::axpy(&mut avg, 1.0 / n as f32, r);
    }
    let consensus_error = board
        .iter()
        .map(|r| crate::util::vecmath::dist(r, &avg))
        .fold(0.0, f64::max);
    let mut loss_sum = 0.0f64;
    let mut correct = 0usize;
    let mut total = 0usize;
    // Batches fan across the pool's lanes; the reduction runs in batch
    // order, so the result is independent of the lane count.
    let scores = compute
        .eval_many(&avg, eval_batches)
        .map_err(|e| LiveError::Eval(e.to_string()))?;
    for ((l, c), b) in scores.into_iter().zip(eval_batches) {
        let r = b.rows();
        loss_sum += l as f64 * r as f64;
        correct += c;
        total += r;
    }
    Ok(EvalRecord {
        k,
        clock,
        test_loss: loss_sum / total.max(1) as f64,
        test_error: 1.0 - correct as f64 / total.max(1) as f64,
        consensus_error,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::transport::{connect_worker, rejoin_worker, TcpTransport};
    use crate::coordinator::setup::Setup;
    use crate::data::batch::BatchSampler;
    use crate::data::partition::{split, Partition};
    use crate::data::synthetic::{gaussian_mixture, MixtureSpec};
    use crate::engine::server::ComputeServer;
    use crate::engine::{native_factory, DenseSource, EngineFactory, GradEngine, NativeEngine};
    use crate::graph::topology;
    use crate::model::ModelMeta;
    use crate::straggler::Dist;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    /// Everything one live run needs, built deterministically from fixed
    /// seeds — calling this twice yields bit-identical inputs, which the
    /// transport-equivalence tests lean on.
    struct TestParts {
        g: Graph,
        cfg: TrainConfig,
        straggler: StragglerModel,
        client: ComputeClient,
        _server: ComputeServer,
        sources: Vec<Box<dyn BatchSource>>,
        eval: Vec<AnyBatch>,
        init: Vec<f32>,
    }

    fn test_parts(iters: usize) -> TestParts {
        let n = 4;
        let mut rng = Rng::new(3);
        let g = topology::random_connected(n, 0.6, &mut rng);
        let meta = ModelMeta::lrm(8, 10, 32);
        let data = gaussian_mixture(&MixtureSpec::mnist_like(8, 1500), &mut rng);
        let (train, test) = data.split(1280);
        let shards = split(&train, n, Partition::Iid, &mut rng);
        let sources: Vec<Box<dyn BatchSource>> = shards
            .into_iter()
            .enumerate()
            .map(|(j, s)| Box::new(DenseSource::new(s, 50 + j as u64)) as Box<dyn BatchSource>)
            .collect();
        let eval: Vec<AnyBatch> =
            BatchSampler::full_batches(&test.subset(&(0..192).collect::<Vec<_>>()), 32)
                .into_iter()
                .map(AnyBatch::Dense)
                .collect();
        let (server, client) = ComputeServer::spawn(native_factory(meta.clone()), 2).unwrap();
        let straggler = StragglerModel {
            base: Dist::Uniform { lo: 0.02, hi: 0.05 },
            worker_scale: vec![1.0; n],
            persistent: vec![1.0; n],
            transient_prob: 0.2,
            transient_factor: 6.0,
            force_one_straggler: true,
            outages: Vec::new(),
            diurnal_amp: 0.0,
            diurnal_period: 0.0,
        };
        let cfg = TrainConfig {
            iters,
            batch_size: 32,
            eval_every: iters,
            seed: 5,
            ..Default::default()
        };
        let init = meta.init_params(&mut rng);
        TestParts {
            g,
            cfg,
            straggler,
            client,
            _server: server,
            sources,
            eval,
            init,
        }
    }

    fn run(algo: Algorithm, iters: usize) -> LiveOutcome {
        let p = test_parts(iters);
        run_live(
            p.g,
            algo,
            p.cfg,
            p.straggler,
            p.client,
            p.sources,
            p.eval,
            p.init,
            1.0,
        )
        .unwrap()
    }

    #[test]
    fn live_dybw_trains_in_real_time() {
        let out = run(Algorithm::CbDybw, 12);
        assert_eq!(out.history.iters.len(), 12);
        let first = &out.history.evals[0];
        let last = out.history.evals.last().unwrap();
        assert!(last.test_loss < first.test_loss, "{first:?} -> {last:?}");
        assert!(out.wall_seconds > 0.1); // really waited
        // with a forced 6x transient straggler every round, termination
        // fires and the aborted workers' acks get timed
        assert!(
            !out.term_ack_latencies.is_empty(),
            "no termination acks recorded"
        );
        assert!(out.term_ack_latencies.iter().all(|&l| l >= 0.0 && l < 10.0));
        let (min, med, max) = out.term_ack_summary().unwrap();
        assert!(min <= med && med <= max);
    }

    #[test]
    fn term_ack_summary_empty_without_termination() {
        // cb-Full never fires the command; the stats stay empty.
        let out = run(Algorithm::CbFull, 4);
        assert!(out.term_ack_latencies.is_empty());
        assert!(out.term_ack_summary().is_none());
    }

    #[test]
    fn live_dybw_faster_than_full() {
        let a = run(Algorithm::CbDybw, 10);
        let b = run(Algorithm::CbFull, 10);
        // cb-Full waits out every 6x straggler; DyBW terminates them.
        assert!(
            a.history.total_time() < b.history.total_time(),
            "dybw {:.3}s vs full {:.3}s",
            a.history.total_time(),
            b.history.total_time()
        );
    }

    /// The reproducibility contract: the recorded history is a pure
    /// function of the seed — real scheduling/jitter may only move
    /// `wall_seconds` and the ack latencies.
    #[test]
    fn live_history_reproducible() {
        let a = run(Algorithm::CbDybw, 6);
        let b = run(Algorithm::CbDybw, 6);
        assert!(
            a.history.bits_eq(&b.history),
            "two same-seed live runs diverged"
        );
    }

    /// Telemetry byte-identity at the live layer: a full observer
    /// (registry + spans + streamed trace) installed process-wide must
    /// leave the recorded history bit-identical to the same-seed run
    /// without one — spans read clocks, never the RNG. Nothing else in
    /// this test binary installs a global observer, so no cross-test
    /// serialisation is needed.
    #[test]
    fn live_history_identical_with_obs_installed() {
        let plain = run(Algorithm::CbDybw, 6);
        let dir = std::env::temp_dir().join(format!("dybw-live-obs-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let obs = crate::obs::Obs::to_dir(&dir).unwrap();
        crate::obs::install(obs.clone());
        let observed = run(Algorithm::CbDybw, 6);
        crate::obs::uninstall();
        obs.finish().unwrap();
        assert!(
            observed.history.bits_eq(&plain.history),
            "telemetry perturbed the live run"
        );
        // and the observer really recorded: leader + worker tracks
        // streamed to the JSONL trace
        let jsonl =
            std::fs::read_to_string(dir.join(crate::obs::trace::TRACE_JSONL)).unwrap();
        assert!(jsonl.lines().any(|l| l.contains("leader")), "no leader track in trace");
        assert!(jsonl.lines().any(|l| l.contains("worker-")), "no worker tracks in trace");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The tentpole guarantee: the same seeded run over real TCP sockets
    /// (framed binary codec, reader threads, the works) produces history
    /// bit-identical to the in-process channel transport.
    #[test]
    fn live_tcp_bit_identical_to_in_process() {
        let reference = run(Algorithm::CbDybw, 5);

        let p = test_parts(5);
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let timeout = Duration::from_secs(20);
        let mut joins = Vec::new();
        for (j, source) in p.sources.into_iter().enumerate() {
            let addr = addr.clone();
            let cfg = p.cfg.clone();
            let client = p.client.clone();
            let init = p.init.clone();
            joins.push(std::thread::spawn(move || {
                let (id, _setup, port) = connect_worker(&addr, Some(j as u32), timeout).unwrap();
                worker_loop(id as usize, cfg, client, source, init, port).unwrap();
            }));
        }
        let mut transport = TcpTransport::accept(&listener, 4, "", timeout).unwrap();
        let opts = LiveOptions::default();
        let out = drive(
            &mut transport,
            &p.g,
            Algorithm::CbDybw,
            &p.cfg,
            &p.straggler,
            &p.client,
            &p.eval,
            p.init.clone(),
            &opts,
        )
        .unwrap();
        drop(transport);
        for h in joins {
            h.join().unwrap();
        }
        assert!(
            out.history.bits_eq(&reference.history),
            "TCP history diverged from the in-process transport"
        );
    }

    #[test]
    fn measure_links_roundtrip_over_channels() {
        let p = test_parts(1);
        let (mut transport, ports) = ChannelTransport::pair(4);
        let handles = spawn_workers(&p.cfg, &p.client, p.sources, &p.init, ports).unwrap();
        let opts = LiveOptions::default();
        let m = measure_links(&mut transport, 3, &opts).unwrap();
        assert_eq!(m.count(), 12);
        let model = m.calibrated(7);
        let mut rng = Rng::new(1);
        for _ in 0..32 {
            let l = model.latency(0, 1, rng.below(100));
            assert!(l.is_finite() && l >= 0.0);
        }
        for j in 0..4 {
            transport.send(j, Msg::Stop).unwrap();
        }
        drop(transport);
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn setup_used_by_example_compiles() {
        // ensure Setup and live driver agree on types (smoke)
        let s = Setup::default();
        let _ = s.to_json();
    }

    /// Engine that works for the first `fail_after` gradient calls, then
    /// errors — simulating a device falling over mid-run.
    struct FlakyEngine {
        inner: NativeEngine,
        calls: Arc<AtomicUsize>,
        fail_after: usize,
    }

    impl GradEngine for FlakyEngine {
        fn param_count(&self) -> usize {
            self.inner.param_count()
        }

        fn grad_into(
            &mut self,
            w: &[f32],
            batch: &crate::engine::AnyBatch,
            grad_out: &mut [f32],
        ) -> anyhow::Result<f32> {
            let c = self.calls.fetch_add(1, Ordering::SeqCst);
            anyhow::ensure!(c < self.fail_after, "injected engine failure (call {c})");
            self.inner.grad_into(w, batch, grad_out)
        }

        fn eval(
            &mut self,
            w: &[f32],
            batch: &crate::engine::AnyBatch,
        ) -> anyhow::Result<(f32, usize)> {
            self.inner.eval(w, batch)
        }

        fn backend(&self) -> &'static str {
            "flaky"
        }
    }

    /// The leader's watchdog window for the scale tests, configurable so
    /// slow shared runners can stretch it: `DYBW_LIVE_WATCHDOG_SECS`.
    fn watchdog_secs() -> u64 {
        std::env::var("DYBW_LIVE_WATCHDOG_SECS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(180)
    }

    /// One full live run at `lanes` compute lanes on the CI-sized scale
    /// workload: 32 real worker threads, a 2NN model heavy enough that
    /// compute (not straggler sleep) dominates the iteration — but with
    /// every GEMM below linalg's `PAR_FLOPS` threshold, so the 1-lane
    /// baseline is genuinely serial (no intra-kernel threads) and the
    /// pooled-vs-sequential comparison isn't noise-bound on small CI
    /// runners.
    fn scale_run(lanes: usize) -> Result<LiveOutcome, LiveError> {
        let n = 32;
        let mut rng = Rng::new(42);
        let g = topology::random_connected(n, 0.25, &mut rng);
        let meta = ModelMeta::mlp2(64, 64, 10, 256);
        let data = gaussian_mixture(&MixtureSpec::mnist_like(64, 16_896), &mut rng);
        let (train, test) = data.split(16_384);
        let shards = split(&train, n, Partition::Iid, &mut rng);
        let sources: Vec<Box<dyn BatchSource>> = shards
            .into_iter()
            .enumerate()
            .map(|(j, s)| Box::new(DenseSource::new(s, 90 + j as u64)) as Box<dyn BatchSource>)
            .collect();
        let eval: Vec<AnyBatch> =
            BatchSampler::full_batches(&test.subset(&(0..256).collect::<Vec<_>>()), 256)
                .into_iter()
                .map(AnyBatch::Dense)
                .collect();
        let (_srv, client) =
            ComputeServer::spawn(native_factory(meta.clone()), lanes).map_err(|e| {
                LiveError::Unsupported(format!("pool spawn failed: {e}"))
            })?;
        let straggler = StragglerModel {
            base: Dist::Uniform { lo: 0.005, hi: 0.01 },
            worker_scale: vec![1.0; n],
            persistent: vec![1.0; n],
            transient_prob: 0.0,
            transient_factor: 1.0,
            force_one_straggler: false,
            outages: Vec::new(),
            diurnal_amp: 0.0,
            diurnal_period: 0.0,
        };
        let cfg = TrainConfig {
            iters: 6,
            batch_size: 256,
            eval_every: 0,
            seed: 77,
            ..Default::default()
        };
        let init = meta.init_params(&mut rng);
        let opts = LiveOptions {
            time_scale: 1.0,
            watchdog: Duration::from_secs(watchdog_secs()),
            ..Default::default()
        };
        run_live_opts(
            g,
            Algorithm::CbDybw,
            cfg,
            straggler,
            client,
            sources,
            eval,
            init,
            &opts,
        )
    }

    /// Run `scale_run` under a watchdog so a scheduling deadlock becomes
    /// a test failure instead of a hung CI job. A panic inside the run is
    /// propagated as itself (not misreported as a deadlock).
    fn scale_run_watchdogged(lanes: usize) -> LiveOutcome {
        use std::sync::mpsc::RecvTimeoutError;
        let secs = watchdog_secs();
        let (tx, rx) = channel();
        let h = std::thread::spawn(move || {
            let _ = tx.send(scale_run(lanes));
        });
        match rx.recv_timeout(Duration::from_secs(secs)) {
            Ok(out) => {
                h.join().unwrap();
                out.unwrap()
            }
            Err(RecvTimeoutError::Timeout) => {
                panic!("live scale run ({lanes} lanes) deadlocked: no result within {secs}s")
            }
            Err(RecvTimeoutError::Disconnected) => {
                // The run thread died without sending — surface its panic.
                match h.join() {
                    Ok(()) => unreachable!("runner dropped the sender without a result"),
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
        }
    }

    /// Min-of-2 wall clock per configuration, so one noisy-neighbor
    /// stall on a shared CI runner can't fail the comparison alone.
    fn best_scale_run(lanes: usize) -> LiveOutcome {
        let a = scale_run_watchdogged(lanes);
        let b = scale_run_watchdogged(lanes);
        if b.wall_seconds < a.wall_seconds {
            b
        } else {
            a
        }
    }

    /// ROADMAP's live-driver scale test, CI-sized: 32 workers on 8 pool
    /// lanes must (a) complete — no deadlock between the shared job
    /// queue, the termination command, and the mix barrier — and (b) not
    /// be slower than the identical run serialised on 1 lane (with slack
    /// for CI runner noise). `cargo test --release -- --ignored live_scale`.
    #[test]
    #[ignore = "CI stress run (~1 min of real compute); cargo test -- --ignored live_scale"]
    fn live_scale_32_workers_8_lanes() {
        let pooled = best_scale_run(8);
        assert_eq!(pooled.history.iters.len(), 6);
        for rec in &pooled.history.iters {
            assert!(rec.train_loss.is_finite(), "bad loss at k={}", rec.k);
        }
        let sequential = best_scale_run(1);
        assert_eq!(sequential.history.iters.len(), 6);
        println!(
            "live scale 32w: pooled(8 lanes) {:.2}s vs sequential(1 lane) {:.2}s",
            pooled.wall_seconds, sequential.wall_seconds
        );
        // termination-command latency: fired -> per-worker shutdown ack
        match pooled.term_ack_summary() {
            Some((min, med, max)) => {
                println!(
                    "term-ack latency over {} acks: min {:.1}ms / median {:.1}ms / max {:.1}ms",
                    pooled.term_ack_latencies.len(),
                    min * 1e3,
                    med * 1e3,
                    max * 1e3
                );
                assert!(min >= 0.0 && min <= med && med <= max);
                // acks ride the parked port + channel; anything near a
                // second means the command path regressed
                assert!(max < 5.0, "termination ack took {max:.2}s");
            }
            None => println!("term-ack latency: no terminations fired"),
        }
        assert!(
            pooled.wall_seconds <= sequential.wall_seconds * 1.15,
            "pooled live run slower than sequential: {:.2}s vs {:.2}s",
            pooled.wall_seconds,
            sequential.wall_seconds
        );
    }

    #[test]
    fn engine_failure_mid_iteration_errors_instead_of_hanging() {
        let n = 4;
        let mut rng = Rng::new(8);
        let g = topology::random_connected(n, 0.6, &mut rng);
        let meta = ModelMeta::lrm(8, 10, 32);
        let data = gaussian_mixture(&MixtureSpec::mnist_like(8, 1500), &mut rng);
        let (train, test) = data.split(1280);
        let shards = split(&train, n, Partition::Iid, &mut rng);
        let sources: Vec<Box<dyn BatchSource>> = shards
            .into_iter()
            .enumerate()
            .map(|(j, s)| Box::new(DenseSource::new(s, 70 + j as u64)) as Box<dyn BatchSource>)
            .collect();
        let eval: Vec<AnyBatch> =
            BatchSampler::full_batches(&test.subset(&(0..64).collect::<Vec<_>>()), 32)
                .into_iter()
                .map(AnyBatch::Dense)
                .collect();
        // Shared call counter across lanes: the failure lands partway
        // through iteration 3 of 6, exercising the `failed` Done branch.
        let calls = Arc::new(AtomicUsize::new(0));
        let meta_f = meta.clone();
        let factory: EngineFactory = Arc::new(move || {
            Ok(Box::new(FlakyEngine {
                inner: NativeEngine::new(meta_f.clone())?,
                calls: Arc::clone(&calls),
                fail_after: n * 2 + 1,
            }) as Box<dyn GradEngine>)
        });
        let (_srv, client) = ComputeServer::spawn(factory, 2).unwrap();
        let straggler = StragglerModel {
            base: Dist::Uniform { lo: 0.01, hi: 0.02 },
            worker_scale: vec![1.0; n],
            persistent: vec![1.0; n],
            transient_prob: 0.0,
            transient_factor: 1.0,
            force_one_straggler: false,
            outages: Vec::new(),
            diurnal_amp: 0.0,
            diurnal_period: 0.0,
        };
        let cfg = TrainConfig {
            iters: 6,
            batch_size: 32,
            eval_every: 0,
            seed: 9,
            ..Default::default()
        };
        let init = meta.init_params(&mut rng);
        let err = run_live(
            g,
            Algorithm::CbFull,
            cfg,
            straggler,
            client,
            sources,
            eval,
            init,
            1.0,
        )
        .unwrap_err();
        assert!(
            err.to_string().contains("compute failed"),
            "expected a compute-failure error, got: {err}"
        );
        assert!(
            matches!(err, LiveError::ComputeFailed { .. }),
            "expected the typed variant, got: {err:?}"
        );
    }

    #[test]
    fn chaos_schedule_merges_time_ordered_downs_first() {
        assert!(ChaosPlan::default().is_empty());
        let plan = ChaosPlan {
            downs: vec![(1, 5.0), (0, 1.0)],
            ups: vec![(1, 9.0), (0, 1.0)],
        };
        assert!(!plan.is_empty());
        assert_eq!(
            plan.schedule(),
            vec![
                (1.0, 0, true),
                (1.0, 0, false),
                (5.0, 1, true),
                (9.0, 1, false)
            ]
        );
    }

    #[test]
    fn chaos_without_ghost_sources_is_rejected() {
        let p = test_parts(2);
        let (mut transport, _ports) = ChannelTransport::pair(4);
        let mut res = LiveResilience {
            ghost_sources: Vec::new(),
            chaos: ChaosPlan {
                downs: vec![(0, 0.0)],
                ups: Vec::new(),
            },
        };
        let err = drive_resilient(
            &mut transport,
            &p.g,
            Algorithm::CbDybw,
            &p.cfg,
            &p.straggler,
            &p.client,
            &p.eval,
            p.init.clone(),
            &LiveOptions::default(),
            &mut res,
        )
        .unwrap_err();
        assert!(matches!(err, LiveError::Unsupported(_)), "{err:?}");
    }

    /// Heartbeats are pure liveness signal: enabling them must not move
    /// a single bit of the recorded history, and nobody gets ghosted.
    #[test]
    fn live_heartbeats_do_not_perturb_history() {
        let reference = run(Algorithm::CbDybw, 4);
        let p = test_parts(4);
        let opts = LiveOptions {
            heartbeat: Duration::from_millis(100),
            ..Default::default()
        };
        let out = run_live_opts(
            p.g,
            Algorithm::CbDybw,
            p.cfg,
            p.straggler,
            p.client,
            p.sources,
            p.eval,
            p.init,
            &opts,
        )
        .unwrap();
        assert_eq!(out.ghost_dones, 0, "heartbeats alone must not ghost anyone");
        assert_eq!(out.rejoins, 0);
        assert!(out.history.bits_eq(&reference.history));
    }

    /// A straggler sleeping out its delay still answers probes — and the
    /// echo does not eat its termination command.
    #[test]
    fn worker_echoes_heartbeats_mid_straggler_wait() {
        let p = test_parts(1);
        let (mut transport, ports) = ChannelTransport::pair(4);
        let handles = spawn_workers(&p.cfg, &p.client, p.sources, &p.init, ports).unwrap();
        transport
            .send(0, Msg::Start { k: 1, delay_s: 30.0 })
            .unwrap();
        std::thread::sleep(Duration::from_millis(150));
        transport.send(0, Msg::Heartbeat { seq: 7 }).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let wait = deadline.saturating_duration_since(Instant::now());
            let (j, msg) = transport.recv(wait).unwrap();
            if let Msg::Heartbeat { seq } = msg {
                assert_eq!((j, seq), (0, 7));
                break;
            }
        }
        transport.send(0, Msg::Terminate { k: 1 }).unwrap();
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            let wait = deadline.saturating_duration_since(Instant::now());
            let (j, msg) = transport.recv(wait).unwrap();
            if let Msg::Done { k, terminated, .. } = msg {
                assert_eq!((j, k, terminated), (0, 1, true));
                break;
            }
        }
        for j in 0..4 {
            transport.send(j, Msg::Stop).unwrap();
        }
        drop(transport);
        for h in handles {
            h.join().unwrap();
        }
    }

    /// The worker-side checkpoint hook: milestones land after the mix,
    /// carry the post-mix parameters, and honour `ckpt_every`.
    #[test]
    fn worker_checkpoints_at_milestones() {
        let dir = std::env::temp_dir().join("dybw_live_worker_ckpt");
        let _ = std::fs::remove_dir_all(&dir);
        let p = test_parts(1);
        let (mut transport, mut ports) = ChannelTransport::pair(1);
        let port = ports.pop().unwrap();
        let mut source = p.sources.into_iter().next().unwrap();
        let cfg = p.cfg.clone();
        let client = p.client.clone();
        let init = p.init.clone();
        let mgr = CkptManager::new(&dir, 0).unwrap();
        let mgr_probe = mgr.clone();
        let h = std::thread::spawn(move || {
            let mut wopts = WorkerOpts {
                ckpt: Some(mgr),
                ckpt_every: 2,
                model: "lrm".to_string(),
            };
            worker_loop_opts(
                0,
                &cfg,
                &client,
                source.as_mut(),
                WorkerState::fresh(init),
                port,
                &mut wopts,
            )
            .unwrap()
        });
        let mut last_w: Vec<f32> = Vec::new();
        for k in 1..=4u64 {
            transport.send(0, Msg::Start { k, delay_s: 0.0 }).unwrap();
            loop {
                let (_, msg) = transport.recv(Duration::from_secs(20)).unwrap();
                if let Msg::Done { k: mk, failed, .. } = msg {
                    assert_eq!(mk, k);
                    assert!(!failed);
                    break;
                }
            }
            transport
                .send(
                    0,
                    Msg::Mix {
                        k,
                        active: false,
                        row: Vec::new(),
                        peers: Vec::new(),
                    },
                )
                .unwrap();
            loop {
                let (_, msg) = transport.recv(Duration::from_secs(20)).unwrap();
                if let Msg::MixAck { k: mk, w } = msg {
                    assert_eq!(mk, k);
                    last_w = w;
                    break;
                }
            }
        }
        transport.send(0, Msg::Stop).unwrap();
        assert!(matches!(h.join().unwrap(), WorkerExit::Stopped));
        let ids: Vec<usize> = mgr_probe
            .list()
            .unwrap()
            .into_iter()
            .map(|(i, _)| i)
            .collect();
        assert_eq!(ids, vec![2, 4]);
        let (ckpt, _) = mgr_probe.latest().unwrap().unwrap();
        assert_eq!(ckpt.iteration, 4);
        assert_eq!(ckpt.model, "lrm");
        assert_eq!(ckpt.params[0], last_w);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Degraded-mode continuation: kill one TCP worker at t=0 and never
    /// let it back. The leader ghosts the slot every iteration and the
    /// history stays bit-identical to the uninterrupted run.
    #[test]
    fn live_tcp_worker_death_degrades_bit_identical() {
        let reference = run(Algorithm::CbDybw, 5);

        let p = test_parts(5);
        let ghosts = test_parts(5).sources;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let timeout = Duration::from_secs(20);
        let mut joins = Vec::new();
        for (j, source) in p.sources.into_iter().enumerate() {
            let addr = addr.clone();
            let cfg = p.cfg.clone();
            let client = p.client.clone();
            let init = p.init.clone();
            joins.push(std::thread::spawn(move || {
                let (id, _setup, port) = connect_worker(&addr, Some(j as u32), timeout).unwrap();
                worker_loop(id as usize, cfg, client, source, init, port).unwrap();
            }));
        }
        let mut transport = TcpTransport::accept(&listener, 4, "", timeout).unwrap();
        let opts = LiveOptions::default();
        let mut res = LiveResilience {
            ghost_sources: ghosts,
            chaos: ChaosPlan {
                downs: vec![(3, 0.0)],
                ups: Vec::new(),
            },
        };
        let out = drive_resilient(
            &mut transport,
            &p.g,
            Algorithm::CbDybw,
            &p.cfg,
            &p.straggler,
            &p.client,
            &p.eval,
            p.init.clone(),
            &opts,
            &mut res,
        )
        .unwrap();
        drop(transport);
        for h in joins {
            h.join().unwrap();
        }
        assert_eq!(out.ghost_dones, 5, "worker 3 ghosted every iteration");
        assert_eq!(out.rejoins, 0);
        assert!(
            out.history.bits_eq(&reference.history),
            "degraded run diverged from the uninterrupted run"
        );
    }

    /// The full failure/rejoin cycle over TCP: worker 3 is killed at
    /// t=0, allowed back at t=0.01, rejoins via `rejoin_worker` +
    /// `apply_state_sync`, and finishes the run — with the recorded
    /// history bit-identical to the uninterrupted reference.
    #[test]
    fn live_tcp_worker_rejoins_bit_identical() {
        let reference = run(Algorithm::CbDybw, 5);

        let p = test_parts(5);
        let ghosts = test_parts(5).sources;
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let timeout = Duration::from_secs(20);
        let mut joins = Vec::new();
        for (j, mut source) in p.sources.into_iter().enumerate() {
            let addr = addr.clone();
            let cfg = p.cfg.clone();
            let client = p.client.clone();
            let init = p.init.clone();
            joins.push(std::thread::spawn(move || {
                let (id, _setup, mut port) =
                    connect_worker(&addr, Some(j as u32), timeout).unwrap();
                let jd = id as usize;
                let mut state = WorkerState::fresh(init);
                let mut wopts = WorkerOpts::default();
                loop {
                    match worker_loop_opts(
                        jd,
                        &cfg,
                        &client,
                        source.as_mut(),
                        state,
                        port,
                        &mut wopts,
                    )
                    .unwrap()
                    {
                        WorkerExit::Stopped => break,
                        WorkerExit::LeaderLost(s) => {
                            state = s;
                            let Ok((sync, fresh)) =
                                rejoin_worker(&addr, jd as u32, state.draws, timeout)
                            else {
                                break; // leader already gone: clean exit
                            };
                            apply_state_sync(
                                &mut state,
                                source.as_mut(),
                                cfg.batch_size,
                                &sync,
                                jd,
                            )
                            .unwrap();
                            port = fresh;
                        }
                    }
                }
            }));
        }
        let mut transport = TcpTransport::accept(&listener, 4, "", timeout).unwrap();
        let opts = LiveOptions::default();
        let mut res = LiveResilience {
            ghost_sources: ghosts,
            chaos: ChaosPlan {
                downs: vec![(3, 0.0)],
                ups: vec![(3, 0.01)],
            },
        };
        let out = drive_resilient(
            &mut transport,
            &p.g,
            Algorithm::CbDybw,
            &p.cfg,
            &p.straggler,
            &p.client,
            &p.eval,
            p.init.clone(),
            &opts,
            &mut res,
        )
        .unwrap();
        drop(transport);
        for h in joins {
            h.join().unwrap();
        }
        assert_eq!(out.rejoins, 1, "worker 3 rejoined exactly once");
        assert!(
            out.ghost_dones >= 1 && out.ghost_dones < 5,
            "ghosted only while down, got {}",
            out.ghost_dones
        );
        assert!(
            out.history.bits_eq(&reference.history),
            "failure/rejoin run diverged from the uninterrupted run"
        );
    }
}
