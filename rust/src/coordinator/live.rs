//! Live driver: real threads, real clocks, real termination commands.
//!
//! One OS thread per worker; gradient compute goes through the multi-lane
//! [`ComputeServer`](crate::engine::server) (a facade over the per-worker
//! [`EnginePool`](crate::engine::EnginePool), so workers really compute
//! in parallel and no parameter vector is cloned); straggler slowness is
//! injected as interruptible sleep on top of the real compute time. The
//! leader (main thread) plays the paper's distributed protocol verbatim:
//!
//! 1. all workers start iteration k simultaneously;
//! 2. as local updates complete, workers announce them (`Done`);
//! 3. for cb-DyBW the leader watches for the first establishment of a
//!    not-yet-established link of P — at that moment it *terminates the
//!    iteration network-wide* (the paper's "send a command to the rest
//!    workers to terminate the current iteration"); stragglers abort
//!    their wait, keep their local update, and sit the round out;
//! 4. participants exchange parameters (shared board = the network) and
//!    apply the Metropolis average; everyone barriers into k+1.
//!
//! This driver exists to prove the stack composes end-to-end in wall
//! clock (e2e example); the figures use the deterministic sim driver.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::consensus::ConsensusMatrix;
use crate::engine::server::ComputeClient;
use crate::engine::{AnyBatch, BatchSource};
use crate::graph::Graph;
use crate::metrics::{EvalRecord, IterRecord, RunHistory};
use crate::straggler::StragglerModel;
use crate::util::rng::Rng;

use super::algorithm::Algorithm;
use super::dtur::Dtur;
use super::sim::TrainConfig;

/// Leader -> worker messages.
enum Cmd {
    Start {
        k: usize,
        delay_s: f64,
    },
    /// Mix with this worker's Metropolis row (the leader builds P(k)
    /// once; workers only ever consume their own row).
    Mix {
        active: bool,
        row: Vec<(usize, f64)>,
    },
    Stop,
}

/// Worker -> leader messages.
struct DoneMsg {
    loss: f32,
    terminated: bool,
    /// Compute failed (shape mismatch, engine error, ...). The leader
    /// aborts the run with a real error instead of hanging.
    failed: bool,
}

struct WorkerChans {
    cmd_tx: Sender<Cmd>,
    done_rx: Receiver<DoneMsg>,
    ack_rx: Receiver<usize>,
}

/// Shared "network": slot j holds worker j's latest locally-updated
/// parameters w̃_j(k) (post eq. 5), then its post-mix w_j(k).
type Board = Arc<Vec<Mutex<Vec<f32>>>>;

#[derive(Debug)]
pub struct LiveOutcome {
    pub history: RunHistory,
    /// Real seconds the whole run took (incl. eval overhead).
    pub wall_seconds: f64,
    /// Per-worker termination-command ack latency: real seconds from the
    /// leader firing the terminate command to each terminated worker's
    /// `Done{terminated}` answer (one entry per terminated worker per
    /// iteration; empty for algorithms that never terminate).
    pub term_ack_latencies: Vec<f64>,
}

impl LiveOutcome {
    /// (min, median, max) of the termination-ack latencies.
    pub fn term_ack_summary(&self) -> Option<(f64, f64, f64)> {
        if self.term_ack_latencies.is_empty() {
            return None;
        }
        let mut v = self.term_ack_latencies.clone();
        v.sort_by(f64::total_cmp);
        Some((v[0], v[v.len() / 2], v[v.len() - 1]))
    }
}

/// Run training with real threads. `time_scale` converts the straggler
/// model's virtual seconds into real sleep seconds (e.g. 0.05 makes a
/// "2s" straggler a 100ms sleep so the example finishes quickly).
#[allow(clippy::too_many_arguments)]
pub fn run_live(
    graph: Graph,
    algo: Algorithm,
    cfg: TrainConfig,
    straggler: StragglerModel,
    compute: ComputeClient,
    sources: Vec<Box<dyn BatchSource>>,
    eval_batches: Vec<AnyBatch>,
    initial: Vec<f32>,
    time_scale: f64,
) -> anyhow::Result<LiveOutcome> {
    anyhow::ensure!(
        matches!(algo, Algorithm::CbDybw | Algorithm::CbFull),
        "live driver implements the consensus algorithms (got {})",
        algo.name()
    );
    let n = graph.n();
    anyhow::ensure!(sources.len() == n && straggler.n() == n);
    let run_start = Instant::now();

    let board: Board = Arc::new((0..n).map(|_| Mutex::new(initial.clone())).collect());
    // iteration id whose in-flight waits should abort (0 = none)
    let terminate = Arc::new(AtomicUsize::new(0));

    // ---- spawn workers ----------------------------------------------------
    let mut chans = Vec::with_capacity(n);
    let mut handles = Vec::with_capacity(n);
    for (j, source) in sources.into_iter().enumerate() {
        let (cmd_tx, cmd_rx) = channel::<Cmd>();
        let (done_tx, done_rx) = channel::<DoneMsg>();
        let (ack_tx, ack_rx) = channel::<usize>();
        let board = Arc::clone(&board);
        let terminate = Arc::clone(&terminate);
        let compute = compute.clone();
        let cfg_l = cfg.clone();
        handles.push(
            std::thread::Builder::new()
                .name(format!("dybw-worker-{j}"))
                .spawn(move || {
                    worker_loop(
                        j, cfg_l, compute, source, board, terminate, cmd_rx, done_tx, ack_tx,
                    )
                })?,
        );
        chans.push(WorkerChans {
            cmd_tx,
            done_rx,
            ack_rx,
        });
    }

    // ---- leader loop -------------------------------------------------------
    let mut history = RunHistory::new(&algo.name(), "live", "synthetic", n);
    let mut dtur = algo.needs_dtur().then(|| Dtur::new(&graph));
    let mut rng = Rng::new(cfg.seed ^ 0x11FE);
    let mut clock = 0.0f64;
    let mut term_ack_latencies: Vec<f64> = Vec::new();

    // initial eval
    history
        .evals
        .push(eval_on_board(&board, &eval_batches, &compute, 0, clock)?);

    for k in 1..=cfg.iters {
        let t = straggler.sample_iteration(&mut rng);
        let iter_start = Instant::now();
        for (j, ch) in chans.iter().enumerate() {
            ch.cmd_tx
                .send(Cmd::Start {
                    k,
                    delay_s: t[j] * time_scale,
                })
                .map_err(|_| anyhow::anyhow!("worker {j} died"))?;
        }

        // Collect Done; for cb-DyBW fire the termination command at the
        // moment the first unestablished P-link completes.
        let mut done = vec![false; n];
        let mut losses = vec![0.0f32; n];
        let mut terminated_flag = vec![false; n];
        let mut fired = !algo.needs_dtur(); // cb-Full never terminates
        let mut fired_at: Option<Instant> = None;
        let mut pending = n;
        let mut theta_real = f64::NAN;
        while pending > 0 {
            for (j, ch) in chans.iter().enumerate() {
                if done[j] {
                    continue;
                }
                if let Ok(msg) = ch.done_rx.try_recv() {
                    anyhow::ensure!(
                        !msg.failed,
                        "worker {j} compute failed at iteration {k} (see log)"
                    );
                    done[j] = true;
                    losses[j] = msg.loss;
                    terminated_flag[j] = msg.terminated;
                    if msg.terminated {
                        // shutdown-ack latency: command fired -> this ack
                        if let Some(t0) = fired_at {
                            term_ack_latencies.push(t0.elapsed().as_secs_f64());
                        }
                    }
                    pending -= 1;
                    if !fired {
                        let finished: Vec<bool> = (0..n)
                            .map(|i| done[i] && !terminated_flag[i])
                            .collect();
                        if let Some(d) = dtur.as_ref() {
                            let hit = d
                                .path()
                                .iter()
                                .enumerate()
                                .any(|(idx, &(a, b))| {
                                    !d.is_established(idx) && finished[a] && finished[b]
                                });
                            if hit {
                                fired = true;
                                theta_real = iter_start.elapsed().as_secs_f64();
                                terminate.store(k, Ordering::SeqCst);
                                fired_at = Some(Instant::now());
                            }
                        }
                    }
                }
            }
            if pending > 0 {
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }
        let duration = if theta_real.is_nan() {
            iter_start.elapsed().as_secs_f64()
        } else {
            theta_real
        };
        terminate.store(0, Ordering::SeqCst);

        // Active set + DTUR bookkeeping (advance the epoch state with the
        // *virtual* times so sim and live share Algorithm 2 semantics).
        let active: Vec<bool> = if let Some(d) = dtur.as_mut() {
            // feed DTUR the realised finish pattern: genuine finishers get
            // their virtual t, terminated ones +inf so they're excluded
            let t_eff: Vec<f64> = (0..n)
                .map(|j| if terminated_flag[j] { f64::INFINITY } else { t[j] })
                .collect();
            d.step(&t_eff).active
        } else {
            vec![true; n]
        };

        // Build P(k) once on the leader and hand each worker its row —
        // same matrix every worker previously rebuilt for itself.
        let p = ConsensusMatrix::metropolis(&graph, &active);
        for (j, ch) in chans.iter().enumerate() {
            ch.cmd_tx
                .send(Cmd::Mix {
                    active: active[j],
                    row: p.row(j).to_vec(),
                })
                .map_err(|_| anyhow::anyhow!("worker died"))?;
        }
        for ch in &chans {
            ch.ack_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("worker died before ack"))?;
        }

        clock += duration;
        let active_count = active.iter().filter(|&&a| a).count();
        let backup_avg = {
            let mut total = 0usize;
            for j in 0..n {
                total += graph.neighbors(j).filter(|&i| !active[i]).count();
            }
            total as f64 / n as f64
        };
        history.iters.push(IterRecord {
            k,
            duration,
            clock,
            train_loss: losses.iter().map(|&l| l as f64).sum::<f64>() / n as f64,
            active: active_count,
            backup_avg,
            theta: theta_real,
        });

        if cfg.eval_every > 0 && k % cfg.eval_every == 0 {
            history
                .evals
                .push(eval_on_board(&board, &eval_batches, &compute, k, clock)?);
        }
    }

    for ch in &chans {
        let _ = ch.cmd_tx.send(Cmd::Stop);
    }
    for h in handles {
        h.join().map_err(|_| anyhow::anyhow!("worker panicked"))?;
    }
    Ok(LiveOutcome {
        history,
        wall_seconds: run_start.elapsed().as_secs_f64(),
        term_ack_latencies,
    })
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    j: usize,
    cfg: TrainConfig,
    compute: ComputeClient,
    mut source: Box<dyn BatchSource>,
    board: Board,
    terminate: Arc<AtomicUsize>,
    cmd_rx: Receiver<Cmd>,
    done_tx: Sender<DoneMsg>,
    ack_tx: Sender<usize>,
) {
    let mut w: Vec<f32> = board[j].lock().unwrap().clone();
    let mut wtilde: Vec<f32> = w.clone();
    // Leased buffers: the gradient is written in place by the engine pool
    // every iteration, the mix accumulator swaps with `w` every round —
    // neither is ever reallocated.
    let mut grad: Vec<f32> = vec![0.0; compute.param_count()];
    let mut mix_buf: Vec<f32> = vec![0.0; w.len()];
    while let Ok(cmd) = cmd_rx.recv() {
        match cmd {
            Cmd::Stop => break,
            Cmd::Start { k, delay_s } => {
                let start = Instant::now();
                let batch = source.next_train(cfg.batch_size);
                let loss = match compute.grad_into(&w, &batch, &mut grad) {
                    Ok(r) => r,
                    Err(e) => {
                        crate::util::log::log(
                            crate::util::log::Level::Error,
                            "live",
                            &format!("worker {j} compute failed: {e}"),
                        );
                        let _ = done_tx.send(DoneMsg {
                            loss: f32::NAN,
                            terminated: false,
                            failed: true,
                        });
                        break;
                    }
                };
                // Straggler injection: wait out the remaining virtual
                // compute time, abortable by the termination command.
                let mut terminated = false;
                while start.elapsed().as_secs_f64() < delay_s {
                    if terminate.load(Ordering::SeqCst) == k {
                        terminated = true;
                        break;
                    }
                    std::thread::sleep(std::time::Duration::from_micros(300));
                }
                // eq. (5): local update (kept even when terminated).
                let eta = cfg.lr(k) as f32;
                wtilde.copy_from_slice(&w);
                crate::util::vecmath::axpy(&mut wtilde, -eta, &grad);
                *board[j].lock().unwrap() = wtilde.clone();
                let _ = done_tx.send(DoneMsg {
                    loss,
                    terminated,
                    failed: false,
                });
            }
            Cmd::Mix { active, row } => {
                if active {
                    // eq. (6) over the active neighbourhood, accumulated
                    // in row order (deterministic) into the leased buffer.
                    mix_buf.fill(0.0);
                    for &(i, wt) in &row {
                        let src = board[i].lock().unwrap();
                        crate::util::vecmath::axpy(&mut mix_buf, wt as f32, &src);
                    }
                    std::mem::swap(&mut w, &mut mix_buf);
                } else {
                    w.copy_from_slice(&wtilde);
                }
                *board[j].lock().unwrap() = w.clone();
                let _ = ack_tx.send(j);
            }
        }
    }
}

fn eval_on_board(
    board: &Board,
    eval_batches: &[AnyBatch],
    compute: &ComputeClient,
    k: usize,
    clock: f64,
) -> anyhow::Result<EvalRecord> {
    let n = board.len();
    let dim = board[0].lock().unwrap().len();
    let mut avg = vec![0.0f32; dim];
    let mut rows: Vec<Vec<f32>> = Vec::with_capacity(n);
    for slot in board.iter() {
        rows.push(slot.lock().unwrap().clone());
    }
    for r in &rows {
        crate::util::vecmath::axpy(&mut avg, 1.0 / n as f32, r);
    }
    let consensus_error = rows
        .iter()
        .map(|r| crate::util::vecmath::dist(r, &avg))
        .fold(0.0, f64::max);
    let mut loss_sum = 0.0f64;
    let mut correct = 0usize;
    let mut total = 0usize;
    // Batches fan across the pool's lanes; the reduction runs in batch
    // order, so the result is independent of the lane count.
    let scores = compute.eval_many(&avg, eval_batches)?;
    for ((l, c), b) in scores.into_iter().zip(eval_batches) {
        let r = b.rows();
        loss_sum += l as f64 * r as f64;
        correct += c;
        total += r;
    }
    Ok(EvalRecord {
        k,
        clock,
        test_loss: loss_sum / total.max(1) as f64,
        test_error: 1.0 - correct as f64 / total.max(1) as f64,
        consensus_error,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::setup::Setup;
    use crate::data::batch::BatchSampler;
    use crate::data::partition::{split, Partition};
    use crate::data::synthetic::{gaussian_mixture, MixtureSpec};
    use crate::engine::server::ComputeServer;
    use crate::engine::{native_factory, DenseSource, EngineFactory, GradEngine, NativeEngine};
    use crate::graph::topology;
    use crate::model::ModelMeta;
    use crate::straggler::Dist;

    fn run(algo: Algorithm, iters: usize) -> LiveOutcome {
        let n = 4;
        let mut rng = Rng::new(3);
        let g = topology::random_connected(n, 0.6, &mut rng);
        let meta = ModelMeta::lrm(8, 10, 32);
        let data = gaussian_mixture(&MixtureSpec::mnist_like(8, 1500), &mut rng);
        let (train, test) = data.split(1280);
        let shards = split(&train, n, Partition::Iid, &mut rng);
        let sources: Vec<Box<dyn BatchSource>> = shards
            .into_iter()
            .enumerate()
            .map(|(j, s)| Box::new(DenseSource::new(s, 50 + j as u64)) as Box<dyn BatchSource>)
            .collect();
        let eval: Vec<AnyBatch> = BatchSampler::full_batches(
            &test.subset(&(0..192).collect::<Vec<_>>()),
            32,
        )
        .into_iter()
        .map(AnyBatch::Dense)
        .collect();
        let (_srv, client) = ComputeServer::spawn(native_factory(meta.clone()), 2).unwrap();
        let straggler = StragglerModel {
            base: Dist::Uniform { lo: 0.02, hi: 0.05 },
            worker_scale: vec![1.0; n],
            persistent: vec![1.0; n],
            transient_prob: 0.2,
            transient_factor: 6.0,
            force_one_straggler: true,
            outages: Vec::new(),
        };
        let cfg = TrainConfig {
            iters,
            batch_size: 32,
            eval_every: iters,
            seed: 5,
            ..Default::default()
        };
        let init = meta.init_params(&mut rng);
        run_live(
            g, algo, cfg, straggler, client, sources, eval, init, 1.0,
        )
        .unwrap()
    }

    #[test]
    fn live_dybw_trains_in_real_time() {
        let out = run(Algorithm::CbDybw, 12);
        assert_eq!(out.history.iters.len(), 12);
        let first = &out.history.evals[0];
        let last = out.history.evals.last().unwrap();
        assert!(last.test_loss < first.test_loss, "{first:?} -> {last:?}");
        assert!(out.wall_seconds > 0.1); // really slept
        // with a forced 6x transient straggler every round, termination
        // fires and the aborted workers' acks get timed
        assert!(
            !out.term_ack_latencies.is_empty(),
            "no termination acks recorded"
        );
        assert!(out.term_ack_latencies.iter().all(|&l| l >= 0.0 && l < 10.0));
        let (min, med, max) = out.term_ack_summary().unwrap();
        assert!(min <= med && med <= max);
    }

    #[test]
    fn term_ack_summary_empty_without_termination() {
        // cb-Full never fires the command; the stats stay empty.
        let out = run(Algorithm::CbFull, 4);
        assert!(out.term_ack_latencies.is_empty());
        assert!(out.term_ack_summary().is_none());
    }

    #[test]
    fn live_dybw_faster_than_full() {
        let a = run(Algorithm::CbDybw, 10);
        let b = run(Algorithm::CbFull, 10);
        // cb-Full waits out every 6x straggler sleep; DyBW terminates them.
        assert!(
            a.history.total_time() < b.history.total_time(),
            "dybw {:.3}s vs full {:.3}s",
            a.history.total_time(),
            b.history.total_time()
        );
    }

    #[test]
    fn setup_used_by_example_compiles() {
        // ensure Setup and live driver agree on types (smoke)
        let s = Setup::default();
        let _ = s.to_json();
    }

    /// Engine that works for the first `fail_after` gradient calls, then
    /// errors — simulating a device falling over mid-run.
    struct FlakyEngine {
        inner: NativeEngine,
        calls: Arc<AtomicUsize>,
        fail_after: usize,
    }

    impl GradEngine for FlakyEngine {
        fn param_count(&self) -> usize {
            self.inner.param_count()
        }

        fn grad_into(
            &mut self,
            w: &[f32],
            batch: &crate::engine::AnyBatch,
            grad_out: &mut [f32],
        ) -> anyhow::Result<f32> {
            let c = self.calls.fetch_add(1, Ordering::SeqCst);
            anyhow::ensure!(c < self.fail_after, "injected engine failure (call {c})");
            self.inner.grad_into(w, batch, grad_out)
        }

        fn eval(
            &mut self,
            w: &[f32],
            batch: &crate::engine::AnyBatch,
        ) -> anyhow::Result<(f32, usize)> {
            self.inner.eval(w, batch)
        }

        fn backend(&self) -> &'static str {
            "flaky"
        }
    }

    /// One full live run at `lanes` compute lanes on the CI-sized scale
    /// workload: 32 real worker threads, a 2NN model heavy enough that
    /// compute (not straggler sleep) dominates the iteration — but with
    /// every GEMM below linalg's `PAR_FLOPS` threshold, so the 1-lane
    /// baseline is genuinely serial (no intra-kernel threads) and the
    /// pooled-vs-sequential comparison isn't noise-bound on small CI
    /// runners.
    fn scale_run(lanes: usize) -> anyhow::Result<LiveOutcome> {
        let n = 32;
        let mut rng = Rng::new(42);
        let g = topology::random_connected(n, 0.25, &mut rng);
        let meta = ModelMeta::mlp2(64, 64, 10, 256);
        let data = gaussian_mixture(&MixtureSpec::mnist_like(64, 16_896), &mut rng);
        let (train, test) = data.split(16_384);
        let shards = split(&train, n, Partition::Iid, &mut rng);
        let sources: Vec<Box<dyn BatchSource>> = shards
            .into_iter()
            .enumerate()
            .map(|(j, s)| Box::new(DenseSource::new(s, 90 + j as u64)) as Box<dyn BatchSource>)
            .collect();
        let eval: Vec<AnyBatch> =
            BatchSampler::full_batches(&test.subset(&(0..256).collect::<Vec<_>>()), 256)
                .into_iter()
                .map(AnyBatch::Dense)
                .collect();
        let (_srv, client) = ComputeServer::spawn(native_factory(meta.clone()), lanes)?;
        let straggler = StragglerModel {
            base: Dist::Uniform { lo: 0.005, hi: 0.01 },
            worker_scale: vec![1.0; n],
            persistent: vec![1.0; n],
            transient_prob: 0.0,
            transient_factor: 1.0,
            force_one_straggler: false,
            outages: Vec::new(),
        };
        let cfg = TrainConfig {
            iters: 6,
            batch_size: 256,
            eval_every: 0,
            seed: 77,
            ..Default::default()
        };
        let init = meta.init_params(&mut rng);
        run_live(g, Algorithm::CbDybw, cfg, straggler, client, sources, eval, init, 1.0)
    }

    /// Run `scale_run` under a watchdog so a scheduling deadlock becomes
    /// a test failure instead of a hung CI job. A panic inside the run is
    /// propagated as itself (not misreported as a deadlock).
    fn scale_run_watchdogged(lanes: usize) -> LiveOutcome {
        use std::sync::mpsc::RecvTimeoutError;
        let (tx, rx) = channel();
        let h = std::thread::spawn(move || {
            let _ = tx.send(scale_run(lanes));
        });
        match rx.recv_timeout(std::time::Duration::from_secs(180)) {
            Ok(out) => {
                h.join().unwrap();
                out.unwrap()
            }
            Err(RecvTimeoutError::Timeout) => {
                panic!("live scale run ({lanes} lanes) deadlocked: no result within 180s")
            }
            Err(RecvTimeoutError::Disconnected) => {
                // The run thread died without sending — surface its panic.
                match h.join() {
                    Ok(()) => unreachable!("runner dropped the sender without a result"),
                    Err(payload) => std::panic::resume_unwind(payload),
                }
            }
        }
    }

    /// Min-of-2 wall clock per configuration, so one noisy-neighbor
    /// stall on a shared CI runner can't fail the comparison alone.
    fn best_scale_run(lanes: usize) -> LiveOutcome {
        let a = scale_run_watchdogged(lanes);
        let b = scale_run_watchdogged(lanes);
        if b.wall_seconds < a.wall_seconds {
            b
        } else {
            a
        }
    }

    /// ROADMAP's live-driver scale test, CI-sized: 32 workers on 8 pool
    /// lanes must (a) complete — no deadlock between the shared job
    /// queue, the termination command, and the mix barrier — and (b) not
    /// be slower than the identical run serialised on 1 lane (with slack
    /// for CI runner noise). `cargo test --release -- --ignored live_scale`.
    #[test]
    #[ignore = "CI stress run (~1 min of real compute); cargo test -- --ignored live_scale"]
    fn live_scale_32_workers_8_lanes() {
        let pooled = best_scale_run(8);
        assert_eq!(pooled.history.iters.len(), 6);
        for rec in &pooled.history.iters {
            assert!(rec.train_loss.is_finite(), "bad loss at k={}", rec.k);
        }
        let sequential = best_scale_run(1);
        assert_eq!(sequential.history.iters.len(), 6);
        println!(
            "live scale 32w: pooled(8 lanes) {:.2}s vs sequential(1 lane) {:.2}s",
            pooled.wall_seconds, sequential.wall_seconds
        );
        // termination-command latency: fired -> per-worker shutdown ack
        match pooled.term_ack_summary() {
            Some((min, med, max)) => {
                println!(
                    "term-ack latency over {} acks: min {:.1}ms / median {:.1}ms / max {:.1}ms",
                    pooled.term_ack_latencies.len(),
                    min * 1e3,
                    med * 1e3,
                    max * 1e3
                );
                assert!(min >= 0.0 && min <= med && med <= max);
                // acks ride a 300us poll loop + channel; anything near a
                // second means the command path regressed
                assert!(max < 5.0, "termination ack took {max:.2}s");
            }
            None => println!("term-ack latency: no terminations fired"),
        }
        assert!(
            pooled.wall_seconds <= sequential.wall_seconds * 1.15,
            "pooled live run slower than sequential: {:.2}s vs {:.2}s",
            pooled.wall_seconds,
            sequential.wall_seconds
        );
    }

    #[test]
    fn engine_failure_mid_iteration_errors_instead_of_hanging() {
        let n = 4;
        let mut rng = Rng::new(8);
        let g = topology::random_connected(n, 0.6, &mut rng);
        let meta = ModelMeta::lrm(8, 10, 32);
        let data = gaussian_mixture(&MixtureSpec::mnist_like(8, 1500), &mut rng);
        let (train, test) = data.split(1280);
        let shards = split(&train, n, Partition::Iid, &mut rng);
        let sources: Vec<Box<dyn BatchSource>> = shards
            .into_iter()
            .enumerate()
            .map(|(j, s)| Box::new(DenseSource::new(s, 70 + j as u64)) as Box<dyn BatchSource>)
            .collect();
        let eval: Vec<AnyBatch> =
            BatchSampler::full_batches(&test.subset(&(0..64).collect::<Vec<_>>()), 32)
                .into_iter()
                .map(AnyBatch::Dense)
                .collect();
        // Shared call counter across lanes: the failure lands partway
        // through iteration 3 of 6, exercising the `failed` DoneMsg branch.
        let calls = Arc::new(AtomicUsize::new(0));
        let meta_f = meta.clone();
        let factory: EngineFactory = Arc::new(move || {
            Ok(Box::new(FlakyEngine {
                inner: NativeEngine::new(meta_f.clone())?,
                calls: Arc::clone(&calls),
                fail_after: n * 2 + 1,
            }) as Box<dyn GradEngine>)
        });
        let (_srv, client) = ComputeServer::spawn(factory, 2).unwrap();
        let straggler = StragglerModel {
            base: Dist::Uniform { lo: 0.01, hi: 0.02 },
            worker_scale: vec![1.0; n],
            persistent: vec![1.0; n],
            transient_prob: 0.0,
            transient_factor: 1.0,
            force_one_straggler: false,
            outages: Vec::new(),
        };
        let cfg = TrainConfig {
            iters: 6,
            batch_size: 32,
            eval_every: 0,
            seed: 9,
            ..Default::default()
        };
        let init = meta.init_params(&mut rng);
        let err = run_live(g, Algorithm::CbFull, cfg, straggler, client, sources, eval, init, 1.0)
            .unwrap_err();
        assert!(
            err.to_string().contains("compute failed"),
            "expected a compute-failure error, got: {err}"
        );
    }
}
