//! The discrete-event simulation driver.
//!
//! Runs Algorithm 1 (cb-DyBW) or a baseline with **real gradients** and a
//! **virtual clock**: per-worker compute times t_j(k) come from the
//! straggler model (the thing the authors' multi-machine testbed provided
//! physically), everything else — eq. (5) local updates, eq. (6)
//! Metropolis mixing, DTUR thresholds, evaluation — is executed exactly.
//! Deterministic given the config seed, so every figure regenerates
//! bit-identically.
//!
//! Gradients fan out over the [`EnginePool`]: one engine per lane thread,
//! one leased gradient buffer per worker, batches drawn from per-worker
//! RNG streams split off the config seed. The eq. (6) mixing phase fans
//! out over the same lanes (each worker's weighted row-sum is an
//! independent borrowed-closure task), and with
//! [`TrainConfig::prefetch`] the NEXT iteration's batches are drawn on
//! spare lanes while the current gradients run (each worker's sampler
//! lives in its own [`BatchSource`] slot, so streams never interleave —
//! one draw per worker per iteration, prefetched or not). Because every
//! job is a pure function of its inputs and all reductions run in worker
//! order on the coordinator thread, a pooled run is **bit-identical** to
//! a single-thread run — parallelism only changes the wall clock.

use crate::consensus::mixing::ParamBuffers;
use crate::consensus::ConsensusMatrix;
use crate::engine::{AnyBatch, BatchSource, EnginePool};
use crate::graph::Graph;
use crate::metrics::{EvalRecord, IterRecord, RunHistory};
use crate::straggler::StragglerModel;
use crate::util::rng::Rng;
use crate::util::vecmath;

use super::algorithm::{plan, Algorithm};
use super::dtur::Dtur;

/// Hyperparameters of one training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub iters: usize,
    pub batch_size: usize,
    /// η(k) = lr0 · lr_decay^k (paper: η₀·δ^k with δ=0.95 per *epoch*-ish
    /// cadence; we apply the decay every `lr_decay_every` iterations).
    pub lr0: f64,
    pub lr_decay: f64,
    pub lr_decay_every: usize,
    pub eval_every: usize,
    /// Overlap the data path with compute: draw iteration k+1's batches
    /// on spare pool lanes while iteration k's gradients run.
    /// Bit-identical on or off — per-worker sampler streams advance once
    /// per iteration either way (asserted by tests).
    pub prefetch: bool,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            iters: 200,
            batch_size: 256,
            lr0: 0.2,
            lr_decay: 0.95,
            lr_decay_every: 10,
            eval_every: 10,
            prefetch: true,
            seed: 2021,
        }
    }
}

impl TrainConfig {
    pub fn lr(&self, k: usize) -> f64 {
        self.lr0 * self.lr_decay.powi((k / self.lr_decay_every.max(1)) as i32)
    }
}

/// The simulation trainer. Generic over the gradient engine (native or
/// PJRT) and the per-worker batch sources.
pub struct SimTrainer {
    pub graph: Graph,
    pub algo: Algorithm,
    pub cfg: TrainConfig,
    pub straggler: StragglerModel,
    /// One engine per pool lane (parameters live in `params`; engines
    /// carry scratch only, so results don't depend on lane assignment).
    pool: EnginePool,
    sources: Vec<Box<dyn BatchSource>>,
    eval_batches: Vec<AnyBatch>,
    params: ParamBuffers,
    dtur: Option<Dtur>,
    rng: Rng,
    clock: f64,
    /// One leased gradient buffer per worker, written in place each
    /// iteration by [`EnginePool::grad_many`].
    grad_bufs: Vec<Vec<f32>>,
    /// Batches drawn ahead of time by the prefetch tasks (iteration k+1's
    /// batches, filled while iteration k's gradients ran).
    prefetched: Option<Vec<AnyBatch>>,
    /// Optional per-iteration observer (e.g. live progress printing).
    pub on_iter: Option<Box<dyn FnMut(&IterRecord)>>,
    /// When set, compute times replay this trace instead of sampling the
    /// straggler model — variance-free A/B of algorithms on identical
    /// timing realisations.
    pub trace: Option<crate::straggler::trace::TraceReplay>,
    /// When set, the eq. (6) exchange is compressed with error feedback
    /// (consensus::compress); accumulates simulated wire bytes.
    pub compression: Option<CompressionState>,
    /// Starting iteration (for checkpoint resume).
    start_k: usize,
    /// Last iteration actually completed by `run` (== `start_k` until the
    /// first iteration finishes); this is what checkpoints stamp.
    last_k: usize,
    /// When set, `run` persists a checkpoint (with history) through the
    /// manager every `ckpt_every` iterations.
    pub ckpt_mgr: Option<super::ckpt_manager::CkptManager>,
    /// Checkpoint cadence in iterations; 0 disables periodic saves.
    pub ckpt_every: usize,
    /// Model name stamped into periodic checkpoints.
    pub ckpt_model: String,
    /// Fault injection: `run` errors out right after completing (and
    /// checkpointing, if due) this iteration — the CI kill-and-replay
    /// harness uses it to die at a deterministic point.
    pub kill_at: Option<usize>,
    /// History carried over from a restored checkpoint; `run` continues
    /// appending to it instead of starting a fresh series.
    resume_history: Option<RunHistory>,
}

/// Compressed-gossip state: the operator + one error-feedback buffer per
/// worker + the running wire-byte counter. The operator is `Send + Sync`
/// so the compress/reconstruct phase can fan over the engine pool.
pub struct CompressionState {
    pub comp: Box<dyn crate::consensus::compress::Compressor + Send + Sync>,
    pub efs: Vec<crate::consensus::compress::ErrorFeedback>,
    pub wire_bytes: usize,
}

impl CompressionState {
    pub fn new(
        comp: Box<dyn crate::consensus::compress::Compressor + Send + Sync>,
        n: usize,
        dim: usize,
    ) -> Self {
        CompressionState {
            comp,
            efs: (0..n)
                .map(|_| crate::consensus::compress::ErrorFeedback::new(dim))
                .collect(),
            wire_bytes: 0,
        }
    }
}

impl SimTrainer {
    /// `initial` params are cloned to every worker (paper: common w(0)).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        graph: Graph,
        algo: Algorithm,
        cfg: TrainConfig,
        straggler: StragglerModel,
        pool: EnginePool,
        sources: Vec<Box<dyn BatchSource>>,
        eval_batches: Vec<AnyBatch>,
        initial: Vec<f32>,
    ) -> anyhow::Result<Self> {
        let n = graph.n();
        anyhow::ensure!(n >= 2, "need >= 2 workers");
        anyhow::ensure!(sources.len() == n, "one batch source per worker");
        anyhow::ensure!(straggler.n() == n, "straggler model size mismatch");
        anyhow::ensure!(initial.len() == pool.param_count(), "bad init length");
        anyhow::ensure!(graph.is_connected(), "graph must be connected");
        let params = ParamBuffers::from_initial(vec![initial; n]);
        let dtur = algo.needs_dtur().then(|| Dtur::new(&graph));
        let rng = Rng::new(cfg.seed ^ 0xD1B2_57A1);
        let p = pool.param_count();
        Ok(SimTrainer {
            graph,
            algo,
            cfg,
            straggler,
            pool,
            sources,
            eval_batches,
            params,
            dtur,
            rng,
            clock: 0.0,
            grad_bufs: vec![vec![0.0; p]; n],
            prefetched: None,
            on_iter: None,
            trace: None,
            compression: None,
            start_k: 0,
            last_k: 0,
            ckpt_mgr: None,
            ckpt_every: 0,
            ckpt_model: "sim".to_string(),
            kill_at: None,
            resume_history: None,
        })
    }

    /// Network-average parameters ȳ(k).
    pub fn average_params(&self) -> Vec<f32> {
        self.params.average()
    }

    /// Snapshot the current state as a checkpoint, stamped with the last
    /// iteration `run` actually completed (NOT `start_k + cfg.iters`,
    /// which over-counts when a run is invoked for fewer iterations or
    /// a checkpoint is taken before any run).
    pub fn checkpoint(&self, model: &str) -> super::checkpoint::Checkpoint {
        super::checkpoint::Checkpoint::from_buffers(self.last_k, self.clock, model, &self.params)
    }

    /// Resume from a checkpoint: restores parameters, clock, and the
    /// iteration counter, then **fast-forwards every stream** — the
    /// straggler RNG (or trace replay), the per-worker batch samplers,
    /// and the global DTUR epoch state — by replaying iterations
    /// `1..=ckpt.iteration` without compute. A subsequent `run` therefore
    /// continues bit-for-bit where the original run left off, which is
    /// what makes kill-and-replay byte-identical (the old restore left
    /// the streams at zero, so resumed runs silently diverged).
    ///
    /// Call on a freshly built trainer (same seed/config), after setting
    /// `trace` if the original run replayed one.
    pub fn restore(&mut self, ckpt: super::checkpoint::Checkpoint) -> anyhow::Result<()> {
        anyhow::ensure!(
            ckpt.params.len() == self.graph.n(),
            "checkpoint has {} workers, trainer has {}",
            ckpt.params.len(),
            self.graph.n()
        );
        anyhow::ensure!(
            ckpt.params[0].len() == self.pool.param_count(),
            "checkpoint param dim mismatch"
        );
        for k in 1..=ckpt.iteration {
            let t = match self.trace.as_mut() {
                Some(replay) => replay.next_iteration(),
                None => self.straggler.sample_iteration_at(k, &mut self.rng),
            };
            let _ = plan(self.algo, &t, self.dtur.as_mut());
            for src in self.sources.iter_mut() {
                let _ = src.next_train(self.cfg.batch_size);
            }
        }
        self.clock = ckpt.clock;
        self.start_k = ckpt.iteration;
        self.last_k = ckpt.iteration;
        self.params = ParamBuffers::from_initial(ckpt.params);
        self.prefetched = None;
        self.resume_history = (!ckpt.history.iters.is_empty()
            || !ckpt.history.evals.is_empty())
        .then_some(ckpt.history);
        Ok(())
    }

    /// Restore from the newest intact checkpoint in `ckpt_mgr`'s
    /// directory, if any. Returns whether a checkpoint was found.
    pub fn resume_latest(&mut self) -> anyhow::Result<bool> {
        let found = match self.ckpt_mgr.as_ref() {
            None => None,
            Some(mgr) => mgr.latest()?,
        };
        match found {
            Some((ckpt, _)) => {
                self.restore(ckpt)?;
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Iteration the next `run` starts after (0 on a fresh trainer).
    pub fn start_k(&self) -> usize {
        self.start_k
    }

    pub fn params(&self) -> &ParamBuffers {
        &self.params
    }

    /// Number of engine-pool lanes serving this trainer.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Evaluate average params on the held-out set (batches scored in
    /// parallel across the pool; the reduction runs in batch order, so
    /// the result is independent of the pool size).
    pub fn evaluate(&mut self, k: usize) -> anyhow::Result<EvalRecord> {
        let avg = self.params.average();
        let (test_loss, test_error) = self.pool.score(&avg, &self.eval_batches)?;
        Ok(EvalRecord {
            k,
            clock: self.clock,
            test_loss,
            test_error,
            consensus_error: self.params.consensus_error(),
        })
    }

    /// Run the full training loop, returning the recorded history.
    pub fn run(&mut self) -> anyhow::Result<RunHistory> {
        let n = self.graph.n();
        crate::obs::span::set_track("sim");
        let mut history = match self.resume_history.take() {
            // restored mid-run: the series (including the k = start eval
            // and any eval already due at the checkpoint boundary) was
            // carried in the checkpoint — appending continues it exactly.
            Some(h) => h,
            None => {
                let mut h =
                    RunHistory::new(&self.algo.name(), self.pool.backend(), "synthetic", n);
                // initial eval (k = start)
                let e0 = {
                    let _s = crate::obs::span::enter(crate::obs::span::Phase::Eval);
                    self.evaluate(self.start_k)?
                };
                h.evals.push(e0);
                h
            }
        };

        for k in (self.start_k + 1)..=(self.start_k + self.cfg.iters) {
            // --- timing: draw t_j(k), derive the participation plan -----
            let t = match self.trace.as_mut() {
                Some(replay) => replay.next_iteration(),
                None => self.straggler.sample_iteration_at(k, &mut self.rng),
            };
            let iter_plan = plan(self.algo, &t, self.dtur.as_mut());
            let eta = self.cfg.lr(k) as f32;

            // --- eq. (5): local SGD step at every worker ----------------
            // (Stragglers compute too — they are just not waited for; the
            //  PS baselines discard non-participant updates below.)
            //
            // Fan out over the engine pool: every worker's batch comes
            // from its own sampler slot (drawn last iteration by the
            // prefetch tasks, or right now on the coordinator thread),
            // all gradients run in parallel into the per-worker leased
            // buffers, then updates and the loss reduction run in worker
            // order — bit-identical to the sequential loop this replaces.
            // With prefetch on, iteration k+1's batch draws ride the SAME
            // queue submission as k's gradient jobs and drain on spare
            // lanes; per-worker draw order is unchanged, so prefetch
            // on/off is bit-identical too.
            let bsz = self.cfg.batch_size;
            let batches: Vec<AnyBatch> = match self.prefetched.take() {
                Some(b) => b,
                None => self.sources.iter_mut().map(|s| s.next_train(bsz)).collect(),
            };
            let prefetch_now = self.cfg.prefetch && k < self.start_k + self.cfg.iters;
            let compute_span = crate::obs::span::enter(crate::obs::span::Phase::Compute);
            let ws: Vec<&[f32]> = (0..n).map(|j| self.params.get(j)).collect();
            let losses = if prefetch_now {
                let mut slots: Vec<Option<AnyBatch>> = (0..n).map(|_| None).collect();
                let losses = {
                    let mut tasks: Vec<_> = self
                        .sources
                        .iter_mut()
                        .zip(slots.iter_mut())
                        .map(|(src, slot)| {
                            move || -> anyhow::Result<()> {
                                *slot = Some(src.next_train(bsz));
                                Ok(())
                            }
                        })
                        .collect();
                    let pool = &self.pool;
                    let bufs = &mut self.grad_bufs;
                    pool.grad_many_overlapped(&ws, &batches, bufs, &mut tasks)?
                };
                let drawn: Vec<AnyBatch> = slots
                    .into_iter()
                    .map(|s| s.expect("prefetch task filled its slot"))
                    .collect();
                self.prefetched = Some(drawn);
                losses
            } else {
                self.pool.grad_many(&ws, &batches, &mut self.grad_bufs)?
            };
            drop(ws);
            let mut loss_sum = 0.0f64;
            for j in 0..n {
                loss_sum += losses[j] as f64;
                if !iter_plan.ps_style || iter_plan.active[j] {
                    vecmath::axpy(self.params.get_mut(j), -eta, &self.grad_bufs[j]);
                }
            }
            drop(compute_span);

            // --- eq. (6): mixing ----------------------------------------
            let mix_span = crate::obs::span::enter(crate::obs::span::Phase::Mix);
            if iter_plan.ps_style {
                // Exact averaging of participants, broadcast to everyone —
                // the dimension chunked across the pool's lanes
                // (bit-identical to the sequential reduction; see
                // `vecmath::mean_of_pooled`).
                let active_rows: Vec<&[f32]> = (0..n)
                    .filter(|&j| iter_plan.active[j])
                    .map(|j| self.params.get(j))
                    .collect();
                let avg = vecmath::mean_of_pooled(&active_rows, &self.pool)?;
                for j in 0..n {
                    self.params.get_mut(j).copy_from_slice(&avg);
                }
            } else {
                let p = ConsensusMatrix::metropolis(&self.graph, &iter_plan.active);
                debug_assert!(p.check_doubly_stochastic(1e-9).is_ok());
                // Pooled variants fan the per-worker row-sums over the
                // engine pool's lanes; with 1 lane they fall back to the
                // sequential loops. Either way the result is bit-identical.
                match self.compression.as_mut() {
                    Some(cs) => {
                        cs.wire_bytes += self.params.mix_compressed_pooled(
                            &p,
                            &*cs.comp,
                            &mut cs.efs,
                            &self.pool,
                        )?;
                    }
                    None => self.params.mix_pooled(&p, &self.pool)?,
                }
            }
            drop(mix_span);

            // --- bookkeeping --------------------------------------------
            self.clock += iter_plan.duration;
            self.last_k = k;
            let rec = IterRecord {
                k,
                duration: iter_plan.duration,
                clock: self.clock,
                train_loss: loss_sum / n as f64,
                active: iter_plan.active_count(),
                backup_avg: iter_plan.backup_avg(&self.graph),
                theta: iter_plan.theta,
            };
            if let Some(cb) = self.on_iter.as_mut() {
                cb(&rec);
            }
            history.iters.push(rec);

            if self.cfg.eval_every > 0 && k % self.cfg.eval_every == 0 {
                let _s = crate::obs::span::enter(crate::obs::span::Phase::Eval);
                let e = self.evaluate(k)?;
                history.evals.push(e);
            }

            if self.ckpt_every > 0 && k % self.ckpt_every == 0 {
                if let Some(mgr) = self.ckpt_mgr.as_ref() {
                    let _s = crate::obs::span::enter(crate::obs::span::Phase::Ckpt);
                    let mut c = super::checkpoint::Checkpoint::from_buffers(
                        k,
                        self.clock,
                        &self.ckpt_model,
                        &self.params,
                    );
                    c.history = history.clone();
                    mgr.save(&c)?;
                }
            }
            if self.kill_at == Some(k) {
                anyhow::bail!("killed at iteration {k} (kill_at fault injection)");
            }
        }
        Ok(history)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::partition::{split, Partition};
    use crate::data::synthetic::{gaussian_mixture, MixtureSpec};
    use crate::engine::{native_factory, DenseSource};
    use crate::graph::topology;
    use crate::model::ModelMeta;

    fn build_with_threads(algo: Algorithm, iters: usize, seed: u64, threads: usize) -> SimTrainer {
        let n = 6;
        let mut rng = Rng::new(seed);
        let g = topology::random_connected(n, 0.5, &mut rng);
        let meta = ModelMeta::lrm(8, 10, 64);
        let data = gaussian_mixture(&MixtureSpec::mnist_like(8, 3000), &mut rng);
        let (train, test) = data.split(2560);
        let shards = split(&train, n, Partition::Iid, &mut rng);
        let sources: Vec<Box<dyn BatchSource>> = shards
            .into_iter()
            .enumerate()
            .map(|(j, s)| Box::new(DenseSource::new(s, seed + j as u64)) as Box<dyn BatchSource>)
            .collect();
        let eval_batches: Vec<AnyBatch> = crate::data::batch::BatchSampler::full_batches(
            &test.subset(&(0..384).collect::<Vec<_>>()),
            64,
        )
        .into_iter()
        .map(AnyBatch::Dense)
        .collect();
        let pool = EnginePool::new(native_factory(meta.clone()), threads).unwrap();
        let straggler = StragglerModel::paper_default(n, &mut rng);
        let init = meta.init_params(&mut rng);
        let cfg = TrainConfig {
            iters,
            batch_size: 64,
            eval_every: 10,
            seed,
            ..Default::default()
        };
        SimTrainer::new(g, algo, cfg, straggler, pool, sources, eval_batches, init).unwrap()
    }

    fn build(algo: Algorithm, iters: usize, seed: u64) -> SimTrainer {
        build_with_threads(algo, iters, seed, 2)
    }

    #[test]
    fn cb_dybw_trains_and_records() {
        let mut t = build(Algorithm::CbDybw, 60, 1);
        let h = t.run().unwrap();
        assert_eq!(h.iters.len(), 60);
        assert!(h.evals.len() >= 6);
        // learning happened
        let first = h.evals.first().unwrap();
        let last = h.evals.last().unwrap();
        assert!(
            last.test_loss < first.test_loss * 0.8,
            "loss {} -> {}",
            first.test_loss,
            last.test_loss
        );
        // error drops below chance
        assert!(last.test_error < 0.5, "err {}", last.test_error);
        // dynamic backup workers actually engaged
        assert!(h.mean_backup_workers() > 0.1);
    }

    #[test]
    fn cb_full_trains_but_slower_clock() {
        let mut a = build(Algorithm::CbDybw, 50, 2);
        let mut b = build(Algorithm::CbFull, 50, 2);
        let ha = a.run().unwrap();
        let hb = b.run().unwrap();
        // Same iteration count, same convergence order, but DyBW's clock
        // advanced much less (the paper's headline effect).
        assert!(
            ha.total_time() < 0.7 * hb.total_time(),
            "dybw {}s vs full {}s",
            ha.total_time(),
            hb.total_time()
        );
        // full participation: zero backup workers
        assert!(hb.mean_backup_workers() < 1e-9);
    }

    #[test]
    fn ps_sync_equals_centralized_sgd_consensus() {
        let mut t = build(Algorithm::PsSync, 30, 3);
        let h = t.run().unwrap();
        // Exact averaging every round → consensus error stays ~0.
        let last = h.evals.last().unwrap();
        assert!(last.consensus_error < 1e-4, "{}", last.consensus_error);
        assert!(last.test_loss < h.evals[0].test_loss);
    }

    #[test]
    fn static_backup_reduces_duration() {
        let mut a = build(Algorithm::CbStaticBackup { b: 2 }, 40, 4);
        let mut b = build(Algorithm::CbFull, 40, 4);
        let ha = a.run().unwrap();
        let hb = b.run().unwrap();
        assert!(ha.mean_iter_duration() < hb.mean_iter_duration());
    }

    const ALL_ALGOS: [Algorithm; 5] = [
        Algorithm::CbDybw,
        Algorithm::CbFull,
        Algorithm::CbStaticBackup { b: 2 },
        Algorithm::PsSync,
        Algorithm::PsBackup { b: 1 },
    ];

    /// Run `algo` at 1 lane and at 4 lanes (optionally with compressed
    /// gossip) and assert the histories and final parameters are
    /// bit-for-bit identical — at 4 lanes BOTH the gradient fan-out and
    /// the eq. (6) mixing rows run pooled, so this covers the parallel
    /// mixing path end to end.
    fn assert_pool_size_invariant(algo: Algorithm, compressed: bool) {
        use crate::consensus::compress::TopK;
        let build = |threads: usize| {
            let mut t = build_with_threads(algo, 20, 31, threads);
            if compressed {
                let dim = t.params().dim();
                let n = t.params().n();
                let comp = Box::new(TopK { k: dim / 4 });
                t.compression = Some(CompressionState::new(comp, n, dim));
            }
            t
        };
        let mut t1 = build(1);
        let mut t4 = build(4);
        assert_eq!(t1.threads(), 1);
        assert_eq!(t4.threads(), 4);
        let h1 = t1.run().unwrap();
        let h4 = t4.run().unwrap();
        // every f64 in every iter/eval record, compared bit-for-bit
        assert!(
            h1.bits_eq(&h4),
            "{algo:?} (compressed={compressed}) history diverged across pool sizes"
        );
        let (p1, p4) = (t1.average_params(), t4.average_params());
        assert_eq!(p1.len(), p4.len());
        for (x, y) in p1.iter().zip(&p4) {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{algo:?} (compressed={compressed}) final params differ"
            );
        }
        if compressed {
            // wire accounting must not depend on the pool size either
            let (w1, w4) = (
                t1.compression.as_ref().unwrap().wire_bytes,
                t4.compression.as_ref().unwrap().wire_bytes,
            );
            assert_eq!(w1, w4, "{algo:?} wire bytes diverged across pool sizes");
        }
    }

    /// Satellite of the engine-pool refactor: the number of pool lanes
    /// must not change a single bit of the history — losses, clocks, and
    /// final parameters — for any of the five algorithms.
    #[test]
    fn pooled_run_bit_identical_to_single_thread_all_algorithms() {
        for algo in ALL_ALGOS {
            assert_pool_size_invariant(algo, false);
        }
    }

    /// Same invariant on the compressed eq. (6) branch: the pooled
    /// compress→reconstruct→row-sum phases must match the sequential
    /// loop bit for bit (and byte for byte on the wire counter).
    #[test]
    fn pooled_compressed_run_bit_identical_all_algorithms() {
        for algo in ALL_ALGOS {
            assert_pool_size_invariant(algo, true);
        }
    }

    /// Data-pipeline tentpole: drawing iteration k+1's batches on spare
    /// lanes while k's gradients run must not change a single bit of the
    /// 5-algorithm same-seed rerun — per-worker sampler streams advance
    /// once per iteration either way.
    #[test]
    fn prefetch_bit_identical_all_algorithms() {
        for algo in ALL_ALGOS {
            let run = |prefetch: bool| {
                let mut t = build_with_threads(algo, 20, 47, 4);
                t.cfg.prefetch = prefetch;
                let h = t.run().unwrap();
                (h, t.average_params())
            };
            let (h_on, p_on) = run(true);
            let (h_off, p_off) = run(false);
            assert!(h_on.bits_eq(&h_off), "{algo:?}: prefetch changed the history");
            assert_eq!(p_on.len(), p_off.len());
            for (x, y) in p_on.iter().zip(&p_off) {
                assert_eq!(x.to_bits(), y.to_bits(), "{algo:?}: prefetch changed final params");
            }
        }
    }

    #[test]
    fn checkpoint_stamps_actual_last_iteration() {
        // Before any run a checkpoint must stamp k=0, not cfg.iters.
        let t = build(Algorithm::CbDybw, 30, 18);
        assert_eq!(t.checkpoint("x").iteration, 0);
        // After running fewer iterations than originally configured, the
        // checkpoint stamps what actually completed.
        let mut t = build(Algorithm::CbDybw, 30, 18);
        t.cfg.iters = 12;
        t.run().unwrap();
        assert_eq!(t.checkpoint("x").iteration, 12);
    }

    #[test]
    fn deterministic_given_seed() {
        let h1 = build(Algorithm::CbDybw, 25, 7).run().unwrap();
        let h2 = build(Algorithm::CbDybw, 25, 7).run().unwrap();
        assert_eq!(h1.total_time(), h2.total_time());
        let e1 = h1.evals.last().unwrap();
        let e2 = h2.evals.last().unwrap();
        assert_eq!(e1.test_loss, e2.test_loss);
        assert_eq!(e1.test_error, e2.test_error);
    }

    #[test]
    fn consensus_error_stays_bounded() {
        let mut t = build(Algorithm::CbDybw, 80, 9);
        let h = t.run().unwrap();
        for e in &h.evals {
            assert!(e.consensus_error.is_finite());
            assert!(e.consensus_error < 10.0, "consensus diverged: {e:?}");
        }
    }

    #[test]
    fn checkpoint_resume_continues_training() {
        // run 40 iters, checkpoint, restore into a fresh trainer, run 20
        // more: counters continue and the loss keeps dropping.
        let mut a = build(Algorithm::CbDybw, 40, 12);
        let h1 = a.run().unwrap();
        let ckpt = a.checkpoint("lrm_test");
        assert_eq!(ckpt.iteration, 40);

        let mut b = build(Algorithm::CbDybw, 20, 12);
        b.restore(ckpt).unwrap();
        let h2 = b.run().unwrap();
        assert_eq!(h2.iters.first().unwrap().k, 41);
        assert_eq!(h2.iters.last().unwrap().k, 60);
        // resumed clock starts where the checkpoint left off
        assert!(h2.iters[0].clock > h1.total_time());
        // still learning (loss at resume <= initial-eval loss of run 1)
        let resumed_first = h2.evals.first().unwrap().test_loss;
        let original_first = h1.evals.first().unwrap().test_loss;
        assert!(resumed_first < original_first * 0.9);
    }

    /// The PR-8 recovery invariant: a run killed mid-flight and resumed
    /// from `ckpt_manager::latest()` in a fresh trainer produces a
    /// bit-identical history and final parameters to the uninterrupted
    /// same-seed run. Exercises the stream fast-forward in `restore` and
    /// the history carried inside checkpoints.
    #[test]
    fn kill_and_replay_is_bit_identical() {
        use crate::coordinator::ckpt_manager::CkptManager;
        let dir = std::env::temp_dir().join("dybw_sim_killreplay");
        let _ = std::fs::remove_dir_all(&dir);
        let mgr = CkptManager::new(&dir, 2).unwrap();

        let mut full = build(Algorithm::CbDybw, 30, 21);
        let h_full = full.run().unwrap();
        let p_full = full.average_params();

        // kill at iteration 10; checkpoints land at 4 and 8
        let mut killed = build(Algorithm::CbDybw, 30, 21);
        killed.ckpt_mgr = Some(mgr.clone());
        killed.ckpt_every = 4;
        killed.kill_at = Some(10);
        let err = killed.run().unwrap_err();
        assert!(err.to_string().contains("killed at iteration 10"), "{err}");

        // "new process": fresh same-seed trainer, restore newest intact
        let mut resumed = build(Algorithm::CbDybw, 30, 21);
        resumed.ckpt_mgr = Some(mgr);
        resumed.ckpt_every = 4;
        assert!(resumed.resume_latest().unwrap());
        assert_eq!(resumed.start_k(), 8);
        resumed.cfg.iters = 30 - 8;
        let h_res = resumed.run().unwrap();

        assert!(h_full.bits_eq(&h_res), "killed-and-replayed history diverged");
        let p_res = resumed.average_params();
        assert_eq!(p_full.len(), p_res.len());
        for (x, y) in p_full.iter().zip(&p_res) {
            assert_eq!(x.to_bits(), y.to_bits(), "replayed params diverged");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn checkpoint_restore_rejects_mismatch() {
        let a = build(Algorithm::CbDybw, 5, 13);
        let ckpt = a.checkpoint("x");
        let mut bad = ckpt.clone();
        bad.params.pop(); // wrong worker count
        let mut b = build(Algorithm::CbDybw, 5, 13);
        assert!(b.restore(bad).is_err());
    }

    #[test]
    fn trace_replay_gives_identical_timing_across_algorithms() {
        use crate::straggler::trace::{Trace, TraceReplay};
        let mut rng = Rng::new(14);
        let model = crate::straggler::StragglerModel::paper_default(6, &mut rng);
        let trace = Trace::record(&model, 30, &mut rng);

        let mut a = build(Algorithm::CbDybw, 30, 15);
        a.trace = Some(TraceReplay::new(trace.clone()).unwrap());
        let ha = a.run().unwrap();
        let mut b = build(Algorithm::CbFull, 30, 15);
        b.trace = Some(TraceReplay::new(trace.clone()).unwrap());
        let hb = b.run().unwrap();
        // cb-Full's durations must equal the trace's per-iteration max —
        // the A/B is variance-free.
        for (rec, row) in hb.iters.iter().zip(&trace.times) {
            let tmax = row.iter().copied().fold(0.0, f64::max);
            assert!((rec.duration - tmax).abs() < 1e-12);
        }
        // and DyBW is pathwise never slower (Corollary 4, per-draw)
        for (ra, rb) in ha.iters.iter().zip(&hb.iters) {
            assert!(ra.duration <= rb.duration + 1e-12);
        }
    }

    #[test]
    fn compressed_training_tracks_exact() {
        use crate::consensus::compress::TopK;
        use crate::coordinator::sim::CompressionState;
        let mut exact = build(Algorithm::CbDybw, 60, 16);
        let he = exact.run().unwrap();
        let mut comp = build(Algorithm::CbDybw, 60, 16);
        let dim = comp.params().dim();
        comp.compression = Some(CompressionState::new(
            Box::new(TopK { k: dim / 4 }),
            6,
            dim,
        ));
        let hc = comp.run().unwrap();
        let wire = comp.compression.as_ref().unwrap().wire_bytes;
        assert!(wire > 0);
        let (le, lc) = (
            he.final_eval().unwrap().test_loss,
            hc.final_eval().unwrap().test_loss,
        );
        assert!(
            lc < le * 1.25,
            "compressed training diverged: exact {le} vs compressed {lc}"
        );
    }
}
