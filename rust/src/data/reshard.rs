//! Consistent-hash resharding: move ~1/N of the shards on churn.
//!
//! [`partition`](super::partition) splits a dataset into per-worker index
//! sets once, up front. Under churn that is not enough: when a worker
//! joins or leaves, naively re-running the partitioner reshuffles almost
//! every shard. A [`HashRing`] with virtual nodes gives the standard
//! consistent-hashing guarantee instead — a single membership change
//! moves only the shards adjacent to the new/removed worker's ring
//! points, ~S/N of them, and nothing else.
//!
//! Everything is keyed off [`stream_seed`](crate::util::rng::stream_seed)
//! coordinates (worker id × vnode index, shard id), so the ring is a pure
//! function of `(seed, members)`: two processes that agree on those agree
//! on every shard placement without exchanging any state.
//!
//! Invariants asserted by the tests below:
//! - **Determinism**: same seed + same members ⇒ identical assignment.
//! - **Movement minimality (join)**: shards that move all move *to* the
//!   joining worker, and their count is ≤ ⌈S/N_new⌉ plus virtual-node
//!   slack.
//! - **Movement minimality (leave)**: exactly the departing worker's
//!   shards move; every other shard keeps its owner.

use crate::util::rng::stream_seed;

/// Tag for ring-point hashing (`b"RING"` as big-endian u32).
const RING_TAG: u32 = 0x5249_4E47;
/// Tag for shard-key hashing (`b"SHRD"`).
const SHARD_TAG: u32 = 0x5348_5244;

/// Default virtual nodes per worker. 64 keeps the max/mean load ratio
/// near 1.3 while the ring for 10^3 workers stays under a megabyte.
pub const DEFAULT_VNODES: usize = 64;

/// A consistent-hash ring mapping shard ids to worker ids.
///
/// Ring points are `(hash, worker)` pairs sorted by hash; a shard is
/// owned by the first ring point at or after its own hash (wrapping).
/// Ties on hash break toward the smaller worker id, so the assignment is
/// a total function even in the astronomically unlikely collision case.
#[derive(Debug, Clone)]
pub struct HashRing {
    seed: u64,
    vnodes: usize,
    /// Sorted by (hash, worker).
    points: Vec<(u64, u32)>,
}

fn point_hash(seed: u64, worker: u32, vnode: usize) -> u64 {
    stream_seed(seed, RING_TAG as u64, worker as u64, vnode as u64)
}

fn shard_hash(seed: u64, shard: usize) -> u64 {
    stream_seed(seed, SHARD_TAG as u64, shard as u64, 0)
}

impl HashRing {
    /// Build a ring over `members` with [`DEFAULT_VNODES`] per worker.
    pub fn new(seed: u64, members: &[u32]) -> HashRing {
        HashRing::with_vnodes(seed, members, DEFAULT_VNODES)
    }

    /// Build a ring with an explicit virtual-node count.
    pub fn with_vnodes(seed: u64, members: &[u32], vnodes: usize) -> HashRing {
        assert!(vnodes > 0, "a ring needs at least one vnode per worker");
        let mut ring = HashRing {
            seed,
            vnodes,
            points: Vec::with_capacity(members.len() * vnodes),
        };
        for &w in members {
            ring.insert_points(w);
        }
        ring.points.sort_unstable();
        ring
    }

    fn insert_points(&mut self, worker: u32) {
        for v in 0..self.vnodes {
            self.points.push((point_hash(self.seed, worker, v), worker));
        }
    }

    /// Number of distinct workers on the ring.
    pub fn members(&self) -> usize {
        self.points.len() / self.vnodes
    }

    /// Add a worker's virtual nodes. No-op if already present.
    pub fn add_worker(&mut self, worker: u32) {
        if self.points.binary_search(&(point_hash(self.seed, worker, 0), worker)).is_ok() {
            return;
        }
        self.insert_points(worker);
        self.points.sort_unstable();
    }

    /// Remove a worker's virtual nodes. No-op if absent.
    pub fn remove_worker(&mut self, worker: u32) {
        self.points.retain(|&(_, w)| w != worker);
    }

    /// Owner of one shard: successor ring point of the shard's hash.
    pub fn owner(&self, shard: usize) -> u32 {
        assert!(!self.points.is_empty(), "ring has no members");
        let h = shard_hash(self.seed, shard);
        let i = self.points.partition_point(|&(ph, _)| ph < h);
        let i = if i == self.points.len() { 0 } else { i };
        self.points[i].1
    }

    /// Owners of shards `0..shards`, as one vector.
    pub fn assignment(&self, shards: usize) -> Vec<u32> {
        (0..shards).map(|s| self.owner(s)).collect()
    }
}

/// Shards whose owner differs between two assignments, as
/// `(shard, old_owner, new_owner)` triples in shard order.
pub fn moved(before: &[u32], after: &[u32]) -> Vec<(usize, u32, u32)> {
    assert_eq!(before.len(), after.len());
    before
        .iter()
        .zip(after.iter())
        .enumerate()
        .filter(|(_, (b, a))| b != a)
        .map(|(s, (&b, &a))| (s, b, a))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SHARDS: usize = 512;

    fn members(n: u32) -> Vec<u32> {
        (0..n).collect()
    }

    #[test]
    fn deterministic_across_builds() {
        let a = HashRing::new(7, &members(8)).assignment(SHARDS);
        let b = HashRing::new(7, &members(8)).assignment(SHARDS);
        assert_eq!(a, b);
    }

    #[test]
    fn seed_changes_assignment() {
        let a = HashRing::new(7, &members(8)).assignment(SHARDS);
        let b = HashRing::new(8, &members(8)).assignment(SHARDS);
        assert_ne!(a, b);
    }

    #[test]
    fn member_order_is_irrelevant() {
        let fwd = HashRing::new(3, &members(6)).assignment(SHARDS);
        let rev: Vec<u32> = (0..6).rev().collect();
        let bwd = HashRing::new(3, &rev).assignment(SHARDS);
        assert_eq!(fwd, bwd);
    }

    #[test]
    fn every_member_owns_something() {
        let asn = HashRing::new(11, &members(8)).assignment(SHARDS);
        for w in 0..8u32 {
            assert!(asn.contains(&w), "worker {w} owns no shards");
        }
    }

    #[test]
    fn join_moves_only_to_the_new_worker_and_few_shards() {
        let mut ring = HashRing::new(42, &members(8));
        let before = ring.assignment(SHARDS);
        ring.add_worker(8);
        let after = ring.assignment(SHARDS);
        let mv = moved(&before, &after);
        assert!(!mv.is_empty(), "a joining worker should take some shards");
        for &(s, _, to) in &mv {
            assert_eq!(to, 8, "shard {s} moved to {to}, not the joiner");
        }
        // Expected share is S/9 ≈ 57; vnode imbalance at V=64 stays well
        // under 2x, and ⌈S/N⌉ + S/4 is the asserted envelope.
        let bound = SHARDS.div_ceil(9) + SHARDS / 4;
        assert!(mv.len() <= bound, "join moved {} shards (bound {bound})", mv.len());
    }

    #[test]
    fn leave_moves_exactly_the_departed_workers_shards() {
        let mut ring = HashRing::new(42, &members(9));
        let before = ring.assignment(SHARDS);
        ring.remove_worker(3);
        let after = ring.assignment(SHARDS);
        for (s, (&b, &a)) in before.iter().zip(after.iter()).enumerate() {
            if b == 3 {
                assert_ne!(a, 3, "shard {s} still on the departed worker");
            } else {
                assert_eq!(a, b, "shard {s} moved although its owner stayed");
            }
        }
    }

    #[test]
    fn join_then_leave_round_trips() {
        let mut ring = HashRing::new(9, &members(8));
        let before = ring.assignment(SHARDS);
        ring.add_worker(8);
        ring.remove_worker(8);
        assert_eq!(ring.assignment(SHARDS), before);
    }

    #[test]
    fn add_is_idempotent() {
        let mut ring = HashRing::new(5, &members(4));
        let n = ring.points.len();
        ring.add_worker(2);
        assert_eq!(ring.points.len(), n);
    }

    #[test]
    fn matches_fresh_build_after_churn() {
        // Incremental add/remove must land exactly where a from-scratch
        // build of the same membership lands.
        let mut ring = HashRing::new(13, &members(8));
        ring.remove_worker(2);
        ring.add_worker(9);
        let fresh: Vec<u32> = members(8).into_iter().filter(|&w| w != 2).chain([9]).collect();
        let rebuilt = HashRing::new(13, &fresh);
        assert_eq!(ring.assignment(SHARDS), rebuilt.assignment(SHARDS));
    }
}
