//! Partitioning D = ∪_j D_j across workers (paper §2.1 / Appendix B).
//!
//! The paper evenly partitions all training data among workers (6,000
//! MNIST / 5,000 CIFAR examples each) — the i.i.d. case. The analysis also
//! covers non-i.i.d. local datasets, so we provide the standard
//! label-shard split (each worker holds a few label shards, à la
//! McMahan et al.) and a Dirichlet split with tunable concentration.

use super::Dataset;
use crate::engine::EnginePool;
use crate::util::parse::ParseError;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Partition {
    /// Shuffle then even contiguous split — i.i.d. local datasets.
    Iid,
    /// Sort by label, cut into `2·workers` shards, deal 2 shards each —
    /// each worker sees only a couple of classes.
    LabelShards,
    /// Dirichlet(α) class mixture per worker; α→∞ ≈ i.i.d., α→0 extreme skew.
    Dirichlet { alpha: f64 },
}

impl Partition {
    /// The spec string [`Self::parse`] accepts back —
    /// `parse(&p.name()) == Ok(p)` for every value.
    pub fn name(&self) -> String {
        match *self {
            Partition::Iid => "iid".to_string(),
            Partition::LabelShards => "shards".to_string(),
            Partition::Dirichlet { alpha } => format!("dirichlet:{alpha}"),
        }
    }

    pub fn parse(s: &str) -> Result<Partition, ParseError> {
        const EXPECTED: &str = "iid | shards | dirichlet:<alpha>";
        if s == "iid" {
            return Ok(Partition::Iid);
        }
        if s == "shards" || s == "label_shards" {
            return Ok(Partition::LabelShards);
        }
        if let Some(a) = s.strip_prefix("dirichlet:") {
            if let Ok(alpha) = a.parse() {
                return Ok(Partition::Dirichlet { alpha });
            }
        }
        Err(ParseError::new("partition", s, EXPECTED))
    }
}

/// The RNG-consuming half of a split: the per-worker index sets.
fn split_indices(data: &Dataset, workers: usize, how: Partition, rng: &mut Rng) -> Vec<Vec<usize>> {
    match how {
        Partition::Iid => iid_indices(data.n(), workers, rng),
        Partition::LabelShards => shard_indices(data, workers, rng),
        Partition::Dirichlet { alpha } => dirichlet_indices(data, workers, alpha, rng),
    }
}

/// Split `data` into `workers` local datasets.
pub fn split(data: &Dataset, workers: usize, how: Partition, rng: &mut Rng) -> Vec<Dataset> {
    assert!(workers >= 1);
    let idx_sets = split_indices(data, workers, how, rng);
    idx_sets.iter().map(|idx| data.subset(idx)).collect()
}

/// [`split`] with the per-worker shard materialisation fanned over the
/// pool's lanes. The RNG-driven index computation stays on the caller
/// thread (identical stream consumption); only the row copying — pure
/// gathers into disjoint outputs — runs pooled, so the result is
/// bit-identical to the sequential split.
pub fn split_pooled(
    data: &Dataset,
    workers: usize,
    how: Partition,
    rng: &mut Rng,
    pool: &EnginePool,
) -> anyhow::Result<Vec<Dataset>> {
    assert!(workers >= 1);
    let idx_sets = split_indices(data, workers, how, rng);
    if pool.threads() <= 1 {
        return Ok(idx_sets.iter().map(|idx| data.subset(idx)).collect());
    }
    let mut slots: Vec<Option<Dataset>> = (0..workers).map(|_| None).collect();
    {
        let mut tasks: Vec<_> = slots
            .iter_mut()
            .zip(idx_sets.iter())
            .map(|(slot, idx)| {
                move || -> anyhow::Result<()> {
                    *slot = Some(data.subset(idx));
                    Ok(())
                }
            })
            .collect();
        pool.run_tasks(&mut tasks)?;
    }
    Ok(slots
        .into_iter()
        .map(|s| s.expect("split task filled its slot"))
        .collect())
}

fn iid_indices(n: usize, workers: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let base = n / workers;
    let extra = n % workers;
    let mut out = Vec::with_capacity(workers);
    let mut start = 0;
    for w in 0..workers {
        let take = base + usize::from(w < extra);
        out.push(idx[start..start + take].to_vec());
        start += take;
    }
    out
}

fn shard_indices(data: &Dataset, workers: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..data.n()).collect();
    idx.sort_by_key(|&i| data.y[i]);
    let shards_per_worker = 2usize;
    let n_shards = workers * shards_per_worker;
    let shard_len = data.n().div_ceil(n_shards);
    let mut shards: Vec<Vec<usize>> = idx.chunks(shard_len).map(|c| c.to_vec()).collect();
    // pad with empty shards if division was ragged
    while shards.len() < n_shards {
        shards.push(Vec::new());
    }
    let mut order: Vec<usize> = (0..n_shards).collect();
    rng.shuffle(&mut order);
    (0..workers)
        .map(|w| {
            let mut v: Vec<usize> = order[w * shards_per_worker..(w + 1) * shards_per_worker]
                .iter()
                .flat_map(|&s| shards[s].iter().copied())
                .collect();
            v.sort_unstable();
            v
        })
        .collect()
}

fn dirichlet_indices(
    data: &Dataset,
    workers: usize,
    alpha: f64,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    // Per class: draw worker proportions ~ Dirichlet(α) via normalized
    // Gamma(α, 1) samples (Marsaglia-Tsang would be overkill; for α ≥ 0.05
    // the sum-of-exponentials approximation below — Gamma via
    // Johnk/accept-reject fallback — is adequate: we use the simple
    // power-of-uniform trick for α<1 and sum of exponentials for integer
    // part).
    let gamma = |rng: &mut Rng, a: f64| -> f64 {
        // Johnk-ish: Gamma(a) = Gamma(a_int) + Gamma(a_frac)
        let mut x = 0.0;
        let ai = a.floor() as usize;
        for _ in 0..ai {
            x += rng.exponential(1.0);
        }
        let frac = a - ai as f64;
        if frac > 1e-9 {
            // Ahrens-Dieter GS for shape < 1
            loop {
                let u = rng.uniform();
                let v = rng.uniform().max(1e-300);
                let b = 1.0 + frac / std::f64::consts::E;
                let p = b * u;
                if p <= 1.0 {
                    let g = p.powf(1.0 / frac);
                    if v <= (-g).exp() {
                        x += g;
                        break;
                    }
                } else {
                    let g = -((b - p) / frac).ln();
                    if v <= g.powf(frac - 1.0) {
                        x += g;
                        break;
                    }
                }
            }
        }
        x
    };
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); workers];
    for c in 0..data.classes {
        let mut class_idx: Vec<usize> =
            (0..data.n()).filter(|&i| data.y[i] as usize == c).collect();
        rng.shuffle(&mut class_idx);
        let mut props: Vec<f64> = (0..workers).map(|_| gamma(rng, alpha).max(1e-12)).collect();
        let total: f64 = props.iter().sum();
        for p in props.iter_mut() {
            *p /= total;
        }
        let mut start = 0usize;
        for (w, p) in props.iter().enumerate() {
            let take = if w + 1 == workers {
                class_idx.len() - start
            } else {
                ((p * class_idx.len() as f64).round() as usize).min(class_idx.len() - start)
            };
            out[w].extend_from_slice(&class_idx[start..start + take]);
            start += take;
        }
    }
    for v in out.iter_mut() {
        v.sort_unstable();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{gaussian_mixture, MixtureSpec};

    fn data(n: usize, seed: u64) -> Dataset {
        gaussian_mixture(&MixtureSpec::mnist_like(8, n), &mut Rng::new(seed))
    }

    #[test]
    fn iid_split_covers_everything_evenly() {
        let d = data(1000, 0);
        let parts = split(&d, 6, Partition::Iid, &mut Rng::new(1));
        assert_eq!(parts.len(), 6);
        let total: usize = parts.iter().map(|p| p.n()).sum();
        assert_eq!(total, 1000);
        for p in &parts {
            assert!(p.n() == 166 || p.n() == 167);
        }
    }

    #[test]
    fn iid_partition_no_duplicates() {
        let d = data(300, 2);
        let parts = split(&d, 4, Partition::Iid, &mut Rng::new(3));
        // feature sums must add to the global sum (each row used once)
        let global: f64 = d.x.iter().map(|&v| v as f64).sum();
        let partsum: f64 = parts
            .iter()
            .map(|p| p.x.iter().map(|&v| v as f64).sum::<f64>())
            .sum();
        assert!((global - partsum).abs() < 1e-2);
    }

    #[test]
    fn iid_local_class_distribution_balanced() {
        let d = data(5000, 4);
        let parts = split(&d, 5, Partition::Iid, &mut Rng::new(5));
        for p in &parts {
            for &c in &p.class_counts() {
                // expected 100 per class per worker; loose bounds
                assert!(c > 50 && c < 160, "class count {c}");
            }
        }
    }

    #[test]
    fn shards_are_skewed() {
        let d = data(2000, 6);
        let parts = split(&d, 5, Partition::LabelShards, &mut Rng::new(7));
        let total: usize = parts.iter().map(|p| p.n()).sum();
        assert_eq!(total, 2000);
        // Each worker holds 2 shards of label-sorted data; a shard can
        // straddle class boundaries, so allow up to 6 — but the split must
        // be clearly non-i.i.d.: nobody sees all 10 classes, and on
        // average workers see few.
        let mut distinct_total = 0usize;
        for p in &parts {
            let distinct = p.class_counts().iter().filter(|&&c| c > 0).count();
            assert!(distinct <= 6, "worker saw {distinct} classes");
            distinct_total += distinct;
        }
        assert!(distinct_total as f64 / parts.len() as f64 <= 5.0);
    }

    #[test]
    fn dirichlet_small_alpha_is_skewed_large_alpha_balanced() {
        let d = data(4000, 8);
        let skewed = split(&d, 4, Partition::Dirichlet { alpha: 0.1 }, &mut Rng::new(9));
        let balanced = split(&d, 4, Partition::Dirichlet { alpha: 100.0 }, &mut Rng::new(9));
        let imbalance = |parts: &[Dataset]| -> f64 {
            parts
                .iter()
                .map(|p| {
                    let counts = p.class_counts();
                    let n = p.n().max(1) as f64;
                    // max class share
                    counts.iter().map(|&c| c as f64 / n).fold(0.0, f64::max)
                })
                .sum::<f64>()
                / parts.len() as f64
        };
        assert!(imbalance(&skewed) > imbalance(&balanced) + 0.1);
        let total: usize = skewed.iter().map(|p| p.n()).sum();
        assert_eq!(total, 4000);
    }

    #[test]
    fn parse_names() {
        assert_eq!(Partition::parse("iid"), Ok(Partition::Iid));
        assert_eq!(Partition::parse("shards"), Ok(Partition::LabelShards));
        assert_eq!(
            Partition::parse("dirichlet:0.5"),
            Ok(Partition::Dirichlet { alpha: 0.5 })
        );
        for p in [
            Partition::Iid,
            Partition::LabelShards,
            Partition::Dirichlet { alpha: 0.5 },
        ] {
            assert_eq!(Partition::parse(&p.name()), Ok(p), "name: {}", p.name());
        }
        for bad in ["nope", "", "dirichlet:x", "iid "] {
            let err = Partition::parse(bad).unwrap_err();
            assert_eq!(err.what, "partition", "input: {bad}");
            assert_eq!(err.input, bad);
        }
    }

    #[test]
    fn pooled_split_bit_identical_to_sequential() {
        let d = data(1100, 13);
        let pool = crate::engine::EnginePool::tasks_only(3).unwrap();
        for how in [
            Partition::Iid,
            Partition::LabelShards,
            Partition::Dirichlet { alpha: 0.3 },
        ] {
            let mut r_seq = Rng::new(21);
            let mut r_pool = Rng::new(21);
            let a = split(&d, 5, how, &mut r_seq);
            let b = split_pooled(&d, 5, how, &mut r_pool, &pool).unwrap();
            assert_eq!(a.len(), b.len());
            for (p, q) in a.iter().zip(&b) {
                assert_eq!(p.y, q.y, "{how:?}");
                assert_eq!(p.x, q.x, "{how:?}");
            }
            // the caller-visible stream continues identically
            for _ in 0..4 {
                assert_eq!(r_seq.next_u64(), r_pool.next_u64(), "{how:?}");
            }
        }
    }

    #[test]
    fn single_worker_gets_everything() {
        let d = data(100, 10);
        let parts = split(&d, 1, Partition::Iid, &mut Rng::new(11));
        assert_eq!(parts[0].n(), 100);
    }
}
