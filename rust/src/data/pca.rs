//! PCA dimensionality reduction (paper §5: MNIST 784 / CIFAR 3072 inputs
//! are PCA-reduced before training "to enhance the training efficiency").
//!
//! Implemented as mean-centering + top-k principal directions via power
//! iteration with Gram-deflation, computed directly against the data
//! matrix (two mat-vec passes per iteration) so the d×d covariance is
//! never materialised — that keeps CIFAR-scale d=3072 tractable.

use super::Dataset;
use crate::engine::EnginePool;
use crate::util::rng::Rng;

/// A fitted PCA transform.
#[derive(Debug, Clone)]
pub struct Pca {
    pub in_dim: usize,
    pub out_dim: usize,
    /// Feature means, len in_dim.
    pub mean: Vec<f32>,
    /// Row-major (out_dim × in_dim) projection, rows orthonormal.
    pub components: Vec<f32>,
    /// Explained variance per component (descending).
    pub variance: Vec<f64>,
}

impl Pca {
    /// Fit top-`k` components on `data` with `iters` power iterations each.
    pub fn fit(data: &Dataset, k: usize, iters: usize, rng: &mut Rng) -> Pca {
        let (n, d) = (data.n(), data.dim);
        assert!(k <= d && n > 1);
        // feature means
        let mut mean = vec![0.0f64; d];
        for i in 0..n {
            for (m, v) in mean.iter_mut().zip(data.row(i)) {
                *m += *v as f64;
            }
        }
        for m in mean.iter_mut() {
            *m /= n as f64;
        }
        let mean_f32: Vec<f32> = mean.iter().map(|&m| m as f32).collect();

        let mut components = Vec::with_capacity(k * d);
        let mut variance = Vec::with_capacity(k);
        let mut basis: Vec<Vec<f64>> = Vec::new();
        for _ in 0..k {
            // random start, orthogonal to found components
            let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            orthogonalize(&mut v, &basis);
            normalize(&mut v);
            let mut lambda = 0.0f64;
            for _ in 0..iters {
                // w = (1/n) Xᶜᵀ (Xᶜ v)  where Xᶜ is the centered data
                let mut w = vec![0.0f64; d];
                for i in 0..n {
                    let row = data.row(i);
                    let mut proj = 0.0f64;
                    for j in 0..d {
                        proj += (row[j] as f64 - mean[j]) * v[j];
                    }
                    for j in 0..d {
                        w[j] += proj * (row[j] as f64 - mean[j]);
                    }
                }
                for x in w.iter_mut() {
                    *x /= n as f64;
                }
                orthogonalize(&mut w, &basis);
                lambda = norm(&w);
                if lambda < 1e-12 {
                    break;
                }
                for x in w.iter_mut() {
                    *x /= lambda;
                }
                v = w;
            }
            variance.push(lambda);
            components.extend(v.iter().map(|&x| x as f32));
            basis.push(v);
        }
        Pca {
            in_dim: d,
            out_dim: k,
            mean: mean_f32,
            components,
            variance,
        }
    }

    /// The per-row-range projection kernel shared by the sequential and
    /// pooled transforms: fill `x_out.len() / out_dim` projected rows
    /// starting at dataset row `start`.
    fn transform_rows(&self, data: &Dataset, start: usize, x_out: &mut [f32]) {
        let rows = x_out.len() / self.out_dim;
        for r in 0..rows {
            let row = data.row(start + r);
            for c in 0..self.out_dim {
                let comp = &self.components[c * self.in_dim..(c + 1) * self.in_dim];
                let mut acc = 0.0f32;
                for j in 0..self.in_dim {
                    acc += (row[j] - self.mean[j]) * comp[j];
                }
                x_out[r * self.out_dim + c] = acc;
            }
        }
    }

    /// Project a dataset into the fitted subspace.
    pub fn transform(&self, data: &Dataset) -> Dataset {
        assert_eq!(data.dim, self.in_dim);
        let mut x = vec![0.0f32; data.n() * self.out_dim];
        self.transform_rows(data, 0, &mut x);
        Dataset {
            dim: self.out_dim,
            classes: data.classes,
            x,
            y: data.y.clone(),
        }
    }

    /// [`transform`](Self::transform) with row ranges fanned over the
    /// pool's lanes. Rows are independent (each projected row is a set of
    /// dot products against the fitted components, in unchanged per-row
    /// FP order) and ranges write disjoint output chunks, so the result
    /// is bit-identical to the sequential transform. (`fit` itself stays
    /// sequential: power iteration is a data dependence chain, and its
    /// accumulations are order-sensitive.)
    pub fn transform_pooled(&self, data: &Dataset, pool: &EnginePool) -> anyhow::Result<Dataset> {
        if pool.threads() <= 1 || self.out_dim == 0 || data.n() == 0 {
            return Ok(self.transform(data));
        }
        assert_eq!(data.dim, self.in_dim);
        let n = data.n();
        let mut x = vec![0.0f32; n * self.out_dim];
        let rows_per = n.div_ceil(pool.threads() * 4).max(1);
        {
            let mut tasks: Vec<_> = x
                .chunks_mut(rows_per * self.out_dim)
                .enumerate()
                .map(|(c, xc)| {
                    move || -> anyhow::Result<()> {
                        self.transform_rows(data, c * rows_per, xc);
                        Ok(())
                    }
                })
                .collect();
            pool.run_tasks(&mut tasks)?;
        }
        Ok(Dataset {
            dim: self.out_dim,
            classes: data.classes,
            x,
            y: data.y.clone(),
        })
    }
}

fn orthogonalize(v: &mut [f64], basis: &[Vec<f64>]) {
    for b in basis {
        let dot: f64 = v.iter().zip(b).map(|(a, c)| a * c).sum();
        for (x, c) in v.iter_mut().zip(b) {
            *x -= dot * c;
        }
    }
}

fn norm(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum::<f64>().sqrt()
}

fn normalize(v: &mut [f64]) {
    let n = norm(v);
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{gaussian_mixture, MixtureSpec};

    /// Build data with a known dominant direction.
    fn anisotropic(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0f32; n * d];
        for i in 0..n {
            let big = rng.normal() * 10.0; // huge variance along axis 0
            for j in 0..d {
                let noise = rng.normal() * 0.5;
                x[i * d + j] = (if j == 0 { big } else { 0.0 } + noise) as f32;
            }
        }
        Dataset {
            dim: d,
            classes: 2,
            x,
            y: vec![0; n],
        }
    }

    #[test]
    fn finds_dominant_direction() {
        let data = anisotropic(400, 8, 1);
        let pca = Pca::fit(&data, 2, 30, &mut Rng::new(2));
        // first component ≈ ±e0
        let c0 = &pca.components[0..8];
        assert!(c0[0].abs() > 0.99, "c0 = {c0:?}");
        assert!(pca.variance[0] > 10.0 * pca.variance[1]);
    }

    #[test]
    fn components_orthonormal() {
        let data = gaussian_mixture(&MixtureSpec::mnist_like(20, 500), &mut Rng::new(3));
        let pca = Pca::fit(&data, 5, 25, &mut Rng::new(4));
        for a in 0..5 {
            for b in a..5 {
                let dot: f64 = (0..20)
                    .map(|j| {
                        pca.components[a * 20 + j] as f64 * pca.components[b * 20 + j] as f64
                    })
                    .sum();
                if a == b {
                    assert!((dot - 1.0).abs() < 1e-3, "({a},{b}) dot={dot}");
                } else {
                    assert!(dot.abs() < 1e-3, "({a},{b}) dot={dot}");
                }
            }
        }
    }

    #[test]
    fn variances_descending() {
        let data = gaussian_mixture(&MixtureSpec::mnist_like(16, 400), &mut Rng::new(5));
        let pca = Pca::fit(&data, 6, 25, &mut Rng::new(6));
        for w in pca.variance.windows(2) {
            assert!(w[0] >= w[1] - 1e-9, "{:?}", pca.variance);
        }
    }

    #[test]
    fn transform_shape_and_centering() {
        let data = anisotropic(200, 10, 7);
        let pca = Pca::fit(&data, 3, 20, &mut Rng::new(8));
        let t = pca.transform(&data);
        assert_eq!(t.dim, 3);
        assert_eq!(t.n(), 200);
        // projected data is (approximately) mean-centered
        for c in 0..3 {
            let mean: f64 = (0..t.n()).map(|i| t.row(i)[c] as f64).sum::<f64>() / t.n() as f64;
            assert!(mean.abs() < 0.2, "component {c} mean {mean}");
        }
    }

    #[test]
    fn pooled_transform_bit_identical_to_sequential() {
        let data = gaussian_mixture(&MixtureSpec::mnist_like(24, 1019), &mut Rng::new(12));
        let pca = Pca::fit(&data, 7, 20, &mut Rng::new(13));
        let pool = crate::engine::EnginePool::tasks_only(3).unwrap();
        let a = pca.transform(&data);
        let b = pca.transform_pooled(&data, &pool).unwrap();
        assert_eq!(a.y, b.y);
        assert_eq!(a.x.len(), b.x.len());
        for (p, q) in a.x.iter().zip(&b.x) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
    }

    #[test]
    fn projection_preserves_class_structure() {
        // PCA to 8 dims should keep the mixture separable: nearest class
        // mean in PCA space still beats chance comfortably.
        let data = gaussian_mixture(&MixtureSpec::mnist_like(32, 1500), &mut Rng::new(9));
        let pca = Pca::fit(&data, 8, 25, &mut Rng::new(10));
        let proj = pca.transform(&data);
        // quick NCM accuracy in projected space
        let half = proj.n() / 2;
        let d = proj.dim;
        let mut means = vec![0.0f64; proj.classes * d];
        let mut counts = vec![0usize; proj.classes];
        for i in 0..half {
            let c = proj.y[i] as usize;
            counts[c] += 1;
            for (m, v) in means[c * d..(c + 1) * d].iter_mut().zip(proj.row(i)) {
                *m += *v as f64;
            }
        }
        for c in 0..proj.classes {
            for m in means[c * d..(c + 1) * d].iter_mut() {
                *m /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in half..proj.n() {
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..proj.classes {
                let dist: f64 = means[c * d..(c + 1) * d]
                    .iter()
                    .zip(proj.row(i))
                    .map(|(m, v)| (m - *v as f64).powi(2))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == proj.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / (proj.n() - half) as f64;
        assert!(acc > 0.5, "acc = {acc}");
    }
}
