//! Mini-batch sampling: the C_j(k) of eq. (4).
//!
//! Each worker draws a uniform mini-batch (with replacement across
//! iterations, without within a batch when possible) from its local shard
//! D_j. Batches are materialised into flat buffers matching the AOT
//! artifact input layout: `x: f32[B, D]` and one-hot `y: f32[B, C]`
//! (tokens `i32[B, T]` + one-hot `f32[B, T, V]` for the transformer).

use super::{Dataset, SeqDataset};
use crate::util::rng::Rng;

/// A classification batch in artifact layout.
#[derive(Debug, Clone)]
pub struct Batch {
    pub bsz: usize,
    pub dim: usize,
    pub classes: usize,
    /// f32[bsz, dim] row-major
    pub x: Vec<f32>,
    /// f32[bsz, classes] one-hot
    pub y1h: Vec<f32>,
    /// integer labels (for native-engine eval)
    pub y: Vec<u32>,
}

/// A token batch in artifact layout (LM: target = input shifted by one,
/// with the final target wrapping to token 0 — consistent train/eval).
#[derive(Debug, Clone)]
pub struct SeqBatch {
    pub bsz: usize,
    pub seq: usize,
    pub vocab: usize,
    /// i32[bsz, seq]
    pub tokens: Vec<i32>,
    /// f32[bsz, seq, vocab] one-hot of next-token targets
    pub y1h: Vec<f32>,
}

/// Sampler over a worker's local shard.
///
/// Each worker owns exactly one sampler (inside its `BatchSource` slot),
/// seeded from its own stream off the config seed. That ownership is a
/// correctness invariant for the batch prefetcher: a worker's draws form
/// one sequential RNG stream that advances once per iteration, whether
/// the draw happens on the coordinator thread or on a spare pool lane —
/// which is why prefetch on/off is bit-identical.
#[derive(Debug, Clone)]
pub struct BatchSampler {
    rng: Rng,
}

impl BatchSampler {
    pub fn new(seed: u64) -> Self {
        BatchSampler { rng: Rng::new(seed) }
    }

    /// Draw a batch of size `bsz`. If the shard is smaller than `bsz`,
    /// sampling is with replacement (the estimator in eq. (4) stays
    /// unbiased either way).
    pub fn sample(&mut self, data: &Dataset, bsz: usize) -> Batch {
        assert!(data.n() > 0, "empty shard");
        let idx: Vec<usize> = if data.n() >= bsz {
            self.rng.choose_k(data.n(), bsz)
        } else {
            (0..bsz).map(|_| self.rng.below(data.n())).collect()
        };
        let mut x = Vec::with_capacity(bsz * data.dim);
        let mut y1h = vec![0.0f32; bsz * data.classes];
        let mut y = Vec::with_capacity(bsz);
        for (row, &i) in idx.iter().enumerate() {
            x.extend_from_slice(data.row(i));
            let label = data.y[i];
            y1h[row * data.classes + label as usize] = 1.0;
            y.push(label);
        }
        Batch {
            bsz,
            dim: data.dim,
            classes: data.classes,
            x,
            y1h,
            y,
        }
    }

    /// Draw a whole dataset as consecutive batches (for evaluation).
    pub fn full_batches(data: &Dataset, bsz: usize) -> Vec<Batch> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < data.n() {
            let take = bsz.min(data.n() - i);
            let idx: Vec<usize> = (i..i + take).collect();
            let sub = data.subset(&idx);
            let mut x = sub.x.clone();
            // pad the tail batch by repeating the last row so artifact
            // shapes stay fixed; `valid` rows tracked by caller via y len
            let mut y1h = vec![0.0f32; bsz * data.classes];
            let mut y = sub.y.clone();
            for (row, &label) in sub.y.iter().enumerate() {
                y1h[row * data.classes + label as usize] = 1.0;
            }
            while y.len() < bsz {
                let last = (sub.n() - 1) * data.dim;
                let row_copy: Vec<f32> = sub.x[last..last + data.dim].to_vec();
                x.extend_from_slice(&row_copy);
                let label = *sub.y.last().unwrap();
                y1h[y.len() * data.classes + label as usize] = 1.0;
                y.push(label);
            }
            out.push(Batch {
                bsz,
                dim: data.dim,
                classes: data.classes,
                x,
                y1h,
                y,
            });
            i += take;
        }
        out
    }

    /// Draw a token batch for the LM workload.
    pub fn sample_seq(&mut self, data: &SeqDataset, bsz: usize) -> SeqBatch {
        assert!(data.n() > 0);
        let idx: Vec<usize> = if data.n() >= bsz {
            self.rng.choose_k(data.n(), bsz)
        } else {
            (0..bsz).map(|_| self.rng.below(data.n())).collect()
        };
        let (t, v) = (data.seq, data.vocab);
        let mut tokens = Vec::with_capacity(bsz * t);
        let mut y1h = vec![0.0f32; bsz * t * v];
        for (row, &i) in idx.iter().enumerate() {
            let seq = data.row(i);
            tokens.extend_from_slice(seq);
            for pos in 0..t {
                let target = if pos + 1 < t { seq[pos + 1] } else { 0 };
                y1h[row * t * v + pos * v + target as usize] = 1.0;
            }
        }
        SeqBatch {
            bsz,
            seq: t,
            vocab: v,
            tokens,
            y1h,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{gaussian_mixture, markov_sequences, MixtureSpec};

    fn data(n: usize) -> Dataset {
        gaussian_mixture(&MixtureSpec::mnist_like(6, n), &mut Rng::new(0))
    }

    #[test]
    fn batch_shapes() {
        let d = data(100);
        let mut s = BatchSampler::new(1);
        let b = s.sample(&d, 32);
        assert_eq!(b.x.len(), 32 * 6);
        assert_eq!(b.y1h.len(), 32 * 10);
        assert_eq!(b.y.len(), 32);
    }

    #[test]
    fn onehot_consistent_with_labels() {
        let d = data(50);
        let mut s = BatchSampler::new(2);
        let b = s.sample(&d, 16);
        for row in 0..16 {
            let hot: Vec<usize> = (0..10)
                .filter(|&c| b.y1h[row * 10 + c] == 1.0)
                .collect();
            assert_eq!(hot, vec![b.y[row] as usize]);
            let sum: f32 = b.y1h[row * 10..(row + 1) * 10].iter().sum();
            assert_eq!(sum, 1.0);
        }
    }

    #[test]
    fn small_shard_samples_with_replacement() {
        let d = data(5);
        let mut s = BatchSampler::new(3);
        let b = s.sample(&d, 64);
        assert_eq!(b.bsz, 64);
        assert_eq!(b.y.len(), 64);
    }

    #[test]
    fn batches_differ_across_draws() {
        let d = data(500);
        let mut s = BatchSampler::new(4);
        let a = s.sample(&d, 32);
        let b = s.sample(&d, 32);
        assert_ne!(a.x, b.x);
    }

    #[test]
    fn full_batches_cover_all_rows() {
        let d = data(70);
        let bs = BatchSampler::full_batches(&d, 32);
        assert_eq!(bs.len(), 3);
        let total: usize = bs.iter().map(|b| b.y.len()).sum();
        assert_eq!(total, 32 * 3); // padded
        // first 70 labels match the dataset
        let mut labels = Vec::new();
        for b in &bs {
            labels.extend_from_slice(&b.y);
        }
        assert_eq!(&labels[..70], &d.y[..]);
    }

    #[test]
    fn seq_batch_targets_shifted() {
        let sd = markov_sequences(8, 5, 20, &mut Rng::new(5));
        let mut s = BatchSampler::new(6);
        let b = s.sample_seq(&sd, 4);
        assert_eq!(b.tokens.len(), 4 * 5);
        assert_eq!(b.y1h.len(), 4 * 5 * 8);
        for row in 0..4 {
            for pos in 0..4 {
                let next = b.tokens[row * 5 + pos + 1] as usize;
                assert_eq!(b.y1h[row * 5 * 8 + pos * 8 + next], 1.0);
            }
        }
    }
}
