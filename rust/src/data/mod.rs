//! Data substrate: datasets, synthesis, PCA, partitioning, batching.
//!
//! The paper trains on MNIST and CIFAR-10, PCA-reduced, evenly partitioned
//! across workers. Real datasets are not available in this offline
//! environment, so [`synthetic`] generates Gaussian-mixture classification
//! data with MNIST-like / CIFAR-like difficulty profiles (see DESIGN.md
//! §Substitutions); [`pca`] implements the paper's PCA reduction;
//! [`partition`] implements the even i.i.d. split plus a non-i.i.d.
//! label-shard split (the analysis covers both); [`batch`] draws the
//! mini-batches C_j(k) of eq. (4).

pub mod batch;
pub mod partition;
pub mod pca;
pub mod reshard;
pub mod synthetic;

/// A dense classification dataset: row-major features + integer labels.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub dim: usize,
    pub classes: usize,
    /// len = n() * dim, row-major.
    pub x: Vec<f32>,
    /// len = n().
    pub y: Vec<u32>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.y.len()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.x[i * self.dim..(i + 1) * self.dim]
    }

    /// Split into (train, test) at `train_n` examples.
    pub fn split(mut self, train_n: usize) -> (Dataset, Dataset) {
        assert!(train_n <= self.n());
        let test_x = self.x.split_off(train_n * self.dim);
        let test_y = self.y.split_off(train_n);
        let test = Dataset {
            dim: self.dim,
            classes: self.classes,
            x: test_x,
            y: test_y,
        };
        (self, test)
    }

    /// Select rows by index into a new dataset.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut x = Vec::with_capacity(idx.len() * self.dim);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(self.row(i));
            y.push(self.y[i]);
        }
        Dataset {
            dim: self.dim,
            classes: self.classes,
            x,
            y,
        }
    }

    /// Per-class example counts.
    pub fn class_counts(&self) -> Vec<usize> {
        let mut c = vec![0usize; self.classes];
        for &y in &self.y {
            c[y as usize] += 1;
        }
        c
    }
}

/// Token-sequence dataset for the transformer workload.
#[derive(Debug, Clone)]
pub struct SeqDataset {
    pub vocab: usize,
    pub seq: usize,
    /// len = n() * seq; input tokens.
    pub tokens: Vec<i32>,
}

impl SeqDataset {
    pub fn n(&self) -> usize {
        if self.seq == 0 {
            0
        } else {
            self.tokens.len() / self.seq
        }
    }

    pub fn row(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.seq..(i + 1) * self.seq]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            dim: 2,
            classes: 2,
            x: vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0],
            y: vec![0, 1, 0],
        }
    }

    #[test]
    fn rows_and_counts() {
        let d = tiny();
        assert_eq!(d.n(), 3);
        assert_eq!(d.row(1), &[2.0, 3.0]);
        assert_eq!(d.class_counts(), vec![2, 1]);
    }

    #[test]
    fn split_sizes() {
        let (tr, te) = tiny().split(2);
        assert_eq!(tr.n(), 2);
        assert_eq!(te.n(), 1);
        assert_eq!(te.row(0), &[4.0, 5.0]);
    }

    #[test]
    fn subset_picks_rows() {
        let d = tiny();
        let s = d.subset(&[2, 0]);
        assert_eq!(s.n(), 2);
        assert_eq!(s.row(0), &[4.0, 5.0]);
        assert_eq!(s.y, vec![0, 0]);
    }
}
