//! Synthetic dataset generators (the offline stand-ins for MNIST/CIFAR-10).
//!
//! Gaussian-mixture classification: class c draws features from
//! N(μ_c, σ²I) with class means placed at distance `separation` on a
//! random orthant-ish layout. Two presets match the paper's two datasets
//! in *difficulty ordering* — the CIFAR-like preset has lower separation
//! and heavier within-class noise, so (like the paper's Fig. 1) its error
//! floor is markedly higher than the MNIST-like preset's. Sizes default to
//! the paper's: 60k/10k (MNIST-like), 50k/10k (CIFAR-like), scaled down by
//! callers that need speed.
//!
//! Both generators consume a FIXED number of RNG draws per example, so
//! each is split into a per-example-range kernel whose substream is
//! derived from a counter via [`Rng::at_offset`]: the `*_pooled`
//! variants fan those kernels over an
//! [`EnginePool`](crate::engine::EnginePool)'s lanes and are
//! **bit-identical** to the sequential generators (same draws, same
//! order, disjoint output ranges) — asserted by tests, and the reason
//! `Setup::build_data` can always take the pooled path.

use super::{Dataset, SeqDataset};
use crate::engine::EnginePool;
use crate::util::rng::Rng;

/// Gaussian-mixture generator parameters.
#[derive(Debug, Clone)]
pub struct MixtureSpec {
    pub dim: usize,
    pub classes: usize,
    pub n: usize,
    /// Distance scale between class means.
    pub separation: f64,
    /// Within-class standard deviation.
    pub noise: f64,
}

impl MixtureSpec {
    /// MNIST-like: well separated, easy for a linear model (paper reaches
    /// ~90% LRM accuracy).
    pub fn mnist_like(dim: usize, n: usize) -> Self {
        MixtureSpec {
            dim,
            classes: 10,
            n,
            separation: 3.0,
            noise: 1.0,
        }
    }

    /// CIFAR-like: overlapping classes, hard for a linear model (paper's
    /// LRM test error stays ~60-70%).
    pub fn cifar_like(dim: usize, n: usize) -> Self {
        MixtureSpec {
            dim,
            classes: 10,
            n,
            separation: 0.9,
            noise: 1.3,
        }
    }
}

/// RNG draws (`next_u64` calls) one mixture example consumes: one class
/// pick plus `dim` Box–Muller normals (two draws each). Fixed per
/// example, which is what lets a range [a, b) derive its exact substream
/// via [`Rng::at_offset`].
fn mixture_draws_per_example(dim: usize) -> u64 {
    1 + 2 * dim as u64
}

/// Draw the class means (the sequential prefix both paths share).
fn mixture_means(spec: &MixtureSpec, rng: &mut Rng) -> Vec<f32> {
    let mut means = vec![0.0f32; spec.classes * spec.dim];
    for c in 0..spec.classes {
        for d in 0..spec.dim {
            means[c * spec.dim + d] =
                (rng.normal() * spec.separation / (spec.dim as f64).sqrt()) as f32;
        }
    }
    means
}

/// The per-example-range kernel: fill `y.len()` examples, consuming
/// `rng` sequentially (exactly `y.len() * mixture_draws_per_example`
/// draws). `x` must hold `y.len() * dim` floats.
fn fill_mixture_rows(
    spec: &MixtureSpec,
    means: &[f32],
    rng: &mut Rng,
    x: &mut [f32],
    y: &mut [u32],
) {
    let dim = spec.dim;
    debug_assert_eq!(x.len(), y.len() * dim);
    for (yi, row) in y.iter_mut().zip(x.chunks_exact_mut(dim)) {
        let c = rng.below(spec.classes);
        *yi = c as u32;
        let mu = &means[c * dim..(c + 1) * dim];
        for (r, m) in row.iter_mut().zip(mu) {
            *r = *m + (rng.normal() * spec.noise) as f32;
        }
    }
}

/// Generate a mixture dataset. Class means are unit-ish random Gaussian
/// directions scaled by `separation`; features add N(0, noise²) noise.
pub fn gaussian_mixture(spec: &MixtureSpec, rng: &mut Rng) -> Dataset {
    let means = mixture_means(spec, rng);
    let mut x = vec![0.0f32; spec.n * spec.dim];
    let mut y = vec![0u32; spec.n];
    fill_mixture_rows(spec, &means, rng, &mut x, &mut y);
    Dataset {
        dim: spec.dim,
        classes: spec.classes,
        x,
        y,
    }
}

/// [`gaussian_mixture`] with the per-example-range kernels fanned over
/// the pool's lanes. Bit-identical to the sequential generator: every
/// range starts from the exact substream the sequential pass would have
/// reached ([`Rng::at_offset`]), writes a disjoint slice of `x`/`y`, and
/// `rng` is left at the same post-generation state.
pub fn gaussian_mixture_pooled(
    spec: &MixtureSpec,
    rng: &mut Rng,
    pool: &EnginePool,
) -> anyhow::Result<Dataset> {
    if pool.threads() <= 1 || spec.dim == 0 || spec.n == 0 {
        return Ok(gaussian_mixture(spec, rng));
    }
    let means = mixture_means(spec, rng);
    let base = rng.clone();
    let per = mixture_draws_per_example(spec.dim);
    let dim = spec.dim;
    let mut x = vec![0.0f32; spec.n * dim];
    let mut y = vec![0u32; spec.n];
    let rows_per = spec.n.div_ceil(pool.threads() * 4).max(1);
    {
        let means = &means[..];
        let base = &base;
        let mut tasks: Vec<_> = x
            .chunks_mut(rows_per * dim)
            .zip(y.chunks_mut(rows_per))
            .enumerate()
            .map(|(i, (xc, yc))| {
                move || -> anyhow::Result<()> {
                    let start = i * rows_per;
                    let mut r = base.at_offset(start as u64 * per);
                    fill_mixture_rows(spec, means, &mut r, xc, yc);
                    Ok(())
                }
            })
            .collect();
        pool.run_tasks(&mut tasks)?;
    }
    *rng = base.at_offset(spec.n as u64 * per);
    Ok(Dataset {
        dim: spec.dim,
        classes: spec.classes,
        x,
        y,
    })
}

/// RNG draws one Markov sequence consumes: one start-token pick plus one
/// uniform per step. Fixed per sequence (the transition-row scan spends
/// no randomness), so sequence ranges jump via [`Rng::at_offset`] too.
fn markov_draws_per_sequence(seq: usize) -> u64 {
    1 + seq as u64
}

/// Row-stochastic transition matrix concentrated on a band of 4 tokens
/// (the sequential prefix both paths share).
fn markov_transitions(vocab: usize, rng: &mut Rng) -> Vec<f64> {
    let band = 4usize.min(vocab);
    let mut trans = vec![0.0f64; vocab * vocab];
    for a in 0..vocab {
        let mut weights = vec![0.0f64; vocab];
        let mut total = 0.0;
        for off in 0..band {
            let b = (a + 1 + off * 3) % vocab;
            let w = rng.uniform_in(0.5, 1.5);
            weights[b] += w;
            total += w;
        }
        // small uniform smoothing
        for (b, w) in weights.iter_mut().enumerate() {
            trans[a * vocab + b] = (*w + 0.02) / (total + 0.02 * vocab as f64);
        }
    }
    trans
}

/// The per-sequence-range kernel: fill `tokens.len() / seq` sequences,
/// consuming `rng` sequentially.
fn fill_markov_rows(trans: &[f64], vocab: usize, seq: usize, rng: &mut Rng, tokens: &mut [i32]) {
    assert!(seq > 0, "sequence length must be positive");
    debug_assert_eq!(tokens.len() % seq, 0);
    for row in tokens.chunks_exact_mut(seq) {
        let mut cur = rng.below(vocab);
        for slot in row.iter_mut() {
            *slot = cur as i32;
            // sample next from transition row
            let mut u = rng.uniform();
            let trow = &trans[cur * vocab..(cur + 1) * vocab];
            let mut next = vocab - 1;
            for (b, &p) in trow.iter().enumerate() {
                if u < p {
                    next = b;
                    break;
                }
                u -= p;
            }
            cur = next;
        }
    }
}

/// Markov-chain token sequences for the transformer workload: a random
/// banded transition matrix gives the LM a learnable structure (loss can
/// fall well below log(vocab)).
pub fn markov_sequences(vocab: usize, seq: usize, n: usize, rng: &mut Rng) -> SeqDataset {
    assert!(vocab >= 2);
    let trans = markov_transitions(vocab, rng);
    let mut tokens = vec![0i32; n * seq];
    fill_markov_rows(&trans, vocab, seq, rng, &mut tokens);
    SeqDataset { vocab, seq, tokens }
}

/// [`markov_sequences`] with the sequence ranges fanned over the pool's
/// lanes — bit-identical to the sequential generator (same substream
/// derivation as [`gaussian_mixture_pooled`]).
pub fn markov_sequences_pooled(
    vocab: usize,
    seq: usize,
    n: usize,
    rng: &mut Rng,
    pool: &EnginePool,
) -> anyhow::Result<SeqDataset> {
    assert!(vocab >= 2);
    if pool.threads() <= 1 || seq == 0 || n == 0 {
        return Ok(markov_sequences(vocab, seq, n, rng));
    }
    let trans = markov_transitions(vocab, rng);
    let base = rng.clone();
    let per = markov_draws_per_sequence(seq);
    let mut tokens = vec![0i32; n * seq];
    let rows_per = n.div_ceil(pool.threads() * 4).max(1);
    {
        let trans = &trans[..];
        let base = &base;
        let mut tasks: Vec<_> = tokens
            .chunks_mut(rows_per * seq)
            .enumerate()
            .map(|(i, tc)| {
                move || -> anyhow::Result<()> {
                    let start = i * rows_per;
                    let mut r = base.at_offset(start as u64 * per);
                    fill_markov_rows(trans, vocab, seq, &mut r, tc);
                    Ok(())
                }
            })
            .collect();
        pool.run_tasks(&mut tasks)?;
    }
    *rng = base.at_offset(n as u64 * per);
    Ok(SeqDataset { vocab, seq, tokens })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixture_shapes_and_label_range() {
        let mut rng = Rng::new(0);
        let d = gaussian_mixture(&MixtureSpec::mnist_like(16, 500), &mut rng);
        assert_eq!(d.n(), 500);
        assert_eq!(d.dim, 16);
        assert!(d.y.iter().all(|&y| (y as usize) < d.classes));
        // all classes present with high probability
        assert!(d.class_counts().iter().all(|&c| c > 10));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gaussian_mixture(&MixtureSpec::mnist_like(8, 100), &mut Rng::new(7));
        let b = gaussian_mixture(&MixtureSpec::mnist_like(8, 100), &mut Rng::new(7));
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn mnist_like_is_linearly_separable_ish() {
        // Nearest-class-mean classifier must beat 70% on the easy preset
        // and do markedly worse on the hard preset.
        let easy = eval_ncm(&MixtureSpec::mnist_like(32, 2000), 11);
        let hard = eval_ncm(&MixtureSpec::cifar_like(32, 2000), 11);
        assert!(easy > 0.7, "easy acc = {easy}");
        assert!(hard < easy - 0.15, "hard={hard} easy={easy}");
    }

    fn eval_ncm(spec: &MixtureSpec, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let d = gaussian_mixture(spec, &mut rng);
        // estimate class means from first half, evaluate on second half
        let half = d.n() / 2;
        let mut means = vec![0.0f64; d.classes * d.dim];
        let mut counts = vec![0usize; d.classes];
        for i in 0..half {
            let c = d.y[i] as usize;
            counts[c] += 1;
            for (m, v) in means[c * d.dim..(c + 1) * d.dim].iter_mut().zip(d.row(i)) {
                *m += *v as f64;
            }
        }
        for c in 0..d.classes {
            if counts[c] > 0 {
                for m in means[c * d.dim..(c + 1) * d.dim].iter_mut() {
                    *m /= counts[c] as f64;
                }
            }
        }
        let mut correct = 0usize;
        for i in half..d.n() {
            let row = d.row(i);
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..d.classes {
                let dist: f64 = means[c * d.dim..(c + 1) * d.dim]
                    .iter()
                    .zip(row)
                    .map(|(m, v)| (m - *v as f64).powi(2))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == d.y[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / (d.n() - half) as f64
    }

    #[test]
    fn pooled_mixture_bit_identical_to_sequential() {
        // Deliberately ragged n (not a multiple of the range size) and a
        // multi-lane pool: every range must land on the exact substream
        // the sequential pass would have reached.
        let spec = MixtureSpec::cifar_like(9, 1037);
        let pool = crate::engine::EnginePool::tasks_only(3).unwrap();
        let mut r_seq = Rng::new(77);
        let mut r_pool = Rng::new(77);
        let a = gaussian_mixture(&spec, &mut r_seq);
        let b = gaussian_mixture_pooled(&spec, &mut r_pool, &pool).unwrap();
        assert_eq!(a.y, b.y);
        assert_eq!(a.x.len(), b.x.len());
        for (p, q) in a.x.iter().zip(&b.x) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
        // the caller-visible stream continues identically after either path
        for _ in 0..8 {
            assert_eq!(r_seq.next_u64(), r_pool.next_u64());
        }
    }

    #[test]
    fn pooled_markov_bit_identical_to_sequential() {
        let pool = crate::engine::EnginePool::tasks_only(4).unwrap();
        let mut r_seq = Rng::new(31);
        let mut r_pool = Rng::new(31);
        let a = markov_sequences(32, 16, 201, &mut r_seq);
        let b = markov_sequences_pooled(32, 16, 201, &mut r_pool, &pool).unwrap();
        assert_eq!(a.tokens, b.tokens);
        for _ in 0..8 {
            assert_eq!(r_seq.next_u64(), r_pool.next_u64());
        }
    }

    #[test]
    fn pooled_generators_fall_back_on_single_lane() {
        let pool = crate::engine::EnginePool::tasks_only(1).unwrap();
        let spec = MixtureSpec::mnist_like(8, 100);
        let a = gaussian_mixture(&spec, &mut Rng::new(5));
        let b = gaussian_mixture_pooled(&spec, &mut Rng::new(5), &pool).unwrap();
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn markov_tokens_in_range() {
        let mut rng = Rng::new(3);
        let s = markov_sequences(32, 16, 50, &mut rng);
        assert_eq!(s.n(), 50);
        assert!(s.tokens.iter().all(|&t| t >= 0 && (t as usize) < 32));
    }

    #[test]
    fn markov_has_structure() {
        // The banded chain makes some bigrams much more common than the
        // uniform baseline.
        let mut rng = Rng::new(5);
        let v = 16;
        let s = markov_sequences(v, 64, 200, &mut rng);
        let mut bigrams = vec![0usize; v * v];
        for i in 0..s.n() {
            let row = s.row(i);
            for w in row.windows(2) {
                bigrams[w[0] as usize * v + w[1] as usize] += 1;
            }
        }
        let total: usize = bigrams.iter().sum();
        let max = *bigrams.iter().max().unwrap();
        // uniform would put ~total/v² in each cell; structure ⇒ >> that
        assert!(max as f64 > 4.0 * total as f64 / (v * v) as f64);
    }
}
