//! Synthetic dataset generators (the offline stand-ins for MNIST/CIFAR-10).
//!
//! Gaussian-mixture classification: class c draws features from
//! N(μ_c, σ²I) with class means placed at distance `separation` on a
//! random orthant-ish layout. Two presets match the paper's two datasets
//! in *difficulty ordering* — the CIFAR-like preset has lower separation
//! and heavier within-class noise, so (like the paper's Fig. 1) its error
//! floor is markedly higher than the MNIST-like preset's. Sizes default to
//! the paper's: 60k/10k (MNIST-like), 50k/10k (CIFAR-like), scaled down by
//! callers that need speed.

use super::{Dataset, SeqDataset};
use crate::util::rng::Rng;

/// Gaussian-mixture generator parameters.
#[derive(Debug, Clone)]
pub struct MixtureSpec {
    pub dim: usize,
    pub classes: usize,
    pub n: usize,
    /// Distance scale between class means.
    pub separation: f64,
    /// Within-class standard deviation.
    pub noise: f64,
}

impl MixtureSpec {
    /// MNIST-like: well separated, easy for a linear model (paper reaches
    /// ~90% LRM accuracy).
    pub fn mnist_like(dim: usize, n: usize) -> Self {
        MixtureSpec {
            dim,
            classes: 10,
            n,
            separation: 3.0,
            noise: 1.0,
        }
    }

    /// CIFAR-like: overlapping classes, hard for a linear model (paper's
    /// LRM test error stays ~60-70%).
    pub fn cifar_like(dim: usize, n: usize) -> Self {
        MixtureSpec {
            dim,
            classes: 10,
            n,
            separation: 0.9,
            noise: 1.3,
        }
    }
}

/// Generate a mixture dataset. Class means are unit-ish random Gaussian
/// directions scaled by `separation`; features add N(0, noise²) noise.
pub fn gaussian_mixture(spec: &MixtureSpec, rng: &mut Rng) -> Dataset {
    let MixtureSpec {
        dim,
        classes,
        n,
        separation,
        noise,
    } = *spec;
    // class means
    let mut means = vec![0.0f32; classes * dim];
    for c in 0..classes {
        for d in 0..dim {
            means[c * dim + d] = (rng.normal() * separation / (dim as f64).sqrt()) as f32;
        }
    }
    let mut x = vec![0.0f32; n * dim];
    let mut y = vec![0u32; n];
    for i in 0..n {
        let c = rng.below(classes);
        y[i] = c as u32;
        let mu = &means[c * dim..(c + 1) * dim];
        let row = &mut x[i * dim..(i + 1) * dim];
        for (r, m) in row.iter_mut().zip(mu) {
            *r = *m + (rng.normal() * noise) as f32;
        }
    }
    Dataset {
        dim,
        classes,
        x,
        y,
    }
}

/// Markov-chain token sequences for the transformer workload: a random
/// banded transition matrix gives the LM a learnable structure (loss can
/// fall well below log(vocab)).
pub fn markov_sequences(vocab: usize, seq: usize, n: usize, rng: &mut Rng) -> SeqDataset {
    assert!(vocab >= 2);
    // Row-stochastic transition matrix concentrated on a band of 4 tokens.
    let band = 4usize.min(vocab);
    let mut trans = vec![0.0f64; vocab * vocab];
    for a in 0..vocab {
        let mut weights = vec![0.0f64; vocab];
        let mut total = 0.0;
        for off in 0..band {
            let b = (a + 1 + off * 3) % vocab;
            let w = rng.uniform_in(0.5, 1.5);
            weights[b] += w;
            total += w;
        }
        // small uniform smoothing
        for (b, w) in weights.iter_mut().enumerate() {
            trans[a * vocab + b] = (*w + 0.02) / (total + 0.02 * vocab as f64);
        }
    }
    let mut tokens = Vec::with_capacity(n * seq);
    for _ in 0..n {
        let mut cur = rng.below(vocab);
        for _ in 0..seq {
            tokens.push(cur as i32);
            // sample next from transition row
            let mut u = rng.uniform();
            let row = &trans[cur * vocab..(cur + 1) * vocab];
            let mut next = vocab - 1;
            for (b, &p) in row.iter().enumerate() {
                if u < p {
                    next = b;
                    break;
                }
                u -= p;
            }
            cur = next;
        }
    }
    SeqDataset { vocab, seq, tokens }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixture_shapes_and_label_range() {
        let mut rng = Rng::new(0);
        let d = gaussian_mixture(&MixtureSpec::mnist_like(16, 500), &mut rng);
        assert_eq!(d.n(), 500);
        assert_eq!(d.dim, 16);
        assert!(d.y.iter().all(|&y| (y as usize) < d.classes));
        // all classes present with high probability
        assert!(d.class_counts().iter().all(|&c| c > 10));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gaussian_mixture(&MixtureSpec::mnist_like(8, 100), &mut Rng::new(7));
        let b = gaussian_mixture(&MixtureSpec::mnist_like(8, 100), &mut Rng::new(7));
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn mnist_like_is_linearly_separable_ish() {
        // Nearest-class-mean classifier must beat 70% on the easy preset
        // and do markedly worse on the hard preset.
        let easy = eval_ncm(&MixtureSpec::mnist_like(32, 2000), 11);
        let hard = eval_ncm(&MixtureSpec::cifar_like(32, 2000), 11);
        assert!(easy > 0.7, "easy acc = {easy}");
        assert!(hard < easy - 0.15, "hard={hard} easy={easy}");
    }

    fn eval_ncm(spec: &MixtureSpec, seed: u64) -> f64 {
        let mut rng = Rng::new(seed);
        let d = gaussian_mixture(spec, &mut rng);
        // estimate class means from first half, evaluate on second half
        let half = d.n() / 2;
        let mut means = vec![0.0f64; d.classes * d.dim];
        let mut counts = vec![0usize; d.classes];
        for i in 0..half {
            let c = d.y[i] as usize;
            counts[c] += 1;
            for (m, v) in means[c * d.dim..(c + 1) * d.dim].iter_mut().zip(d.row(i)) {
                *m += *v as f64;
            }
        }
        for c in 0..d.classes {
            if counts[c] > 0 {
                for m in means[c * d.dim..(c + 1) * d.dim].iter_mut() {
                    *m /= counts[c] as f64;
                }
            }
        }
        let mut correct = 0usize;
        for i in half..d.n() {
            let row = d.row(i);
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..d.classes {
                let dist: f64 = means[c * d.dim..(c + 1) * d.dim]
                    .iter()
                    .zip(row)
                    .map(|(m, v)| (m - *v as f64).powi(2))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == d.y[i] as usize {
                correct += 1;
            }
        }
        correct as f64 / (d.n() - half) as f64
    }

    #[test]
    fn markov_tokens_in_range() {
        let mut rng = Rng::new(3);
        let s = markov_sequences(32, 16, 50, &mut rng);
        assert_eq!(s.n(), 50);
        assert!(s.tokens.iter().all(|&t| t >= 0 && (t as usize) < 32));
    }

    #[test]
    fn markov_has_structure() {
        // The banded chain makes some bigrams much more common than the
        // uniform baseline.
        let mut rng = Rng::new(5);
        let v = 16;
        let s = markov_sequences(v, 64, 200, &mut rng);
        let mut bigrams = vec![0usize; v * v];
        for i in 0..s.n() {
            let row = s.row(i);
            for w in row.windows(2) {
                bigrams[w[0] as usize * v + w[1] as usize] += 1;
            }
        }
        let total: usize = bigrams.iter().sum();
        let max = *bigrams.iter().max().unwrap();
        // uniform would put ~total/v² in each cell; structure ⇒ >> that
        assert!(max as f64 > 4.0 * total as f64 / (v * v) as f64);
    }
}
