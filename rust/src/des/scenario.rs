//! Scenario harness: declarative JSON → a policy sweep on one identical
//! timing realisation.
//!
//! A scenario names a cluster (size, topology, compute-time model,
//! per-link latency, injected heterogeneity) and a list of wait
//! policies. The harness records ONE timing trace (or loads a CSV) and
//! replays it under every policy, so the sweep is a variance-free A/B on
//! the exact same realisation — the strongest form of the paper's
//! comparisons, now on the asynchronous timeline. Timing-only scenarios
//! scale to 10^5–10^6 workers (event log streamed to disk, never
//! buffered); full-fidelity scenarios run real gradients through
//! [`Setup`]'s model/data wiring.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::coordinator::setup::Setup;
use crate::graph::topology::{self, Topology};
use crate::metrics::export;
use crate::straggler::link::LinkModel;
use crate::straggler::trace::Trace;
use crate::straggler::{Dist, StragglerModel};
use crate::util::json::Json;
use crate::util::parse::ParseError;
use crate::util::rng::Rng;

use super::cluster::{ClusterSim, ClusterStats, ComputeTimes, FaultPlan, NoHooks};
use super::full::RecoveryOpts;
use super::policy::WaitPolicy;

/// Simulation fidelity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fidelity {
    /// No gradients: pure schedule. Scales to thousands of workers.
    Timing,
    /// Real gradients through the engine pool (bit-reproducible).
    Full,
}

impl Fidelity {
    pub fn name(&self) -> &'static str {
        match self {
            Fidelity::Timing => "timing",
            Fidelity::Full => "full",
        }
    }

    /// Round-trip contract: `parse(f.name()) == Ok(f)` for every
    /// fidelity; anything else is a typed [`ParseError`].
    pub fn parse(s: &str) -> Result<Fidelity, ParseError> {
        match s {
            "timing" => Ok(Fidelity::Timing),
            "full" => Ok(Fidelity::Full),
            _ => Err(ParseError::new("fidelity", s, "timing | full")),
        }
    }
}

/// Declarative churn/fault schedule, compiled to a [`FaultPlan`] of
/// per-worker membership events on the DES calendar. Everything is
/// scheduled up front at known virtual times, so faulty runs keep the
/// byte-identical-event-log reproducibility contract.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ScenarioFaults {
    /// Workers absent at t = 0 (each needs a later `joins` entry).
    pub initially_down: Vec<usize>,
    /// (worker, time): the worker (re)joins the cluster.
    pub joins: Vec<(usize, f64)>,
    /// (worker, time): the worker leaves; terminal when no later join.
    pub leaves: Vec<(usize, f64)>,
    /// (a, b, from, to): the edge a–b is partitioned on [from, to);
    /// messages queue (store-and-forward) and drain at heal time.
    pub partitions: Vec<(usize, usize, f64, f64)>,
    /// (rack, from, to): correlated outage — every worker in the rack
    /// (per [`topology::rack_slices`]) leaves at `from`, rejoins at
    /// `to`. Only valid on a `racks:<r>` topology.
    pub rack_outages: Vec<(usize, f64, f64)>,
}

impl ScenarioFaults {
    pub fn is_empty(&self) -> bool {
        self.initially_down.is_empty()
            && self.joins.is_empty()
            && self.leaves.is_empty()
            && self.partitions.is_empty()
            && self.rack_outages.is_empty()
    }

    /// Expand the declarative schedule into raw membership events.
    /// Index/window errors are caught here (and again by the DES, which
    /// additionally checks partitioned pairs are graph edges).
    pub fn compile(&self, topology: Topology, workers: usize) -> anyhow::Result<FaultPlan> {
        fn check(w: usize, t: f64, workers: usize, what: &str) -> anyhow::Result<()> {
            anyhow::ensure!(w < workers, "{what} worker index {w} >= workers {workers}");
            anyhow::ensure!(t.is_finite() && t >= 0.0, "{what} time must be finite and >= 0");
            Ok(())
        }
        let mut plan = FaultPlan {
            initially_down: self.initially_down.clone(),
            ..FaultPlan::default()
        };
        for &w in &self.initially_down {
            anyhow::ensure!(w < workers, "initially_down worker index {w} >= workers {workers}");
        }
        for &(w, t) in &self.joins {
            check(w, t, workers, "joins")?;
            plan.ups.push((w, t));
        }
        for &(w, t) in &self.leaves {
            check(w, t, workers, "leaves")?;
            plan.downs.push((w, t));
        }
        for &(a, b, from, to) in &self.partitions {
            check(a, from, workers, "partitions")?;
            check(b, to, workers, "partitions")?;
            anyhow::ensure!(to > from, "partition window on {a}-{b} must have to > from");
            plan.link_downs.push((a, b, from));
            plan.link_ups.push((a, b, to));
        }
        if !self.rack_outages.is_empty() {
            let Topology::Racks(r) = topology else {
                anyhow::bail!(
                    "rack_outages need a racks:<r> topology (scenario has {})",
                    topology.name()
                );
            };
            let slices = topology::rack_slices(workers, r);
            for &(rack, from, to) in &self.rack_outages {
                anyhow::ensure!(
                    rack < slices.len(),
                    "rack outage rack {rack} >= racks {}",
                    slices.len()
                );
                anyhow::ensure!(
                    from.is_finite() && from >= 0.0 && to.is_finite() && to > from,
                    "rack {rack} outage window must have 0 <= from < to"
                );
                for w in slices[rack].clone() {
                    plan.downs.push((w, from));
                    plan.ups.push((w, to));
                }
            }
        }
        Ok(plan)
    }
}

/// One declarative DES experiment.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub workers: usize,
    pub topology: Topology,
    /// Live-driver liveness probe interval in seconds (0 = the driver's
    /// default). The DES schedules faults at known virtual times and so
    /// detects them instantly; this knob shapes the live mirror of the
    /// scenario (`dybw live --chaos`), kept here so one file configures
    /// both worlds.
    pub heartbeat_secs: f64,
    /// How long a disconnected live worker keeps retrying its rejoin.
    pub rejoin_timeout_secs: f64,
    pub iters: usize,
    pub seed: u64,
    pub fidelity: Fidelity,
    pub policies: Vec<WaitPolicy>,
    /// Base compute-time distribution (ignored when `trace_file` set).
    pub compute: Dist,
    /// Worker-scale spread: scales drawn uniform in [1−h, 1+h].
    pub hetero: f64,
    pub transient_prob: f64,
    pub transient_factor: f64,
    /// Diurnal swing amplitude in [0, 1): compute times are multiplied
    /// by 1 + amp·sin(2πk/period). 0 disables.
    pub diurnal_amp: f64,
    /// Diurnal period in iterations (must be > 0 when amp > 0).
    pub diurnal_period: f64,
    /// Persistent stragglers: (worker, factor).
    pub persistent: Vec<(usize, f64)>,
    pub link_base: f64,
    pub link_jitter: Option<Dist>,
    /// Heterogeneous links: (a, b, factor) on both directions.
    pub slow_links: Vec<(usize, usize, f64)>,
    /// Replay this CSV instead of recording from the model.
    pub trace_file: Option<PathBuf>,
    /// Scheduled churn/partition/outage events (empty = no faults).
    pub faults: ScenarioFaults,
    // full-fidelity knobs (ignored in timing mode)
    pub model: String,
    pub train_n: usize,
    pub test_n: usize,
    pub eval_every: usize,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            name: "ring-1k".into(),
            workers: 1000,
            topology: Topology::Ring,
            heartbeat_secs: 0.0,
            rejoin_timeout_secs: 60.0,
            iters: 30,
            seed: 2021,
            fidelity: Fidelity::Timing,
            policies: vec![
                WaitPolicy::Full,
                WaitPolicy::Static { b: 1 },
                WaitPolicy::Dybw,
            ],
            compute: Dist::ShiftedExp { base: 0.08, rate: 25.0 },
            hetero: 0.2,
            transient_prob: 0.15,
            transient_factor: 4.0,
            diurnal_amp: 0.0,
            diurnal_period: 0.0,
            persistent: Vec::new(),
            link_base: 0.002,
            link_jitter: Some(Dist::ShiftedExp { base: 0.0, rate: 800.0 }),
            slow_links: Vec::new(),
            trace_file: None,
            faults: ScenarioFaults::default(),
            model: "lrm_d64_c10_b256".into(),
            train_n: 12_000,
            test_n: 2_048,
            eval_every: 10,
        }
    }
}

impl Scenario {
    pub fn load(path: &Path) -> anyhow::Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read scenario {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("bad scenario JSON: {e}"))?;
        Scenario::from_json(&j)
    }

    /// Defaults overridden by whatever fields the JSON provides.
    /// Strict: unknown keys and present-but-mistyped values are errors —
    /// a scenario file must never silently run something other than
    /// what it describes.
    ///
    /// The schema nests settings into sections:
    ///
    /// ```text
    /// { "name": ..., "iters": ..., "seed": ..., "fidelity": ...,
    ///   "policies": [...],
    ///   "cluster":  { "workers", "topology", "heartbeat_secs",
    ///                 "rejoin_timeout_secs" },
    ///   "timing":   { "compute", "hetero", "transient_prob",
    ///                 "transient_factor", "diurnal_amp",
    ///                 "diurnal_period", "persistent", "trace_file" },
    ///   "links":    { "base", "jitter", "slow_links" },
    ///   "faults":   { "initially_down", "joins", "leaves",
    ///                 "partitions", "rack_outages" },
    ///   "training": { "model", "train_n", "test_n", "eval_every" } }
    /// ```
    ///
    /// The pre-PR-8 flat keys (`workers`, `compute`, `link_base`, …)
    /// still parse — with a deprecation warning on stderr — so old
    /// scenario files keep working; nested sections take precedence
    /// when both spellings appear.
    pub fn from_json(j: &Json) -> anyhow::Result<Scenario> {
        const KNOWN: &[&str] = &[
            "name", "iters", "seed", "fidelity", "policies", "cluster", "timing", "links",
            "faults", "training",
        ];
        const LEGACY: &[&str] = &[
            "workers", "topology", "compute", "hetero", "transient_prob", "transient_factor",
            "diurnal_amp", "diurnal_period", "persistent", "link_base", "link_jitter",
            "slow_links", "trace_file", "model", "train_n", "test_n", "eval_every",
        ];
        let Json::Obj(map) = j else {
            anyhow::bail!("scenario must be a JSON object");
        };
        for key in map.keys() {
            anyhow::ensure!(
                KNOWN.contains(&key.as_str()) || LEGACY.contains(&key.as_str()),
                "unknown scenario field '{key}' (top-level: {KNOWN:?})"
            );
        }
        let legacy: Vec<&str> = LEGACY
            .iter()
            .copied()
            .filter(|k| map.contains_key(*k))
            .collect();
        if !legacy.is_empty() {
            crate::warn_!(
                "scenario",
                "legacy flat fields {legacy:?}; nest them under \
                 cluster/timing/links/training (run `dybw des template` for the schema)"
            );
        }
        let mut s = Scenario::default();
        if let Some(v) = field(j, "name", Json::as_str, "a string")? {
            s.name = v.to_string();
        }
        if let Some(v) = field(j, "iters", Json::as_usize, "an integer")? {
            s.iters = v;
        }
        if let Some(v) = j.get("seed") {
            // exact for ALL u64 seeds: numbers are f64-backed, so large
            // seeds must travel as strings (to_json writes them so)
            s.seed = match (v.as_str(), v.as_f64()) {
                (Some(txt), _) => txt
                    .parse::<u64>()
                    .map_err(|e| anyhow::anyhow!("bad seed '{txt}': {e}"))?,
                (None, Some(num)) => {
                    anyhow::ensure!(
                        num >= 0.0 && num.fract() == 0.0 && num <= (1u64 << 53) as f64,
                        "numeric seed {num} is not an exact non-negative integer — \
                         write seeds above 2^53 as strings"
                    );
                    num as u64
                }
                (None, None) => anyhow::bail!("seed must be an integer or a decimal string"),
            };
        }
        if let Some(v) = field(j, "fidelity", Json::as_str, "\"timing\" or \"full\"")? {
            s.fidelity = Fidelity::parse(v)?;
        }
        if let Some(arr) = field(j, "policies", Json::as_arr, "an array of policy names")? {
            s.policies = arr
                .iter()
                .map(|p| {
                    let spec = p
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("bad policy {p:?}"))?;
                    Ok(WaitPolicy::parse(spec)?)
                })
                .collect::<anyhow::Result<_>>()?;
        }
        // legacy flat keys first, nested sections on top (nested wins)
        apply_cluster(&mut s, j)?;
        apply_timing(&mut s, j)?;
        apply_links(&mut s, j, "link_base", "link_jitter")?;
        apply_training(&mut s, j)?;
        if let Some(sec) = section(
            j,
            "cluster",
            &["workers", "topology", "heartbeat_secs", "rejoin_timeout_secs"],
        )? {
            apply_cluster(&mut s, sec)?;
        }
        if let Some(sec) = section(
            j,
            "timing",
            &[
                "compute", "hetero", "transient_prob", "transient_factor", "diurnal_amp",
                "diurnal_period", "persistent", "trace_file",
            ],
        )? {
            apply_timing(&mut s, sec)?;
        }
        if let Some(sec) = section(j, "links", &["base", "jitter", "slow_links"])? {
            apply_links(&mut s, sec, "base", "jitter")?;
        }
        if let Some(sec) = section(
            j,
            "faults",
            &["initially_down", "joins", "leaves", "partitions", "rack_outages"],
        )? {
            apply_faults(&mut s, sec)?;
        }
        if let Some(sec) = section(j, "training", &["model", "train_n", "test_n", "eval_every"])? {
            apply_training(&mut s, sec)?;
        }
        s.validate()?;
        Ok(s)
    }

    /// Reject scenarios that would corrupt the virtual-time schedule
    /// (negative latencies/durations schedule events into the past) or
    /// silently differ from what the file describes. Checked after
    /// loading AND again at run time, because the CLI can override
    /// fields (e.g. shrink `workers` under an injection target).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.workers >= 2, "need >= 2 workers");
        anyhow::ensure!(self.iters >= 1, "need >= 1 iteration");
        anyhow::ensure!(!self.policies.is_empty(), "need >= 1 policy");
        anyhow::ensure!((0.0..1.0).contains(&self.hetero), "hetero must be in [0, 1)");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.transient_prob),
            "transient_prob must be in [0, 1]"
        );
        anyhow::ensure!(
            self.transient_factor.is_finite() && self.transient_factor > 0.0,
            "transient_factor must be positive"
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&self.diurnal_amp),
            "diurnal_amp must be in [0, 1) — amplitudes >= 1 make compute times non-positive"
        );
        anyhow::ensure!(
            self.diurnal_period.is_finite() && self.diurnal_period >= 0.0,
            "diurnal_period must be >= 0"
        );
        anyhow::ensure!(
            self.diurnal_amp == 0.0 || self.diurnal_period > 0.0,
            "diurnal_amp > 0 needs diurnal_period > 0"
        );
        anyhow::ensure!(
            self.link_base.is_finite() && self.link_base >= 0.0,
            "link_base must be >= 0"
        );
        anyhow::ensure!(
            self.heartbeat_secs.is_finite() && self.heartbeat_secs >= 0.0,
            "heartbeat_secs must be >= 0"
        );
        anyhow::ensure!(
            self.rejoin_timeout_secs.is_finite() && self.rejoin_timeout_secs >= 0.0,
            "rejoin_timeout_secs must be >= 0"
        );
        anyhow::ensure!(
            self.compute.nonnegative(),
            "compute dist can sample negative times: {}",
            self.compute.spec()
        );
        if let Some(d) = &self.link_jitter {
            anyhow::ensure!(
                d.nonnegative(),
                "link_jitter dist can sample negative latency: {}",
                d.spec()
            );
        }
        for &(w, f) in &self.persistent {
            anyhow::ensure!(
                w < self.workers,
                "persistent straggler index {w} >= workers {}",
                self.workers
            );
            anyhow::ensure!(f.is_finite() && f > 0.0, "persistent factor must be positive");
        }
        // typed slow_links checks (range, factor, duplicate edges) live
        // on the model itself so every constructor path shares them
        self.link_model().validate(self.workers)?;
        // fault indices/windows/topology constraints (compiled again at
        // run time; the DES additionally checks partitioned edges exist)
        self.faults.compile(self.topology, self.workers)?;
        Ok(())
    }

    /// Emit the nested schema (the only one `des template` prints;
    /// legacy flat keys are parse-only).
    pub fn to_json(&self) -> Json {
        let mut cluster = Json::obj();
        cluster
            .set("workers", self.workers.into())
            .set("topology", self.topology.name().into())
            .set("heartbeat_secs", self.heartbeat_secs.into())
            .set("rejoin_timeout_secs", self.rejoin_timeout_secs.into());

        let mut timing = Json::obj();
        timing
            .set("compute", self.compute.spec().into())
            .set("hetero", self.hetero.into())
            .set("transient_prob", self.transient_prob.into())
            .set("transient_factor", self.transient_factor.into())
            .set("diurnal_amp", self.diurnal_amp.into())
            .set("diurnal_period", self.diurnal_period.into())
            .set(
                "persistent",
                Json::Arr(
                    self.persistent
                        .iter()
                        .map(|&(w, f)| Json::Arr(vec![(w).into(), f.into()]))
                        .collect(),
                ),
            );
        if let Some(p) = &self.trace_file {
            timing.set("trace_file", p.display().to_string().into());
        }

        let mut links = Json::obj();
        links
            .set("base", self.link_base.into())
            .set(
                "jitter",
                match &self.link_jitter {
                    Some(d) => d.spec().into(),
                    None => "none".into(),
                },
            )
            .set(
                "slow_links",
                Json::Arr(
                    self.slow_links
                        .iter()
                        .map(|&(a, b, f)| Json::Arr(vec![a.into(), b.into(), f.into()]))
                        .collect(),
                ),
            );

        let mut training = Json::obj();
        training
            .set("model", self.model.as_str().into())
            .set("train_n", self.train_n.into())
            .set("test_n", self.test_n.into())
            .set("eval_every", self.eval_every.into());

        let mut o = Json::obj();
        o.set("name", self.name.as_str().into())
            .set("iters", self.iters.into())
            // string, not number: JSON numbers are f64-backed, which
            // would corrupt seeds above 2^53 on a round trip
            .set("seed", self.seed.to_string().into())
            .set("fidelity", self.fidelity.name().into())
            .set(
                "policies",
                self.policies.iter().map(|p| p.name()).collect::<Vec<_>>().into(),
            )
            .set("cluster", cluster)
            .set("timing", timing)
            .set("links", links)
            .set("training", training);
        if !self.faults.is_empty() {
            let pair = |w: usize, t: f64| Json::Arr(vec![w.into(), t.into()]);
            let mut f = Json::obj();
            f.set(
                "initially_down",
                Json::Arr(self.faults.initially_down.iter().map(|&w| w.into()).collect()),
            )
            .set(
                "joins",
                Json::Arr(self.faults.joins.iter().map(|&(w, t)| pair(w, t)).collect()),
            )
            .set(
                "leaves",
                Json::Arr(self.faults.leaves.iter().map(|&(w, t)| pair(w, t)).collect()),
            )
            .set(
                "partitions",
                Json::Arr(
                    self.faults
                        .partitions
                        .iter()
                        .map(|&(a, b, from, to)| {
                            Json::Arr(vec![a.into(), b.into(), from.into(), to.into()])
                        })
                        .collect(),
                ),
            )
            .set(
                "rack_outages",
                Json::Arr(
                    self.faults
                        .rack_outages
                        .iter()
                        .map(|&(r, from, to)| Json::Arr(vec![r.into(), from.into(), to.into()]))
                        .collect(),
                ),
            );
            o.set("faults", f);
        }
        o
    }

    /// The straggler model the scenario describes (used to record the
    /// shared trace when no CSV is given; the async figure harness
    /// reuses it so its N-sweep matches the sweep's model exactly).
    pub(crate) fn straggler_model(&self, rng: &mut Rng) -> StragglerModel {
        let mut m = StragglerModel {
            base: self.compute,
            worker_scale: (0..self.workers)
                .map(|_| rng.uniform_in(1.0 - self.hetero, 1.0 + self.hetero))
                .collect(),
            persistent: vec![1.0; self.workers],
            transient_prob: self.transient_prob,
            transient_factor: self.transient_factor,
            force_one_straggler: self.transient_prob > 0.0,
            outages: Vec::new(),
            diurnal_amp: self.diurnal_amp,
            diurnal_period: self.diurnal_period,
        };
        for &(w, f) in &self.persistent {
            m.persistent[w] = f;
        }
        m
    }

    pub(crate) fn link_model(&self) -> LinkModel {
        let mut l = LinkModel::new(self.link_base, self.link_jitter, self.seed);
        for &(a, b, f) in &self.slow_links {
            l = l.with_slow_link(a, b, f);
        }
        l
    }

    /// The shared timing realisation every policy replays.
    fn build_trace(&self, rng: &mut Rng) -> anyhow::Result<Arc<Trace>> {
        let trace = match &self.trace_file {
            Some(p) => {
                let t = Trace::load_csv(p)?;
                anyhow::ensure!(
                    t.workers == self.workers,
                    "trace has {} workers, scenario {}",
                    t.workers,
                    self.workers
                );
                t
            }
            None => Trace::record(&self.straggler_model(rng), self.iters, rng),
        };
        Ok(Arc::new(trace))
    }

    /// Run the sweep. Writes per-policy summaries under `out_dir`; when
    /// `export_events` is set, appends every policy's deterministic
    /// event log to that file (the CI reproducibility artifact).
    pub fn run(&self, out_dir: &Path, export_events: Option<&Path>) -> anyhow::Result<String> {
        self.run_with_recovery(out_dir, export_events, None)
    }

    /// Like [`Scenario::run`], with checkpoint/kill/resume wiring for
    /// the full-fidelity path (see [`RecoveryOpts`]).
    pub fn run_with_recovery(
        &self,
        out_dir: &Path,
        export_events: Option<&Path>,
        recovery: Option<RecoveryOpts>,
    ) -> anyhow::Result<String> {
        self.validate()?;
        if recovery.is_some() {
            anyhow::ensure!(
                self.fidelity == Fidelity::Full,
                "checkpoint/recovery needs a full-fidelity scenario (this one is {})",
                self.fidelity.name()
            );
            anyhow::ensure!(
                self.policies.len() == 1,
                "checkpoint/recovery needs exactly one policy (scenario sweeps {})",
                self.policies.len()
            );
        }
        match self.fidelity {
            Fidelity::Timing => self.run_timing(out_dir, export_events),
            Fidelity::Full => self.run_full(out_dir, export_events, recovery),
        }
    }

    fn run_timing(&self, out_dir: &Path, export_events: Option<&Path>) -> anyhow::Result<String> {
        let mut rng = Rng::new(self.seed);
        let graph = topology::build(self.topology, self.workers, &mut rng);
        let trace = self.build_trace(&mut rng)?;
        let link = self.link_model();
        let fault_plan = self.faults.compile(self.topology, self.workers)?;
        let mut out = format!(
            "=== DES scenario '{}' (timing-only, {} workers, {}, {} iters/worker) ===\n",
            self.name,
            self.workers,
            self.topology.name(),
            self.iters
        );
        out.push_str(&format!(
            "{:>10} | {:>11} {:>11} {:>10} {:>8} {:>10} {:>9} {:>8} {:>8}\n",
            "policy",
            "makespan",
            "mean T(k)",
            "mean wait",
            "mean b",
            "cover-miss",
            "messages",
            "max-lag",
            "p50 fin"
        ));
        // the event log streams straight to the file (never buffered in
        // memory — at 10^6 workers a Vec<String> log would dwarf the
        // simulator state); the one BufWriter is threaded through every
        // policy's run via stream_log/take_sink
        let mut sink: Option<Box<dyn std::io::Write + Send>> = match export_events {
            Some(p) => {
                if let Some(dir) = p.parent() {
                    std::fs::create_dir_all(dir)?;
                }
                let f = std::fs::File::create(p)?;
                Some(Box::new(std::io::BufWriter::new(f)))
            }
            None => None,
        };
        let mut summary = Json::obj();
        for &policy in &self.policies {
            let mut sim = ClusterSim::new(
                graph.clone(),
                policy,
                self.iters,
                ComputeTimes::Replay(trace.clone()),
                link.clone(),
            )?;
            sim.set_faults(fault_plan.clone());
            if let Some(mut w) = sink.take() {
                use std::io::Write;
                writeln!(w, "# scenario={} policy={}", self.name, policy.name())?;
                sim.stream_log(w);
            }
            let stats = sim.run(&mut NoHooks)?;
            out.push_str(&render_stats_row(&stats));
            if export_events.is_some() {
                sink = sim.take_sink()?;
                anyhow::ensure!(sink.is_some(), "event-log sink lost during run");
            }
            summary.set(&policy.name(), stats_json(&stats));
        }
        drop(sink); // BufWriter flushed by take_sink; close before returning
        out.push_str(
            "(cover-miss > 0 ⇒ the policy left a neighbour unheard for 2·deg straight\n \
             iterations — the Assumption-2 connectivity cb-DyBW keeps for free)\n",
        );
        std::fs::create_dir_all(out_dir)?;
        std::fs::write(
            out_dir.join(format!("des.{}.summary.json", self.name)),
            summary.to_string_pretty(),
        )?;
        Ok(out)
    }

    fn run_full(
        &self,
        out_dir: &Path,
        export_events: Option<&Path>,
        recovery: Option<RecoveryOpts>,
    ) -> anyhow::Result<String> {
        let mut setup = Setup::default();
        setup.workers = self.workers;
        setup.topology = self.topology;
        setup.model = self.model.clone();
        setup.train_n = self.train_n;
        setup.test_n = self.test_n;
        setup.straggler_base = self.compute;
        setup.straggler_factor = self.transient_factor;
        setup.force_straggler = self.transient_prob > 0.0;
        setup.train.iters = self.iters;
        setup.train.eval_every = self.eval_every;
        setup.train.seed = self.seed;
        // the scenario's own trace (heterogeneity, persistent stragglers,
        // CSV replay) is handed straight to build_des_with_times — the
        // Setup never records one of its own
        let mut rng = Rng::new(self.seed);
        let _ = topology::build(self.topology, self.workers, &mut rng);
        let trace = self.build_trace(&mut rng)?;
        let link = self.link_model();
        let fault_plan = self.faults.compile(self.topology, self.workers)?;

        let mut out = format!(
            "=== DES scenario '{}' (full fidelity, {} workers, {}, {} iters/worker) ===\n",
            self.name,
            self.workers,
            self.topology.name(),
            self.iters
        );
        out.push_str(&format!(
            "{:>10} | {:>11} {:>10} {:>8} {:>12} {:>12} {:>12}\n",
            "policy", "makespan", "mean wait", "mean b", "final loss", "final err%", "consensus"
        ));
        let mut log_out = String::new();
        for &policy in &self.policies {
            let mut trainer = setup.build_des_with_times(
                policy,
                link.clone(),
                Some(ComputeTimes::Replay(trace.clone())),
            )?;
            trainer.set_faults(fault_plan.clone());
            if let Some(r) = &recovery {
                trainer.set_recovery(r.clone());
            }
            if export_events.is_some() {
                trainer.log_events();
            }
            let o = trainer.run()?;
            let e = o
                .history
                .final_eval()
                .ok_or_else(|| anyhow::anyhow!("no eval recorded"))?;
            out.push_str(&format!(
                "{:>10} | {:>10.2}s {:>9.3}s {:>8.2} {:>12.4} {:>12.1} {:>12.4}\n",
                o.stats.policy,
                o.stats.makespan,
                o.stats.mean_wait,
                o.stats.mean_backup,
                e.test_loss,
                e.test_error * 100.0,
                e.consensus_error
            ));
            export::write_csv(
                &o.history,
                out_dir,
                &format!("des.{}.{}", self.name, policy.name().replace(':', "_")),
            )?;
            if export_events.is_some() {
                log_out.push_str(&format!("# scenario={} policy={}\n", self.name, policy.name()));
                for line in &o.event_log {
                    log_out.push_str(line);
                    log_out.push('\n');
                }
            }
        }
        if let Some(p) = export_events {
            if let Some(dir) = p.parent() {
                std::fs::create_dir_all(dir)?;
            }
            std::fs::write(p, log_out)?;
        }
        Ok(out)
    }
}

/// `field(j, key, Json::as_x, "an x")?` = Some(parsed) | None if
/// absent | typed error if present with the wrong type.
fn field<'j, T>(
    j: &'j Json,
    key: &str,
    get: impl Fn(&'j Json) -> Option<T>,
    want: &str,
) -> anyhow::Result<Option<T>> {
    match j.get(key) {
        None => Ok(None),
        Some(v) => get(v)
            .map(Some)
            .ok_or_else(|| anyhow::anyhow!("scenario field '{key}' must be {want}")),
    }
}

/// Fetch a nested section object, rejecting non-objects and unknown
/// keys (same strictness as the top level).
fn section<'j>(j: &'j Json, name: &str, known: &[&str]) -> anyhow::Result<Option<&'j Json>> {
    match j.get(name) {
        None => Ok(None),
        Some(sec) => {
            let Json::Obj(map) = sec else {
                anyhow::bail!("scenario section '{name}' must be an object");
            };
            for key in map.keys() {
                anyhow::ensure!(
                    known.contains(&key.as_str()),
                    "unknown field '{key}' in scenario section '{name}' (known: {known:?})"
                );
            }
            Ok(Some(sec))
        }
    }
}

fn apply_cluster(s: &mut Scenario, j: &Json) -> anyhow::Result<()> {
    if let Some(v) = field(j, "workers", Json::as_usize, "an integer")? {
        s.workers = v;
    }
    if let Some(v) = field(j, "topology", Json::as_str, "a topology name")? {
        s.topology = Topology::parse(v)?;
    }
    if let Some(v) = field(j, "heartbeat_secs", Json::as_f64, "a number")? {
        s.heartbeat_secs = v;
    }
    if let Some(v) = field(j, "rejoin_timeout_secs", Json::as_f64, "a number")? {
        s.rejoin_timeout_secs = v;
    }
    Ok(())
}

fn apply_timing(s: &mut Scenario, j: &Json) -> anyhow::Result<()> {
    if let Some(v) = field(j, "compute", Json::as_str, "a dist spec")? {
        s.compute = Dist::parse(v)?;
    }
    if let Some(v) = field(j, "hetero", Json::as_f64, "a number")? {
        s.hetero = v;
    }
    if let Some(v) = field(j, "transient_prob", Json::as_f64, "a number")? {
        s.transient_prob = v;
    }
    if let Some(v) = field(j, "transient_factor", Json::as_f64, "a number")? {
        s.transient_factor = v;
    }
    if let Some(v) = field(j, "diurnal_amp", Json::as_f64, "a number")? {
        s.diurnal_amp = v;
    }
    if let Some(v) = field(j, "diurnal_period", Json::as_f64, "a number")? {
        s.diurnal_period = v;
    }
    if let Some(arr) = field(j, "persistent", Json::as_arr, "an array of pairs")? {
        s.persistent = parse_pairs(arr, "persistent")?
            .into_iter()
            .map(|(a, f)| Ok((worker_index(a, "persistent")?, f)))
            .collect::<anyhow::Result<_>>()?;
    }
    if let Some(v) = field(j, "trace_file", Json::as_str, "a path string")? {
        s.trace_file = Some(PathBuf::from(v));
    }
    Ok(())
}

fn apply_links(s: &mut Scenario, j: &Json, base_key: &str, jitter_key: &str) -> anyhow::Result<()> {
    if let Some(v) = field(j, base_key, Json::as_f64, "a number")? {
        s.link_base = v;
    }
    if let Some(v) = j.get(jitter_key) {
        // strict like every other field: only "none" or a dist spec
        let spec = v
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("{jitter_key} must be \"none\" or a dist spec"))?;
        s.link_jitter = match spec {
            "none" => None,
            spec => Some(Dist::parse(spec)?),
        };
    }
    if let Some(arr) = field(j, "slow_links", Json::as_arr, "an array of triples")? {
        s.slow_links = parse_tuples(arr, 3, "slow_links", "[a, b, factor] triples")?
            .into_iter()
            .map(|t| {
                Ok((
                    worker_index(t[0], "slow_links")?,
                    worker_index(t[1], "slow_links")?,
                    t[2],
                ))
            })
            .collect::<anyhow::Result<_>>()?;
    }
    Ok(())
}

fn apply_training(s: &mut Scenario, j: &Json) -> anyhow::Result<()> {
    if let Some(v) = field(j, "model", Json::as_str, "a model name")? {
        s.model = v.to_string();
    }
    if let Some(v) = field(j, "train_n", Json::as_usize, "an integer")? {
        s.train_n = v;
    }
    if let Some(v) = field(j, "test_n", Json::as_usize, "an integer")? {
        s.test_n = v;
    }
    if let Some(v) = field(j, "eval_every", Json::as_usize, "an integer")? {
        s.eval_every = v;
    }
    Ok(())
}

fn apply_faults(s: &mut Scenario, j: &Json) -> anyhow::Result<()> {
    if let Some(arr) = field(j, "initially_down", Json::as_arr, "an array of worker indices")? {
        s.faults.initially_down = arr
            .iter()
            .map(|v| {
                let f = v
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("non-numeric initially_down entry"))?;
                worker_index(f, "initially_down")
            })
            .collect::<anyhow::Result<_>>()?;
    }
    if let Some(arr) = field(j, "joins", Json::as_arr, "an array of [worker, time] pairs")? {
        s.faults.joins = parse_pairs(arr, "joins")?
            .into_iter()
            .map(|(w, t)| Ok((worker_index(w, "joins")?, t)))
            .collect::<anyhow::Result<_>>()?;
    }
    if let Some(arr) = field(j, "leaves", Json::as_arr, "an array of [worker, time] pairs")? {
        s.faults.leaves = parse_pairs(arr, "leaves")?
            .into_iter()
            .map(|(w, t)| Ok((worker_index(w, "leaves")?, t)))
            .collect::<anyhow::Result<_>>()?;
    }
    if let Some(arr) = field(j, "partitions", Json::as_arr, "an array of [a, b, from, to]")? {
        s.faults.partitions = parse_tuples(arr, 4, "partitions", "[a, b, from, to] quadruples")?
            .into_iter()
            .map(|q| {
                Ok((
                    worker_index(q[0], "partitions")?,
                    worker_index(q[1], "partitions")?,
                    q[2],
                    q[3],
                ))
            })
            .collect::<anyhow::Result<_>>()?;
    }
    if let Some(arr) = field(j, "rack_outages", Json::as_arr, "an array of [rack, from, to]")? {
        s.faults.rack_outages = parse_tuples(arr, 3, "rack_outages", "[rack, from, to] triples")?
            .into_iter()
            .map(|t| Ok((worker_index(t[0], "rack_outages")?, t[1], t[2])))
            .collect::<anyhow::Result<_>>()?;
    }
    Ok(())
}

/// A JSON number used as a worker/rack index: must be an exact
/// non-negative integer.
fn worker_index(f: f64, what: &str) -> anyhow::Result<usize> {
    anyhow::ensure!(
        f >= 0.0 && f.fract() == 0.0,
        "{what} index must be a non-negative integer (got {f})"
    );
    Ok(f as usize)
}

/// Fixed-arity numeric tuples (`[[a, b, ...], ...]`).
fn parse_tuples(
    arr: &[Json],
    arity: usize,
    what: &str,
    shape: &str,
) -> anyhow::Result<Vec<Vec<f64>>> {
    arr.iter()
        .map(|t| {
            let t = t
                .as_arr()
                .filter(|t| t.len() == arity)
                .ok_or_else(|| anyhow::anyhow!("{what} entries are {shape}"))?;
            t.iter()
                .map(|v| {
                    v.as_f64()
                        .ok_or_else(|| anyhow::anyhow!("non-numeric {what} entry"))
                })
                .collect::<anyhow::Result<Vec<f64>>>()
        })
        .collect()
}

fn parse_pairs(arr: &[Json], what: &str) -> anyhow::Result<Vec<(f64, f64)>> {
    arr.iter()
        .map(|p| {
            let p = p
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| anyhow::anyhow!("{what} entries are [worker, factor] pairs"))?;
            let a = p[0]
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("non-numeric {what} entry"))?;
            let b = p[1]
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("non-numeric {what} entry"))?;
            Ok((a, b))
        })
        .collect()
}

fn render_stats_row(s: &ClusterStats) -> String {
    format!(
        "{:>10} | {:>10.2}s {:>10.4}s {:>9.4}s {:>8.2} {:>10} {:>9} {:>8} {:>7.2}s\n",
        s.policy,
        s.makespan,
        s.mean_iter_duration,
        s.mean_wait,
        s.mean_backup,
        s.coverage_violations,
        s.messages_sent,
        s.max_lag,
        s.finish_percentile(50.0)
    )
}

fn stats_json(s: &ClusterStats) -> Json {
    let mut o = Json::obj();
    o.set("makespan", s.makespan.into())
        .set("mean_iter_duration", s.mean_iter_duration.into())
        .set("mean_wait", s.mean_wait.into())
        .set("mean_backup", s.mean_backup.into())
        .set("messages_sent", (s.messages_sent as i64).into())
        .set("stale_messages", (s.stale_messages as i64).into())
        .set("events", (s.events as i64).into())
        .set("coverage_violations", (s.coverage_violations as i64).into())
        .set("departed", (s.departed as i64).into())
        .set("max_lag", s.max_lag.into())
        .set("p50_finish", s.finish_percentile(50.0).into())
        .set("p99_finish", s.finish_percentile(99.0).into());
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut s = Scenario::default();
        s.name = "rt".into();
        s.workers = 64;
        s.policies = vec![WaitPolicy::Dybw, WaitPolicy::Static { b: 2 }];
        s.persistent = vec![(3, 5.0)];
        s.slow_links = vec![(0, 1, 4.0)];
        s.link_jitter = None;
        s.heartbeat_secs = 2.5;
        s.rejoin_timeout_secs = 30.0;
        // above 2^53: must survive exactly (seeds travel as strings)
        s.seed = (1u64 << 60) + 3;
        let j = s.to_json();
        let s2 = Scenario::from_json(&j).unwrap();
        assert_eq!(s2.name, "rt");
        assert_eq!(s2.workers, 64);
        assert_eq!(s2.policies, s.policies);
        assert_eq!(s2.persistent, s.persistent);
        assert_eq!(s2.slow_links, s.slow_links);
        assert_eq!(s2.link_jitter, None);
        assert_eq!(s2.compute, s.compute);
        assert_eq!(s2.heartbeat_secs, 2.5);
        assert_eq!(s2.rejoin_timeout_secs, 30.0);
        assert_eq!(s2.seed, (1u64 << 60) + 3);
    }

    #[test]
    fn from_json_rejects_garbage() {
        for bad in [
            r#"{"workers": 1}"#,
            r#"{"iters": 0}"#,
            r#"{"policies": []}"#,
            r#"{"policies": ["wat"]}"#,
            r#"{"topology": "dodecahedron"}"#,
            r#"{"fidelity": "imaginary"}"#,
            r#"{"compute": "nope:1"}"#,
            r#"{"hetero": 1.5}"#,
            r#"{"persistent": [[1]]}"#,
            r#"{"persistent": [[-1, 5.0]]}"#,
            r#"{"persistent": [[1.5, 2.0]]}"#,
            r#"{"persistent": [[1, -2.0]]}"#,
            r#"{"slow_links": [[1, 2]]}"#,
            r#"{"slow_links": [[0, 1, 2.0], [1, 0, 3.0]]}"#,
            r#"{"slow_links": [[0, 1, -2.0]]}"#,
            r#"{"link_jitter": 5}"#,
            r#"{"link_jitter": "uniform:-0.01,0.01"}"#,
            r#"{"link_base": -0.002}"#,
            r#"{"compute": "det:-0.1"}"#,
            r#"{"compute": "uniform:-0.05,0.2"}"#,
            r#"{"transient_prob": 1.5}"#,
            r#"{"transient_factor": 0}"#,
            r#"{"diurnal_amp": 1.0}"#,
            r#"{"diurnal_amp": -0.1}"#,
            r#"{"diurnal_amp": 0.3}"#,
            r#"{"diurnal_amp": 0.3, "diurnal_period": 0}"#,
            r#"{"diurnal_period": -2}"#,
            r#"{"topology": "racks:0"}"#,
            r#"{"workers": "250"}"#,
            r#"{"wrokers": 6}"#,
            r#"{"seed": 1.5}"#,
            r#"{"seed": "abc"}"#,
            r#"[]"#,
            // nested sections are exactly as strict as the flat keys
            r#"{"cluster": {"workers": 1}}"#,
            r#"{"cluster": {"wrokers": 6}}"#,
            r#"{"cluster": 5}"#,
            r#"{"cluster": {"topology": "racks:0"}}"#,
            r#"{"cluster": {"heartbeat_secs": "fast"}}"#,
            r#"{"cluster": {"heartbeat_secs": -1}}"#,
            r#"{"cluster": {"rejoin_timeout_secs": -0.5}}"#,
            // liveness knobs are cluster-section only, never flat
            r#"{"heartbeat_secs": 2}"#,
            r#"{"links": {"link_base": 0.001}}"#,
            r#"{"links": {"base": -0.002}}"#,
            r#"{"timing": {"compute": "nope:1"}}"#,
            r#"{"training": {"eval_every": "often"}}"#,
            r#"{"faults": {"leaves": [[2000, 1.0]]}}"#,
            r#"{"faults": {"joins": [[1.5, 1.0]]}}"#,
            r#"{"faults": {"partitions": [[0, 1, 2.0]]}}"#,
            r#"{"faults": {"partitions": [[0, 1, 2.0, 1.0]]}}"#,
            r#"{"faults": {"rack_outages": [[0, 0.5, 1.0]]}}"#,
            r#"{"faults": {"sabotage": []}}"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Scenario::from_json(&j).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn fidelity_parse_roundtrips() {
        for f in [Fidelity::Timing, Fidelity::Full] {
            assert_eq!(Fidelity::parse(f.name()), Ok(f));
        }
        for bad in ["", "timing ", "Full", "exact"] {
            let err = Fidelity::parse(bad).unwrap_err();
            assert_eq!(err.what, "fidelity");
            assert_eq!(err.input, bad);
            assert!(err.to_string().contains("timing | full"));
        }
    }

    #[test]
    fn faults_section_roundtrips() {
        let mut s = Scenario::default();
        s.topology = Topology::Racks(4);
        s.faults.initially_down = vec![7];
        s.faults.joins = vec![(7, 0.5)];
        s.faults.leaves = vec![(3, 1.25)];
        s.faults.partitions = vec![(0, 1, 0.5, 2.0)];
        s.faults.rack_outages = vec![(2, 1.0, 3.0)];
        let s2 = Scenario::from_json(&s.to_json()).unwrap();
        assert_eq!(s2.faults, s.faults);
        // no faults → no faults section emitted, and it parses back empty
        let s3 = Scenario::from_json(&Scenario::default().to_json()).unwrap();
        assert!(s3.faults.is_empty());
        assert!(Scenario::default().to_json().get("faults").is_none());
    }

    #[test]
    fn legacy_flat_scenario_still_parses() {
        // a pre-PR-8 flat file: every key at top level
        let j = Json::parse(
            r#"{"name": "old", "workers": 40, "topology": "racks:4", "iters": 5,
                "hetero": 0.1, "link_base": 0.001, "link_jitter": "none",
                "model": "lrm_d16_c10_b64", "eval_every": 5, "train_n": 4000}"#,
        )
        .unwrap();
        let s = Scenario::from_json(&j).unwrap();
        assert_eq!(s.workers, 40);
        assert_eq!(s.topology, Topology::Racks(4));
        assert_eq!(s.hetero, 0.1);
        assert_eq!(s.link_base, 0.001);
        assert_eq!(s.link_jitter, None);
        assert_eq!(s.model, "lrm_d16_c10_b64");
        assert_eq!(s.eval_every, 5);
        assert_eq!(s.train_n, 4000);
        // the nested re-emission describes the same scenario
        let s2 = Scenario::from_json(&s.to_json()).unwrap();
        assert_eq!(s2.workers, s.workers);
        assert_eq!(s2.topology, s.topology);
        assert_eq!(s2.link_base, s.link_base);
        // nested sections take precedence when both spellings appear
        let j = Json::parse(r#"{"workers": 10, "cluster": {"workers": 20}}"#).unwrap();
        assert_eq!(Scenario::from_json(&j).unwrap().workers, 20);
    }

    /// PR-8 tentpole: a correlated rack outage (every worker in the
    /// rack down for a window) must leave zero coverage violations
    /// after recovery, retire nobody, and stay byte-reproducible.
    #[test]
    fn rack_outage_scenario_recovers_coverage() {
        let dir = std::env::temp_dir().join("dybw_des_scn_rack_outage");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Scenario::default();
        s.name = "rackout".into();
        s.workers = 40;
        s.iters = 30;
        s.topology = Topology::Racks(4);
        s.policies = vec![WaitPolicy::Dybw, WaitPolicy::Full];
        s.faults.rack_outages = vec![(1, 0.4, 1.2)];
        let events = dir.join("events.log");
        let out = s.run(&dir, Some(&events)).unwrap();
        assert!(out.contains("dybw"), "{out}");
        let log = std::fs::read_to_string(&events).unwrap();
        assert!(log.contains("worker_down"));
        assert!(log.contains("worker_up"));
        // fault events are scheduled up front, so churn runs keep the
        // byte-identical reproducibility contract
        s.run(&dir, Some(&events)).unwrap();
        assert_eq!(std::fs::read_to_string(&events).unwrap(), log);
        let summary = std::fs::read_to_string(dir.join("des.rackout.summary.json")).unwrap();
        let j = Json::parse(&summary).unwrap();
        for p in ["dybw", "full"] {
            let stat = |key: &str| {
                j.get(p)
                    .and_then(|o| o.get(key))
                    .and_then(Json::as_f64)
                    .unwrap()
            };
            assert_eq!(stat("coverage_violations"), 0.0, "{p}");
            assert_eq!(stat("departed"), 0.0, "{p}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn heavy_tail_diurnal_racks_scenario_runs_and_roundtrips() {
        let dir = std::env::temp_dir().join("dybw_des_scn_divers");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Scenario::default();
        s.name = "divers".into();
        s.workers = 60;
        s.iters = 6;
        s.topology = Topology::Racks(5);
        s.compute = crate::straggler::Dist::Pareto { xm: 0.05, alpha: 2.5 };
        s.diurnal_amp = 0.4;
        s.diurnal_period = 3.0;
        let out = s.run(&dir, None).unwrap();
        assert!(out.contains("racks:5"), "{out}");
        assert!(out.contains("dybw"));
        let s2 = Scenario::from_json(&s.to_json()).unwrap();
        assert_eq!(s2.topology, Topology::Racks(5));
        assert_eq!(s2.diurnal_amp, 0.4);
        assert_eq!(s2.diurnal_period, 3.0);
        assert_eq!(s2.compute, s.compute);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_rejects_out_of_range_injection_targets() {
        // worker counts can shrink after load (CLI override): injection
        // targets outside the cluster must error, not silently vanish.
        let dir = std::env::temp_dir().join("dybw_des_scn_range");
        let mut s = Scenario::default();
        s.workers = 10;
        s.iters = 2;
        s.persistent = vec![(17, 5.0)];
        assert!(s.run(&dir, None).unwrap_err().to_string().contains("persistent"));
        s.persistent.clear();
        s.slow_links = vec![(0, 99, 4.0)];
        assert!(s.run(&dir, None).unwrap_err().to_string().contains("slow_links"));
        // duplicate edges (even direction-flipped) would compound their
        // factors; they must be rejected, not applied twice
        s.slow_links = vec![(0, 1, 4.0), (1, 0, 2.0)];
        let err = s.run(&dir, None).unwrap_err().to_string();
        assert!(err.contains("slow_links") && err.contains("more than once"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn timing_sweep_runs_and_exports() {
        let dir = std::env::temp_dir().join("dybw_des_scn_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Scenario::default();
        s.name = "smoke".into();
        s.workers = 120;
        s.iters = 8;
        let events = dir.join("events.log");
        let out = s.run(&dir, Some(&events)).unwrap();
        assert!(out.contains("dybw"), "{out}");
        assert!(out.contains("full"));
        assert!(dir.join("des.smoke.summary.json").exists());
        let log = std::fs::read_to_string(&events).unwrap();
        assert!(log.contains("# scenario=smoke policy=dybw"));
        assert!(log.contains("compute_done"));
        // re-running produces a byte-identical event log
        let out2 = s.run(&dir, Some(&events)).unwrap();
        assert_eq!(out, out2);
        assert_eq!(std::fs::read_to_string(&events).unwrap(), log);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn full_fidelity_scenario_runs() {
        let dir = std::env::temp_dir().join("dybw_des_scn_full_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Scenario::default();
        s.name = "fullsmoke".into();
        s.fidelity = Fidelity::Full;
        s.workers = 4;
        s.iters = 6;
        s.eval_every = 3;
        s.policies = vec![WaitPolicy::Dybw];
        s.model = "lrm_d16_c10_b64".into();
        s.train_n = 2000;
        s.test_n = 512;
        let out = s.run(&dir, None).unwrap();
        assert!(out.contains("final loss"), "{out}");
        assert!(dir.join("des.fullsmoke.dybw.evals.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
