//! Scenario harness: declarative JSON → a policy sweep on one identical
//! timing realisation.
//!
//! A scenario names a cluster (size, topology, compute-time model,
//! per-link latency, injected heterogeneity) and a list of wait
//! policies. The harness records ONE timing trace (or loads a CSV) and
//! replays it under every policy, so the sweep is a variance-free A/B on
//! the exact same realisation — the strongest form of the paper's
//! comparisons, now on the asynchronous timeline. Timing-only scenarios
//! scale to 10^5–10^6 workers (event log streamed to disk, never
//! buffered); full-fidelity scenarios run real gradients through
//! [`Setup`]'s model/data wiring.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::coordinator::setup::Setup;
use crate::graph::topology::{self, Topology};
use crate::metrics::export;
use crate::straggler::link::LinkModel;
use crate::straggler::trace::Trace;
use crate::straggler::{Dist, StragglerModel};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::cluster::{ClusterSim, ClusterStats, ComputeTimes, NoHooks};
use super::policy::WaitPolicy;

/// Simulation fidelity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fidelity {
    /// No gradients: pure schedule. Scales to thousands of workers.
    Timing,
    /// Real gradients through the engine pool (bit-reproducible).
    Full,
}

impl Fidelity {
    pub fn name(&self) -> &'static str {
        match self {
            Fidelity::Timing => "timing",
            Fidelity::Full => "full",
        }
    }

    pub fn parse(s: &str) -> Option<Fidelity> {
        match s {
            "timing" => Some(Fidelity::Timing),
            "full" => Some(Fidelity::Full),
            _ => None,
        }
    }
}

/// One declarative DES experiment.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub workers: usize,
    pub topology: Topology,
    pub iters: usize,
    pub seed: u64,
    pub fidelity: Fidelity,
    pub policies: Vec<WaitPolicy>,
    /// Base compute-time distribution (ignored when `trace_file` set).
    pub compute: Dist,
    /// Worker-scale spread: scales drawn uniform in [1−h, 1+h].
    pub hetero: f64,
    pub transient_prob: f64,
    pub transient_factor: f64,
    /// Diurnal swing amplitude in [0, 1): compute times are multiplied
    /// by 1 + amp·sin(2πk/period). 0 disables.
    pub diurnal_amp: f64,
    /// Diurnal period in iterations (must be > 0 when amp > 0).
    pub diurnal_period: f64,
    /// Persistent stragglers: (worker, factor).
    pub persistent: Vec<(usize, f64)>,
    pub link_base: f64,
    pub link_jitter: Option<Dist>,
    /// Heterogeneous links: (a, b, factor) on both directions.
    pub slow_links: Vec<(usize, usize, f64)>,
    /// Replay this CSV instead of recording from the model.
    pub trace_file: Option<PathBuf>,
    // full-fidelity knobs (ignored in timing mode)
    pub model: String,
    pub train_n: usize,
    pub test_n: usize,
    pub eval_every: usize,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            name: "ring-1k".into(),
            workers: 1000,
            topology: Topology::Ring,
            iters: 30,
            seed: 2021,
            fidelity: Fidelity::Timing,
            policies: vec![
                WaitPolicy::Full,
                WaitPolicy::Static { b: 1 },
                WaitPolicy::Dybw,
            ],
            compute: Dist::ShiftedExp { base: 0.08, rate: 25.0 },
            hetero: 0.2,
            transient_prob: 0.15,
            transient_factor: 4.0,
            diurnal_amp: 0.0,
            diurnal_period: 0.0,
            persistent: Vec::new(),
            link_base: 0.002,
            link_jitter: Some(Dist::ShiftedExp { base: 0.0, rate: 800.0 }),
            slow_links: Vec::new(),
            trace_file: None,
            model: "lrm_d64_c10_b256".into(),
            train_n: 12_000,
            test_n: 2_048,
            eval_every: 10,
        }
    }
}

impl Scenario {
    pub fn load(path: &Path) -> anyhow::Result<Scenario> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("cannot read scenario {}: {e}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("bad scenario JSON: {e}"))?;
        Scenario::from_json(&j)
    }

    /// Defaults overridden by whatever fields the JSON provides.
    /// Strict: unknown keys and present-but-mistyped values are errors —
    /// a scenario file must never silently run something other than
    /// what it describes.
    pub fn from_json(j: &Json) -> anyhow::Result<Scenario> {
        const KNOWN: &[&str] = &[
            "name", "workers", "topology", "iters", "seed", "fidelity", "policies", "compute",
            "hetero", "transient_prob", "transient_factor", "diurnal_amp", "diurnal_period",
            "persistent", "link_base", "link_jitter", "slow_links", "trace_file", "model",
            "train_n", "test_n", "eval_every",
        ];
        let Json::Obj(map) = j else {
            anyhow::bail!("scenario must be a JSON object");
        };
        for key in map.keys() {
            anyhow::ensure!(
                KNOWN.contains(&key.as_str()),
                "unknown scenario field '{key}' (known: {KNOWN:?})"
            );
        }
        // `field(j, key, Json::as_x, "an x")?` = Some(parsed) | None if
        // absent | typed error if present with the wrong type.
        fn field<'j, T>(
            j: &'j Json,
            key: &str,
            get: impl Fn(&'j Json) -> Option<T>,
            want: &str,
        ) -> anyhow::Result<Option<T>> {
            match j.get(key) {
                None => Ok(None),
                Some(v) => get(v)
                    .map(Some)
                    .ok_or_else(|| anyhow::anyhow!("scenario field '{key}' must be {want}")),
            }
        }
        let mut s = Scenario::default();
        if let Some(v) = field(j, "name", Json::as_str, "a string")? {
            s.name = v.to_string();
        }
        if let Some(v) = field(j, "workers", Json::as_usize, "an integer")? {
            s.workers = v;
        }
        if let Some(v) = field(j, "topology", Json::as_str, "a topology name")? {
            s.topology = Topology::parse(v).ok_or_else(|| anyhow::anyhow!("bad topology '{v}'"))?;
        }
        if let Some(v) = field(j, "iters", Json::as_usize, "an integer")? {
            s.iters = v;
        }
        if let Some(v) = j.get("seed") {
            // exact for ALL u64 seeds: numbers are f64-backed, so large
            // seeds must travel as strings (to_json writes them so)
            s.seed = match (v.as_str(), v.as_f64()) {
                (Some(txt), _) => txt
                    .parse::<u64>()
                    .map_err(|e| anyhow::anyhow!("bad seed '{txt}': {e}"))?,
                (None, Some(num)) => {
                    anyhow::ensure!(
                        num >= 0.0 && num.fract() == 0.0 && num <= (1u64 << 53) as f64,
                        "numeric seed {num} is not an exact non-negative integer — \
                         write seeds above 2^53 as strings"
                    );
                    num as u64
                }
                (None, None) => anyhow::bail!("seed must be an integer or a decimal string"),
            };
        }
        if let Some(v) = field(j, "fidelity", Json::as_str, "\"timing\" or \"full\"")? {
            s.fidelity = Fidelity::parse(v).ok_or_else(|| anyhow::anyhow!("bad fidelity '{v}'"))?;
        }
        if let Some(arr) = field(j, "policies", Json::as_arr, "an array of policy names")? {
            s.policies = arr
                .iter()
                .map(|p| {
                    p.as_str()
                        .and_then(WaitPolicy::parse)
                        .ok_or_else(|| anyhow::anyhow!("bad policy {p:?}"))
                })
                .collect::<anyhow::Result<_>>()?;
        }
        if let Some(v) = field(j, "compute", Json::as_str, "a dist spec")? {
            s.compute = Dist::parse(v).ok_or_else(|| anyhow::anyhow!("bad compute '{v}'"))?;
        }
        if let Some(v) = field(j, "hetero", Json::as_f64, "a number")? {
            s.hetero = v;
        }
        if let Some(v) = field(j, "transient_prob", Json::as_f64, "a number")? {
            s.transient_prob = v;
        }
        if let Some(v) = field(j, "transient_factor", Json::as_f64, "a number")? {
            s.transient_factor = v;
        }
        if let Some(v) = field(j, "diurnal_amp", Json::as_f64, "a number")? {
            s.diurnal_amp = v;
        }
        if let Some(v) = field(j, "diurnal_period", Json::as_f64, "a number")? {
            s.diurnal_period = v;
        }
        if let Some(arr) = field(j, "persistent", Json::as_arr, "an array of pairs")? {
            s.persistent = parse_pairs(arr, "persistent")?
                .into_iter()
                .map(|(a, f)| {
                    anyhow::ensure!(
                        a >= 0.0 && a.fract() == 0.0,
                        "persistent worker index must be a non-negative integer (got {a})"
                    );
                    Ok((a as usize, f))
                })
                .collect::<anyhow::Result<_>>()?;
        }
        if let Some(v) = field(j, "link_base", Json::as_f64, "a number")? {
            s.link_base = v;
        }
        if let Some(v) = j.get("link_jitter") {
            // strict like every other field: only "none" or a dist spec
            let spec = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("link_jitter must be \"none\" or a dist spec"))?;
            s.link_jitter = match spec {
                "none" => None,
                spec => Some(
                    Dist::parse(spec).ok_or_else(|| anyhow::anyhow!("bad link_jitter '{spec}'"))?,
                ),
            };
        }
        if let Some(arr) = field(j, "slow_links", Json::as_arr, "an array of triples")? {
            s.slow_links = arr
                .iter()
                .map(|t| {
                    let t = t.as_arr().filter(|t| t.len() == 3).ok_or_else(|| {
                        anyhow::anyhow!("slow_links entries are [a, b, factor] triples")
                    })?;
                    let get = |i: usize| {
                        t[i].as_f64()
                            .ok_or_else(|| anyhow::anyhow!("non-numeric slow_links entry"))
                    };
                    let (a, b) = (get(0)?, get(1)?);
                    anyhow::ensure!(
                        a >= 0.0 && a.fract() == 0.0 && b >= 0.0 && b.fract() == 0.0,
                        "slow_links endpoints must be non-negative integers"
                    );
                    Ok((a as usize, b as usize, get(2)?))
                })
                .collect::<anyhow::Result<_>>()?;
        }
        if let Some(v) = field(j, "trace_file", Json::as_str, "a path string")? {
            s.trace_file = Some(PathBuf::from(v));
        }
        if let Some(v) = field(j, "model", Json::as_str, "a model name")? {
            s.model = v.to_string();
        }
        if let Some(v) = field(j, "train_n", Json::as_usize, "an integer")? {
            s.train_n = v;
        }
        if let Some(v) = field(j, "test_n", Json::as_usize, "an integer")? {
            s.test_n = v;
        }
        if let Some(v) = field(j, "eval_every", Json::as_usize, "an integer")? {
            s.eval_every = v;
        }
        s.validate()?;
        Ok(s)
    }

    /// Reject scenarios that would corrupt the virtual-time schedule
    /// (negative latencies/durations schedule events into the past) or
    /// silently differ from what the file describes. Checked after
    /// loading AND again at run time, because the CLI can override
    /// fields (e.g. shrink `workers` under an injection target).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.workers >= 2, "need >= 2 workers");
        anyhow::ensure!(self.iters >= 1, "need >= 1 iteration");
        anyhow::ensure!(!self.policies.is_empty(), "need >= 1 policy");
        anyhow::ensure!((0.0..1.0).contains(&self.hetero), "hetero must be in [0, 1)");
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.transient_prob),
            "transient_prob must be in [0, 1]"
        );
        anyhow::ensure!(
            self.transient_factor.is_finite() && self.transient_factor > 0.0,
            "transient_factor must be positive"
        );
        anyhow::ensure!(
            (0.0..1.0).contains(&self.diurnal_amp),
            "diurnal_amp must be in [0, 1) — amplitudes >= 1 make compute times non-positive"
        );
        anyhow::ensure!(
            self.diurnal_period.is_finite() && self.diurnal_period >= 0.0,
            "diurnal_period must be >= 0"
        );
        anyhow::ensure!(
            self.diurnal_amp == 0.0 || self.diurnal_period > 0.0,
            "diurnal_amp > 0 needs diurnal_period > 0"
        );
        anyhow::ensure!(
            self.link_base.is_finite() && self.link_base >= 0.0,
            "link_base must be >= 0"
        );
        anyhow::ensure!(
            self.compute.nonnegative(),
            "compute dist can sample negative times: {}",
            self.compute.spec()
        );
        if let Some(d) = &self.link_jitter {
            anyhow::ensure!(
                d.nonnegative(),
                "link_jitter dist can sample negative latency: {}",
                d.spec()
            );
        }
        for &(w, f) in &self.persistent {
            anyhow::ensure!(
                w < self.workers,
                "persistent straggler index {w} >= workers {}",
                self.workers
            );
            anyhow::ensure!(f.is_finite() && f > 0.0, "persistent factor must be positive");
        }
        // typed slow_links checks (range, factor, duplicate edges) live
        // on the model itself so every constructor path shares them
        self.link_model().validate(self.workers)?;
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str().into())
            .set("workers", self.workers.into())
            .set("topology", self.topology.name().into())
            .set("iters", self.iters.into())
            // string, not number: JSON numbers are f64-backed, which
            // would corrupt seeds above 2^53 on a round trip
            .set("seed", self.seed.to_string().into())
            .set("fidelity", self.fidelity.name().into())
            .set(
                "policies",
                self.policies.iter().map(|p| p.name()).collect::<Vec<_>>().into(),
            )
            .set("compute", self.compute.spec().into())
            .set("hetero", self.hetero.into())
            .set("transient_prob", self.transient_prob.into())
            .set("transient_factor", self.transient_factor.into())
            .set("diurnal_amp", self.diurnal_amp.into())
            .set("diurnal_period", self.diurnal_period.into())
            .set(
                "persistent",
                Json::Arr(
                    self.persistent
                        .iter()
                        .map(|&(w, f)| Json::Arr(vec![(w).into(), f.into()]))
                        .collect(),
                ),
            )
            .set("link_base", self.link_base.into())
            .set(
                "link_jitter",
                match &self.link_jitter {
                    Some(d) => d.spec().into(),
                    None => "none".into(),
                },
            )
            .set(
                "slow_links",
                Json::Arr(
                    self.slow_links
                        .iter()
                        .map(|&(a, b, f)| Json::Arr(vec![a.into(), b.into(), f.into()]))
                        .collect(),
                ),
            )
            .set("model", self.model.as_str().into())
            .set("train_n", self.train_n.into())
            .set("test_n", self.test_n.into())
            .set("eval_every", self.eval_every.into());
        if let Some(p) = &self.trace_file {
            o.set("trace_file", p.display().to_string().into());
        }
        o
    }

    /// The straggler model the scenario describes (used to record the
    /// shared trace when no CSV is given; the async figure harness
    /// reuses it so its N-sweep matches the sweep's model exactly).
    pub(crate) fn straggler_model(&self, rng: &mut Rng) -> StragglerModel {
        let mut m = StragglerModel {
            base: self.compute,
            worker_scale: (0..self.workers)
                .map(|_| rng.uniform_in(1.0 - self.hetero, 1.0 + self.hetero))
                .collect(),
            persistent: vec![1.0; self.workers],
            transient_prob: self.transient_prob,
            transient_factor: self.transient_factor,
            force_one_straggler: self.transient_prob > 0.0,
            outages: Vec::new(),
            diurnal_amp: self.diurnal_amp,
            diurnal_period: self.diurnal_period,
        };
        for &(w, f) in &self.persistent {
            m.persistent[w] = f;
        }
        m
    }

    pub(crate) fn link_model(&self) -> LinkModel {
        let mut l = LinkModel::new(self.link_base, self.link_jitter, self.seed);
        for &(a, b, f) in &self.slow_links {
            l = l.with_slow_link(a, b, f);
        }
        l
    }

    /// The shared timing realisation every policy replays.
    fn build_trace(&self, rng: &mut Rng) -> anyhow::Result<Arc<Trace>> {
        let trace = match &self.trace_file {
            Some(p) => {
                let t = Trace::load_csv(p)?;
                anyhow::ensure!(
                    t.workers == self.workers,
                    "trace has {} workers, scenario {}",
                    t.workers,
                    self.workers
                );
                t
            }
            None => Trace::record(&self.straggler_model(rng), self.iters, rng),
        };
        Ok(Arc::new(trace))
    }

    /// Run the sweep. Writes per-policy summaries under `out_dir`; when
    /// `export_events` is set, appends every policy's deterministic
    /// event log to that file (the CI reproducibility artifact).
    pub fn run(&self, out_dir: &Path, export_events: Option<&Path>) -> anyhow::Result<String> {
        self.validate()?;
        match self.fidelity {
            Fidelity::Timing => self.run_timing(out_dir, export_events),
            Fidelity::Full => self.run_full(out_dir, export_events),
        }
    }

    fn run_timing(&self, out_dir: &Path, export_events: Option<&Path>) -> anyhow::Result<String> {
        let mut rng = Rng::new(self.seed);
        let graph = topology::build(self.topology, self.workers, &mut rng);
        let trace = self.build_trace(&mut rng)?;
        let link = self.link_model();
        let mut out = format!(
            "=== DES scenario '{}' (timing-only, {} workers, {}, {} iters/worker) ===\n",
            self.name,
            self.workers,
            self.topology.name(),
            self.iters
        );
        out.push_str(&format!(
            "{:>10} | {:>11} {:>11} {:>10} {:>8} {:>10} {:>9} {:>8} {:>8}\n",
            "policy",
            "makespan",
            "mean T(k)",
            "mean wait",
            "mean b",
            "cover-miss",
            "messages",
            "max-lag",
            "p50 fin"
        ));
        // the event log streams straight to the file (never buffered in
        // memory — at 10^6 workers a Vec<String> log would dwarf the
        // simulator state); the one BufWriter is threaded through every
        // policy's run via stream_log/take_sink
        let mut sink: Option<Box<dyn std::io::Write + Send>> = match export_events {
            Some(p) => {
                if let Some(dir) = p.parent() {
                    std::fs::create_dir_all(dir)?;
                }
                let f = std::fs::File::create(p)?;
                Some(Box::new(std::io::BufWriter::new(f)))
            }
            None => None,
        };
        let mut summary = Json::obj();
        for &policy in &self.policies {
            let mut sim = ClusterSim::new(
                graph.clone(),
                policy,
                self.iters,
                ComputeTimes::Replay(trace.clone()),
                link.clone(),
            )?;
            if let Some(mut w) = sink.take() {
                use std::io::Write;
                writeln!(w, "# scenario={} policy={}", self.name, policy.name())?;
                sim.stream_log(w);
            }
            let stats = sim.run(&mut NoHooks)?;
            out.push_str(&render_stats_row(&stats));
            if export_events.is_some() {
                sink = sim.take_sink()?;
                anyhow::ensure!(sink.is_some(), "event-log sink lost during run");
            }
            summary.set(&policy.name(), stats_json(&stats));
        }
        drop(sink); // BufWriter flushed by take_sink; close before returning
        out.push_str(
            "(cover-miss > 0 ⇒ the policy left a neighbour unheard for 2·deg straight\n \
             iterations — the Assumption-2 connectivity cb-DyBW keeps for free)\n",
        );
        std::fs::create_dir_all(out_dir)?;
        std::fs::write(
            out_dir.join(format!("des.{}.summary.json", self.name)),
            summary.to_string_pretty(),
        )?;
        Ok(out)
    }

    fn run_full(&self, out_dir: &Path, export_events: Option<&Path>) -> anyhow::Result<String> {
        let mut setup = Setup::default();
        setup.workers = self.workers;
        setup.topology = self.topology;
        setup.model = self.model.clone();
        setup.train_n = self.train_n;
        setup.test_n = self.test_n;
        setup.straggler_base = self.compute;
        setup.straggler_factor = self.transient_factor;
        setup.force_straggler = self.transient_prob > 0.0;
        setup.train.iters = self.iters;
        setup.train.eval_every = self.eval_every;
        setup.train.seed = self.seed;
        // the scenario's own trace (heterogeneity, persistent stragglers,
        // CSV replay) is handed straight to build_des_with_times — the
        // Setup never records one of its own
        let mut rng = Rng::new(self.seed);
        let _ = topology::build(self.topology, self.workers, &mut rng);
        let trace = self.build_trace(&mut rng)?;
        let link = self.link_model();

        let mut out = format!(
            "=== DES scenario '{}' (full fidelity, {} workers, {}, {} iters/worker) ===\n",
            self.name,
            self.workers,
            self.topology.name(),
            self.iters
        );
        out.push_str(&format!(
            "{:>10} | {:>11} {:>10} {:>8} {:>12} {:>12} {:>12}\n",
            "policy", "makespan", "mean wait", "mean b", "final loss", "final err%", "consensus"
        ));
        let mut log_out = String::new();
        for &policy in &self.policies {
            let mut trainer = setup.build_des_with_times(
                policy,
                link.clone(),
                Some(ComputeTimes::Replay(trace.clone())),
            )?;
            if export_events.is_some() {
                trainer.log_events();
            }
            let o = trainer.run()?;
            let e = o
                .history
                .final_eval()
                .ok_or_else(|| anyhow::anyhow!("no eval recorded"))?;
            out.push_str(&format!(
                "{:>10} | {:>10.2}s {:>9.3}s {:>8.2} {:>12.4} {:>12.1} {:>12.4}\n",
                o.stats.policy,
                o.stats.makespan,
                o.stats.mean_wait,
                o.stats.mean_backup,
                e.test_loss,
                e.test_error * 100.0,
                e.consensus_error
            ));
            export::write_csv(
                &o.history,
                out_dir,
                &format!("des.{}.{}", self.name, policy.name().replace(':', "_")),
            )?;
            if export_events.is_some() {
                log_out.push_str(&format!("# scenario={} policy={}\n", self.name, policy.name()));
                for line in &o.event_log {
                    log_out.push_str(line);
                    log_out.push('\n');
                }
            }
        }
        if let Some(p) = export_events {
            if let Some(dir) = p.parent() {
                std::fs::create_dir_all(dir)?;
            }
            std::fs::write(p, log_out)?;
        }
        Ok(out)
    }
}

fn parse_pairs(arr: &[Json], what: &str) -> anyhow::Result<Vec<(f64, f64)>> {
    arr.iter()
        .map(|p| {
            let p = p
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| anyhow::anyhow!("{what} entries are [worker, factor] pairs"))?;
            let a = p[0]
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("non-numeric {what} entry"))?;
            let b = p[1]
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("non-numeric {what} entry"))?;
            Ok((a, b))
        })
        .collect()
}

fn render_stats_row(s: &ClusterStats) -> String {
    format!(
        "{:>10} | {:>10.2}s {:>10.4}s {:>9.4}s {:>8.2} {:>10} {:>9} {:>8} {:>7.2}s\n",
        s.policy,
        s.makespan,
        s.mean_iter_duration,
        s.mean_wait,
        s.mean_backup,
        s.coverage_violations,
        s.messages_sent,
        s.max_lag,
        s.finish_percentile(50.0)
    )
}

fn stats_json(s: &ClusterStats) -> Json {
    let mut o = Json::obj();
    o.set("makespan", s.makespan.into())
        .set("mean_iter_duration", s.mean_iter_duration.into())
        .set("mean_wait", s.mean_wait.into())
        .set("mean_backup", s.mean_backup.into())
        .set("messages_sent", (s.messages_sent as i64).into())
        .set("stale_messages", (s.stale_messages as i64).into())
        .set("events", (s.events as i64).into())
        .set("coverage_violations", (s.coverage_violations as i64).into())
        .set("max_lag", s.max_lag.into())
        .set("p50_finish", s.finish_percentile(50.0).into())
        .set("p99_finish", s.finish_percentile(99.0).into());
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut s = Scenario::default();
        s.name = "rt".into();
        s.workers = 64;
        s.policies = vec![WaitPolicy::Dybw, WaitPolicy::Static { b: 2 }];
        s.persistent = vec![(3, 5.0)];
        s.slow_links = vec![(0, 1, 4.0)];
        s.link_jitter = None;
        // above 2^53: must survive exactly (seeds travel as strings)
        s.seed = (1u64 << 60) + 3;
        let j = s.to_json();
        let s2 = Scenario::from_json(&j).unwrap();
        assert_eq!(s2.name, "rt");
        assert_eq!(s2.workers, 64);
        assert_eq!(s2.policies, s.policies);
        assert_eq!(s2.persistent, s.persistent);
        assert_eq!(s2.slow_links, s.slow_links);
        assert_eq!(s2.link_jitter, None);
        assert_eq!(s2.compute, s.compute);
        assert_eq!(s2.seed, (1u64 << 60) + 3);
    }

    #[test]
    fn from_json_rejects_garbage() {
        for bad in [
            r#"{"workers": 1}"#,
            r#"{"iters": 0}"#,
            r#"{"policies": []}"#,
            r#"{"policies": ["wat"]}"#,
            r#"{"topology": "dodecahedron"}"#,
            r#"{"fidelity": "imaginary"}"#,
            r#"{"compute": "nope:1"}"#,
            r#"{"hetero": 1.5}"#,
            r#"{"persistent": [[1]]}"#,
            r#"{"persistent": [[-1, 5.0]]}"#,
            r#"{"persistent": [[1.5, 2.0]]}"#,
            r#"{"persistent": [[1, -2.0]]}"#,
            r#"{"slow_links": [[1, 2]]}"#,
            r#"{"slow_links": [[0, 1, 2.0], [1, 0, 3.0]]}"#,
            r#"{"slow_links": [[0, 1, -2.0]]}"#,
            r#"{"link_jitter": 5}"#,
            r#"{"link_jitter": "uniform:-0.01,0.01"}"#,
            r#"{"link_base": -0.002}"#,
            r#"{"compute": "det:-0.1"}"#,
            r#"{"compute": "uniform:-0.05,0.2"}"#,
            r#"{"transient_prob": 1.5}"#,
            r#"{"transient_factor": 0}"#,
            r#"{"diurnal_amp": 1.0}"#,
            r#"{"diurnal_amp": -0.1}"#,
            r#"{"diurnal_amp": 0.3}"#,
            r#"{"diurnal_amp": 0.3, "diurnal_period": 0}"#,
            r#"{"diurnal_period": -2}"#,
            r#"{"topology": "racks:0"}"#,
            r#"{"workers": "250"}"#,
            r#"{"wrokers": 6}"#,
            r#"{"seed": 1.5}"#,
            r#"{"seed": "abc"}"#,
            r#"[]"#,
        ] {
            let j = Json::parse(bad).unwrap();
            assert!(Scenario::from_json(&j).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn heavy_tail_diurnal_racks_scenario_runs_and_roundtrips() {
        let dir = std::env::temp_dir().join("dybw_des_scn_divers");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Scenario::default();
        s.name = "divers".into();
        s.workers = 60;
        s.iters = 6;
        s.topology = Topology::Racks(5);
        s.compute = crate::straggler::Dist::Pareto { xm: 0.05, alpha: 2.5 };
        s.diurnal_amp = 0.4;
        s.diurnal_period = 3.0;
        let out = s.run(&dir, None).unwrap();
        assert!(out.contains("racks:5"), "{out}");
        assert!(out.contains("dybw"));
        let s2 = Scenario::from_json(&s.to_json()).unwrap();
        assert_eq!(s2.topology, Topology::Racks(5));
        assert_eq!(s2.diurnal_amp, 0.4);
        assert_eq!(s2.diurnal_period, 3.0);
        assert_eq!(s2.compute, s.compute);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_rejects_out_of_range_injection_targets() {
        // worker counts can shrink after load (CLI override): injection
        // targets outside the cluster must error, not silently vanish.
        let dir = std::env::temp_dir().join("dybw_des_scn_range");
        let mut s = Scenario::default();
        s.workers = 10;
        s.iters = 2;
        s.persistent = vec![(17, 5.0)];
        assert!(s.run(&dir, None).unwrap_err().to_string().contains("persistent"));
        s.persistent.clear();
        s.slow_links = vec![(0, 99, 4.0)];
        assert!(s.run(&dir, None).unwrap_err().to_string().contains("slow_links"));
        // duplicate edges (even direction-flipped) would compound their
        // factors; they must be rejected, not applied twice
        s.slow_links = vec![(0, 1, 4.0), (1, 0, 2.0)];
        let err = s.run(&dir, None).unwrap_err().to_string();
        assert!(err.contains("slow_links") && err.contains("more than once"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn timing_sweep_runs_and_exports() {
        let dir = std::env::temp_dir().join("dybw_des_scn_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Scenario::default();
        s.name = "smoke".into();
        s.workers = 120;
        s.iters = 8;
        let events = dir.join("events.log");
        let out = s.run(&dir, Some(&events)).unwrap();
        assert!(out.contains("dybw"), "{out}");
        assert!(out.contains("full"));
        assert!(dir.join("des.smoke.summary.json").exists());
        let log = std::fs::read_to_string(&events).unwrap();
        assert!(log.contains("# scenario=smoke policy=dybw"));
        assert!(log.contains("compute_done"));
        // re-running produces a byte-identical event log
        let out2 = s.run(&dir, Some(&events)).unwrap();
        assert_eq!(out, out2);
        assert_eq!(std::fs::read_to_string(&events).unwrap(), log);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn full_fidelity_scenario_runs() {
        let dir = std::env::temp_dir().join("dybw_des_scn_full_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = Scenario::default();
        s.name = "fullsmoke".into();
        s.fidelity = Fidelity::Full;
        s.workers = 4;
        s.iters = 6;
        s.eval_every = 3;
        s.policies = vec![WaitPolicy::Dybw];
        s.model = "lrm_d16_c10_b64".into();
        s.train_n = 2000;
        s.test_n = 512;
        let out = s.run(&dir, None).unwrap();
        assert!(out.contains("final loss"), "{out}");
        assert!(dir.join("des.fullsmoke.dybw.evals.csv").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
