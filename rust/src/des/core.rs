//! The discrete-event core: virtual clock + calendar event queue.
//!
//! Everything above this file is simulation *policy*; this file is the
//! simulation *physics*: events carry a virtual timestamp, the queue pops
//! them in time order, and ties break by insertion sequence number — a
//! total, deterministic order, so two runs that schedule the same events
//! process them identically (the byte-for-byte event-log reproducibility
//! the CI `des-smoke` job asserts).
//!
//! Two interchangeable backends live behind the same `schedule`/`pop`
//! API:
//!
//! * **Calendar** (the default, `EventQueue::new`) — a bucket queue in
//!   the style of Brown's calendar queue. Future events hash into
//!   `floor(time / width) mod nbuckets` buckets, unsorted; the bucket
//!   whose window contains the next timestamp is *activated*: drained
//!   into a sorted `active` run popped front-to-back through a cursor.
//!   Pops are O(1), inserts are O(1) appends for future windows, and
//!   the geometry (width, bucket count) is recomputed deterministically
//!   from the stored events on growth — no sampling, no RNG, no wall
//!   clock — so the structure is a pure function of the schedule/pop
//!   sequence. Crucially the *pop order* does not depend on geometry at
//!   all: activation always selects the globally minimal window and
//!   sorts it by `(time, seq)`, so the calendar is bit-identical to a
//!   heap (asserted by the property tests below and `tests/proptests.rs`).
//! * **Heap** (`EventQueue::new_heap`) — the reference `BinaryHeap`
//!   implementation, kept as the oracle for equivalence tests.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::fmt;

/// Virtual time in seconds.
pub type Time = f64;

/// The simulator's event alphabet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// Worker finished the eq. (5) local update of its iteration `k`.
    ComputeDone { worker: usize, k: usize },
    /// Worker `dst` receives `src`'s iteration-`k` parameter estimate.
    MsgArrive { dst: usize, src: usize, k: usize },
    /// Fault plan: the worker crashes / leaves the membership.
    WorkerDown { worker: usize },
    /// Fault plan: the worker (re)joins the membership.
    WorkerUp { worker: usize },
    /// Fault plan: the edge (a, b) partitions (messages queue).
    LinkDown { a: usize, b: usize },
    /// Fault plan: the edge (a, b) heals (queued messages deliver).
    LinkUp { a: usize, b: usize },
}

impl Event {
    /// One deterministic log line (no padding, shortest-roundtrip floats:
    /// identical runs serialise identically byte for byte).
    pub fn log_line(&self, seq: u64, time: Time) -> String {
        match *self {
            Event::ComputeDone { worker, k } => {
                format!("{seq} {time} compute_done w={worker} k={k}")
            }
            Event::MsgArrive { dst, src, k } => {
                format!("{seq} {time} msg_arrive src={src} dst={dst} k={k}")
            }
            Event::WorkerDown { worker } => format!("{seq} {time} worker_down w={worker}"),
            Event::WorkerUp { worker } => format!("{seq} {time} worker_up w={worker}"),
            Event::LinkDown { a, b } => format!("{seq} {time} link_down a={a} b={b}"),
            Event::LinkUp { a, b } => format!("{seq} {time} link_up a={a} b={b}"),
        }
    }
}

/// A rejected `schedule` call. In `--release` a NaN or past-time event
/// used to slip past the `debug_assert!`s and silently corrupt the pop
/// order; now both are hard errors on every build profile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScheduleError {
    /// The event time is NaN or infinite.
    NonFiniteTime(Time),
    /// The event time precedes the virtual clock (the simulated past).
    PastTime { time: Time, now: Time },
}

impl fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            ScheduleError::NonFiniteTime(t) => {
                write!(f, "event time must be finite, got {t}")
            }
            ScheduleError::PastTime { time, now } => {
                write!(f, "cannot schedule into the past: {time} < now {now}")
            }
        }
    }
}

impl std::error::Error for ScheduleError {}

/// A scheduled event. Ordering: earliest `time` first (f64 total order —
/// times are never NaN, checked at insert), then lowest `seq`: ties
/// resolve in scheduling order, never by queue internals.
#[derive(Clone)]
struct Scheduled {
    time: Time,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    // Reversed so the std max-heap pops the EARLIEST event.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Ascending `(time, seq)` comparison for the calendar's active run.
fn asc(a: &Scheduled, b: &Scheduled) -> Ordering {
    a.time
        .total_cmp(&b.time)
        .then_with(|| a.seq.cmp(&b.seq))
}

const MIN_BUCKETS: usize = 4;
const MAX_BUCKETS: usize = 1 << 22;
/// Window indices are clamped here before the `as u64` cast so a tiny
/// width never overflows; events past the clamp simply share the last
/// window (they still pop in exact `(time, seq)` order once activated).
const MAX_WINDOW_IDX: f64 = (1u64 << 62) as f64;

/// Calendar-queue backend. See the module docs for the design.
struct Calendar {
    /// Events of the current window, sorted ascending by `(time, seq)`;
    /// `head` is the pop cursor (popped entries are trimmed lazily when
    /// the window drains rather than memmoved one by one).
    active: Vec<Scheduled>,
    head: usize,
    /// Future events, unsorted, keyed by `floor(time / width) & mask`.
    buckets: Vec<Vec<Scheduled>>,
    width: Time,
    /// Unwrapped index of the window currently being drained.
    cur_window: u64,
    /// Live events across `active[head..]` and all buckets.
    len: usize,
}

impl Calendar {
    fn new() -> Self {
        Calendar {
            active: Vec::new(),
            head: 0,
            buckets: vec![Vec::new(); MIN_BUCKETS],
            width: 1.0,
            cur_window: 0,
            len: 0,
        }
    }

    fn window_of(&self, time: Time) -> u64 {
        (time / self.width).min(MAX_WINDOW_IDX) as u64
    }

    fn push(&mut self, s: Scheduled, clock: Time) {
        if self.len + 1 > 2 * self.buckets.len() && self.buckets.len() < MAX_BUCKETS {
            self.rebuild(clock);
        }
        let w = self.window_of(s.time);
        if w <= self.cur_window {
            // Current window (never the past: schedule() enforces
            // time >= clock): keep the active run sorted. New events
            // carry the largest seq so far, so a same-time burst appends
            // at the end — O(1), no memmove even under mass ties.
            let pos = self.head
                + self.active[self.head..]
                    .partition_point(|e| asc(e, &s) == Ordering::Less);
            self.active.insert(pos, s);
        } else {
            let mask = self.buckets.len() - 1;
            self.buckets[(w as usize) & mask].push(s);
        }
        self.len += 1;
    }

    fn pop(&mut self) -> Option<Scheduled> {
        if self.head == self.active.len() {
            self.activate_next();
        }
        let s = self.active.get(self.head)?.clone();
        self.head += 1;
        self.len -= 1;
        Some(s)
    }

    fn peek_time(&mut self) -> Option<Time> {
        if self.head == self.active.len() {
            self.activate_next();
        }
        self.active.get(self.head).map(|s| s.time)
    }

    /// The active run is spent: find the smallest window holding events,
    /// drain it into `active`, and sort it once. Scans forward from the
    /// current window; if a full cycle (or more inspected entries than
    /// live events) finds nothing, jumps straight to the global minimum.
    fn activate_next(&mut self) {
        self.active.clear();
        self.head = 0;
        if self.len == 0 {
            return;
        }
        let nb = self.buckets.len() as u64;
        let mask = self.buckets.len() - 1;
        let width = self.width;
        let window_of =
            |time: Time| -> u64 { (time / width).min(MAX_WINDOW_IDX) as u64 };
        let mut inspected = 0usize;
        for step in 1..=nb {
            let w = self.cur_window + step;
            let bucket = &mut self.buckets[(w as usize) & mask];
            if bucket.is_empty() {
                continue;
            }
            inspected += bucket.len();
            let mut i = 0;
            while i < bucket.len() {
                if window_of(bucket[i].time) == w {
                    self.active.push(bucket.swap_remove(i));
                } else {
                    i += 1;
                }
            }
            if !self.active.is_empty() {
                self.active.sort_unstable_by(asc);
                self.cur_window = w;
                return;
            }
            if inspected > self.len {
                break;
            }
        }
        // Sparse tail: jump the dial to the window of the global minimum.
        let mut min_time = f64::INFINITY;
        for bucket in &self.buckets {
            for e in bucket {
                if e.time < min_time {
                    min_time = e.time;
                }
            }
        }
        let w = window_of(min_time);
        for bucket in &mut self.buckets {
            let mut i = 0;
            while i < bucket.len() {
                if window_of(bucket[i].time) == w {
                    self.active.push(bucket.swap_remove(i));
                } else {
                    i += 1;
                }
            }
        }
        self.active.sort_unstable_by(asc);
        self.cur_window = w;
    }

    /// Recompute geometry from the stored events — deterministically:
    /// width is the stored time range divided by the event count (≈ one
    /// event per window), bucket count the next power of two. All-tie
    /// schedules (zero range) fall back to width 1.0: everything shares
    /// one window and activation sorts it once.
    fn rebuild(&mut self, clock: Time) {
        let mut all: Vec<Scheduled> = Vec::with_capacity(self.len);
        all.extend(self.active.drain(..).skip(self.head));
        self.head = 0;
        for bucket in &mut self.buckets {
            all.append(bucket);
        }
        debug_assert_eq!(all.len(), self.len);
        let n = all.len().max(1);
        let mut t_min = f64::INFINITY;
        let mut t_max = f64::NEG_INFINITY;
        for e in &all {
            t_min = t_min.min(e.time);
            t_max = t_max.max(e.time);
        }
        let range = (t_max - t_min).max(0.0);
        self.width = if range > 0.0 { range / n as f64 } else { 1.0 };
        let nb = n.next_power_of_two().clamp(MIN_BUCKETS, MAX_BUCKETS);
        self.buckets = vec![Vec::new(); nb];
        let mask = nb - 1;
        self.cur_window = self.window_of(clock);
        for e in all {
            let w = self.window_of(e.time);
            if w <= self.cur_window {
                self.active.push(e);
            } else {
                self.buckets[(w as usize) & mask].push(e);
            }
        }
        self.active.sort_unstable_by(asc);
    }
}

enum Backend {
    Calendar(Calendar),
    Heap(BinaryHeap<Scheduled>),
}

/// The event queue + virtual clock.
pub struct EventQueue {
    backend: Backend,
    next_seq: u64,
    clock: Time,
    processed: u64,
    len: usize,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQueue {
    /// The default calendar-queue backend.
    pub fn new() -> Self {
        EventQueue {
            backend: Backend::Calendar(Calendar::new()),
            next_seq: 0,
            clock: 0.0,
            processed: 0,
            len: 0,
        }
    }

    /// The reference binary-heap backend — same API, same pop order
    /// (asserted by the property tests); kept as the equivalence oracle.
    pub fn new_heap() -> Self {
        EventQueue {
            backend: Backend::Heap(BinaryHeap::new()),
            next_seq: 0,
            clock: 0.0,
            processed: 0,
            len: 0,
        }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> Time {
        self.clock
    }

    /// Events popped so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn len(&self) -> usize {
        self.len
    }

    /// Schedule `event` at absolute virtual time `time` (>= now; the
    /// simulated future only). NaN, infinite, or past times are typed
    /// errors on every build profile — in `--release` they previously
    /// corrupted the pop order silently.
    pub fn schedule(&mut self, time: Time, event: Event) -> Result<(), ScheduleError> {
        if !time.is_finite() {
            return Err(ScheduleError::NonFiniteTime(time));
        }
        if time < self.clock {
            return Err(ScheduleError::PastTime {
                time,
                now: self.clock,
            });
        }
        self.push(time, event);
        Ok(())
    }

    /// Fast path: schedule `event` at the current virtual time. `now()`
    /// is always finite and never in the past, so no validation runs.
    pub fn schedule_at_now(&mut self, event: Event) {
        let time = self.clock;
        self.push(time, event);
    }

    fn push(&mut self, time: Time, event: Event) {
        let seq = self.next_seq;
        self.next_seq += 1;
        let s = Scheduled { time, seq, event };
        match &mut self.backend {
            Backend::Calendar(c) => c.push(s, self.clock),
            Backend::Heap(h) => h.push(s),
        }
        self.len += 1;
    }

    /// Pop the next event, advancing the clock to its timestamp.
    /// Returns `(seq, time, event)`.
    pub fn pop(&mut self) -> Option<(u64, Time, Event)> {
        let s = match &mut self.backend {
            Backend::Calendar(c) => c.pop()?,
            Backend::Heap(h) => h.pop()?,
        };
        debug_assert!(s.time >= self.clock);
        self.clock = s.time;
        self.processed += 1;
        self.len -= 1;
        Some((s.seq, s.time, s.event))
    }

    /// Timestamp of the next event without popping it. `&mut` because
    /// the calendar backend may need to activate a window to look.
    pub fn next_time(&mut self) -> Option<Time> {
        match &mut self.backend {
            Backend::Calendar(c) => c.peek_time(),
            Backend::Heap(h) => h.peek().map(|s| s.time),
        }
    }

    /// Pop the next event and every further event sharing its exact
    /// timestamp into `out` (cleared first), in `(time, seq)` order;
    /// returns the count. Events scheduled *while processing* the batch
    /// carry higher seqs and form a later batch, so draining is provably
    /// the same order as popping one by one — the batching tentpole in
    /// `des::cluster` relies on exactly that.
    pub fn drain_simultaneous(&mut self, out: &mut Vec<(u64, Time, Event)>) -> usize {
        out.clear();
        let Some(first) = self.pop() else {
            return 0;
        };
        let t = first.1;
        out.push(first);
        while self.next_time() == Some(t) {
            match self.pop() {
                Some(e) => out.push(e),
                None => break,
            }
        }
        out.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pops_in_time_order() {
        for mut q in [EventQueue::new(), EventQueue::new_heap()] {
            q.schedule(3.0, Event::ComputeDone { worker: 0, k: 1 }).unwrap();
            q.schedule(1.0, Event::ComputeDone { worker: 1, k: 1 }).unwrap();
            q.schedule(2.0, Event::ComputeDone { worker: 2, k: 1 }).unwrap();
            let order: Vec<usize> = std::iter::from_fn(|| q.pop())
                .map(|(_, _, e)| match e {
                    Event::ComputeDone { worker, .. } => worker,
                    _ => unreachable!(),
                })
                .collect();
            assert_eq!(order, vec![1, 2, 0]);
            assert_eq!(q.now(), 3.0);
            assert_eq!(q.processed(), 3);
        }
    }

    #[test]
    fn ties_break_by_insertion_order() {
        for mut q in [EventQueue::new(), EventQueue::new_heap()] {
            for w in 0..16 {
                q.schedule(1.0, Event::ComputeDone { worker: w, k: 1 }).unwrap();
            }
            // an earlier event interleaved after the ties were queued
            q.schedule(0.5, Event::ComputeDone { worker: 99, k: 1 }).unwrap();
            let order: Vec<usize> = std::iter::from_fn(|| q.pop())
                .map(|(_, _, e)| match e {
                    Event::ComputeDone { worker, .. } => worker,
                    _ => unreachable!(),
                })
                .collect();
            assert_eq!(order[0], 99);
            assert_eq!(&order[1..], &(0..16).collect::<Vec<_>>()[..]);
        }
    }

    #[test]
    fn clock_is_monotone_under_equal_times() {
        let mut q = EventQueue::new();
        q.schedule(0.0, Event::MsgArrive { dst: 0, src: 1, k: 1 }).unwrap();
        q.schedule(0.0, Event::MsgArrive { dst: 1, src: 0, k: 1 }).unwrap();
        let mut last = f64::NEG_INFINITY;
        while let Some((_, t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
    }

    #[test]
    fn log_lines_are_stable() {
        let e = Event::MsgArrive { dst: 3, src: 7, k: 2 };
        assert_eq!(e.log_line(12, 0.25), "12 0.25 msg_arrive src=7 dst=3 k=2");
        let c = Event::ComputeDone { worker: 5, k: 9 };
        assert_eq!(c.log_line(0, 1.5), "0 1.5 compute_done w=5 k=9");
        assert_eq!(
            Event::WorkerDown { worker: 4 }.log_line(3, 2.5),
            "3 2.5 worker_down w=4"
        );
        assert_eq!(Event::WorkerUp { worker: 4 }.log_line(4, 3.5), "4 3.5 worker_up w=4");
        assert_eq!(
            Event::LinkDown { a: 1, b: 2 }.log_line(5, 4.5),
            "5 4.5 link_down a=1 b=2"
        );
        assert_eq!(Event::LinkUp { a: 1, b: 2 }.log_line(6, 5.5), "6 5.5 link_up a=1 b=2");
    }

    #[test]
    fn schedule_rejects_nan_inf_and_past_times_in_release_too() {
        let mut q = EventQueue::new();
        assert!(matches!(
            q.schedule(f64::NAN, Event::ComputeDone { worker: 0, k: 1 }),
            Err(ScheduleError::NonFiniteTime(t)) if t.is_nan()
        ));
        assert!(matches!(
            q.schedule(f64::INFINITY, Event::ComputeDone { worker: 0, k: 1 }),
            Err(ScheduleError::NonFiniteTime(_))
        ));
        q.schedule(2.0, Event::ComputeDone { worker: 0, k: 1 }).unwrap();
        q.pop().unwrap();
        let err = q
            .schedule(1.0, Event::ComputeDone { worker: 1, k: 1 })
            .unwrap_err();
        assert_eq!(err, ScheduleError::PastTime { time: 1.0, now: 2.0 });
        assert!(err.to_string().contains("past"));
        // the queue survives a rejected schedule
        q.schedule(2.5, Event::ComputeDone { worker: 2, k: 1 }).unwrap();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn schedule_at_now_pops_after_existing_ties_at_now() {
        let mut q = EventQueue::new();
        q.schedule(1.0, Event::ComputeDone { worker: 0, k: 1 }).unwrap();
        q.schedule(1.0, Event::ComputeDone { worker: 1, k: 1 }).unwrap();
        let (_, t, _) = q.pop().unwrap();
        assert_eq!(t, 1.0);
        // scheduled at now == 1.0: same timestamp, higher seq → pops last
        q.schedule_at_now(Event::ComputeDone { worker: 7, k: 1 });
        let order: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|(_, _, e)| match e {
                Event::ComputeDone { worker, .. } => worker,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![1, 7]);
    }

    #[test]
    fn drain_simultaneous_splits_tie_groups() {
        let mut q = EventQueue::new();
        for w in 0..5 {
            q.schedule(1.0, Event::ComputeDone { worker: w, k: 1 }).unwrap();
        }
        for w in 5..8 {
            q.schedule(2.0, Event::ComputeDone { worker: w, k: 1 }).unwrap();
        }
        q.schedule(3.0, Event::ComputeDone { worker: 8, k: 1 }).unwrap();
        let mut batch = Vec::new();
        assert_eq!(q.drain_simultaneous(&mut batch), 5);
        assert!(batch.iter().all(|&(_, t, _)| t == 1.0));
        let seqs: Vec<u64> = batch.iter().map(|&(s, _, _)| s).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
        // events scheduled mid-batch at the same timestamp form the NEXT batch
        q.schedule_at_now(Event::ComputeDone { worker: 99, k: 2 });
        assert_eq!(q.drain_simultaneous(&mut batch), 1);
        assert!(matches!(batch[0].2, Event::ComputeDone { worker: 99, .. }));
        assert_eq!(q.drain_simultaneous(&mut batch), 3);
        assert!(batch.iter().all(|&(_, t, _)| t == 2.0));
        assert_eq!(q.drain_simultaneous(&mut batch), 1);
        assert_eq!(q.drain_simultaneous(&mut batch), 0);
        assert!(batch.is_empty());
    }

    #[test]
    fn drain_simultaneous_on_all_tie_schedule() {
        let mut q = EventQueue::new();
        for w in 0..1000 {
            q.schedule(0.5, Event::ComputeDone { worker: w, k: 1 }).unwrap();
        }
        let mut batch = Vec::new();
        assert_eq!(q.drain_simultaneous(&mut batch), 1000);
        for (i, &(seq, t, _)) in batch.iter().enumerate() {
            assert_eq!(seq, i as u64);
            assert_eq!(t, 0.5);
        }
        assert!(q.is_empty());
        assert_eq!(q.next_time(), None);
    }

    /// Property: the calendar queue pops the exact `(seq, time, event)`
    /// sequence of the reference heap, over randomized schedules with
    /// mass ties and interleaved inserts-during-pop. No proptest crate
    /// offline, so this is a seeded sweep with the failing seed printed.
    #[test]
    fn calendar_matches_heap_on_random_schedules() {
        for case in 0..200u64 {
            let mut rng = Rng::new(0xCA1E_0000 + case);
            let mut cal = EventQueue::new();
            let mut heap = EventQueue::new_heap();
            // A few distinct timestamps force mass ties; a wide span
            // forces window jumps and geometry rebuilds.
            let n_times = 1 + (rng.next_u64() % 12) as usize;
            let span = if case % 3 == 0 { 1e-3 } else { 1e3 };
            let times: Vec<f64> = (0..n_times).map(|_| rng.uniform() * span).collect();
            let n_ops = 50 + (rng.next_u64() % 200) as usize;
            let mut popped = 0usize;
            for _ in 0..n_ops {
                let roll = rng.next_u64() % 10;
                if roll < 6 || cal.is_empty() {
                    // schedule a fresh event at (a tie of) a known time,
                    // clamped to the present so both queues accept it
                    let t = times[(rng.next_u64() as usize) % n_times].max(cal.now());
                    let ev = if rng.next_u64() % 2 == 0 {
                        Event::ComputeDone {
                            worker: (rng.next_u64() % 64) as usize,
                            k: 1 + (rng.next_u64() % 8) as usize,
                        }
                    } else {
                        Event::MsgArrive {
                            dst: (rng.next_u64() % 64) as usize,
                            src: (rng.next_u64() % 64) as usize,
                            k: 1 + (rng.next_u64() % 8) as usize,
                        }
                    };
                    cal.schedule(t, ev).unwrap();
                    heap.schedule(t, ev).unwrap();
                } else if roll < 8 {
                    let a = cal.pop();
                    let b = heap.pop();
                    assert_eq!(a, b, "pop diverged at case {case} after {popped} pops");
                    popped += 1;
                    // insert-during-pop: schedule at the just-advanced now
                    if a.is_some() && rng.next_u64() % 2 == 0 {
                        let ev = Event::ComputeDone { worker: 7, k: popped };
                        cal.schedule_at_now(ev);
                        heap.schedule_at_now(ev);
                    }
                } else {
                    let mut ba = Vec::new();
                    let mut bb = Vec::new();
                    cal.drain_simultaneous(&mut ba);
                    heap.drain_simultaneous(&mut bb);
                    assert_eq!(ba, bb, "drain diverged at case {case}");
                    popped += ba.len();
                }
                assert_eq!(cal.len(), heap.len(), "len diverged at case {case}");
            }
            // full drain must match to the last event
            loop {
                let a = cal.pop();
                let b = heap.pop();
                assert_eq!(a, b, "final drain diverged at case {case}");
                if a.is_none() {
                    break;
                }
            }
            assert_eq!(cal.processed(), heap.processed(), "case {case}");
        }
    }
}
