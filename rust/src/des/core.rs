//! The discrete-event core: virtual clock + stable binary-heap queue.
//!
//! Everything above this file is simulation *policy*; this file is the
//! simulation *physics*: events carry a virtual timestamp, the queue pops
//! them in time order, and ties break by insertion sequence number — a
//! total, deterministic order, so two runs that schedule the same events
//! process them identically (the byte-for-byte event-log reproducibility
//! the CI `des-smoke` job asserts).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in seconds.
pub type Time = f64;

/// The simulator's event alphabet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Event {
    /// Worker finished the eq. (5) local update of its iteration `k`.
    ComputeDone { worker: usize, k: usize },
    /// Worker `dst` receives `src`'s iteration-`k` parameter estimate.
    MsgArrive { dst: usize, src: usize, k: usize },
}

impl Event {
    /// One deterministic log line (no padding, shortest-roundtrip floats:
    /// identical runs serialise identically byte for byte).
    pub fn log_line(&self, seq: u64, time: Time) -> String {
        match *self {
            Event::ComputeDone { worker, k } => {
                format!("{seq} {time} compute_done w={worker} k={k}")
            }
            Event::MsgArrive { dst, src, k } => {
                format!("{seq} {time} msg_arrive src={src} dst={dst} k={k}")
            }
        }
    }
}

/// A scheduled event. Ordering: earliest `time` first (f64 total order —
/// times are never NaN, asserted at insert), then lowest `seq`: ties
/// resolve in scheduling order, never by heap internals.
struct Scheduled {
    time: Time,
    seq: u64,
    event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for Scheduled {}
impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Scheduled {
    // Reversed so the std max-heap pops the EARLIEST event.
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The event queue + virtual clock.
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    next_seq: u64,
    clock: Time,
    processed: u64,
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl EventQueue {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            next_seq: 0,
            clock: 0.0,
            processed: 0,
        }
    }

    /// Current virtual time (the timestamp of the last popped event).
    pub fn now(&self) -> Time {
        self.clock
    }

    /// Events popped so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `event` at absolute virtual time `time` (>= now; the
    /// simulated future only).
    pub fn schedule(&mut self, time: Time, event: Event) {
        debug_assert!(time.is_finite(), "event time must be finite: {time}");
        debug_assert!(
            time >= self.clock,
            "cannot schedule into the past: {time} < {}",
            self.clock
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Scheduled { time, seq, event });
    }

    /// Pop the next event, advancing the clock to its timestamp.
    /// Returns `(seq, time, event)`.
    pub fn pop(&mut self) -> Option<(u64, Time, Event)> {
        let s = self.heap.pop()?;
        debug_assert!(s.time >= self.clock);
        self.clock = s.time;
        self.processed += 1;
        Some((s.seq, s.time, s.event))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, Event::ComputeDone { worker: 0, k: 1 });
        q.schedule(1.0, Event::ComputeDone { worker: 1, k: 1 });
        q.schedule(2.0, Event::ComputeDone { worker: 2, k: 1 });
        let order: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|(_, _, e)| match e {
                Event::ComputeDone { worker, .. } => worker,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order, vec![1, 2, 0]);
        assert_eq!(q.now(), 3.0);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for w in 0..16 {
            q.schedule(1.0, Event::ComputeDone { worker: w, k: 1 });
        }
        // an earlier event interleaved after the ties were queued
        q.schedule(0.5, Event::ComputeDone { worker: 99, k: 1 });
        let order: Vec<usize> = std::iter::from_fn(|| q.pop())
            .map(|(_, _, e)| match e {
                Event::ComputeDone { worker, .. } => worker,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(order[0], 99);
        assert_eq!(&order[1..], &(0..16).collect::<Vec<_>>()[..]);
    }

    #[test]
    fn clock_is_monotone_under_equal_times() {
        let mut q = EventQueue::new();
        q.schedule(0.0, Event::MsgArrive { dst: 0, src: 1, k: 1 });
        q.schedule(0.0, Event::MsgArrive { dst: 1, src: 0, k: 1 });
        let mut last = f64::NEG_INFINITY;
        while let Some((_, t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
    }

    #[test]
    fn log_lines_are_stable() {
        let e = Event::MsgArrive { dst: 3, src: 7, k: 2 };
        assert_eq!(e.log_line(12, 0.25), "12 0.25 msg_arrive src=7 dst=3 k=2");
        let c = Event::ComputeDone { worker: 5, k: 9 };
        assert_eq!(c.log_line(0, 1.5), "0 1.5 compute_done w=5 k=9");
    }
}
