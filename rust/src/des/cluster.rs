//! The asynchronous cluster simulator (timing-only fidelity).
//!
//! Every worker runs the consensus loop on its *own* clock: compute the
//! local update (duration drawn from the straggler substrate), broadcast
//! the estimate to the neighbours (per-link latency from
//! [`LinkModel`](crate::straggler::link::LinkModel)), wait until the
//! [`WaitPolicy`] is satisfied by the estimates that actually arrived,
//! mix, repeat. No global barrier exists: at any virtual instant
//! different workers sit at different iterations, which is the regime
//! the paper's wall-clock claims (§5) actually live in.
//!
//! Timing-only mode moves no parameters — an iteration is pure
//! bookkeeping — so a thousand-worker scenario sweep costs milliseconds
//! and the linear-speedup claim can be probed at sizes the lockstep
//! driver cannot touch. The same event loop drives full fidelity through
//! the [`DesHooks`] trait: [`full::DesTrainer`](super::full::DesTrainer)
//! hangs real `EnginePool` gradient jobs and the eq. (6) averaging on
//! the hooks without changing one line of the schedule.
//!
//! Scale: per-worker state lives in one flat [`WorkerBank`] — CSR
//! adjacency arenas, bitsets for arrived/established flags, and
//! structure-of-arrays scalars — roughly 75 bytes per ring worker and
//! zero per-worker heap allocations, so a 10^6-worker scenario fits in
//! well under a gigabyte. The event loop drains all events sharing a
//! timestamp at once ([`EventQueue::drain_simultaneous`]) so full
//! fidelity can batch simultaneous gradient jobs through
//! `EnginePool::grad_many` ([`DesHooks::on_compute_batch`]); each event
//! is still *processed* one at a time in exact `(time, seq)` order, so
//! the schedule — and the event log — is bit-identical to the unbatched
//! loop. The event log itself can stream to any writer ([`LogSink`])
//! instead of accumulating strings in memory.
//!
//! Determinism: event times are pure functions of (worker, k) / (src,
//! dst, k), the queue breaks ties by insertion order, and per-worker
//! mailboxes are plain vectors — two same-seed runs process the same
//! events in the same order and serialise identical event logs
//! (byte-for-byte, asserted by tests and the CI `des-smoke` job).

use std::collections::HashMap;
use std::io::Write;
use std::sync::Arc;
use std::time::Instant;

use crate::graph::Graph;
use crate::obs::registry::{Counter, Gauge, Histogram};
use crate::straggler::link::LinkModel;
use crate::straggler::trace::Trace;
use crate::straggler::Dist;
use crate::util::rng::{stream_seed, Rng};

use super::core::{Event, EventQueue, Time};
use super::policy::WaitPolicy;

/// Tag for compute-time streams (see `stream_seed`).
const COMPUTE_TAG: u64 = 0x434F_4D50; // "COMP"

/// Where per-(worker, iteration) compute times come from.
#[derive(Debug, Clone)]
pub enum ComputeTimes {
    /// t_i(k) = dist.sample(stream(seed, i, k)) · scale[i] — a pure
    /// function of (i, k), so the realisation is identical no matter
    /// which policy consumes it or in which order events fire.
    PerWorker {
        dist: Dist,
        scale: Vec<f64>,
        seed: u64,
    },
    /// Replay a recorded trace: t_i(k) = times[(k-1) mod len][i]. The
    /// strongest A/B form: every policy sees the *identical* timing
    /// realisation.
    Replay(Arc<Trace>),
}

impl ComputeTimes {
    pub fn homogeneous(n: usize, dist: Dist, seed: u64) -> Self {
        ComputeTimes::PerWorker {
            dist,
            scale: vec![1.0; n],
            seed,
        }
    }

    pub fn workers(&self) -> usize {
        match self {
            ComputeTimes::PerWorker { scale, .. } => scale.len(),
            ComputeTimes::Replay(t) => t.workers,
        }
    }

    /// Compute time of worker `i`'s iteration `k` (1-based).
    pub fn time(&self, i: usize, k: usize) -> f64 {
        debug_assert!(k >= 1);
        match self {
            ComputeTimes::PerWorker { dist, scale, seed } => {
                let mut rng = Rng::new(stream_seed(*seed, COMPUTE_TAG, i as u64, k as u64));
                dist.sample(&mut rng) * scale[i]
            }
            ComputeTimes::Replay(t) => t.times[(k - 1) % t.times.len()][i],
        }
    }
}

/// Everything a hook can know about one worker's mix moment.
pub struct MixInfo<'a> {
    pub worker: usize,
    /// The iteration just completed (1-based).
    pub k: usize,
    /// Virtual time of the mix.
    pub now: Time,
    /// now − previous mix (the worker's iteration duration T_i(k)).
    pub iter_duration: f64,
    /// now − own compute completion (time spent waiting on neighbours).
    pub wait: f64,
    /// Global neighbour ids, sorted ascending.
    pub nbrs: &'a [usize],
    /// counted[j] ⇔ nbrs[j]'s iteration-k estimate is in the mix.
    pub counted: &'a [bool],
    /// b_i(k) = deg(i) − |counted| (the realised backup count).
    pub backup: usize,
    /// The backup allowance the wait policy granted this iteration:
    /// 0 for full, min(b, deg−1) for static-b, and deg−1 for dybw
    /// (which mixes on the first fresh arrival). Always ≥ `backup`.
    pub chosen_b: usize,
    /// Iterations completed by EVERY worker after this mix (the global
    /// frontier — full fidelity evaluates when it crosses milestones).
    pub min_done: usize,
}

/// Simulation callbacks. Timing-only mode uses the no-op defaults; full
/// fidelity implements real gradient + averaging math on top.
pub trait DesHooks {
    /// Opt in to [`Self::on_compute_batch`] notifications.
    fn wants_compute_batch(&self) -> bool {
        false
    }

    /// All `(worker, k)` compute completions sharing one virtual
    /// timestamp, in event order, delivered BEFORE their individual
    /// [`Self::on_compute_done`] calls. The per-event calls still fire;
    /// this is a prefetch window: the workers' states are untouched by
    /// any event earlier in the batch (a worker's mix always follows its
    /// own compute), so independent per-worker work — gradient jobs in
    /// full fidelity — can fan out together (`EnginePool::grad_many`)
    /// with results bit-identical to the one-at-a-time path.
    fn on_compute_batch(&mut self, _items: &[(usize, usize)]) -> anyhow::Result<()> {
        Ok(())
    }

    /// Worker `i` finished computing iteration `k`'s local update (its
    /// estimate is broadcast immediately after this returns).
    fn on_compute_done(&mut self, _worker: usize, _k: usize) -> anyhow::Result<()> {
        Ok(())
    }

    /// Worker mixed iteration `k` with the counted estimate set.
    fn on_mix(&mut self, _info: &MixInfo) -> anyhow::Result<()> {
        Ok(())
    }
}

/// Timing-only: no side effects beyond the recorded statistics.
pub struct NoHooks;
impl DesHooks for NoHooks {}

/// Where processed-event log lines go.
///
/// `Memory` is the historical behaviour (one `String` per event —
/// convenient for tests and byte-identity diffs); `Writer` streams each
/// line as it happens, so exporting the event log of a 10^5+-worker run
/// costs no memory proportional to the event count.
pub enum LogSink {
    Memory(Vec<String>),
    Writer(Box<dyn Write + Send>),
}

/// Aggregate outcome of one simulated run.
#[derive(Debug, Clone)]
pub struct ClusterStats {
    pub policy: String,
    pub workers: usize,
    pub iters: usize,
    /// Virtual time at which the LAST worker completed iteration K.
    pub makespan: Time,
    /// Mean per-worker iteration duration.
    pub mean_iter_duration: f64,
    /// Mean b_i(k) over all (worker, iteration) pairs.
    pub mean_backup: f64,
    /// Mean time spent waiting on neighbours after own compute.
    pub mean_wait: f64,
    pub messages_sent: u64,
    /// Estimates that arrived after their iteration was already mixed
    /// (the sender was a backup worker that round) or after the receiver
    /// finished — dropped.
    pub stale_messages: u64,
    pub events: u64,
    /// Σ over workers of coverage-audit violations: a neighbour left
    /// uncounted for 2·deg consecutive iterations (0 for full/dybw by
    /// construction; >0 flags broken Assumption-2 connectivity for
    /// static-b).
    pub coverage_violations: u64,
    /// Max observed iteration spread between fastest and slowest worker.
    pub max_lag: usize,
    /// Workers that left the cluster for good (a [`FaultPlan`] departure
    /// with no later rejoin); their `worker_finish` entry is the leave
    /// time. Always 0 without an injected fault plan.
    pub departed: usize,
    /// Per-worker completion time of iteration K.
    pub worker_finish: Vec<Time>,
}

impl ClusterStats {
    /// p-th percentile (0..=100) of the per-worker finish times.
    pub fn finish_percentile(&self, p: f64) -> Time {
        let mut v = self.worker_finish.clone();
        v.sort_by(f64::total_cmp);
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx]
    }
}

/// A plain bitset over `0..bits`.
struct BitSet {
    words: Vec<u64>,
}

impl BitSet {
    fn new(bits: usize) -> Self {
        BitSet {
            words: vec![0u64; bits.div_ceil(64)],
        }
    }

    #[inline]
    fn get(&self, i: usize) -> bool {
        (self.words[i >> 6] >> (i & 63)) & 1 == 1
    }

    #[inline]
    fn set(&mut self, i: usize) {
        self.words[i >> 6] |= 1u64 << (i & 63);
    }

    #[inline]
    fn clear(&mut self, i: usize) {
        self.words[i >> 6] &= !(1u64 << (i & 63));
    }
}

/// Declarative churn/failure schedule for one simulated run. Times are
/// virtual; every fault is scheduled up-front on the event queue, so a
/// faulty run is exactly as deterministic (and byte-identical under a
/// fixed seed) as a clean one.
///
/// Semantics:
/// - A **down** worker computes nothing and cannot mix, but its mailbox
///   is durable: estimates sent to it still land (its consensus state is
///   not lost, mirroring a process that restarts from a checkpoint). On
///   rejoin its current iteration's compute is rescheduled from the up
///   time — work in flight at the down moment is lost, a completed
///   not-yet-mixed update survives.
/// - Workers listed in `initially_down` join the cluster at their first
///   `ups` time (late joiners); a down worker with no remaining `ups`
///   entry has left for good and is retired from the run (counted in
///   [`ClusterStats::departed`], not deadlocking the finish audit).
/// - A **down edge** queues traffic (store-and-forward): estimates sent
///   across a partitioned edge deliver when the partition heals, paying
///   the usual pure-function link latency from the heal time. Membership
///   is untouched — partitions slow a neighbour down, they do not remove
///   it.
/// - Neighbours of a down worker re-derive their DTUR epoch length d_i
///   from the live degree, and the coverage audit exempts a faulted peer
///   (down, or behind a partitioned edge) until it recovers — so churn
///   windows never count as Assumption-2 violations.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultPlan {
    /// Workers absent from t = 0 (each must join via an `ups` entry).
    pub initially_down: Vec<usize>,
    /// (worker, time): scheduled departures.
    pub downs: Vec<(usize, Time)>,
    /// (worker, time): scheduled (re)joins.
    pub ups: Vec<(usize, Time)>,
    /// (a, b, time): the a–b edge partitions at `time`.
    pub link_downs: Vec<(usize, usize, Time)>,
    /// (a, b, time): the a–b edge heals at `time`.
    pub link_ups: Vec<(usize, usize, Time)>,
}

impl FaultPlan {
    pub fn is_empty(&self) -> bool {
        self.initially_down.is_empty()
            && self.downs.is_empty()
            && self.ups.is_empty()
            && self.link_downs.is_empty()
            && self.link_ups.is_empty()
    }
}

/// Undirected-edge key (normalised endpoint order).
fn edge_key(a: usize, b: usize) -> (u32, u32) {
    if a < b {
        (a as u32, b as u32)
    } else {
        (b as u32, a as u32)
    }
}

/// Mutable fault bookkeeping while a run is in flight. Allocated only
/// when a [`FaultPlan`] is installed; the clean path never touches it.
struct FaultState {
    /// Workers currently down.
    down: BitSet,
    /// Future `WorkerUp` events still scheduled per worker — 0 at a
    /// `WorkerDown` means the departure is terminal.
    rejoins_left: Vec<u32>,
    /// Time of each worker's currently valid `ComputeDone` event. A
    /// completion superseded by a crash (its reschedule at rejoin bears
    /// a different timestamp) is recognised — and skipped — here.
    valid_done_at: Vec<Time>,
    /// Down edges → traffic queued on them as (src, dst, k), send order.
    down_edges: HashMap<(u32, u32), Vec<(u32, u32, u32)>>,
}

impl FaultState {
    fn new(n: usize) -> Self {
        FaultState {
            down: BitSet::new(n),
            rejoins_left: vec![0; n],
            valid_done_at: vec![f64::NAN; n],
            down_edges: HashMap::new(),
        }
    }

    fn edge_down(&self, a: usize, b: usize) -> bool {
        self.down_edges.contains_key(&edge_key(a, b))
    }
}

const NO_PENDING: u32 = u32::MAX;

/// Flat per-worker simulation state: CSR adjacency + bitsets + SoA
/// scalars, shared by all workers. Replaces the old
/// one-struct-per-worker layout (whose `Vec<Vec<usize>>` pending lists,
/// `Vec<bool>` arrival flags, and per-worker `WorkerWait` cost ~8 heap
/// allocations and ~400 bytes per ring worker) with ~75 bytes per ring
/// worker and zero per-worker allocations — the difference between a
/// 10^6-worker scenario fitting in memory or not.
///
/// The wait-policy semantics (including the DTUR epoch rule and the
/// 2·deg coverage audit) are re-implemented here over the flat arrays;
/// [`WorkerWait`](super::policy::WorkerWait) remains the reference
/// implementation, and a property test below drives both on identical
/// arrival sequences and asserts equal decisions.
struct WorkerBank {
    policy: WaitPolicy,
    /// CSR row offsets into `nbrs` (`n + 1` entries).
    offsets: Vec<u32>,
    /// Neighbour arena, ascending within each worker's segment.
    nbrs: Vec<u32>,
    // --- per worker (structure of arrays) ---
    /// Current iteration (1-based); `iters + 1` once finished.
    k: Vec<u32>,
    compute_done: BitSet,
    compute_done_at: Vec<Time>,
    last_mix_at: Vec<Time>,
    finish_at: Vec<Time>,
    /// Arrived estimates for the current iteration (count of set bits in
    /// the worker's `arrived` slot range — O(1) ready checks).
    arrived_count: Vec<u32>,
    /// Dybw: arrived estimates over not-yet-established links.
    fresh_count: Vec<u32>,
    /// Full/static: arrivals needed before the worker may mix.
    needed: Vec<u32>,
    /// Neighbours currently up (= degree without churn). The DTUR epoch
    /// length d_i, the audit window, and the full/static quotas are all
    /// measured against this live view.
    live_deg: Vec<u32>,
    /// Dybw: iterations completed in the current DTUR epoch.
    epoch_pos: Vec<u32>,
    /// Commits so far (the coverage audit's clock).
    mixes: Vec<u32>,
    // --- per slot (CSR arena order) ---
    arrived: BitSet,
    /// Dybw: links counted this epoch (the LocalDtur `established` set).
    established: BitSet,
    /// Coverage audit: mix index at which the slot was last counted.
    last_counted: Vec<u32>,
    /// One buffered early arrival per slot (`NO_PENDING` = none); the
    /// rare slot holding several buffers the rest in `pending_more`
    /// (lookup-only map — iteration order never observed).
    pending_first: Vec<u32>,
    pending_more: HashMap<u32, Vec<u32>>,
    coverage_violations: u64,
}

impl WorkerBank {
    fn new(graph: &Graph, policy: WaitPolicy) -> Self {
        let n = graph.n();
        let total_slots: usize = (0..n).map(|i| graph.degree(i)).sum();
        let mut offsets = Vec::with_capacity(n + 1);
        let mut nbrs: Vec<u32> = Vec::with_capacity(total_slots);
        offsets.push(0u32);
        for i in 0..n {
            // Graph adjacency iterates ascending (BTreeSet), so each CSR
            // segment is sorted by construction — binary search below.
            nbrs.extend(graph.neighbors(i).map(|j| j as u32));
            offsets.push(nbrs.len() as u32);
        }
        let slots = nbrs.len();
        let needed = (0..n)
            .map(|i| {
                let deg = (offsets[i + 1] - offsets[i]) as usize;
                let need = match policy {
                    WaitPolicy::Full => deg,
                    // b clamped to deg − 1: a worker always waits for at
                    // least one estimate (see WorkerWait::ready).
                    WaitPolicy::Static { b } => deg.saturating_sub(b).max(1),
                    WaitPolicy::Dybw => 0,
                };
                need as u32
            })
            .collect();
        let live_deg: Vec<u32> = (0..n).map(|i| offsets[i + 1] - offsets[i]).collect();
        WorkerBank {
            policy,
            offsets,
            nbrs,
            k: vec![1; n],
            compute_done: BitSet::new(n),
            compute_done_at: vec![0.0; n],
            last_mix_at: vec![0.0; n],
            finish_at: vec![f64::NAN; n],
            arrived_count: vec![0; n],
            fresh_count: vec![0; n],
            needed,
            live_deg,
            epoch_pos: vec![0; n],
            mixes: vec![0; n],
            arrived: BitSet::new(slots),
            established: BitSet::new(slots),
            last_counted: vec![0; slots],
            pending_first: vec![NO_PENDING; slots],
            pending_more: HashMap::new(),
            coverage_violations: 0,
        }
    }

    #[inline]
    fn slot_range(&self, i: usize) -> std::ops::Range<usize> {
        self.offsets[i] as usize..self.offsets[i + 1] as usize
    }

    /// The slot of global neighbour `src` in worker `i`'s segment.
    fn local_slot(&self, i: usize, src: usize) -> Option<usize> {
        let r = self.slot_range(i);
        self.nbrs[r.clone()]
            .binary_search(&(src as u32))
            .ok()
            .map(|off| r.start + off)
    }

    /// Record the current-iteration arrival in `slot` of worker `i`.
    fn on_arrival(&mut self, i: usize, slot: usize) {
        if !self.arrived.get(slot) {
            self.arrived.set(slot);
            self.arrived_count[i] += 1;
            if !self.established.get(slot) {
                self.fresh_count[i] += 1;
            }
        }
    }

    /// The policy's backup allowance for worker `i` right now (see
    /// [`MixInfo::chosen_b`]).
    fn chosen_b(&self, i: usize) -> usize {
        let live = self.live_deg[i] as usize;
        match self.policy {
            WaitPolicy::Full => 0,
            WaitPolicy::Static { .. } => live.saturating_sub(self.needed[i] as usize),
            WaitPolicy::Dybw => live.saturating_sub(1),
        }
    }

    /// May worker `i` mix now? O(1) from the maintained counts.
    #[inline]
    fn ready(&self, i: usize) -> bool {
        match self.policy {
            WaitPolicy::Full | WaitPolicy::Static { .. } => {
                self.arrived_count[i] >= self.needed[i]
            }
            // an islanded worker (every neighbour down) mixes alone
            WaitPolicy::Dybw => self.fresh_count[i] > 0 || self.live_deg[i] == 0,
        }
    }

    /// Churn: worker `i`'s live membership changed (a neighbour went
    /// down or came back, or `i` itself just rejoined). Re-derives the
    /// live degree and the policy's arrival quota, restarts the DTUR
    /// epoch with the new d_i (a half-finished epoch over the old
    /// membership proves nothing about the new one), and re-arms the
    /// coverage audit across the whole neighbourhood — the 2·d_i
    /// starvation window is measured against the new membership from the
    /// moment it exists.
    fn membership_changed(&mut self, i: usize, faults: &FaultState) {
        let range = self.slot_range(i);
        let mut live = 0u32;
        for slot in range.clone() {
            if !faults.down.get(self.nbrs[slot] as usize) {
                live += 1;
            }
        }
        self.live_deg[i] = live;
        self.needed[i] = match self.policy {
            WaitPolicy::Full => live,
            // islanded workers (live = 0) mix alone instead of deadlocking
            WaitPolicy::Static { b } => {
                if live == 0 {
                    0
                } else {
                    (live as usize).saturating_sub(b).max(1) as u32
                }
            }
            WaitPolicy::Dybw => 0,
        };
        let mix = self.mixes[i];
        for slot in range {
            self.established.clear(slot);
            self.last_counted[slot] = mix;
        }
        self.epoch_pos[i] = 0;
        // every arrival is fresh again once the established set clears
        self.fresh_count[i] = self.arrived_count[i];
    }

    /// Commit worker `i`'s iteration with the arrived set as the counted
    /// set; advances the DTUR epoch and coverage audit. Returns b_i(k).
    ///
    /// Under churn (`faults` set) every per-neighbour quantity is
    /// measured against the LIVE membership: the DTUR epoch length is
    /// the live degree (the d_i re-derivation), the audit window is
    /// 2·live_deg, and a neighbour that is down — or behind a
    /// partitioned edge — is exempt from the starvation audit while the
    /// fault lasts (its window re-arms, so recovery starts a fresh
    /// 2·d_i grace period instead of firing a spurious violation).
    fn commit(&mut self, i: usize, faults: Option<&FaultState>) -> usize {
        debug_assert!(self.ready(i));
        let live_deg = self.live_deg[i];
        let range = self.slot_range(i);
        self.mixes[i] += 1;
        let mix = self.mixes[i];
        let window = 2 * live_deg.max(1);
        let mut established_live = 0u32;
        let mut arrived_live = 0u32;
        let mut live_seen = 0u32;
        for slot in range.clone() {
            let a = self.arrived.get(slot);
            let (nbr_down, exempt) = match faults {
                Some(f) => {
                    let nbr = self.nbrs[slot] as usize;
                    let d = f.down.get(nbr);
                    (d, d || f.edge_down(i, nbr))
                }
                None => (false, false),
            };
            // coverage audit (all policies): starved neighbours re-arm
            // after each violation, so sustained starvation counts once
            // per 2·deg window (see WorkerWait::commit); faulted
            // neighbours stay armed without ever firing.
            if a || exempt {
                self.last_counted[slot] = mix;
            } else if mix - self.last_counted[slot] >= window {
                self.coverage_violations += 1;
                self.last_counted[slot] = mix;
            }
            if !nbr_down {
                live_seen += 1;
                if a {
                    arrived_live += 1;
                }
            }
            if matches!(self.policy, WaitPolicy::Dybw) {
                if a {
                    self.established.set(slot);
                }
                if !nbr_down && self.established.get(slot) {
                    established_live += 1;
                }
            }
        }
        if matches!(self.policy, WaitPolicy::Dybw) {
            self.epoch_pos[i] += 1;
            // epoch ends after d_i = live_deg iterations, or early once
            // every live link established (LocalDtur::commit)
            if self.epoch_pos[i] >= live_deg.max(1) || established_live == live_seen {
                for slot in range {
                    self.established.clear(slot);
                }
                self.epoch_pos[i] = 0;
            }
        }
        // b_i(k): live neighbours whose estimate was not counted
        (live_seen - arrived_live) as usize
    }

    /// Clear worker `i`'s arrival state for iteration `next_k` and move
    /// any buffered early arrival for `next_k` in.
    fn advance(&mut self, i: usize, next_k: usize) {
        let mut arrived_count = 0u32;
        let mut fresh_count = 0u32;
        for slot in self.slot_range(i) {
            self.arrived.clear(slot);
            if self.pending_take(slot, next_k as u32) {
                self.arrived.set(slot);
                arrived_count += 1;
                if !self.established.get(slot) {
                    fresh_count += 1;
                }
            }
        }
        self.arrived_count[i] = arrived_count;
        self.fresh_count[i] = fresh_count;
    }

    /// Buffer an early arrival (iteration `k` > the worker's current).
    fn pending_push(&mut self, slot: usize, k: usize) {
        let k = k as u32;
        if self.pending_first[slot] == NO_PENDING {
            self.pending_first[slot] = k;
        } else {
            self.pending_more.entry(slot as u32).or_default().push(k);
        }
    }

    /// Remove the buffered arrival for iteration `k` of `slot`, if any.
    /// Iterations are distinct per slot (each (src, k) is broadcast
    /// once), so membership is all that matters.
    fn pending_take(&mut self, slot: usize, k: u32) -> bool {
        if self.pending_first[slot] == k {
            self.pending_first[slot] = match self.pending_more.get_mut(&(slot as u32)) {
                Some(more) => {
                    let next = more.pop().unwrap_or(NO_PENDING);
                    if more.is_empty() {
                        self.pending_more.remove(&(slot as u32));
                    }
                    next
                }
                None => NO_PENDING,
            };
            return true;
        }
        if let Some(more) = self.pending_more.get_mut(&(slot as u32)) {
            if let Some(pos) = more.iter().position(|&pk| pk == k) {
                more.swap_remove(pos);
                if more.is_empty() {
                    self.pending_more.remove(&(slot as u32));
                }
                return true;
            }
        }
        false
    }
}

/// Pre-resolved telemetry instruments for one [`ClusterSim::run`] —
/// looked up once so the hot loop never touches the registry's name
/// map.
struct DesObsHandles {
    wait: Arc<Histogram>,
    compute: Arc<Histogram>,
    iter: Arc<Histogram>,
    backup: Arc<Histogram>,
    mixes: Arc<Counter>,
    events: Arc<Counter>,
    qdepth: Arc<Gauge>,
}

/// The event-driven cluster simulator.
pub struct ClusterSim {
    graph: Graph,
    policy: WaitPolicy,
    iters: usize,
    times: ComputeTimes,
    link: LinkModel,
    /// Injected churn/failure schedule (empty = clean run).
    faults: FaultPlan,
    /// When set, every processed event is appended as one log line.
    log: Option<LogSink>,
    /// Telemetry observer (captured from [`crate::obs::active`] at
    /// construction; override with [`Self::set_obs`]). Observational
    /// only: it reads the virtual clock and event counts, never the RNG
    /// — the recorded history is identical with or without it.
    obs: Option<Arc<crate::obs::Obs>>,
}

impl ClusterSim {
    pub fn new(
        graph: Graph,
        policy: WaitPolicy,
        iters: usize,
        times: ComputeTimes,
        link: LinkModel,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(graph.n() >= 2, "need >= 2 workers");
        anyhow::ensure!(graph.is_connected(), "graph must be connected");
        anyhow::ensure!(iters >= 1, "need >= 1 iteration");
        anyhow::ensure!(
            graph.n() < u32::MAX as usize && iters < u32::MAX as usize,
            "worker count and iteration count must fit u32"
        );
        anyhow::ensure!(
            times.workers() == graph.n(),
            "compute-time source has {} workers, graph {}",
            times.workers(),
            graph.n()
        );
        Ok(ClusterSim {
            graph,
            policy,
            iters,
            times,
            link,
            faults: FaultPlan::default(),
            log: None,
            obs: crate::obs::active(),
        })
    }

    /// Override the telemetry observer (`None` disables it). Benches
    /// use this to price instrumentation without installing a global.
    pub fn set_obs(&mut self, obs: Option<Arc<crate::obs::Obs>>) {
        self.obs = obs;
    }

    /// Inject a churn/failure schedule (see [`FaultPlan`]). Indices and
    /// edges are validated against the graph at run time.
    pub fn set_faults(&mut self, faults: FaultPlan) {
        self.faults = faults;
    }

    /// Record one line per processed event in memory (for byte-for-byte
    /// reproducibility diffs). Costs memory ∝ events; off by default.
    pub fn enable_log(&mut self) {
        self.log = Some(LogSink::Memory(Vec::new()));
    }

    /// Stream one line per processed event to `sink` as it happens —
    /// constant memory, for exporting logs of 10^5+-worker runs.
    pub fn stream_log(&mut self, sink: Box<dyn Write + Send>) {
        self.log = Some(LogSink::Writer(sink));
    }

    /// The recorded in-memory event log (empty unless [`Self::enable_log`]).
    pub fn take_log(&mut self) -> Vec<String> {
        match self.log.take() {
            Some(LogSink::Memory(v)) => v,
            other => {
                self.log = other;
                Vec::new()
            }
        }
    }

    /// Recover the streaming sink set by [`Self::stream_log`] (flushed),
    /// e.g. to hand the same writer to the next policy's run.
    pub fn take_sink(&mut self) -> anyhow::Result<Option<Box<dyn Write + Send>>> {
        match self.log.take() {
            Some(LogSink::Writer(mut w)) => {
                w.flush()?;
                Ok(Some(w))
            }
            other => {
                self.log = other;
                Ok(None)
            }
        }
    }

    /// Run the full simulation: every worker completes `iters`
    /// iterations. Returns the aggregate statistics.
    pub fn run<H: DesHooks>(&mut self, hooks: &mut H) -> anyhow::Result<ClusterStats> {
        let n = self.graph.n();
        let iters = self.iters;
        let mut q = EventQueue::new();
        let mut bank = WorkerBank::new(&self.graph, self.policy);

        // global-frontier bookkeeping: done_at[c] = workers with exactly
        // c completed iterations; min/max completed track the spread.
        let mut done_at = vec![0u64; iters + 1];
        done_at[0] = n as u64;
        let mut min_done = 0usize;
        let mut max_done = 0usize;
        let mut max_lag = 0usize;

        // accumulators
        let mut dur_sum = 0.0f64;
        let mut wait_sum = 0.0f64;
        let mut backup_sum = 0u64;
        let mut messages_sent = 0u64;
        let mut stale = 0u64;
        let mut finished = 0usize;
        let mut departed = 0usize;

        // Fault schedule: every churn event is known up-front, so the
        // processed order is a pure function of the plan — a faulty run
        // is exactly as reproducible as a clean one.
        let faults_on = !self.faults.is_empty();
        let mut fstate = FaultState::new(n);
        if faults_on {
            for &w in &self.faults.initially_down {
                anyhow::ensure!(w < n, "fault worker index {w} >= workers {n}");
                fstate.down.set(w);
            }
            for &(w, t) in &self.faults.downs {
                anyhow::ensure!(w < n, "fault worker index {w} >= workers {n}");
                q.schedule(t, Event::WorkerDown { worker: w })?;
            }
            for &(w, t) in &self.faults.ups {
                anyhow::ensure!(w < n, "fault worker index {w} >= workers {n}");
                q.schedule(t, Event::WorkerUp { worker: w })?;
                fstate.rejoins_left[w] += 1;
            }
            for &(a, b, _) in self.faults.link_downs.iter().chain(&self.faults.link_ups) {
                anyhow::ensure!(a < n && b < n, "fault edge {a}-{b} out of range");
                anyhow::ensure!(
                    bank.local_slot(a, b).is_some(),
                    "fault on non-edge {a}-{b}"
                );
            }
            for &(a, b, t) in &self.faults.link_downs {
                q.schedule(t, Event::LinkDown { a, b })?;
            }
            for &(a, b, t) in &self.faults.link_ups {
                q.schedule(t, Event::LinkUp { a, b })?;
            }
            for &w in &self.faults.initially_down {
                anyhow::ensure!(
                    fstate.rejoins_left[w] > 0,
                    "initially-down worker {w} never joins (no ups entry)"
                );
            }
        }

        for i in 0..n {
            if faults_on && fstate.down.get(i) {
                continue; // joins later; compute starts at its WorkerUp
            }
            let t = self.times.time(i, 1);
            q.schedule(t, Event::ComputeDone { worker: i, k: 1 })?;
            if faults_on {
                fstate.valid_done_at[i] = t;
            }
        }
        if faults_on {
            // initially-down members shrink their neighbours' live view
            for i in 0..n {
                bank.membership_changed(i, &fstate);
            }
        }

        // MixInfo scratch (reused across mixes; filled per mix in O(deg),
        // which the commit/audit pass costs anyway)
        let mut nbr_scratch: Vec<usize> = Vec::new();
        let mut counted_scratch: Vec<bool> = Vec::new();
        // workers an event may have made ready to mix (usually 0 or 1;
        // a membership change can free a whole neighbourhood at once)
        let mut cands: Vec<usize> = Vec::new();
        // same-timestamp event batches (reused)
        let mut batch: Vec<(u64, Time, Event)> = Vec::new();
        let mut compute_batch: Vec<(usize, usize)> = Vec::new();
        // the gradient-prefetch window assumes every batched completion
        // is valid; under churn a completion can be superseded by a
        // crash, so batching is disabled (the one-at-a-time path is the
        // bit-identical reference anyway)
        let wants_batch = hooks.wants_compute_batch() && !faults_on;

        // Telemetry handles resolved once up front: with an observer the
        // per-event cost is a few relaxed atomic adds; without one, a
        // single branch on a local Option. Reads the virtual clock and
        // event counts only — never the RNG — so the recorded history is
        // identical either way (pinned by bit-identity tests).
        let wall_start = Instant::now();
        let obs = self.obs.clone();
        let oh = obs.as_ref().map(|o| DesObsHandles {
            wait: o.registry.histogram("des/wait_secs"),
            compute: o.registry.histogram("des/compute_secs"),
            iter: o.registry.histogram("des/iter_secs"),
            backup: o.registry.histogram("des/backup"),
            mixes: o.registry.counter("des/mixes"),
            events: o.registry.counter("des/events"),
            qdepth: o.registry.gauge("des/queue_depth_max"),
        });
        let policy_name = self.policy.name();

        while q.drain_simultaneous(&mut batch) > 0 {
            if let Some(h) = &oh {
                h.events.add(batch.len() as u64);
                h.qdepth.max(q.len() as i64);
            }
            if wants_batch {
                // hand all simultaneous compute completions to the hook
                // first (a gradient-prefetch window; see the trait docs),
                // then process each event exactly as the one-at-a-time
                // loop would.
                compute_batch.clear();
                compute_batch.extend(batch.iter().filter_map(|&(_, _, ev)| match ev {
                    Event::ComputeDone { worker, k } => Some((worker, k)),
                    _ => None,
                }));
                if compute_batch.len() > 1 {
                    hooks.on_compute_batch(&compute_batch)?;
                }
            }
            for &(seq, now, ev) in &batch {
                match &mut self.log {
                    Some(LogSink::Memory(v)) => v.push(ev.log_line(seq, now)),
                    Some(LogSink::Writer(w)) => {
                        w.write_all(ev.log_line(seq, now).as_bytes())?;
                        w.write_all(b"\n")?;
                    }
                    None => {}
                }
                // workers that might become ready to mix because of this
                // event (membership changes can free several at once)
                cands.clear();
                match ev {
                    Event::ComputeDone { worker, k } => {
                        if faults_on
                            && (fstate.down.get(worker)
                                || k != bank.k[worker] as usize
                                || bank.compute_done.get(worker)
                                || now != fstate.valid_done_at[worker])
                        {
                            // a completion lost to a crash (superseded by
                            // the reschedule at rejoin) — skip it
                        } else {
                            debug_assert_eq!(bank.k[worker] as usize, k);
                            bank.compute_done.set(worker);
                            bank.compute_done_at[worker] = now;
                            hooks.on_compute_done(worker, k)?;
                            // broadcast the estimate to every neighbour
                            for slot in bank.slot_range(worker) {
                                let dst = bank.nbrs[slot] as usize;
                                if faults_on {
                                    if let Some(queued) =
                                        fstate.down_edges.get_mut(&edge_key(worker, dst))
                                    {
                                        // partitioned edge: store-and-forward
                                        queued.push((worker as u32, dst as u32, k as u32));
                                        messages_sent += 1;
                                        continue;
                                    }
                                }
                                let at = now + self.link.latency(worker, dst, k);
                                q.schedule(at, Event::MsgArrive { dst, src: worker, k })?;
                                messages_sent += 1;
                            }
                            cands.push(worker);
                        }
                    }
                    Event::MsgArrive { dst, src, k } => {
                        let wk = bank.k[dst] as usize;
                        if wk > iters || k < wk {
                            // receiver finished, or the sender was a backup
                            // for an iteration the receiver already mixed
                            stale += 1;
                        } else {
                            let slot = bank.local_slot(dst, src).ok_or_else(|| {
                                anyhow::anyhow!("message over non-edge {src}->{dst}")
                            })?;
                            if k > wk {
                                bank.pending_push(slot, k);
                            } else {
                                bank.on_arrival(dst, slot);
                                cands.push(dst);
                            }
                        }
                    }
                    Event::WorkerDown { worker } => {
                        if !fstate.down.get(worker) && (bank.k[worker] as usize) <= iters {
                            fstate.down.set(worker);
                            // neighbours re-derive their live membership
                            // (a smaller quota may make them ready now)
                            for slot in bank.slot_range(worker) {
                                let nbr = bank.nbrs[slot] as usize;
                                if !fstate.down.get(nbr) && (bank.k[nbr] as usize) <= iters {
                                    bank.membership_changed(nbr, &fstate);
                                    cands.push(nbr);
                                }
                            }
                            if fstate.rejoins_left[worker] == 0 {
                                // terminal departure: retire the worker so
                                // the cluster neither waits for it nor
                                // trips the finish audit
                                let c = bank.k[worker] as usize - 1;
                                done_at[c] -= 1;
                                while min_done < iters && done_at[min_done] == 0 {
                                    min_done += 1;
                                }
                                bank.k[worker] = iters as u32 + 1;
                                bank.finish_at[worker] = now;
                                finished += 1;
                                departed += 1;
                            }
                        }
                    }
                    Event::WorkerUp { worker } => {
                        fstate.rejoins_left[worker] =
                            fstate.rejoins_left[worker].saturating_sub(1);
                        if fstate.down.get(worker) && (bank.k[worker] as usize) <= iters {
                            fstate.down.clear(worker);
                            for slot in bank.slot_range(worker) {
                                let nbr = bank.nbrs[slot] as usize;
                                if !fstate.down.get(nbr) && (bank.k[nbr] as usize) <= iters {
                                    bank.membership_changed(nbr, &fstate);
                                    cands.push(nbr);
                                }
                            }
                            // the rejoiner re-derives its own view too: the
                            // membership it left may not be the one it finds
                            bank.membership_changed(worker, &fstate);
                            if bank.compute_done.get(worker) {
                                // its completed update survived the outage
                                // (durable mailbox may already satisfy it)
                                cands.push(worker);
                            } else {
                                let k = bank.k[worker] as usize;
                                let t = now + self.times.time(worker, k);
                                q.schedule(t, Event::ComputeDone { worker, k })?;
                                fstate.valid_done_at[worker] = t;
                            }
                        }
                    }
                    Event::LinkDown { a, b } => {
                        fstate.down_edges.entry(edge_key(a, b)).or_default();
                    }
                    Event::LinkUp { a, b } => {
                        if let Some(queued) = fstate.down_edges.remove(&edge_key(a, b)) {
                            // partition heals: queued traffic drains in
                            // send order, paying link latency from now
                            for (src, dst, k) in queued {
                                let (src, dst, k) = (src as usize, dst as usize, k as usize);
                                let at = now + self.link.latency(src, dst, k);
                                q.schedule(at, Event::MsgArrive { dst, src, k })?;
                            }
                        }
                    }
                }

                // mix every candidate whose wait rule is now satisfied
                for idx in 0..cands.len() {
                    let i = cands[idx];
                    if faults_on && fstate.down.get(i) {
                        continue;
                    }
                    if !bank.compute_done.get(i) || !bank.ready(i) {
                        continue;
                    }
                    let k = bank.k[i] as usize;
                    nbr_scratch.clear();
                    counted_scratch.clear();
                    for slot in bank.slot_range(i) {
                        nbr_scratch.push(bank.nbrs[slot] as usize);
                        counted_scratch.push(bank.arrived.get(slot));
                    }
                    let chosen_b = bank.chosen_b(i);
                    let backup =
                        bank.commit(i, if faults_on { Some(&fstate) } else { None });
                    let iter_duration = now - bank.last_mix_at[i];
                    let wait = now - bank.compute_done_at[i];
                    dur_sum += iter_duration;
                    wait_sum += wait;
                    backup_sum += backup as u64;

                    // frontier update: worker completed iteration k
                    done_at[k - 1] -= 1;
                    done_at[k] += 1;
                    while min_done < iters && done_at[min_done] == 0 {
                        min_done += 1;
                    }
                    max_done = max_done.max(k);
                    max_lag = max_lag.max(max_done - min_done);

                    let info = MixInfo {
                        worker: i,
                        k,
                        now,
                        iter_duration,
                        wait,
                        nbrs: &nbr_scratch,
                        counted: &counted_scratch,
                        backup,
                        chosen_b,
                        min_done,
                    };
                    hooks.on_mix(&info)?;

                    if let Some(h) = &oh {
                        let compute_t = bank.compute_done_at[i] - bank.last_mix_at[i];
                        h.wait.record_secs(wait);
                        h.compute.record_secs(compute_t);
                        h.iter.record_secs(iter_duration);
                        h.backup.record(backup as u64);
                        h.mixes.inc();
                        if let Some(sink) = obs.as_ref().and_then(|o| o.trace()) {
                            // DES trace timestamps are VIRTUAL seconds
                            // scaled to microseconds (one track per
                            // worker, prefixed by policy so multi-policy
                            // scenario runs stay separable).
                            let track = format!("{policy_name}/worker-{i}");
                            let mix_us = (now * 1e6) as u64;
                            let cstart = (bank.last_mix_at[i] * 1e6) as u64;
                            sink.complete(
                                &track,
                                "compute",
                                cstart,
                                (compute_t * 1e6) as u64,
                                &[("k", k as f64)],
                            );
                            sink.complete(
                                &track,
                                "wait",
                                (bank.compute_done_at[i] * 1e6) as u64,
                                (wait * 1e6) as u64,
                                &[("k", k as f64)],
                            );
                            sink.complete(
                                &track,
                                "mix",
                                mix_us,
                                0,
                                &[
                                    ("k", k as f64),
                                    ("b", backup as f64),
                                    ("b_chosen", chosen_b as f64),
                                ],
                            );
                        }
                    }

                    // advance to iteration k+1 (or finish)
                    bank.k[i] += 1;
                    bank.compute_done.clear(i);
                    bank.last_mix_at[i] = now;
                    if bank.k[i] as usize > iters {
                        bank.finish_at[i] = now;
                        finished += 1;
                        continue;
                    }
                    let next_k = bank.k[i] as usize;
                    bank.advance(i, next_k);
                    let t = now + self.times.time(i, next_k);
                    q.schedule(t, Event::ComputeDone { worker: i, k: next_k })?;
                    if faults_on {
                        fstate.valid_done_at[i] = t;
                    }
                }
            }
        }
        if let Some(LogSink::Writer(w)) = &mut self.log {
            w.flush()?;
        }

        if let Some(o) = &obs {
            let wall = wall_start.elapsed().as_secs_f64();
            o.registry.gauge("des/events_total").set(q.processed() as i64);
            if wall > 0.0 {
                o.registry
                    .gauge("des/events_per_sec")
                    .set((q.processed() as f64 / wall) as i64);
            }
        }

        anyhow::ensure!(
            finished == n,
            "deadlock: only {finished}/{n} workers finished (policy {}, {departed} departed)",
            self.policy.name()
        );
        let total_iters = (n * iters) as f64;
        Ok(ClusterStats {
            policy: self.policy.name(),
            workers: n,
            iters,
            makespan: bank.finish_at.iter().copied().fold(0.0, f64::max),
            mean_iter_duration: dur_sum / total_iters,
            mean_backup: backup_sum as f64 / total_iters,
            mean_wait: wait_sum / total_iters,
            messages_sent,
            stale_messages: stale,
            events: q.processed(),
            coverage_violations: bank.coverage_violations,
            max_lag,
            departed,
            worker_finish: bank.finish_at.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::policy::WorkerWait;
    use crate::graph::topology;
    use crate::straggler::StragglerModel;
    use std::sync::Mutex;

    fn ring_trace(n: usize, iters: usize, seed: u64) -> Arc<Trace> {
        let mut rng = Rng::new(seed);
        let model = StragglerModel::paper_default(n, &mut rng);
        Arc::new(Trace::record(&model, iters, &mut rng))
    }

    fn run_policy(
        n: usize,
        iters: usize,
        policy: WaitPolicy,
        trace: Arc<Trace>,
        link: LinkModel,
    ) -> ClusterStats {
        let g = topology::ring(n);
        let mut sim = ClusterSim::new(g, policy, iters, ComputeTimes::Replay(trace), link).unwrap();
        sim.run(&mut NoHooks).unwrap()
    }

    #[test]
    fn full_policy_on_complete_graph_zero_latency_matches_lockstep() {
        // With zero link latency and full participation on a complete
        // graph, the async schedule degenerates to lockstep: every
        // worker mixes iteration k at Σ_{m<=k} max_j t_j(m) — the exact
        // semantics of the lockstep SimTrainer's cb-Full. This pins the
        // DES to the existing driver where their domains overlap.
        let n = 5;
        let iters = 12;
        let trace = ring_trace(n, iters, 7);
        let g = topology::complete(n);
        let mut sim = ClusterSim::new(
            g,
            WaitPolicy::Full,
            iters,
            ComputeTimes::Replay(trace.clone()),
            LinkModel::zero(),
        )
        .unwrap();
        let stats = sim.run(&mut NoHooks).unwrap();
        let lockstep: f64 = trace
            .times
            .iter()
            .map(|row| row.iter().copied().fold(0.0, f64::max))
            .sum();
        assert!((stats.makespan - lockstep).abs() < 1e-9, "{} vs {lockstep}", stats.makespan);
        for &f in &stats.worker_finish {
            assert!((f - lockstep).abs() < 1e-9);
        }
        assert_eq!(stats.mean_backup, 0.0);
        assert_eq!(stats.coverage_violations, 0);
        assert_eq!(stats.max_lag, 1); // workers desync only within an iteration
    }

    #[test]
    fn same_seed_runs_are_byte_identical() {
        let trace = ring_trace(40, 15, 3);
        let link = LinkModel::new(0.002, Some(Dist::ShiftedExp { base: 0.0, rate: 400.0 }), 9);
        let run = || {
            let g = topology::ring(40);
            let mut sim = ClusterSim::new(
                g,
                WaitPolicy::Dybw,
                15,
                ComputeTimes::Replay(trace.clone()),
                link.clone(),
            )
            .unwrap();
            sim.enable_log();
            let stats = sim.run(&mut NoHooks).unwrap();
            (stats, sim.take_log())
        };
        let (s1, l1) = run();
        let (s2, l2) = run();
        assert_eq!(l1, l2, "event logs diverged across same-seed runs");
        assert!(!l1.is_empty());
        assert_eq!(s1.makespan.to_bits(), s2.makespan.to_bits());
        assert_eq!(s1.events, s2.events);
        for (a, b) in s1.worker_finish.iter().zip(&s2.worker_finish) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// A `Write` that appends into a shared buffer — lets the test keep
    /// a handle to bytes written through the boxed sink.
    #[derive(Clone)]
    struct SharedBuf(Arc<Mutex<Vec<u8>>>);
    impl Write for SharedBuf {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }
        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn streamed_log_is_byte_identical_to_memory_log() {
        let trace = ring_trace(20, 8, 13);
        let link = LinkModel::new(0.001, Some(Dist::ShiftedExp { base: 0.0, rate: 600.0 }), 4);
        let build = || {
            ClusterSim::new(
                topology::ring(20),
                WaitPolicy::Dybw,
                8,
                ComputeTimes::Replay(trace.clone()),
                link.clone(),
            )
            .unwrap()
        };
        let mut mem_sim = build();
        mem_sim.enable_log();
        mem_sim.run(&mut NoHooks).unwrap();
        let mut expect: String = String::new();
        for line in mem_sim.take_log() {
            expect.push_str(&line);
            expect.push('\n');
        }

        let buf = SharedBuf(Arc::new(Mutex::new(Vec::new())));
        let mut stream_sim = build();
        stream_sim.stream_log(Box::new(buf.clone()));
        stream_sim.run(&mut NoHooks).unwrap();
        let sink = stream_sim.take_sink().unwrap();
        assert!(sink.is_some(), "sink must be recoverable after the run");
        assert!(stream_sim.take_log().is_empty(), "no in-memory log when streaming");
        let got = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        assert_eq!(got, expect, "streamed event log diverged from in-memory log");
    }

    #[test]
    fn dybw_beats_full_on_identical_realisation_ring_1000() {
        // The acceptance scenario: 1000 workers on a ring, the same
        // recorded trace replayed under each policy. cb-DyBW's wall
        // clock to complete the workload must beat the full-barrier
        // baseline (b = 0), while preserving the per-epoch neighbour
        // coverage the static-b baselines give up.
        let n = 1000;
        let iters = 30;
        let trace = ring_trace(n, iters, 2021);
        let link = LinkModel::new(0.001, Some(Dist::ShiftedExp { base: 0.0, rate: 800.0 }), 5);
        let full = run_policy(n, iters, WaitPolicy::Full, trace.clone(), link.clone());
        let dybw = run_policy(n, iters, WaitPolicy::Dybw, trace.clone(), link.clone());
        let static1 = run_policy(n, iters, WaitPolicy::Static { b: 1 }, trace, link);
        // The async win is structurally smaller than the lockstep 55-70%
        // (every worker always pays its own compute; only neighbour
        // WAITS are saved), and the ring's degree-2 wait is the minimal
        // case: expect ~15% at this scale, assert a safe 5%.
        assert!(
            dybw.makespan < 0.95 * full.makespan,
            "dybw {} vs full {}",
            dybw.makespan,
            full.makespan
        );
        // dynamic backups actually engaged
        assert!(dybw.mean_backup > 0.1, "mean backup {}", dybw.mean_backup);
        // connectivity: full and dybw never skip a neighbour for a whole
        // epoch; the fixed-b baseline silently does.
        assert_eq!(full.coverage_violations, 0);
        assert_eq!(dybw.coverage_violations, 0);
        assert!(static1.coverage_violations > 0);
        // and the run really was asynchronous
        assert!(dybw.max_lag > 1, "no iteration spread: {}", dybw.max_lag);
    }

    #[test]
    fn wait_times_drop_with_backups() {
        let n = 60;
        let iters = 20;
        let trace = ring_trace(n, iters, 8);
        let link = LinkModel::new(0.001, None, 0);
        let full = run_policy(n, iters, WaitPolicy::Full, trace.clone(), link.clone());
        let dybw = run_policy(n, iters, WaitPolicy::Dybw, trace, link);
        assert!(dybw.mean_wait < full.mean_wait);
        assert!(dybw.mean_iter_duration < full.mean_iter_duration);
    }

    #[test]
    fn per_worker_dist_mode_is_deterministic_and_positive() {
        let g = topology::ring(24);
        let times = ComputeTimes::PerWorker {
            dist: Dist::ShiftedExp { base: 0.05, rate: 20.0 },
            scale: (0..24).map(|i| 0.8 + 0.02 * i as f64).collect(),
            seed: 4,
        };
        assert_eq!(times.time(3, 7), times.time(3, 7));
        assert_ne!(times.time(3, 7), times.time(3, 8));
        let mut sim =
            ClusterSim::new(g, WaitPolicy::Static { b: 1 }, 10, times, LinkModel::zero()).unwrap();
        let stats = sim.run(&mut NoHooks).unwrap();
        assert!(stats.makespan > 0.0);
        assert_eq!(stats.worker_finish.len(), 24);
        assert!(stats.messages_sent >= 24 * 10 * 2);
    }

    #[test]
    fn finish_percentiles_ordered() {
        let trace = ring_trace(50, 10, 6);
        let stats = run_policy(50, 10, WaitPolicy::Dybw, trace, LinkModel::zero());
        let p10 = stats.finish_percentile(10.0);
        let p50 = stats.finish_percentile(50.0);
        let p100 = stats.finish_percentile(100.0);
        assert!(p10 <= p50 && p50 <= p100);
        assert_eq!(p100, stats.makespan);
    }

    #[test]
    fn rejects_bad_configs() {
        let g = topology::ring(4);
        let times = ComputeTimes::homogeneous(3, Dist::Deterministic { base: 0.1 }, 0);
        assert!(ClusterSim::new(g.clone(), WaitPolicy::Full, 5, times, LinkModel::zero()).is_err());
        let times = ComputeTimes::homogeneous(4, Dist::Deterministic { base: 0.1 }, 0);
        assert!(ClusterSim::new(g, WaitPolicy::Full, 0, times, LinkModel::zero()).is_err());
    }

    /// Property: the flattened `WorkerBank` wait/commit/audit semantics
    /// match the reference `WorkerWait` on identical arrival sequences,
    /// for every policy. Seeded sweep (no proptest crate offline) with
    /// the failing seed in the assert message.
    #[test]
    fn worker_bank_matches_reference_worker_wait() {
        for case in 0..150u64 {
            let mut rng = Rng::new(0xBA4C + case);
            let deg = 2 + (rng.next_u64() % 5) as usize; // 2..=6
            let policy = match rng.next_u64() % 3 {
                0 => WaitPolicy::Full,
                1 => WaitPolicy::Static { b: (rng.next_u64() % (deg as u64 + 2)) as usize },
                _ => WaitPolicy::Dybw,
            };
            // complete graph on deg+1 nodes gives worker 0 degree `deg`
            let g = topology::complete(deg + 1);
            let mut bank = WorkerBank::new(&g, policy);
            let mut re = WorkerWait::new(policy, deg);
            let mut arrived = vec![false; deg];
            let mut commits = 0usize;
            while commits < 6 * deg {
                // grow the arrival set one estimate at a time
                let j = (rng.next_u64() as usize) % deg;
                if !arrived[j] {
                    arrived[j] = true;
                    // worker 0's neighbours are 1..=deg, so slot j maps
                    // to neighbour j+1
                    bank.on_arrival(0, bank.local_slot(0, j + 1).unwrap());
                }
                assert_eq!(
                    bank.ready(0),
                    re.ready(&arrived),
                    "case {case}, policy {}: ready diverged on {arrived:?}",
                    policy.name()
                );
                if bank.ready(0) && rng.next_u64() % 2 == 0 {
                    let b_bank = bank.commit(0, None);
                    let b_re = re.commit(&arrived);
                    assert_eq!(b_bank, b_re, "case {case}: backup count diverged");
                    bank.advance(0, commits + 2); // no pending: clears arrivals
                    arrived.iter_mut().for_each(|a| *a = false);
                    commits += 1;
                }
            }
            assert_eq!(
                bank.coverage_violations,
                re.coverage_violations,
                "case {case}, policy {}: audit diverged",
                policy.name()
            );
        }
    }

    /// A churn plan exercising every fault type: one transient outage,
    /// one terminal departure, one partition window on an edge.
    fn churn_plan() -> FaultPlan {
        FaultPlan {
            initially_down: Vec::new(),
            downs: vec![(3, 0.8), (7, 1.2)],
            ups: vec![(3, 2.0)],
            link_downs: vec![(0, 1, 0.5)],
            link_ups: vec![(0, 1, 2.5)],
        }
    }

    fn run_churn(policy: WaitPolicy, seed: u64) -> (ClusterStats, Vec<String>) {
        let n = 12;
        let g = topology::ring(n);
        let times = ComputeTimes::PerWorker {
            dist: Dist::ShiftedExp { base: 0.05, rate: 20.0 },
            scale: vec![1.0; n],
            seed,
        };
        let link = LinkModel::new(0.002, Some(Dist::ShiftedExp { base: 0.0, rate: 500.0 }), seed);
        let mut sim = ClusterSim::new(g, policy, 20, times, link).unwrap();
        sim.set_faults(churn_plan());
        sim.enable_log();
        let stats = sim.run(&mut NoHooks).unwrap();
        let log = sim.take_log();
        (stats, log)
    }

    #[test]
    fn churn_runs_are_byte_identical() {
        // same seed + same fault plan → identical event logs, stats bits
        let (s1, l1) = run_churn(WaitPolicy::Dybw, 77);
        let (s2, l2) = run_churn(WaitPolicy::Dybw, 77);
        assert_eq!(l1, l2, "churn event logs diverged across same-seed runs");
        assert!(l1.iter().any(|l| l.contains("worker_down")), "no churn in log");
        assert!(l1.iter().any(|l| l.contains("worker_up")));
        assert!(l1.iter().any(|l| l.contains("link_down")));
        assert_eq!(s1.makespan.to_bits(), s2.makespan.to_bits());
        assert_eq!(s1.events, s2.events);
    }

    #[test]
    fn dybw_and_full_keep_coverage_under_churn() {
        // The tentpole invariant: a neighbour that is down or behind a
        // partition is never counted as an Assumption-2 violation, and
        // after recovery every current neighbour is re-covered within
        // the re-derived 2·d_i window — zero audit violations end to
        // end for both violation-free-by-construction policies.
        for policy in [WaitPolicy::Dybw, WaitPolicy::Full] {
            let (stats, _) = run_churn(policy, 41);
            assert_eq!(
                stats.coverage_violations, 0,
                "{}: churn produced audit violations",
                policy.name()
            );
            // worker 7 left for good; everyone else finished the workload
            assert_eq!(stats.departed, 1, "{}", policy.name());
            assert!(stats.makespan.is_finite() && stats.makespan > 0.0);
            // the partition healed at 2.5: traffic queued on the 0-1 edge
            // was delivered afterwards, so the run outlived the window
            assert!(stats.makespan > 2.5, "{}: makespan {}", policy.name(), stats.makespan);
        }
    }

    #[test]
    fn terminal_departure_retires_worker_at_leave_time() {
        let (stats, _) = run_churn(WaitPolicy::Dybw, 19);
        // worker 7 leaves at t = 1.2 and its finish time is the leave time
        assert_eq!(stats.departed, 1);
        assert!((stats.worker_finish[7] - 1.2).abs() < 1e-12, "{}", stats.worker_finish[7]);
        // the survivors' finish times are real completions, after the leave
        for (i, &f) in stats.worker_finish.iter().enumerate() {
            if i != 7 {
                assert!(f > 1.2, "worker {i} finished at {f}");
            }
        }
    }

    #[test]
    fn late_joiner_catches_up_and_finishes() {
        // worker 5 does not exist until t = 0.6; after joining it drains
        // the durable mailbox (neighbours' earlier broadcasts) and still
        // completes the full workload — no deadlock, no departures.
        let n = 8;
        let g = topology::ring(n);
        let times = ComputeTimes::homogeneous(n, Dist::Deterministic { base: 0.1 }, 0);
        for policy in [WaitPolicy::Full, WaitPolicy::Dybw] {
            let mut sim =
                ClusterSim::new(g.clone(), policy, 10, times.clone(), LinkModel::zero()).unwrap();
            sim.set_faults(FaultPlan {
                initially_down: vec![5],
                ups: vec![(5, 0.6)],
                ..FaultPlan::default()
            });
            let stats = sim.run(&mut NoHooks).unwrap();
            assert_eq!(stats.departed, 0, "{}", policy.name());
            assert_eq!(stats.coverage_violations, 0, "{}", policy.name());
            // the joiner's first compute starts at the join time
            assert!(stats.worker_finish[5] > 0.6, "{}", policy.name());
        }
    }

    #[test]
    fn clean_run_is_unchanged_by_empty_fault_plan() {
        // set_faults(default) must leave the clean fast path — and its
        // byte-exact event log — untouched
        let trace = ring_trace(20, 8, 5);
        let link = LinkModel::new(0.001, Some(Dist::ShiftedExp { base: 0.0, rate: 600.0 }), 2);
        let run = |with_empty_plan: bool| {
            let mut sim = ClusterSim::new(
                topology::ring(20),
                WaitPolicy::Dybw,
                8,
                ComputeTimes::Replay(trace.clone()),
                link.clone(),
            )
            .unwrap();
            if with_empty_plan {
                sim.set_faults(FaultPlan::default());
            }
            sim.enable_log();
            let stats = sim.run(&mut NoHooks).unwrap();
            (stats, sim.take_log())
        };
        let (s1, l1) = run(false);
        let (s2, l2) = run(true);
        assert_eq!(l1, l2);
        assert_eq!(s1.makespan.to_bits(), s2.makespan.to_bits());
        assert_eq!(s1.departed, 0);
    }

    #[test]
    fn rejects_bad_fault_plans() {
        let build = || {
            let times = ComputeTimes::homogeneous(6, Dist::Deterministic { base: 0.1 }, 0);
            ClusterSim::new(topology::ring(6), WaitPolicy::Full, 5, times, LinkModel::zero())
                .unwrap()
        };
        // worker index out of range
        let mut sim = build();
        sim.set_faults(FaultPlan { downs: vec![(9, 1.0)], ..FaultPlan::default() });
        let err = sim.run(&mut NoHooks).unwrap_err().to_string();
        assert!(err.contains("fault worker index"), "{err}");
        // partition on a non-edge
        let mut sim = build();
        sim.set_faults(FaultPlan {
            link_downs: vec![(0, 3, 1.0)],
            link_ups: vec![(0, 3, 2.0)],
            ..FaultPlan::default()
        });
        let err = sim.run(&mut NoHooks).unwrap_err().to_string();
        assert!(err.contains("non-edge"), "{err}");
        // initially-down worker that never joins
        let mut sim = build();
        sim.set_faults(FaultPlan { initially_down: vec![2], ..FaultPlan::default() });
        let err = sim.run(&mut NoHooks).unwrap_err().to_string();
        assert!(err.contains("never joins"), "{err}");
    }

    #[test]
    fn pending_buffer_handles_deep_early_arrivals() {
        // a slot can buffer several future iterations (fast neighbour
        // far ahead); membership semantics must survive the overflow map
        let g = topology::ring(4);
        let mut bank = WorkerBank::new(&g, WaitPolicy::Full);
        let slot = bank.local_slot(0, 1).unwrap();
        for k in [5usize, 3, 9, 7] {
            bank.pending_push(slot, k);
        }
        assert!(!bank.pending_take(slot, 4));
        assert!(bank.pending_take(slot, 3));
        assert!(!bank.pending_take(slot, 3), "taken entries stay gone");
        assert!(bank.pending_take(slot, 5));
        assert!(bank.pending_take(slot, 9));
        assert!(bank.pending_take(slot, 7));
        assert!(!bank.pending_take(slot, 7));
    }
}
