//! The asynchronous cluster simulator (timing-only fidelity).
//!
//! Every worker runs the consensus loop on its *own* clock: compute the
//! local update (duration drawn from the straggler substrate), broadcast
//! the estimate to the neighbours (per-link latency from
//! [`LinkModel`](crate::straggler::link::LinkModel)), wait until the
//! [`WaitPolicy`] is satisfied by the estimates that actually arrived,
//! mix, repeat. No global barrier exists: at any virtual instant
//! different workers sit at different iterations, which is the regime
//! the paper's wall-clock claims (§5) actually live in.
//!
//! Timing-only mode moves no parameters — an iteration is pure
//! bookkeeping — so a thousand-worker scenario sweep costs milliseconds
//! and the linear-speedup claim can be probed at sizes the lockstep
//! driver cannot touch. The same event loop drives full fidelity through
//! the [`DesHooks`] trait: [`full::DesTrainer`](super::full::DesTrainer)
//! hangs real `EnginePool` gradient jobs and the eq. (6) averaging on
//! the hooks without changing one line of the schedule.
//!
//! Determinism: event times are pure functions of (worker, k) / (src,
//! dst, k), the queue breaks ties by insertion order, and per-worker
//! mailboxes are plain vectors — two same-seed runs process the same
//! events in the same order and serialise identical event logs
//! (byte-for-byte, asserted by tests and the CI `des-smoke` job).

use std::sync::Arc;

use crate::graph::Graph;
use crate::straggler::link::LinkModel;
use crate::straggler::trace::Trace;
use crate::straggler::Dist;
use crate::util::rng::{stream_seed, Rng};

use super::core::{Event, EventQueue, Time};
use super::policy::{WaitPolicy, WorkerWait};

/// Tag for compute-time streams (see `stream_seed`).
const COMPUTE_TAG: u64 = 0x434F_4D50; // "COMP"

/// Where per-(worker, iteration) compute times come from.
#[derive(Debug, Clone)]
pub enum ComputeTimes {
    /// t_i(k) = dist.sample(stream(seed, i, k)) · scale[i] — a pure
    /// function of (i, k), so the realisation is identical no matter
    /// which policy consumes it or in which order events fire.
    PerWorker {
        dist: Dist,
        scale: Vec<f64>,
        seed: u64,
    },
    /// Replay a recorded trace: t_i(k) = times[(k-1) mod len][i]. The
    /// strongest A/B form: every policy sees the *identical* timing
    /// realisation.
    Replay(Arc<Trace>),
}

impl ComputeTimes {
    pub fn homogeneous(n: usize, dist: Dist, seed: u64) -> Self {
        ComputeTimes::PerWorker {
            dist,
            scale: vec![1.0; n],
            seed,
        }
    }

    pub fn workers(&self) -> usize {
        match self {
            ComputeTimes::PerWorker { scale, .. } => scale.len(),
            ComputeTimes::Replay(t) => t.workers,
        }
    }

    /// Compute time of worker `i`'s iteration `k` (1-based).
    pub fn time(&self, i: usize, k: usize) -> f64 {
        debug_assert!(k >= 1);
        match self {
            ComputeTimes::PerWorker { dist, scale, seed } => {
                let mut rng = Rng::new(stream_seed(*seed, COMPUTE_TAG, i as u64, k as u64));
                dist.sample(&mut rng) * scale[i]
            }
            ComputeTimes::Replay(t) => t.times[(k - 1) % t.times.len()][i],
        }
    }
}

/// Everything a hook can know about one worker's mix moment.
pub struct MixInfo<'a> {
    pub worker: usize,
    /// The iteration just completed (1-based).
    pub k: usize,
    /// Virtual time of the mix.
    pub now: Time,
    /// now − previous mix (the worker's iteration duration T_i(k)).
    pub iter_duration: f64,
    /// now − own compute completion (time spent waiting on neighbours).
    pub wait: f64,
    /// Global neighbour ids, sorted ascending.
    pub nbrs: &'a [usize],
    /// counted[j] ⇔ nbrs[j]'s iteration-k estimate is in the mix.
    pub counted: &'a [bool],
    /// b_i(k) = deg(i) − |counted|.
    pub backup: usize,
    /// Iterations completed by EVERY worker after this mix (the global
    /// frontier — full fidelity evaluates when it crosses milestones).
    pub min_done: usize,
}

/// Simulation callbacks. Timing-only mode uses the no-op defaults; full
/// fidelity implements real gradient + averaging math on top.
pub trait DesHooks {
    /// Worker `i` finished computing iteration `k`'s local update (its
    /// estimate is broadcast immediately after this returns).
    fn on_compute_done(&mut self, _worker: usize, _k: usize) -> anyhow::Result<()> {
        Ok(())
    }

    /// Worker mixed iteration `k` with the counted estimate set.
    fn on_mix(&mut self, _info: &MixInfo) -> anyhow::Result<()> {
        Ok(())
    }
}

/// Timing-only: no side effects beyond the recorded statistics.
pub struct NoHooks;
impl DesHooks for NoHooks {}

/// Aggregate outcome of one simulated run.
#[derive(Debug, Clone)]
pub struct ClusterStats {
    pub policy: String,
    pub workers: usize,
    pub iters: usize,
    /// Virtual time at which the LAST worker completed iteration K.
    pub makespan: Time,
    /// Mean per-worker iteration duration.
    pub mean_iter_duration: f64,
    /// Mean b_i(k) over all (worker, iteration) pairs.
    pub mean_backup: f64,
    /// Mean time spent waiting on neighbours after own compute.
    pub mean_wait: f64,
    pub messages_sent: u64,
    /// Estimates that arrived after their iteration was already mixed
    /// (the sender was a backup worker that round) or after the receiver
    /// finished — dropped.
    pub stale_messages: u64,
    pub events: u64,
    /// Σ over workers of coverage-audit violations: a neighbour left
    /// uncounted for 2·deg consecutive iterations (0 for full/dybw by
    /// construction; >0 flags broken Assumption-2 connectivity for
    /// static-b).
    pub coverage_violations: u64,
    /// Max observed iteration spread between fastest and slowest worker.
    pub max_lag: usize,
    /// Per-worker completion time of iteration K.
    pub worker_finish: Vec<Time>,
}

impl ClusterStats {
    /// p-th percentile (0..=100) of the per-worker finish times.
    pub fn finish_percentile(&self, p: f64) -> Time {
        let mut v = self.worker_finish.clone();
        v.sort_by(f64::total_cmp);
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx]
    }
}

struct WorkerState {
    /// Sorted global neighbour ids.
    nbrs: Vec<usize>,
    /// Current iteration (1-based); `iters + 1` once finished.
    k: usize,
    compute_done: bool,
    /// When the current iteration's own compute completed.
    compute_done_at: Time,
    /// arrived[j] ⇔ nbrs[j]'s current-iteration estimate is here.
    arrived: Vec<bool>,
    /// Early arrivals per neighbour: iterations > k already received
    /// (a fast neighbour can run ahead — the lag is unbounded in
    /// principle, so this buffers rather than asserts).
    pending: Vec<Vec<usize>>,
    wait: WorkerWait,
    last_mix_at: Time,
    finish_at: Time,
}

impl WorkerState {
    fn local_idx(&self, global: usize) -> Option<usize> {
        self.nbrs.binary_search(&global).ok()
    }
}

/// The event-driven cluster simulator.
pub struct ClusterSim {
    graph: Graph,
    policy: WaitPolicy,
    iters: usize,
    times: ComputeTimes,
    link: LinkModel,
    /// When set, every processed event is appended as one log line.
    log: Option<Vec<String>>,
}

impl ClusterSim {
    pub fn new(
        graph: Graph,
        policy: WaitPolicy,
        iters: usize,
        times: ComputeTimes,
        link: LinkModel,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(graph.n() >= 2, "need >= 2 workers");
        anyhow::ensure!(graph.is_connected(), "graph must be connected");
        anyhow::ensure!(iters >= 1, "need >= 1 iteration");
        anyhow::ensure!(
            times.workers() == graph.n(),
            "compute-time source has {} workers, graph {}",
            times.workers(),
            graph.n()
        );
        Ok(ClusterSim {
            graph,
            policy,
            iters,
            times,
            link,
            log: None,
        })
    }

    /// Record one line per processed event (for byte-for-byte
    /// reproducibility diffs). Costs memory ∝ events; off by default.
    pub fn enable_log(&mut self) {
        self.log = Some(Vec::new());
    }

    /// The recorded event log (empty unless [`Self::enable_log`]).
    pub fn take_log(&mut self) -> Vec<String> {
        self.log.take().unwrap_or_default()
    }

    /// Run the full simulation: every worker completes `iters`
    /// iterations. Returns the aggregate statistics.
    pub fn run<H: DesHooks>(&mut self, hooks: &mut H) -> anyhow::Result<ClusterStats> {
        let n = self.graph.n();
        let iters = self.iters;
        let mut q = EventQueue::new();
        let mut workers: Vec<WorkerState> = (0..n)
            .map(|i| {
                let nbrs: Vec<usize> = self.graph.neighbors(i).collect();
                let deg = nbrs.len();
                WorkerState {
                    nbrs,
                    k: 1,
                    compute_done: false,
                    compute_done_at: 0.0,
                    arrived: vec![false; deg],
                    pending: vec![Vec::new(); deg],
                    wait: WorkerWait::new(self.policy, deg),
                    last_mix_at: 0.0,
                    finish_at: f64::NAN,
                }
            })
            .collect();

        // global-frontier bookkeeping: done_at[c] = workers with exactly
        // c completed iterations; min/max completed track the spread.
        let mut done_at = vec![0u64; iters + 1];
        done_at[0] = n as u64;
        let mut min_done = 0usize;
        let mut max_done = 0usize;
        let mut max_lag = 0usize;

        // accumulators
        let mut dur_sum = 0.0f64;
        let mut wait_sum = 0.0f64;
        let mut backup_sum = 0u64;
        let mut messages_sent = 0u64;
        let mut stale = 0u64;
        let mut finished = 0usize;

        for i in 0..n {
            q.schedule(self.times.time(i, 1), Event::ComputeDone { worker: i, k: 1 });
        }

        while let Some((seq, now, ev)) = q.pop() {
            if let Some(log) = self.log.as_mut() {
                log.push(ev.log_line(seq, now));
            }
            // which worker might become ready to mix because of this event
            let candidate = match ev {
                Event::ComputeDone { worker, k } => {
                    let w = &mut workers[worker];
                    debug_assert_eq!(w.k, k);
                    w.compute_done = true;
                    w.compute_done_at = now;
                    hooks.on_compute_done(worker, k)?;
                    // broadcast the estimate to every neighbour
                    for idx in 0..workers[worker].nbrs.len() {
                        let dst = workers[worker].nbrs[idx];
                        let at = now + self.link.latency(worker, dst, k);
                        q.schedule(at, Event::MsgArrive { dst, src: worker, k });
                        messages_sent += 1;
                    }
                    Some(worker)
                }
                Event::MsgArrive { dst, src, k } => {
                    let w = &mut workers[dst];
                    if w.k > iters || k < w.k {
                        // receiver finished, or the sender was a backup
                        // for an iteration the receiver already mixed
                        stale += 1;
                        None
                    } else {
                        let idx = w
                            .local_idx(src)
                            .ok_or_else(|| anyhow::anyhow!("message over non-edge {src}->{dst}"))?;
                        if k > w.k {
                            w.pending[idx].push(k);
                            None
                        } else {
                            w.arrived[idx] = true;
                            Some(dst)
                        }
                    }
                }
            };

            // mix if the wait rule is now satisfied
            let Some(i) = candidate else { continue };
            let w = &mut workers[i];
            if !w.compute_done || !w.wait.ready(&w.arrived) {
                continue;
            }
            let k = w.k;
            let backup = w.wait.commit(&w.arrived);
            let iter_duration = now - w.last_mix_at;
            let wait = now - w.compute_done_at;
            dur_sum += iter_duration;
            wait_sum += wait;
            backup_sum += backup as u64;

            // frontier update: worker completed iteration k
            done_at[k - 1] -= 1;
            done_at[k] += 1;
            while min_done < iters && done_at[min_done] == 0 {
                min_done += 1;
            }
            max_done = max_done.max(k);
            max_lag = max_lag.max(max_done - min_done);

            let info = MixInfo {
                worker: i,
                k,
                now,
                iter_duration,
                wait,
                nbrs: &w.nbrs,
                counted: &w.arrived,
                backup,
                min_done,
            };
            hooks.on_mix(&info)?;

            // advance to iteration k+1 (or finish)
            let w = &mut workers[i];
            w.k += 1;
            w.compute_done = false;
            w.last_mix_at = now;
            if w.k > iters {
                w.finish_at = now;
                finished += 1;
                continue;
            }
            let next_k = w.k;
            for (slot, pend) in w.arrived.iter_mut().zip(w.pending.iter_mut()) {
                *slot = false;
                // move any early arrival for the new iteration in
                let before = pend.len();
                pend.retain(|&pk| pk != next_k);
                if pend.len() != before {
                    *slot = true;
                }
            }
            q.schedule(
                now + self.times.time(i, next_k),
                Event::ComputeDone { worker: i, k: next_k },
            );
        }

        anyhow::ensure!(
            finished == n,
            "deadlock: only {finished}/{n} workers finished (policy {})",
            self.policy.name()
        );
        let total_iters = (n * iters) as f64;
        Ok(ClusterStats {
            policy: self.policy.name(),
            workers: n,
            iters,
            makespan: workers.iter().map(|w| w.finish_at).fold(0.0, f64::max),
            mean_iter_duration: dur_sum / total_iters,
            mean_backup: backup_sum as f64 / total_iters,
            mean_wait: wait_sum / total_iters,
            messages_sent,
            stale_messages: stale,
            events: q.processed(),
            coverage_violations: workers.iter().map(|w| w.wait.coverage_violations).sum(),
            max_lag,
            worker_finish: workers.iter().map(|w| w.finish_at).collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::topology;
    use crate::straggler::StragglerModel;

    fn ring_trace(n: usize, iters: usize, seed: u64) -> Arc<Trace> {
        let mut rng = Rng::new(seed);
        let model = StragglerModel::paper_default(n, &mut rng);
        Arc::new(Trace::record(&model, iters, &mut rng))
    }

    fn run_policy(
        n: usize,
        iters: usize,
        policy: WaitPolicy,
        trace: Arc<Trace>,
        link: LinkModel,
    ) -> ClusterStats {
        let g = topology::ring(n);
        let mut sim = ClusterSim::new(g, policy, iters, ComputeTimes::Replay(trace), link).unwrap();
        sim.run(&mut NoHooks).unwrap()
    }

    #[test]
    fn full_policy_on_complete_graph_zero_latency_matches_lockstep() {
        // With zero link latency and full participation on a complete
        // graph, the async schedule degenerates to lockstep: every
        // worker mixes iteration k at Σ_{m<=k} max_j t_j(m) — the exact
        // semantics of the lockstep SimTrainer's cb-Full. This pins the
        // DES to the existing driver where their domains overlap.
        let n = 5;
        let iters = 12;
        let trace = ring_trace(n, iters, 7);
        let g = topology::complete(n);
        let mut sim = ClusterSim::new(
            g,
            WaitPolicy::Full,
            iters,
            ComputeTimes::Replay(trace.clone()),
            LinkModel::zero(),
        )
        .unwrap();
        let stats = sim.run(&mut NoHooks).unwrap();
        let lockstep: f64 = trace
            .times
            .iter()
            .map(|row| row.iter().copied().fold(0.0, f64::max))
            .sum();
        assert!((stats.makespan - lockstep).abs() < 1e-9, "{} vs {lockstep}", stats.makespan);
        for &f in &stats.worker_finish {
            assert!((f - lockstep).abs() < 1e-9);
        }
        assert_eq!(stats.mean_backup, 0.0);
        assert_eq!(stats.coverage_violations, 0);
        assert_eq!(stats.max_lag, 1); // workers desync only within an iteration
    }

    #[test]
    fn same_seed_runs_are_byte_identical() {
        let trace = ring_trace(40, 15, 3);
        let link = LinkModel::new(0.002, Some(Dist::ShiftedExp { base: 0.0, rate: 400.0 }), 9);
        let run = || {
            let g = topology::ring(40);
            let mut sim = ClusterSim::new(
                g,
                WaitPolicy::Dybw,
                15,
                ComputeTimes::Replay(trace.clone()),
                link.clone(),
            )
            .unwrap();
            sim.enable_log();
            let stats = sim.run(&mut NoHooks).unwrap();
            (stats, sim.take_log())
        };
        let (s1, l1) = run();
        let (s2, l2) = run();
        assert_eq!(l1, l2, "event logs diverged across same-seed runs");
        assert!(!l1.is_empty());
        assert_eq!(s1.makespan.to_bits(), s2.makespan.to_bits());
        assert_eq!(s1.events, s2.events);
        for (a, b) in s1.worker_finish.iter().zip(&s2.worker_finish) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn dybw_beats_full_on_identical_realisation_ring_1000() {
        // The acceptance scenario: 1000 workers on a ring, the same
        // recorded trace replayed under each policy. cb-DyBW's wall
        // clock to complete the workload must beat the full-barrier
        // baseline (b = 0), while preserving the per-epoch neighbour
        // coverage the static-b baselines give up.
        let n = 1000;
        let iters = 30;
        let trace = ring_trace(n, iters, 2021);
        let link = LinkModel::new(0.001, Some(Dist::ShiftedExp { base: 0.0, rate: 800.0 }), 5);
        let full = run_policy(n, iters, WaitPolicy::Full, trace.clone(), link.clone());
        let dybw = run_policy(n, iters, WaitPolicy::Dybw, trace.clone(), link.clone());
        let static1 = run_policy(n, iters, WaitPolicy::Static { b: 1 }, trace, link);
        // The async win is structurally smaller than the lockstep 55-70%
        // (every worker always pays its own compute; only neighbour
        // WAITS are saved), and the ring's degree-2 wait is the minimal
        // case: expect ~15% at this scale, assert a safe 5%.
        assert!(
            dybw.makespan < 0.95 * full.makespan,
            "dybw {} vs full {}",
            dybw.makespan,
            full.makespan
        );
        // dynamic backups actually engaged
        assert!(dybw.mean_backup > 0.1, "mean backup {}", dybw.mean_backup);
        // connectivity: full and dybw never skip a neighbour for a whole
        // epoch; the fixed-b baseline silently does.
        assert_eq!(full.coverage_violations, 0);
        assert_eq!(dybw.coverage_violations, 0);
        assert!(static1.coverage_violations > 0);
        // and the run really was asynchronous
        assert!(dybw.max_lag > 1, "no iteration spread: {}", dybw.max_lag);
    }

    #[test]
    fn wait_times_drop_with_backups() {
        let n = 60;
        let iters = 20;
        let trace = ring_trace(n, iters, 8);
        let link = LinkModel::new(0.001, None, 0);
        let full = run_policy(n, iters, WaitPolicy::Full, trace.clone(), link.clone());
        let dybw = run_policy(n, iters, WaitPolicy::Dybw, trace, link);
        assert!(dybw.mean_wait < full.mean_wait);
        assert!(dybw.mean_iter_duration < full.mean_iter_duration);
    }

    #[test]
    fn per_worker_dist_mode_is_deterministic_and_positive() {
        let g = topology::ring(24);
        let times = ComputeTimes::PerWorker {
            dist: Dist::ShiftedExp { base: 0.05, rate: 20.0 },
            scale: (0..24).map(|i| 0.8 + 0.02 * i as f64).collect(),
            seed: 4,
        };
        assert_eq!(times.time(3, 7), times.time(3, 7));
        assert_ne!(times.time(3, 7), times.time(3, 8));
        let mut sim =
            ClusterSim::new(g, WaitPolicy::Static { b: 1 }, 10, times, LinkModel::zero()).unwrap();
        let stats = sim.run(&mut NoHooks).unwrap();
        assert!(stats.makespan > 0.0);
        assert_eq!(stats.worker_finish.len(), 24);
        assert!(stats.messages_sent >= 24 * 10 * 2);
    }

    #[test]
    fn finish_percentiles_ordered() {
        let trace = ring_trace(50, 10, 6);
        let stats = run_policy(50, 10, WaitPolicy::Dybw, trace, LinkModel::zero());
        let p10 = stats.finish_percentile(10.0);
        let p50 = stats.finish_percentile(50.0);
        let p100 = stats.finish_percentile(100.0);
        assert!(p10 <= p50 && p50 <= p100);
        assert_eq!(p100, stats.makespan);
    }

    #[test]
    fn rejects_bad_configs() {
        let g = topology::ring(4);
        let times = ComputeTimes::homogeneous(3, Dist::Deterministic { base: 0.1 }, 0);
        assert!(ClusterSim::new(g.clone(), WaitPolicy::Full, 5, times, LinkModel::zero()).is_err());
        let times = ComputeTimes::homogeneous(4, Dist::Deterministic { base: 0.1 }, 0);
        assert!(ClusterSim::new(g, WaitPolicy::Full, 0, times, LinkModel::zero()).is_err());
    }
}
