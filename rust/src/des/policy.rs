//! Per-worker wait policies: when may an asynchronous worker stop
//! collecting neighbour estimates and mix?
//!
//! The lockstep drivers answer this with one global rule per iteration;
//! here every worker answers it locally from the estimates that have
//! actually arrived:
//!
//! | policy     | waits for                                   | paper role |
//! |------------|---------------------------------------------|------------|
//! | `full`     | all deg(i) neighbour estimates              | cb-Full    |
//! | `static:b` | the first deg(i) − b estimates (fixed b)    | static-b   |
//! | `dybw`     | the first not-yet-established link (DTUR)   | Alg. 1+2   |
//!
//! Every policy also runs a *coverage audit*: a violation is a
//! neighbour that went uncounted for 2·deg(i) consecutive iterations.
//! `full` counts everyone every round, and `dybw`'s DTUR epochs count
//! every neighbour at least once per ≤ deg(i) iterations, so the gap
//! between counts is at most 2·deg(i) − 1 — both policies are
//! violation-free *by construction* (the Assumption-2 connectivity the
//! convergence proof needs). For `static:b` the audit measures exactly
//! what the paper argues makes fixed backup workers unsafe: a
//! persistently slow neighbour is silently never heard from.

use crate::coordinator::dtur::LocalDtur;
use crate::util::parse::ParseError;

/// The asynchronous wait rule, parsed from scenario/CLI specs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WaitPolicy {
    /// Wait for every neighbour (asynchronous cb-Full).
    Full,
    /// Fixed b backup workers: wait for the fastest deg(i) − b estimates.
    Static { b: usize },
    /// Dynamic backup workers via the per-worker DTUR (asynchronous
    /// cb-DyBW).
    Dybw,
}

impl WaitPolicy {
    pub fn name(&self) -> String {
        match self {
            WaitPolicy::Full => "full".into(),
            WaitPolicy::Static { b } => format!("static:{b}"),
            WaitPolicy::Dybw => "dybw".into(),
        }
    }

    /// Parse `"full"`, `"static:<b>"`, `"dybw"` (alias `"cb-dybw"`).
    /// Round-trip contract: `parse(&p.name()) == Ok(p)` for every
    /// policy; anything else is a typed [`ParseError`].
    pub fn parse(s: &str) -> Result<WaitPolicy, ParseError> {
        match s {
            "full" => Ok(WaitPolicy::Full),
            "dybw" | "cb-dybw" => Ok(WaitPolicy::Dybw),
            _ => s
                .strip_prefix("static:")
                .and_then(|b| b.parse().ok())
                .map(|b| WaitPolicy::Static { b })
                .ok_or_else(|| {
                    ParseError::new("wait policy", s, "full | static:<b> | dybw")
                }),
        }
    }
}

/// One worker's wait state. Owns the policy-specific bookkeeping (DTUR
/// epoch state) plus the policy-independent epoch-coverage audit.
#[derive(Debug, Clone)]
pub struct WorkerWait {
    policy: WaitPolicy,
    deg: usize,
    dtur: Option<LocalDtur>,
    /// Coverage audit: the mix index at which each neighbour was last
    /// counted (0 = never).
    last_counted: Vec<u64>,
    /// Commits so far.
    mixes: u64,
    /// Times a neighbour went uncounted for 2·deg consecutive mixes
    /// (each starved neighbour re-arms after a violation, so sustained
    /// starvation counts once per 2·deg window, not once per mix).
    pub coverage_violations: u64,
}

impl WorkerWait {
    pub fn new(policy: WaitPolicy, deg: usize) -> Self {
        WorkerWait {
            policy,
            deg,
            dtur: matches!(policy, WaitPolicy::Dybw).then(|| LocalDtur::new(deg)),
            last_counted: vec![0; deg],
            mixes: 0,
            coverage_violations: 0,
        }
    }

    /// May the worker mix now, given which neighbour estimates arrived?
    pub fn ready(&self, arrived: &[bool]) -> bool {
        debug_assert_eq!(arrived.len(), self.deg);
        match &self.policy {
            WaitPolicy::Full => arrived.iter().all(|&a| a),
            WaitPolicy::Static { b } => {
                // b is clamped to deg − 1 (the paper requires b < n_i):
                // a worker always waits for at least ONE estimate, so an
                // oversized b can never silently degenerate the run to
                // zero-communication local SGD.
                let needed = self.deg.saturating_sub(*b).max(1);
                arrived.iter().filter(|&&a| a).count() >= needed
            }
            WaitPolicy::Dybw => self.dtur.as_ref().unwrap().ready(arrived),
        }
    }

    /// Churn: the worker's neighbourhood changed size. The DTUR epoch
    /// restarts with the new d_i (a half-finished epoch over the old
    /// neighbour set proves nothing about the new one), and the audit
    /// re-arms every neighbour at the current mix index — the 2·d_i
    /// starvation window is measured against the *new* membership from
    /// the moment it exists, so a just-joined neighbour is not instantly
    /// "starved" and a just-removed one cannot violate.
    pub fn set_degree(&mut self, deg: usize) {
        if deg == self.deg {
            return;
        }
        self.deg = deg;
        if let Some(d) = self.dtur.as_mut() {
            d.set_degree(deg);
        }
        self.last_counted.clear();
        self.last_counted.resize(deg, self.mixes);
    }

    pub fn deg(&self) -> usize {
        self.deg
    }

    /// Commit the iteration with `arrived` as the counted set; returns
    /// this round's backup count b_i(k) and advances epoch/audit state.
    pub fn commit(&mut self, arrived: &[bool]) -> usize {
        debug_assert!(self.ready(arrived));
        let b = match &mut self.dtur {
            Some(d) => d.commit(arrived),
            None => arrived.iter().filter(|&&a| !a).count(),
        };
        self.mixes += 1;
        for (last, &a) in self.last_counted.iter_mut().zip(arrived) {
            if a {
                *last = self.mixes;
            } else if self.mixes - *last >= 2 * self.deg as u64 {
                self.coverage_violations += 1;
                *last = self.mixes;
            }
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for b in 0..6 {
            let p = WaitPolicy::Static { b };
            assert_eq!(WaitPolicy::parse(&p.name()), Ok(p));
        }
        for p in [WaitPolicy::Full, WaitPolicy::Dybw] {
            assert_eq!(WaitPolicy::parse(&p.name()), Ok(p));
        }
        assert_eq!(WaitPolicy::parse("cb-dybw"), Ok(WaitPolicy::Dybw));
        for bad in ["static:x", "static:", "wat", "", "Full", "dybw "] {
            let err = WaitPolicy::parse(bad).unwrap_err();
            assert_eq!(err.what, "wait policy");
            assert_eq!(err.input, bad);
            assert!(err.to_string().contains("static:<b>"));
        }
    }

    #[test]
    fn full_waits_for_everyone() {
        let mut w = WorkerWait::new(WaitPolicy::Full, 3);
        assert!(!w.ready(&[true, true, false]));
        assert!(w.ready(&[true, true, true]));
        assert_eq!(w.commit(&[true, true, true]), 0);
        assert_eq!(w.coverage_violations, 0);
    }

    #[test]
    fn static_waits_for_order_statistic() {
        let mut w = WorkerWait::new(WaitPolicy::Static { b: 1 }, 3);
        assert!(!w.ready(&[true, false, false]));
        assert!(w.ready(&[true, true, false]));
        assert_eq!(w.commit(&[true, true, false]), 1);
    }

    #[test]
    fn static_oversized_b_still_waits_for_one_estimate() {
        // b >= deg must not degenerate to zero-communication SGD
        let w = WorkerWait::new(WaitPolicy::Static { b: 9 }, 3);
        assert!(!w.ready(&[false, false, false]));
        assert!(w.ready(&[false, true, false]));
    }

    #[test]
    fn static_records_coverage_violations() {
        // deg 2, b = 1: always count neighbour 0, never neighbour 1 —
        // neighbour 1 starves past the 2·deg = 4 gap at mixes 4 and 8.
        let mut w = WorkerWait::new(WaitPolicy::Static { b: 1 }, 2);
        for _ in 0..8 {
            assert!(w.ready(&[true, false]));
            w.commit(&[true, false]);
        }
        assert_eq!(w.coverage_violations, 2);
    }

    #[test]
    fn dybw_never_violates_coverage() {
        // Arbitrary arrival patterns: the wait rule forces a fresh link
        // each commit, so every epoch (≤ deg iterations) counts every
        // neighbour — the gap between counts stays < 2·deg always.
        let mut rng = crate::util::rng::Rng::new(3);
        for deg in [2usize, 3, 5] {
            let mut w = WorkerWait::new(WaitPolicy::Dybw, deg);
            for _ in 0..8 * deg {
                let mut arrived = vec![false; deg];
                // grow the arrival set one estimate at a time until ready
                let mut order: Vec<usize> = (0..deg).collect();
                rng.shuffle(&mut order);
                for &j in &order {
                    arrived[j] = true;
                    if w.ready(&arrived) {
                        break;
                    }
                }
                assert!(w.ready(&arrived));
                w.commit(&arrived);
            }
            assert_eq!(w.coverage_violations, 0, "deg {deg}");
        }
    }

    /// PR-8 churn satellite: after a mid-run degree change the epoch
    /// restarts with the new d_i, and every *current* neighbour is
    /// re-covered within 2·d_i commits — zero audit violations across
    /// growth, shrink, and no-op changes.
    #[test]
    fn dybw_recovers_coverage_after_degree_change() {
        let mut rng = crate::util::rng::Rng::new(9);
        fn drive(w: &mut WorkerWait, rng: &mut crate::util::rng::Rng, rounds: usize) {
            for _ in 0..rounds {
                let deg = w.deg();
                let mut arrived = vec![false; deg];
                let mut order: Vec<usize> = (0..deg).collect();
                rng.shuffle(&mut order);
                for &j in &order {
                    arrived[j] = true;
                    if w.ready(&arrived) {
                        break;
                    }
                }
                assert!(w.ready(&arrived));
                w.commit(&arrived);
            }
        }
        for (from, to) in [(3usize, 5usize), (5, 2), (2, 6), (4, 4)] {
            let mut w = WorkerWait::new(WaitPolicy::Dybw, from);
            drive(&mut w, &mut rng, 2 * from + 1); // land mid-epoch
            w.set_degree(to);
            assert_eq!(w.deg(), to);
            drive(&mut w, &mut rng, 6 * to);
            assert_eq!(w.coverage_violations, 0, "{from}->{to}");
        }
    }

    #[test]
    fn dybw_backup_count_dynamic() {
        let mut w = WorkerWait::new(WaitPolicy::Dybw, 3);
        // first arrival of the epoch satisfies the wait: 2 backups
        assert!(w.ready(&[false, true, false]));
        assert_eq!(w.commit(&[false, true, false]), 2);
        // neighbour 1 established: its arrival alone no longer suffices
        assert!(!w.ready(&[false, true, false]));
        assert!(w.ready(&[true, true, false]));
        assert_eq!(w.commit(&[true, true, false]), 1);
    }
}
