//! Full-fidelity DES: the asynchronous schedule drives **real
//! gradients**.
//!
//! Same event loop as the timing-only simulator ([`ClusterSim`] via
//! [`DesHooks`]), but now a `ComputeDone` event runs an actual eq. (5)
//! local update through the [`EnginePool`], estimates carry real
//! parameter vectors, and a mix applies Metropolis-style weights over
//! the counted neighbourhood:
//!
//!   w_i ← p_ii·w̃_i + Σ_{j ∈ counted} p_ij·w̃_j,
//!   p_ij = 1 / (1 + max(deg_i, deg_j)),  p_ii = 1 − Σ_j p_ij
//!
//! — the paper's eq. (7) weights restricted to the estimates that
//! actually arrived (row-stochastic, so the update is a convex
//! combination even when neighbours are skipped).
//!
//! Bit-reproducible under a fixed seed: compute/link times are pure
//! functions of their coordinates, each worker's batch stream advances
//! only on its own draws, gradient jobs are pure, and mixing runs in
//! sorted-neighbour order — two same-seed runs produce identical event
//! logs, histories, and final parameters (asserted in tests).

use std::path::PathBuf;
use std::sync::Arc;

use crate::coordinator::checkpoint::Checkpoint;
use crate::coordinator::ckpt_manager::CkptManager;
use crate::engine::{AnyBatch, BatchSource, EnginePool};
use crate::graph::Graph;
use crate::metrics::{EvalRecord, IterRecord, RunHistory};
use crate::straggler::link::LinkModel;
use crate::util::vecmath;

use super::cluster::{ClusterSim, ClusterStats, ComputeTimes, DesHooks, FaultPlan, MixInfo};
use super::policy::WaitPolicy;
use crate::coordinator::TrainConfig;

/// Checkpoint/restart wiring for a full-fidelity run.
///
/// The asynchronous DES is bit-reproducible from its seed, so recovery
/// is a **verified replay**: checkpoints are written atomically (via
/// [`CkptManager`]) every `every` frontier milestones, and a resumed
/// run re-executes from iteration 0, asserting — bit for bit — that
/// the replayed parameters, clock, and history at the latest intact
/// checkpoint's milestone equal what was persisted before the crash.
/// Divergence is a hard error (the store was corrupt or the binary
/// changed); agreement proves the resumed run's outputs are byte-
/// identical to an uninterrupted one, which CI enforces with `cmp`.
#[derive(Debug, Clone)]
pub struct RecoveryOpts {
    /// Checkpoint directory (created on demand).
    pub dir: PathBuf,
    /// Checkpoint every this many global-frontier iterations (0 = off).
    pub every: usize,
    /// Keep only the newest `retain` checkpoints (0 = keep all).
    pub retain: usize,
    /// Fault injection: abort right after saving the checkpoint at this
    /// milestone (must be a multiple of `every` to trigger).
    pub kill_at: Option<usize>,
    /// Verify the replay against the latest intact on-disk checkpoint.
    pub resume: bool,
}

/// Outcome of one full-fidelity DES run.
pub struct DesOutcome {
    pub history: RunHistory,
    pub stats: ClusterStats,
    /// Per-event log lines (only when event logging was requested).
    pub event_log: Vec<String>,
    /// Gradient jobs that ran through the batched `grad_many` path
    /// (same-timestamp compute completions fanned out together).
    pub batched_jobs: u64,
}

/// The asynchronous trainer.
pub struct DesTrainer {
    graph: Graph,
    policy: WaitPolicy,
    cfg: TrainConfig,
    times: ComputeTimes,
    link: LinkModel,
    pool: EnginePool,
    sources: Vec<Box<dyn BatchSource>>,
    eval_batches: Vec<AnyBatch>,
    params: Vec<Vec<f32>>,
    model_name: String,
    log_events: bool,
    batch_compute: bool,
    faults: FaultPlan,
    recovery: Option<RecoveryOpts>,
}

impl DesTrainer {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        graph: Graph,
        policy: WaitPolicy,
        cfg: TrainConfig,
        times: ComputeTimes,
        link: LinkModel,
        pool: EnginePool,
        sources: Vec<Box<dyn BatchSource>>,
        eval_batches: Vec<AnyBatch>,
        initial: Vec<f32>,
        model_name: &str,
    ) -> anyhow::Result<Self> {
        let n = graph.n();
        anyhow::ensure!(n >= 2, "need >= 2 workers");
        anyhow::ensure!(sources.len() == n, "one batch source per worker");
        anyhow::ensure!(times.workers() == n, "compute-time source size mismatch");
        anyhow::ensure!(initial.len() == pool.param_count(), "bad init length");
        anyhow::ensure!(graph.is_connected(), "graph must be connected");
        anyhow::ensure!(!eval_batches.is_empty(), "empty eval set");
        Ok(DesTrainer {
            graph,
            policy,
            cfg,
            times,
            link,
            pool,
            sources,
            eval_batches,
            params: vec![initial; n],
            model_name: model_name.to_string(),
            log_events: false,
            batch_compute: true,
            faults: FaultPlan::default(),
            recovery: None,
        })
    }

    /// Inject scheduled membership/partition events into the run.
    pub fn set_faults(&mut self, faults: FaultPlan) {
        self.faults = faults;
    }

    /// Enable milestone checkpointing (and optionally kill/resume).
    pub fn set_recovery(&mut self, recovery: RecoveryOpts) {
        self.recovery = Some(recovery);
    }

    /// Record the per-event log (reproducibility diffs; costs memory).
    pub fn log_events(&mut self) {
        self.log_events = true;
    }

    /// Enable/disable batching same-timestamp gradient jobs through
    /// `EnginePool::grad_many` (on by default). The unbatched path is
    /// kept for the bit-identity assertions: both must produce the same
    /// event log, history, and final parameters.
    pub fn set_batch_compute(&mut self, on: bool) {
        self.batch_compute = on;
    }

    /// Replace the compute-time source (e.g. a CSV trace replay).
    pub fn set_times(&mut self, times: ComputeTimes) -> anyhow::Result<()> {
        anyhow::ensure!(times.workers() == self.graph.n(), "size mismatch");
        self.times = times;
        Ok(())
    }

    /// Network-average parameters.
    pub fn average_params(&self) -> Vec<f32> {
        let rows: Vec<&[f32]> = self.params.iter().map(|p| p.as_slice()).collect();
        vecmath::mean_of(&rows)
    }

    /// Run every worker through `cfg.iters` asynchronous iterations.
    pub fn run(&mut self) -> anyhow::Result<DesOutcome> {
        let n = self.graph.n();
        let dim = self.pool.param_count();
        let degrees: Vec<usize> = (0..n).map(|i| self.graph.degree(i)).collect();
        let nbr_lists: Vec<Vec<usize>> =
            (0..n).map(|i| self.graph.neighbors(i).collect()).collect();
        // reverse index: where worker i sits in each neighbour's list
        let outboxes: Vec<Vec<(usize, usize)>> = (0..n)
            .map(|i| {
                nbr_lists[i]
                    .iter()
                    .map(|&dst| (dst, nbr_lists[dst].binary_search(&i).unwrap()))
                    .collect()
            })
            .collect();

        let mut history = RunHistory::new(
            &format!("des-{}", self.policy.name()),
            &self.model_name,
            "synthetic",
            n,
        );
        history.evals.push(evaluate(
            &self.pool,
            &self.eval_batches,
            &self.params,
            0,
            0.0,
        )?);

        let ckpt = match &self.recovery {
            Some(r) if r.every > 0 => {
                let mgr = CkptManager::new(&r.dir, r.retain)?;
                let verify = if r.resume {
                    mgr.latest()?.map(|(c, _)| c)
                } else {
                    None
                };
                Some(CkptState {
                    mgr,
                    every: r.every,
                    kill_at: r.kill_at,
                    verify,
                    next: r.every,
                    model: &self.model_name,
                })
            }
            _ => None,
        };

        let mut hooks = FullHooks {
            cfg: &self.cfg,
            pool: &self.pool,
            sources: &mut self.sources,
            eval_batches: &self.eval_batches,
            params: &mut self.params,
            tilde: vec![vec![0.0f32; dim]; n],
            last_loss: vec![0.0f32; n],
            mail: nbr_lists.iter().map(|l| vec![Vec::new(); l.len()]).collect(),
            finished: vec![false; n],
            grad_buf: vec![0.0f32; dim],
            mix_buf: vec![0.0f32; dim],
            degrees: &degrees,
            outboxes: &outboxes,
            history: &mut history,
            next_milestone: self.cfg.eval_every.max(1),
            batch_compute: self.batch_compute,
            precomputed: vec![false; n],
            batch_grads: Vec::new(),
            batched_jobs: 0,
            ckpt,
        };
        let mut sim = ClusterSim::new(
            self.graph.clone(),
            self.policy,
            self.cfg.iters,
            self.times.clone(),
            self.link.clone(),
        )?;
        sim.set_faults(self.faults.clone());
        if self.log_events {
            sim.enable_log();
        }
        let stats = sim.run(&mut hooks)?;
        let batched_jobs = hooks.batched_jobs;
        Ok(DesOutcome {
            history,
            stats,
            event_log: sim.take_log(),
            batched_jobs,
        })
    }
}

fn evaluate(
    pool: &EnginePool,
    eval_batches: &[AnyBatch],
    params: &[Vec<f32>],
    k: usize,
    clock: f64,
) -> anyhow::Result<EvalRecord> {
    let rows: Vec<&[f32]> = params.iter().map(|p| p.as_slice()).collect();
    let avg = vecmath::mean_of(&rows);
    let (test_loss, test_error) = pool.score(&avg, eval_batches)?;
    let consensus_error = params
        .iter()
        .map(|p| vecmath::dist(p, &avg))
        .fold(0.0, f64::max);
    Ok(EvalRecord {
        k,
        clock,
        test_loss,
        test_error,
        consensus_error,
    })
}

struct FullHooks<'a> {
    cfg: &'a TrainConfig,
    pool: &'a EnginePool,
    sources: &'a mut Vec<Box<dyn BatchSource>>,
    eval_batches: &'a [AnyBatch],
    params: &'a mut Vec<Vec<f32>>,
    /// w̃_i: worker i's latest eq. (5) local update.
    tilde: Vec<Vec<f32>>,
    last_loss: Vec<f32>,
    /// mail[i][j]: buffered (k, w̃) estimates from neighbour nbrs[i][j].
    /// Payloads are stashed at *send* time (one shared allocation per
    /// compute event, handles fanned to the neighbours); the core's
    /// arrival/pending bookkeeping decides what gets counted, so early
    /// payloads are harmless, late ones are pruned after each mix, and
    /// workers past their final mix stop receiving entirely (their mail
    /// would otherwise accumulate dead payloads until the run ends).
    mail: Vec<Vec<Vec<(usize, Arc<Vec<f32>>)>>>,
    /// finished[i] ⇔ worker i mixed its final iteration.
    finished: Vec<bool>,
    grad_buf: Vec<f32>,
    mix_buf: Vec<f32>,
    degrees: &'a [usize],
    /// outboxes[i]: (dst, local index of i in dst's neighbour list).
    outboxes: &'a [Vec<(usize, usize)>],
    history: &'a mut RunHistory,
    next_milestone: usize,
    batch_compute: bool,
    /// precomputed[i] ⇔ tilde[i]/last_loss[i] already hold iteration
    /// k's eq. (5) update (computed by the batch hook).
    precomputed: Vec<bool>,
    batch_grads: Vec<Vec<f32>>,
    batched_jobs: u64,
    ckpt: Option<CkptState<'a>>,
}

/// Milestone checkpointing state (see [`RecoveryOpts`]).
struct CkptState<'a> {
    mgr: CkptManager,
    every: usize,
    kill_at: Option<usize>,
    /// Latest intact on-disk checkpoint, cross-checked bit-for-bit when
    /// a verified replay passes its milestone.
    verify: Option<Checkpoint>,
    /// Next frontier milestone to checkpoint at.
    next: usize,
    model: &'a str,
}

impl DesHooks for FullHooks<'_> {
    fn wants_compute_batch(&self) -> bool {
        self.batch_compute
    }

    fn on_compute_batch(&mut self, items: &[(usize, usize)]) -> anyhow::Result<()> {
        // Fan all simultaneous gradient jobs out together. Safe because
        // no event earlier in the batch can touch these workers' params
        // or batch streams (a worker's mix always follows its own
        // compute event), and bit-identical because each lane job is the
        // exact same pure computation grad_one would run; batch draws
        // happen in event order, just as the per-event path would.
        let dim = self.grad_buf.len();
        while self.batch_grads.len() < items.len() {
            self.batch_grads.push(vec![0.0f32; dim]);
        }
        let batches: Vec<AnyBatch> = items
            .iter()
            .map(|&(i, _)| self.sources[i].next_train(self.cfg.batch_size))
            .collect();
        let ws: Vec<&[f32]> = items.iter().map(|&(i, _)| self.params[i].as_slice()).collect();
        let losses = self
            .pool
            .grad_many(&ws, &batches, &mut self.batch_grads[..items.len()])?;
        for (j, &(i, k)) in items.iter().enumerate() {
            self.last_loss[i] = losses[j];
            let eta = self.cfg.lr(k) as f32;
            self.tilde[i].copy_from_slice(&self.params[i]);
            vecmath::axpy(&mut self.tilde[i], -eta, &self.batch_grads[j]);
            self.precomputed[i] = true;
        }
        self.batched_jobs += items.len() as u64;
        Ok(())
    }

    fn on_compute_done(&mut self, i: usize, k: usize) -> anyhow::Result<()> {
        if self.precomputed[i] {
            // the batch hook already ran eq. (5) for this event
            self.precomputed[i] = false;
        } else {
            let batch = self.sources[i].next_train(self.cfg.batch_size);
            let loss = self
                .pool
                .grad_one(&self.params[i], &batch, &mut self.grad_buf)?;
            self.last_loss[i] = loss;
            let eta = self.cfg.lr(k) as f32;
            self.tilde[i].copy_from_slice(&self.params[i]);
            vecmath::axpy(&mut self.tilde[i], -eta, &self.grad_buf);
        }
        let estimate = Arc::new(self.tilde[i].clone());
        for &(dst, slot) in &self.outboxes[i] {
            if !self.finished[dst] {
                self.mail[dst][slot].push((k, Arc::clone(&estimate)));
            }
        }
        Ok(())
    }

    fn on_mix(&mut self, info: &MixInfo) -> anyhow::Result<()> {
        let i = info.worker;
        let k = info.k;
        // Metropolis weights over the counted neighbourhood.
        let mut self_weight = 1.0f32;
        self.mix_buf.fill(0.0);
        for (j, (&nbr, &counted)) in info.nbrs.iter().zip(info.counted).enumerate() {
            let inbox = &mut self.mail[i][j];
            if counted {
                let pos = inbox
                    .iter()
                    .position(|e| e.0 == k)
                    .ok_or_else(|| anyhow::anyhow!("counted estimate without payload"))?;
                let (_, payload) = inbox.swap_remove(pos);
                let w = 1.0 / (1 + self.degrees[i].max(self.degrees[nbr])) as f32;
                vecmath::axpy(&mut self.mix_buf, w, &payload);
                self_weight -= w;
            }
            // estimates for iterations the worker has now passed can
            // never be counted anymore — drop them
            inbox.retain(|e| e.0 > k);
        }
        vecmath::axpy(&mut self.mix_buf, self_weight, &self.tilde[i]);
        self.params[i].copy_from_slice(&self.mix_buf);
        if k >= self.cfg.iters {
            self.finished[i] = true;
        }

        self.history.iters.push(IterRecord {
            k,
            duration: info.iter_duration,
            clock: info.now,
            train_loss: self.last_loss[i] as f64,
            active: 1 + info.counted.iter().filter(|&&c| c).count(),
            backup_avg: info.backup as f64,
            theta: info.wait,
        });

        // evaluate whenever the global frontier crosses a milestone
        while self.cfg.eval_every > 0 && info.min_done >= self.next_milestone {
            let rec = evaluate(
                self.pool,
                self.eval_batches,
                self.params,
                self.next_milestone,
                info.now,
            )?;
            self.history.evals.push(rec);
            self.next_milestone += self.cfg.eval_every;
        }

        // checkpoint whenever the global frontier crosses a milestone
        if let Some(c) = self.ckpt.as_mut() {
            while info.min_done >= c.next {
                let m = c.next;
                c.next += c.every;
                if matches!(&c.verify, Some(v) if v.iteration == m) {
                    let v = c.verify.take().unwrap();
                    anyhow::ensure!(
                        v.clock.to_bits() == info.now.to_bits(),
                        "resume verification failed at milestone {m}: replayed \
                         clock {} != checkpointed {}",
                        info.now,
                        v.clock
                    );
                    anyhow::ensure!(
                        v.history.bits_eq(self.history),
                        "resume verification failed at milestone {m}: replayed \
                         history diverges from the checkpoint"
                    );
                    let same = v.params.len() == self.params.len()
                        && v.params.iter().zip(self.params.iter()).all(|(a, b)| {
                            a.len() == b.len()
                                && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
                        });
                    anyhow::ensure!(
                        same,
                        "resume verification failed at milestone {m}: replayed \
                         parameters diverge from the checkpoint"
                    );
                }
                let snap = Checkpoint {
                    iteration: m,
                    clock: info.now,
                    model: c.model.to_string(),
                    params: self.params.clone(),
                    history: self.history.clone(),
                };
                c.mgr.save(&snap)?;
                if c.kill_at == Some(m) {
                    anyhow::bail!("killed at checkpoint milestone {m} (kill_at fault injection)");
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::data::batch::BatchSampler;
    use crate::data::partition::{split, Partition};
    use crate::data::synthetic::{gaussian_mixture, MixtureSpec};
    use crate::engine::{native_factory, DenseSource};
    use crate::graph::topology;
    use crate::model::ModelMeta;
    use crate::straggler::trace::Trace;
    use crate::straggler::{Dist, StragglerModel};
    use crate::util::rng::Rng;

    fn build(policy: WaitPolicy, iters: usize, seed: u64, trace: Arc<Trace>) -> DesTrainer {
        let link = LinkModel::new(0.002, Some(Dist::ShiftedExp { base: 0.0, rate: 500.0 }), seed);
        build_custom(policy, iters, seed, ComputeTimes::Replay(trace), link)
    }

    fn build_custom(
        policy: WaitPolicy,
        iters: usize,
        seed: u64,
        times: ComputeTimes,
        link: LinkModel,
    ) -> DesTrainer {
        let n = 6;
        let mut rng = Rng::new(seed);
        let g = topology::ring(n);
        let meta = ModelMeta::lrm(8, 10, 64);
        let data = gaussian_mixture(&MixtureSpec::mnist_like(8, 3000), &mut rng);
        let (train, test) = data.split(2560);
        let shards = split(&train, n, Partition::Iid, &mut rng);
        let sources: Vec<Box<dyn BatchSource>> = shards
            .into_iter()
            .enumerate()
            .map(|(j, s)| Box::new(DenseSource::new(s, seed + j as u64)) as Box<dyn BatchSource>)
            .collect();
        let eval_batches: Vec<AnyBatch> = BatchSampler::full_batches(
            &test.subset(&(0..384).collect::<Vec<_>>()),
            64,
        )
        .into_iter()
        .map(AnyBatch::Dense)
        .collect();
        let pool = EnginePool::new(native_factory(meta.clone()), 2).unwrap();
        let init = meta.init_params(&mut rng);
        let cfg = TrainConfig {
            iters,
            batch_size: 64,
            eval_every: 10,
            seed,
            ..Default::default()
        };
        DesTrainer::new(
            g,
            policy,
            cfg,
            times,
            link,
            pool,
            sources,
            eval_batches,
            init,
            "lrm_d8_c10_b64",
        )
        .unwrap()
    }

    fn test_trace(iters: usize) -> Arc<Trace> {
        // iid transient stragglers (>= 1 forced per iteration, the
        // paper's Appendix-B regime). NOT a persistent straggler: in the
        // asynchronous setting a permanently slow worker's own compute
        // bounds the makespan under EVERY policy, so the wall-clock win
        // lives in the transient regime.
        let mut rng = Rng::new(99);
        let model = StragglerModel::paper_default(6, &mut rng);
        Arc::new(Trace::record(&model, iters, &mut rng))
    }

    #[test]
    fn async_dybw_trains_and_records() {
        let trace = test_trace(60);
        let mut t = build(WaitPolicy::Dybw, 60, 1, trace);
        let out = t.run().unwrap();
        assert_eq!(out.history.iters.len(), 6 * 60); // one record per worker-mix
        assert!(out.history.evals.len() >= 6);
        let first = out.history.evals.first().unwrap();
        let last = out.history.evals.last().unwrap();
        assert!(
            last.test_loss < first.test_loss * 0.8,
            "loss {} -> {}",
            first.test_loss,
            last.test_loss
        );
        assert!(last.consensus_error.is_finite());
        assert!(out.history.mean_backup_workers() > 0.05);
        assert_eq!(out.stats.coverage_violations, 0);
    }

    #[test]
    fn same_seed_full_runs_bit_identical() {
        // The acceptance invariant: two same-seed full-fidelity runs
        // must agree on the event log, every history record, and every
        // final parameter — bit for bit.
        let trace = test_trace(25);
        let run = || {
            let mut t = build(WaitPolicy::Dybw, 25, 5, trace.clone());
            t.log_events();
            let out = t.run().unwrap();
            (out, t.average_params())
        };
        let (o1, p1) = run();
        let (o2, p2) = run();
        assert_eq!(o1.event_log, o2.event_log, "event logs diverged");
        assert!(!o1.event_log.is_empty());
        assert!(o1.history.bits_eq(&o2.history), "histories diverged");
        assert_eq!(p1.len(), p2.len());
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.to_bits(), b.to_bits(), "final params diverged");
        }
    }

    #[test]
    fn async_dybw_beats_full_wall_clock_on_identical_trace() {
        // Same trace, same data, same seed: the dynamic-backup policy
        // must finish the workload faster than the full barrier while
        // converging comparably — Fig. 2's time-vs-loss story on the
        // asynchronous timeline.
        let iters = 50;
        let trace = test_trace(iters);
        let mut a = build(WaitPolicy::Dybw, iters, 7, trace.clone());
        let mut b = build(WaitPolicy::Full, iters, 7, trace);
        let oa = a.run().unwrap();
        let ob = b.run().unwrap();
        // The async win on a degree-2 ring is structurally smaller than
        // the lockstep 55-70% — every worker always pays its own
        // compute, only neighbour waits are saved (~10-20% here).
        assert!(
            oa.stats.makespan < 0.95 * ob.stats.makespan,
            "dybw {}s vs full {}s",
            oa.stats.makespan,
            ob.stats.makespan
        );
        let (la, lb) = (
            oa.history.final_eval().unwrap().test_loss,
            ob.history.final_eval().unwrap().test_loss,
        );
        assert!(la < lb * 1.25, "async dybw diverged: {la} vs full {lb}");
        // both reach a common loose target on the virtual clock, and the
        // same-iteration-count run ends earlier under dybw
        let target = la.max(lb) * 1.05;
        let ta = oa.history.time_to_test_loss(target);
        let tb = ob.history.time_to_test_loss(target);
        assert!(ta.is_some() && tb.is_some(), "target {target} unreached");
        assert!(oa.history.total_time() < ob.history.total_time());
    }

    #[test]
    fn batched_grad_many_is_bit_identical_to_unbatched() {
        // Deterministic compute times + zero link latency force mass
        // timestamp ties, so the batch hook actually fans simultaneous
        // gradients through grad_many — and the run must still be bit
        // for bit the run the one-at-a-time path produces: same event
        // log, same history, same final parameters, for every policy.
        for policy in [WaitPolicy::Dybw, WaitPolicy::Full, WaitPolicy::Static { b: 1 }] {
            let run = |batched: bool| {
                let times =
                    ComputeTimes::homogeneous(6, Dist::Deterministic { base: 0.1 }, 0);
                let mut t = build_custom(policy, 20, 11, times, LinkModel::zero());
                t.log_events();
                t.set_batch_compute(batched);
                let out = t.run().unwrap();
                let avg = t.average_params();
                (out, avg)
            };
            let (ob, pb) = run(true);
            let (ou, pu) = run(false);
            assert!(ob.batched_jobs > 0, "{}: batching never engaged", policy.name());
            assert_eq!(ou.batched_jobs, 0);
            assert_eq!(ob.event_log, ou.event_log, "{}: event logs diverged", policy.name());
            assert!(!ob.event_log.is_empty());
            assert!(ob.history.bits_eq(&ou.history), "{}: histories diverged", policy.name());
            assert_eq!(pb.len(), pu.len());
            for (a, b) in pb.iter().zip(&pu) {
                assert_eq!(a.to_bits(), b.to_bits(), "{}: final params diverged", policy.name());
            }
        }
    }

    /// PR-8 tentpole: kill a full-fidelity run right after a milestone
    /// checkpoint, resume from `CkptManager::latest()`, and the
    /// verified replay must reproduce the uninterrupted run — event
    /// log, history, and final parameters, bit for bit.
    #[test]
    fn full_fidelity_kill_and_resume_is_bit_identical() {
        let trace = test_trace(30);
        let dir = std::env::temp_dir().join(format!("dybw-des-ckpt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let run = |recovery: Option<RecoveryOpts>| {
            let mut t = build(WaitPolicy::Dybw, 30, 13, trace.clone());
            t.log_events();
            if let Some(r) = recovery {
                t.set_recovery(r);
            }
            t.run().map(|o| {
                let avg = t.average_params();
                (o, avg)
            })
        };
        // uninterrupted reference — no checkpointing at all
        let (base, pbase) = run(None).unwrap();
        // killed right after saving the milestone-20 checkpoint
        let err = run(Some(RecoveryOpts {
            dir: dir.clone(),
            every: 10,
            retain: 2,
            kill_at: Some(20),
            resume: false,
        }))
        .unwrap_err();
        assert!(err.to_string().contains("killed at checkpoint milestone 20"), "{err}");
        // resumed: replay from zero, verified against the latest intact
        // checkpoint at its milestone, then run to completion
        let (resumed, pres) = run(Some(RecoveryOpts {
            dir: dir.clone(),
            every: 10,
            retain: 2,
            kill_at: None,
            resume: true,
        }))
        .unwrap();
        assert_eq!(base.event_log, resumed.event_log, "event logs diverged");
        assert!(!base.event_log.is_empty());
        assert!(base.history.bits_eq(&resumed.history), "histories diverged");
        assert_eq!(pbase.len(), pres.len());
        for (a, b) in pbase.iter().zip(&pres) {
            assert_eq!(a.to_bits(), b.to_bits(), "final params diverged");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// PR-8 tentpole: real gradients under churn. A down/up cycle plus
    /// a partition window must stay bit-reproducible, keep DTUR
    /// coverage intact, and finish every worker.
    #[test]
    fn full_fidelity_churn_run_is_bit_identical_and_covered() {
        let trace = test_trace(25);
        let faults = FaultPlan {
            downs: vec![(2, 0.5)],
            ups: vec![(2, 1.0)],
            link_downs: vec![(0, 1, 0.3)],
            link_ups: vec![(0, 1, 1.5)],
            ..Default::default()
        };
        let run = || {
            let mut t = build(WaitPolicy::Dybw, 25, 17, trace.clone());
            t.log_events();
            t.set_faults(faults.clone());
            let out = t.run().unwrap();
            let avg = t.average_params();
            (out, avg)
        };
        let (o1, p1) = run();
        let (o2, p2) = run();
        assert_eq!(o1.event_log, o2.event_log, "event logs diverged");
        assert!(o1.event_log.iter().any(|l| l.contains("worker_down")));
        assert!(o1.event_log.iter().any(|l| l.contains("link_down")));
        assert!(o1.history.bits_eq(&o2.history), "histories diverged");
        for (a, b) in p1.iter().zip(&p2) {
            assert_eq!(a.to_bits(), b.to_bits(), "final params diverged");
        }
        assert_eq!(o1.stats.coverage_violations, 0);
        assert_eq!(o1.stats.departed, 0);
        for r in &o1.history.iters {
            assert!(r.train_loss.is_finite());
        }
    }

    #[test]
    fn mix_weights_stay_convex() {
        // After any mix the parameters are convex combinations of
        // updates, so with bounded data nothing can blow up even under
        // heavy asynchrony.
        let trace = test_trace(30);
        let mut t = build(WaitPolicy::Static { b: 1 }, 30, 3, trace);
        let out = t.run().unwrap();
        for r in &out.history.iters {
            assert!(r.train_loss.is_finite());
        }
        assert!(out.history.final_eval().unwrap().test_loss.is_finite());
    }
}
