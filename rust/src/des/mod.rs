//! `des` — the event-driven cluster simulator (asynchronous per-worker
//! time).
//!
//! The lockstep drivers ([`coordinator::sim`](crate::coordinator::sim),
//! [`coordinator::live`](crate::coordinator::live)) advance every worker
//! through the same iteration k with one global cut per round. The
//! paper's mechanism is not like that: worker i waits only for its
//! n_i − b_i(k) fastest *neighbours* and proceeds, so at any wall-clock
//! instant different workers sit at different iterations. This layer
//! simulates exactly that regime on a deterministic discrete-event core:
//!
//! - [`core`] — virtual clock + calendar event queue with stable
//!   tie-breaking (the determinism substrate; a reference binary-heap
//!   backend remains as the equivalence oracle).
//! - [`policy`] — per-worker wait rules: `full`, `static:b`, and `dybw`
//!   (the per-worker [`LocalDtur`](crate::coordinator::dtur::LocalDtur)
//!   driven by locally observed arrival times).
//! - [`cluster`] — the timing-only simulator: per-worker state machines
//!   over the straggler substrate plus a per-link latency model
//!   ([`straggler::link`](crate::straggler::link)); CSR/bitset worker
//!   state scales a scenario sweep to 10^5–10^6 workers.
//! - [`full`] — full fidelity: the same schedule drives real
//!   [`EnginePool`](crate::engine::EnginePool) gradient jobs,
//!   bit-reproducible under a fixed seed.
//! - [`scenario`] — declarative JSON scenarios swept over policies on
//!   one identical timing realisation (`dybw des run --scenario …`).

pub mod cluster;
pub mod core;
pub mod full;
pub mod policy;
pub mod scenario;

pub use self::core::{Event, EventQueue, ScheduleError, Time};
pub use cluster::{
    ClusterSim, ClusterStats, ComputeTimes, DesHooks, FaultPlan, LogSink, MixInfo, NoHooks,
};
pub use full::{DesOutcome, DesTrainer, RecoveryOpts};
pub use policy::{WaitPolicy, WorkerWait};
pub use scenario::{Fidelity, Scenario, ScenarioFaults};
