//! Minimal JSON parser + writer.
//!
//! The artifact metadata sidecars (`artifacts/*.meta.json`), experiment
//! configs, and metric exports all speak JSON; with no `serde` in the
//! offline vendor set we implement the subset of RFC 8259 we need:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept sorted (BTreeMap) so serialisation
/// is deterministic — handy for golden tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // -- constructors ------------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Json {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("set() on non-object");
        }
        self
    }

    // -- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// `obj.path("a.b.c")` — dotted lookup.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    // -- parsing -----------------------------------------------------------
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: input.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- serialisation -----------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full UTF-8 code point
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            map.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

// Convenience From impls for building values.
impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e2").unwrap(), Json::Num(-250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.path("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,true,null,"s"],"obj":{"k":"v"}}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::parse(r#"{"a":{"b":[1,2]},"c":[]}"#).unwrap();
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("line\n\ttab \"q\" \\ back".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn dotted_path() {
        let v = Json::parse(r#"{"a":{"b":{"c":3}}}"#).unwrap();
        assert_eq!(v.path("a.b.c").unwrap().as_f64().unwrap(), 3.0);
        assert!(v.path("a.x.c").is_none());
    }

    #[test]
    fn parses_real_meta_shape() {
        let meta = r#"{
          "name": "lrm_d8_c4_b16", "param_count": 36,
          "segments": [{"name": "w", "shape": [8, 4], "offset": 0, "size": 32}],
          "x_shape": [16, 8], "x_dtype": "float32"
        }"#;
        let v = Json::parse(meta).unwrap();
        assert_eq!(v.path("param_count").unwrap().as_usize().unwrap(), 36);
        let seg = &v.path("segments").unwrap().as_arr().unwrap()[0];
        assert_eq!(seg.path("shape").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn integers_serialise_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(2.5).to_string(), "2.5");
    }
}
