//! Flat-vector math for the consensus hot path.
//!
//! Parameter vectors are `Vec<f32>` (the consensus update eq. (6) averages
//! flat vectors), so these kernels are THE Layer-3 hot path: every worker
//! runs `weighted_sum_into` once per iteration over P floats. Written as
//! chunked loops the autovectoriser turns into AVX; no allocation inside
//! any of them.

/// y += a * x
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * *xi;
    }
}

/// y = a * y
#[inline]
pub fn scale(y: &mut [f32], a: f32) {
    for yi in y.iter_mut() {
        *yi *= a;
    }
}

/// out = sum_i coeffs[i] * xs[i], written in-place into `out`.
///
/// This is the consensus mixing kernel (eq. 6): `out` is worker j's next
/// parameter vector, `xs` are the locally-updated vectors of S_j(k) ∪ {j},
/// `coeffs` the Metropolis weights. Processes the accumulator in L2-sized
/// blocks so every source vector streams through cache once.
pub fn weighted_sum_into(out: &mut [f32], xs: &[&[f32]], coeffs: &[f32]) {
    assert_eq!(xs.len(), coeffs.len());
    assert!(!xs.is_empty(), "weighted_sum_into needs >= 1 source");
    for x in xs {
        assert_eq!(x.len(), out.len());
    }
    const BLOCK: usize = 8192;
    let n = out.len();
    let mut start = 0;
    while start < n {
        let end = (start + BLOCK).min(n);
        let ob = &mut out[start..end];
        // first source initialises the block
        let x0 = &xs[0][start..end];
        let c0 = coeffs[0];
        for (o, x) in ob.iter_mut().zip(x0) {
            *o = c0 * *x;
        }
        for (x, &c) in xs.iter().zip(coeffs.iter()).skip(1) {
            let xb = &x[start..end];
            for (o, xv) in ob.iter_mut().zip(xb) {
                *o += c * *xv;
            }
        }
        start = end;
    }
}

#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| *x as f64 * *y as f64).sum()
}

#[inline]
pub fn norm2(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

/// Euclidean distance ||a - b||.
pub fn dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Max |a_i - b_i|.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

/// Mean of several equal-length vectors.
pub fn mean_of(xs: &[&[f32]]) -> Vec<f32> {
    assert!(!xs.is_empty());
    let mut out = vec![0.0f32; xs[0].len()];
    let c = 1.0 / xs.len() as f32;
    let coeffs = vec![c; xs.len()];
    weighted_sum_into(&mut out, xs, &coeffs);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[10.0, 20.0, 30.0]);
        assert_eq!(y, vec![21.0, 42.0, 63.0]);
    }

    #[test]
    fn weighted_sum_matches_naive() {
        let a: Vec<f32> = (0..10000).map(|i| i as f32 * 0.01).collect();
        let b: Vec<f32> = (0..10000).map(|i| (i as f32).sin()).collect();
        let c: Vec<f32> = (0..10000).map(|i| 1.0 / (1.0 + i as f32)).collect();
        let mut out = vec![0.0; 10000];
        weighted_sum_into(&mut out, &[&a, &b, &c], &[0.2, 0.3, 0.5]);
        for i in [0usize, 1, 8191, 8192, 9999] {
            let want = 0.2 * a[i] + 0.3 * b[i] + 0.5 * c[i];
            assert!((out[i] - want).abs() < 1e-6, "i={i}");
        }
    }

    #[test]
    fn weighted_sum_single_source_is_scale() {
        let a = vec![2.0f32; 100];
        let mut out = vec![9.0; 100];
        weighted_sum_into(&mut out, &[&a], &[0.5]);
        assert!(out.iter().all(|&v| (v - 1.0).abs() < 1e-7));
    }

    #[test]
    fn convex_combination_preserves_constant() {
        // Mixing identical constant vectors with weights summing to 1 is a
        // fixed point — the consensus invariant.
        let v = vec![3.25f32; 5000];
        let mut out = vec![0.0; 5000];
        weighted_sum_into(&mut out, &[&v, &v, &v], &[0.3, 0.45, 0.25]);
        for &o in &out {
            assert!((o - 3.25).abs() < 1e-5);
        }
    }

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn dist_symmetric_zero_on_self() {
        let a = vec![1.0f32, -2.0, 3.0];
        let b = vec![0.0f32, 1.0, 1.0];
        assert_eq!(dist(&a, &a), 0.0);
        assert!((dist(&a, &b) - dist(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn mean_of_vectors() {
        let a = vec![1.0f32, 3.0];
        let b = vec![3.0f32, 5.0];
        assert_eq!(mean_of(&[&a, &b]), vec![2.0, 4.0]);
    }

    #[test]
    fn max_abs_diff_basic() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 4.0]), 1.0);
    }

    #[test]
    #[should_panic]
    fn weighted_sum_len_mismatch_panics() {
        let a = vec![1.0f32; 4];
        let mut out = vec![0.0f32; 5];
        weighted_sum_into(&mut out, &[&a], &[1.0]);
    }
}
