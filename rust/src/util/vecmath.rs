//! Flat-vector math for the consensus hot path.
//!
//! Parameter vectors are `Vec<f32>` (the consensus update eq. (6) averages
//! flat vectors), so these kernels are THE Layer-3 hot path: every worker
//! runs `weighted_sum_into` once per iteration over P floats. Written as
//! chunked loops the autovectoriser turns into AVX; no allocation inside
//! any of them.

use crate::engine::EnginePool;

/// y += a * x
///
/// Explicit 4-lane unroll so the autovectoriser reliably emits packed
/// FMAs even in non-LTO builds. Per element the operation is unchanged
/// (`y[i] += a * x[i]`), so the result is bit-identical to the naive
/// loop — asserted by tests.
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    let quads = y.len() / 4 * 4;
    let (yh, yt) = y.split_at_mut(quads);
    let (xh, xt) = x.split_at(quads);
    for (cy, cx) in yh.chunks_exact_mut(4).zip(xh.chunks_exact(4)) {
        cy[0] += a * cx[0];
        cy[1] += a * cx[1];
        cy[2] += a * cx[2];
        cy[3] += a * cx[3];
    }
    for (yi, xi) in yt.iter_mut().zip(xt) {
        *yi += a * *xi;
    }
}

/// y = a * y
#[inline]
pub fn scale(y: &mut [f32], a: f32) {
    for yi in y.iter_mut() {
        *yi *= a;
    }
}

/// out = sum_i coeffs[i] * xs[i], written in-place into `out`.
///
/// This is the consensus mixing kernel (eq. 6): `out` is worker j's next
/// parameter vector, `xs` are the locally-updated vectors of S_j(k) ∪ {j},
/// `coeffs` the Metropolis weights. Processes the accumulator in L2-sized
/// blocks so every source vector streams through cache once.
pub fn weighted_sum_into(out: &mut [f32], xs: &[&[f32]], coeffs: &[f32]) {
    assert_eq!(xs.len(), coeffs.len());
    assert!(!xs.is_empty(), "weighted_sum_into needs >= 1 source");
    for x in xs {
        assert_eq!(x.len(), out.len());
    }
    const BLOCK: usize = 8192;
    let n = out.len();
    let mut start = 0;
    while start < n {
        let end = (start + BLOCK).min(n);
        let ob = &mut out[start..end];
        // first source initialises the block
        let x0 = &xs[0][start..end];
        let c0 = coeffs[0];
        for (o, x) in ob.iter_mut().zip(x0) {
            *o = c0 * *x;
        }
        for (x, &c) in xs.iter().zip(coeffs.iter()).skip(1) {
            let xb = &x[start..end];
            for (o, xv) in ob.iter_mut().zip(xb) {
                *o += c * *xv;
            }
        }
        start = end;
    }
}

/// Σ aᵢ·bᵢ in f64, accumulated across 4 independent lanes (a serial sum
/// is a dependence chain the CPU cannot pipeline; 4 lanes quadruple the
/// FLOP rate). NOTE: the 4-lane reduction legitimately changes the f64
/// accumulation ORDER versus a naive left-to-right sum, so values differ
/// from the pre-unroll kernel in the last ulps — nothing bit-asserts raw
/// `dot`/`norm2` output across that boundary, every caller is a metric
/// or a tolerance-tested quantity, and the function stays deterministic
/// for fixed input (asserted by tests).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let quads = a.len() / 4 * 4;
    let (ah, at) = a.split_at(quads);
    let (bh, bt) = b.split_at(quads);
    let mut acc = [0.0f64; 4];
    for (ca, cb) in ah.chunks_exact(4).zip(bh.chunks_exact(4)) {
        acc[0] += ca[0] as f64 * cb[0] as f64;
        acc[1] += ca[1] as f64 * cb[1] as f64;
        acc[2] += ca[2] as f64 * cb[2] as f64;
        acc[3] += ca[3] as f64 * cb[3] as f64;
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    for (x, y) in at.iter().zip(bt) {
        s += *x as f64 * *y as f64;
    }
    s
}

/// ||a||₂, via the 4-lane [`dot`] (same accumulation-order note applies).
#[inline]
pub fn norm2(a: &[f32]) -> f64 {
    dot(a, a).sqrt()
}

/// Euclidean distance ||a - b||.
pub fn dist(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// Max |a_i - b_i|.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

/// Mean of several equal-length vectors.
pub fn mean_of(xs: &[&[f32]]) -> Vec<f32> {
    assert!(!xs.is_empty());
    let mut out = vec![0.0f32; xs[0].len()];
    let c = 1.0 / xs.len() as f32;
    let coeffs = vec![c; xs.len()];
    weighted_sum_into(&mut out, xs, &coeffs);
    out
}

/// Pooled [`mean_of`]: the output dimension is chunked across the pool's
/// lanes, each chunk running the same blocked kernel over subslices of
/// every source. Per element the accumulation order over sources is
/// unchanged, so the result is bit-identical to [`mean_of`] at any lane
/// count (asserted by tests). This is the parallel PS-style exact
/// averaging path — the last coordinator-thread hot loop in
/// `SimTrainer::run`.
pub fn mean_of_pooled(xs: &[&[f32]], pool: &EnginePool) -> anyhow::Result<Vec<f32>> {
    assert!(!xs.is_empty());
    let dim = xs[0].len();
    if pool.threads() <= 1 || dim < 8192 {
        return Ok(mean_of(xs));
    }
    let mut out = vec![0.0f32; dim];
    let c = 1.0 / xs.len() as f32;
    let coeffs = vec![c; xs.len()];
    let chunk = dim.div_ceil(pool.threads() * 2).max(1);
    {
        let coeffs = &coeffs[..];
        let mut tasks: Vec<_> = out
            .chunks_mut(chunk)
            .enumerate()
            .map(|(idx, ob)| {
                move || -> anyhow::Result<()> {
                    let start = idx * chunk;
                    let len = ob.len();
                    let sub: Vec<&[f32]> = xs.iter().map(|x| &x[start..start + len]).collect();
                    weighted_sum_into(ob, &sub, coeffs);
                    Ok(())
                }
            })
            .collect();
        pool.run_tasks(&mut tasks)?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[10.0, 20.0, 30.0]);
        assert_eq!(y, vec![21.0, 42.0, 63.0]);
    }

    #[test]
    fn weighted_sum_matches_naive() {
        let a: Vec<f32> = (0..10000).map(|i| i as f32 * 0.01).collect();
        let b: Vec<f32> = (0..10000).map(|i| (i as f32).sin()).collect();
        let c: Vec<f32> = (0..10000).map(|i| 1.0 / (1.0 + i as f32)).collect();
        let mut out = vec![0.0; 10000];
        weighted_sum_into(&mut out, &[&a, &b, &c], &[0.2, 0.3, 0.5]);
        for i in [0usize, 1, 8191, 8192, 9999] {
            let want = 0.2 * a[i] + 0.3 * b[i] + 0.5 * c[i];
            assert!((out[i] - want).abs() < 1e-6, "i={i}");
        }
    }

    #[test]
    fn weighted_sum_single_source_is_scale() {
        let a = vec![2.0f32; 100];
        let mut out = vec![9.0; 100];
        weighted_sum_into(&mut out, &[&a], &[0.5]);
        assert!(out.iter().all(|&v| (v - 1.0).abs() < 1e-7));
    }

    #[test]
    fn convex_combination_preserves_constant() {
        // Mixing identical constant vectors with weights summing to 1 is a
        // fixed point — the consensus invariant.
        let v = vec![3.25f32; 5000];
        let mut out = vec![0.0; 5000];
        weighted_sum_into(&mut out, &[&v, &v, &v], &[0.3, 0.45, 0.25]);
        for &o in &out {
            assert!((o - 3.25).abs() < 1e-5);
        }
    }

    #[test]
    fn dot_and_norm() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn dist_symmetric_zero_on_self() {
        let a = vec![1.0f32, -2.0, 3.0];
        let b = vec![0.0f32, 1.0, 1.0];
        assert_eq!(dist(&a, &a), 0.0);
        assert!((dist(&a, &b) - dist(&b, &a)).abs() < 1e-12);
    }

    #[test]
    fn mean_of_vectors() {
        let a = vec![1.0f32, 3.0];
        let b = vec![3.0f32, 5.0];
        assert_eq!(mean_of(&[&a, &b]), vec![2.0, 4.0]);
    }

    #[test]
    fn max_abs_diff_basic() {
        assert_eq!(max_abs_diff(&[1.0, 5.0], &[1.5, 4.0]), 1.0);
    }

    /// Deterministic pseudo-random fill without an Rng dependency.
    fn wobble(n: usize, salt: u64) -> Vec<f32> {
        (0..n)
            .map(|i| {
                let h = (i as u64 + salt).wrapping_mul(0x9E37_79B9_7F4A_7C15);
                ((h >> 40) as f32) / (1u64 << 24) as f32 - 0.5
            })
            .collect()
    }

    #[test]
    fn axpy_unrolled_matches_naive_bitwise() {
        // Ragged length exercises both the quad body and the tail.
        let x = wobble(1003, 1);
        let mut y = wobble(1003, 2);
        let mut naive = y.clone();
        for (yi, xi) in naive.iter_mut().zip(&x) {
            *yi += 0.37 * *xi;
        }
        axpy(&mut y, 0.37, &x);
        for (a, b) in y.iter().zip(&naive) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn dot_four_lane_deterministic_and_close_to_naive() {
        let a = wobble(1003, 3);
        let b = wobble(1003, 4);
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| *x as f64 * *y as f64).sum();
        let got = dot(&a, &b);
        // 4-lane accumulation reorders the f64 sum: equal to a tight
        // tolerance, and exactly reproducible call-to-call.
        assert!((got - naive).abs() <= 1e-9 * (1.0 + naive.abs()), "{got} vs {naive}");
        assert_eq!(got.to_bits(), dot(&a, &b).to_bits());
        assert_eq!(norm2(&a).to_bits(), norm2(&a).to_bits());
    }

    #[test]
    fn mean_of_pooled_bit_identical_to_sequential() {
        use crate::engine::EnginePool;
        let pool = EnginePool::tasks_only(3).unwrap();
        for dim in [100usize, 8192, 20_001] {
            let rows: Vec<Vec<f32>> = (0..5).map(|r| wobble(dim, 10 + r)).collect();
            let xs: Vec<&[f32]> = rows.iter().map(|v| v.as_slice()).collect();
            let seq = mean_of(&xs);
            let par = mean_of_pooled(&xs, &pool).unwrap();
            assert_eq!(seq.len(), par.len());
            for (a, b) in seq.iter().zip(&par) {
                assert_eq!(a.to_bits(), b.to_bits(), "dim {dim}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn weighted_sum_len_mismatch_panics() {
        let a = vec![1.0f32; 4];
        let mut out = vec![0.0f32; 5];
        weighted_sum_into(&mut out, &[&a], &[1.0]);
    }
}
