//! Tiny declarative CLI argument parser (no `clap` in the vendor set).
//!
//! Supports subcommands, `--key value`, `--key=value`, `--flag` booleans,
//! positional arguments, defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

/// One declared option.
#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative command: declare options, then `parse` an arg list.
#[derive(Debug, Clone)]
pub struct Command {
    pub name: String,
    pub about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<(String, String)>, // (name, help)
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positionals: Vec<String>,
}

impl Command {
    pub fn new(name: &str, about: &str) -> Self {
        Command {
            name: name.into(),
            about: about.into(),
            opts: Vec::new(),
            positionals: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_flag: false,
        });
        self
    }

    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: false,
        });
        self
    }

    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_flag: true,
        });
        self
    }

    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positionals.push((name.into(), help.into()));
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        for (p, _) in &self.positionals {
            out.push_str(&format!(" <{p}>"));
        }
        out.push_str(" [OPTIONS]\n");
        if !self.positionals.is_empty() {
            out.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                out.push_str(&format!("  <{p}>  {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            out.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let d = match (&o.default, o.is_flag) {
                    (_, true) => String::new(),
                    (Some(d), _) => format!(" [default: {d}]"),
                    (None, _) => " (required)".into(),
                };
                out.push_str(&format!("  --{:<24} {}{}\n", o.name, o.help, d));
            }
        }
        out
    }

    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.values.insert(o.name.clone(), d.clone());
            }
            if o.is_flag {
                args.flags.insert(o.name.clone(), false);
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError(self.usage()));
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| {
                        CliError(format!("unknown option --{key}\n\n{}", self.usage()))
                    })?;
                if spec.is_flag {
                    args.flags.insert(key, true);
                } else {
                    let val = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{key} needs a value")))?
                        }
                    };
                    args.values.insert(key, val);
                }
            } else {
                args.positionals.push(a.clone());
            }
            i += 1;
        }
        // required options present?
        for o in &self.opts {
            if !o.is_flag && o.default.is_none() && !args.values.contains_key(&o.name) {
                return Err(CliError(format!("missing required --{}", o.name)));
            }
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, key: &str) -> &str {
        self.values
            .get(key)
            .unwrap_or_else(|| panic!("option --{key} was not declared"))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize, CliError> {
        self.get(key)
            .parse()
            .map_err(|_| CliError(format!("--{key} expects an integer, got '{}'", self.get(key))))
    }

    pub fn get_u64(&self, key: &str) -> Result<u64, CliError> {
        self.get(key)
            .parse()
            .map_err(|_| CliError(format!("--{key} expects an integer, got '{}'", self.get(key))))
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, CliError> {
        self.get(key)
            .parse()
            .map_err(|_| CliError(format!("--{key} expects a number, got '{}'", self.get(key))))
    }

    pub fn flag(&self, key: &str) -> bool {
        *self.flags.get(key).unwrap_or(&false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("train", "run training")
            .opt("workers", "6", "number of workers")
            .opt("lr", "0.2", "learning rate")
            .req("model", "model name")
            .flag("verbose", "chatty output")
            .positional("config", "config path")
    }

    #[test]
    fn defaults_apply() {
        let a = cmd().parse(&argv(&["--model", "lrm"])).unwrap();
        assert_eq!(a.get_usize("workers").unwrap(), 6);
        assert_eq!(a.get_f64("lr").unwrap(), 0.2);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn overrides_and_eq_syntax() {
        let a = cmd()
            .parse(&argv(&["--model=mlp2", "--workers", "10", "--verbose", "cfg.json"]))
            .unwrap();
        assert_eq!(a.get("model"), "mlp2");
        assert_eq!(a.get_usize("workers").unwrap(), 10);
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["cfg.json"]);
    }

    #[test]
    fn missing_required_errors() {
        assert!(cmd().parse(&argv(&[])).is_err());
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&argv(&["--model", "x", "--bogus", "1"])).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = cmd().parse(&argv(&["--model", "x", "--workers", "many"])).unwrap();
        assert!(a.get_usize("workers").is_err());
    }

    #[test]
    fn help_contains_options() {
        let u = cmd().usage();
        assert!(u.contains("--workers"));
        assert!(u.contains("required"));
        assert!(u.contains("<config>"));
    }
}
