//! Fixed-size worker thread pool (no `tokio`/`rayon` in the vendor set).
//!
//! Used by the live-mode coordinator to host one OS thread per training
//! worker and by the evaluation path to parallelise batch scoring. Jobs
//! are boxed closures pushed through an mpsc channel guarded by a mutex on
//! the receiving side (classic shared-queue pool).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|i| {
                let rx: Arc<Mutex<Receiver<Job>>> = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("dybw-pool-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // sender dropped -> shut down
                        }
                    })
                    .expect("spawn pool thread")
            })
            .collect();
        ThreadPool { tx: Some(tx), handles }
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool thread died");
    }

    /// Run `f(i)` for i in 0..n across the pool and wait for all results.
    pub fn scatter_gather<T, F>(&self, n: usize, f: F) -> Vec<T>
    where
        T: Send + 'static,
        F: Fn(usize) -> T + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (tx, rx) = channel::<(usize, T)>();
        for i in 0..n {
            let f = Arc::clone(&f);
            let tx = tx.clone();
            self.execute(move || {
                let _ = tx.send((i, f(i)));
            });
        }
        drop(tx);
        let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            out[i] = Some(v);
        }
        out.into_iter().map(|v| v.expect("worker panicked")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..100 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scatter_gather_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.scatter_gather(20, |i| i * i);
        assert_eq!(out, (0..20).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn drop_joins_threads() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }
}
