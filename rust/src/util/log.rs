//! Minimal leveled logger (env-controlled, thread-safe).
//!
//! `DYBW_LOG=trace|debug|info|warn|error` (default `info`). Timestamps
//! are millis since process start — enough to correlate worker events in
//! live mode without pulling in a clock formatting dependency. `trace`
//! additionally mirrors obs span open/close events (see [`crate::obs`])
//! for quick console debugging without a trace file.
//!
//! Initialisation is lazy: the first `log`/`enabled` call parses the
//! environment if [`init`] was never called, so library users get
//! correct levels without a mandatory setup step. An unrecognised
//! `DYBW_LOG` value warns once and falls back to `info` instead of
//! being silently swallowed.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Once;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Trace = 0,
    Debug = 1,
    Info = 2,
    Warn = 3,
    Error = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static INIT: Once = Once::new();

fn start() -> Instant {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    *START.get_or_init(Instant::now)
}

/// Parse a `DYBW_LOG` value. Returns the level plus a warning message
/// when the value is present but unrecognised (in which case the level
/// falls back to `info`).
fn parse_level(v: Option<&str>) -> (Level, Option<String>) {
    match v {
        None => (Level::Info, None),
        Some("trace") => (Level::Trace, None),
        Some("debug") => (Level::Debug, None),
        Some("info") => (Level::Info, None),
        Some("warn") => (Level::Warn, None),
        Some("error") => (Level::Error, None),
        Some(bad) => (
            Level::Info,
            Some(format!(
                "unrecognised DYBW_LOG value {bad:?} (valid: trace|debug|info|warn|error); using info"
            )),
        ),
    }
}

/// Idempotent: parses `DYBW_LOG` exactly once (also runs lazily from
/// the first `log`/`enabled` call). Warns once on unrecognised values.
pub fn init() {
    INIT.call_once(|| {
        let (lvl, warning) = parse_level(std::env::var("DYBW_LOG").ok().as_deref());
        LEVEL.store(lvl as u8, Ordering::Relaxed);
        let t = start().elapsed().as_millis();
        if let Some(msg) = warning {
            // Written directly (not via `log`) — `Once::call_once` is
            // not re-entrant.
            let mut err = std::io::stderr().lock();
            let _ = writeln!(err, "[{t:>8}ms WARN  log] {msg}");
        }
    });
}

/// Override the level programmatically (tests, CLI flags). Marks the
/// logger initialised so a later lazy [`init`] cannot clobber it with
/// the environment value.
pub fn set_level(l: Level) {
    INIT.call_once(|| {
        start();
    });
    LEVEL.store(l as u8, Ordering::Relaxed);
}

#[inline]
pub fn enabled(l: Level) -> bool {
    if !INIT.is_completed() {
        init();
    }
    l as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, target: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = start().elapsed().as_millis();
    let tag = match l {
        Level::Trace => "TRACE",
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:>8}ms {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! trace_ {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Trace) {
            $crate::util::log::log($crate::util::log::Level::Trace, $target, &format!($($arg)*))
        }
    };
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            $crate::util::log::log($crate::util::log::Level::Debug, $target, &format!($($arg)*))
        }
    };
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Info) {
            $crate::util::log::log($crate::util::log::Level::Info, $target, &format!($($arg)*))
        }
    };
}

#[macro_export]
macro_rules! warn_ {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Warn) {
            $crate::util::log::log($crate::util::log::Level::Warn, $target, &format!($($arg)*))
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Trace));
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }

    #[test]
    fn parse_recognises_all_levels() {
        assert_eq!(parse_level(None), (Level::Info, None));
        assert_eq!(parse_level(Some("trace")), (Level::Trace, None));
        assert_eq!(parse_level(Some("debug")), (Level::Debug, None));
        assert_eq!(parse_level(Some("info")), (Level::Info, None));
        assert_eq!(parse_level(Some("warn")), (Level::Warn, None));
        assert_eq!(parse_level(Some("error")), (Level::Error, None));
    }

    #[test]
    fn parse_warns_on_unrecognised_value() {
        // the historical bug: DYBW_LOG=inof silently meant info
        let (lvl, warning) = parse_level(Some("inof"));
        assert_eq!(lvl, Level::Info, "invalid value still falls back to info");
        let msg = warning.expect("unrecognised value must produce a warning");
        assert!(msg.contains("inof") && msg.contains("DYBW_LOG"), "{msg}");
        // case-sensitive on purpose: "INFO" is not a documented value
        assert!(parse_level(Some("INFO")).1.is_some());
    }

    #[test]
    fn lazy_init_never_leaves_sentinel() {
        // `enabled` must work without `init()` — no uninitialised
        // sentinel value can leak into the comparison.
        assert!(enabled(Level::Error));
        let raw = LEVEL.load(Ordering::Relaxed);
        assert!(raw <= Level::Error as u8, "LEVEL holds a real level, got {raw}");
    }
}
