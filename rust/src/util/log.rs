//! Minimal leveled logger (env-controlled, thread-safe).
//!
//! `DYBW_LOG=debug|info|warn|error` (default `info`). Timestamps are
//! millis since process start — enough to correlate worker events in live
//! mode without pulling in a clock formatting dependency.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialised

fn start() -> Instant {
    static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();
    *START.get_or_init(Instant::now)
}

pub fn init() {
    let lvl = match std::env::var("DYBW_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    };
    LEVEL.store(lvl as u8, Ordering::Relaxed);
    start();
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

#[inline]
pub fn enabled(l: Level) -> bool {
    let cur = LEVEL.load(Ordering::Relaxed);
    let cur = if cur == 255 {
        init();
        LEVEL.load(Ordering::Relaxed)
    } else {
        cur
    };
    l as u8 >= cur
}

pub fn log(l: Level, target: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = start().elapsed().as_millis();
    let tag = match l {
        Level::Debug => "DEBUG",
        Level::Info => "INFO ",
        Level::Warn => "WARN ",
        Level::Error => "ERROR",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:>8}ms {tag} {target}] {msg}");
}

#[macro_export]
macro_rules! debug {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Debug) {
            $crate::util::log::log($crate::util::log::Level::Debug, $target, &format!($($arg)*))
        }
    };
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Info) {
            $crate::util::log::log($crate::util::log::Level::Info, $target, &format!($($arg)*))
        }
    };
}

#[macro_export]
macro_rules! warn_ {
    ($target:expr, $($arg:tt)*) => {
        if $crate::util::log::enabled($crate::util::log::Level::Warn) {
            $crate::util::log::log($crate::util::log::Level::Warn, $target, &format!($($arg)*))
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(!enabled(Level::Debug));
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }
}
