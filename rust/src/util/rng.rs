//! Deterministic PRNG + sampling distributions.
//!
//! The offline vendor set has no `rand` crate, so we carry our own:
//! xoshiro256++ seeded through SplitMix64 (the reference construction from
//! Blackman & Vigna). Every stochastic component in the system — straggler
//! compute times, data synthesis, partitioning, topology generation —
//! draws from this type, so a run is fully reproducible from one `u64`
//! seed.

use std::sync::OnceLock;

/// xoshiro256++ PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

// ---------------------------------------------------------------------------
// O(log n) stream offsets (counter-based substreams)
// ---------------------------------------------------------------------------
//
// The xoshiro256++ *state transition* is linear over GF(2): every bit of
// the next state is an XOR of bits of the current state (the add/rotate
// in the output function never feeds back into the state). "The state
// after n draws" is therefore the matrix power T^n applied to the
// 256-bit state vector, computable in O(log n) matrix-vector products.
// That is what turns one sequential stream into counter-based
// substreams: a range of work items [a, b) that consumes a FIXED number
// of draws per item can derive its exact stream state from the base
// state and the counter `a`, independently of every other range — the
// foundation of the pooled (bit-identical) data-synthesis path.

/// One state transition (the state-update half of `next_u64`).
fn step_state(s: &mut [u64; 4]) {
    let t = s[1] << 17;
    s[2] ^= s[0];
    s[3] ^= s[1];
    s[1] ^= s[2];
    s[0] ^= s[3];
    s[2] ^= t;
    s[3] = s[3].rotate_left(45);
}

/// GF(2) matrix as 256 columns, each a 256-bit vector (4 × u64 words).
type StateMatrix = Vec<[u64; 4]>;

/// m · v over GF(2): XOR the columns selected by v's set bits.
fn mat_vec(m: &[[u64; 4]], v: &[u64; 4]) -> [u64; 4] {
    let mut out = [0u64; 4];
    for (i, col) in m.iter().enumerate() {
        if (v[i / 64] >> (i % 64)) & 1 == 1 {
            out[0] ^= col[0];
            out[1] ^= col[1];
            out[2] ^= col[2];
            out[3] ^= col[3];
        }
    }
    out
}

/// T^(2^k) for k in 0..64, built once and cached: after that, any jump
/// costs one `mat_vec` per set bit of the offset (microseconds).
static JUMP_POWERS: OnceLock<Vec<StateMatrix>> = OnceLock::new();

fn jump_powers() -> &'static [StateMatrix] {
    JUMP_POWERS.get_or_init(|| {
        // T itself: column i is the transition applied to basis vector e_i.
        let mut t: StateMatrix = (0..256)
            .map(|i| {
                let mut s = [0u64; 4];
                s[i / 64] = 1u64 << (i % 64);
                step_state(&mut s);
                s
            })
            .collect();
        let mut powers = Vec::with_capacity(64);
        for _ in 0..64 {
            powers.push(t.clone());
            // square: column i of T² is T applied to T's column i
            let sq: StateMatrix = t.iter().map(|col| mat_vec(&t, col)).collect();
            t = sq;
        }
        powers
    })
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds produce unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    /// Derive an independent stream (e.g. one per worker) from this one.
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    /// The stream exactly `draws` calls of [`next_u64`](Self::next_u64)
    /// ahead of `self`, computed in O(log draws) via the GF(2)-linear
    /// state transition (see the module-level substream note). `self` is
    /// left untouched; `at_offset(0)` is a plain clone. This is the
    /// counter-based substream primitive behind the pooled data
    /// synthesis: range [a, b) of a generator that consumes `c` draws
    /// per item starts its kernel at `base.at_offset(a * c)`.
    pub fn at_offset(&self, draws: u64) -> Rng {
        let powers = jump_powers();
        let mut s = self.s;
        let mut n = draws;
        let mut k = 0usize;
        while n > 0 {
            if n & 1 == 1 {
                s = mat_vec(&powers[k], &s);
            }
            n >>= 1;
            k += 1;
        }
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let res = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        res
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here;
        // we use plain modulo with a 64-bit draw — bias < 2^-40 for any
        // n that fits this codebase.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (cached second value).
    pub fn normal(&mut self) -> f64 {
        // No cached-value state to keep Clone semantics simple; two draws.
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with given rate λ (mean 1/λ).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.uniform().max(1e-300).ln() / rate
    }

    /// Pareto (Type I) with scale xm > 0 and shape α > 0.
    pub fn pareto(&mut self, xm: f64, alpha: f64) -> f64 {
        xm / self.uniform().max(1e-300).powf(1.0 / alpha)
    }

    /// Log-normal with parameters of the underlying normal.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices from [0, n) (k <= n), in random order.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

/// Derive a pure, order-independent stream seed from a base seed and up
/// to three coordinates (e.g. `(seed, tag, worker, iteration)`): a
/// SplitMix64-finalised mix, so `Rng::new(stream_seed(..))` gives every
/// coordinate tuple its own decorrelated stream without any shared
/// mutable RNG state. This is what keeps the event-driven simulator
/// deterministic: a sample attached to (worker, k) is a pure function
/// of the tuple, independent of the order events fire in.
pub fn stream_seed(seed: u64, tag: u64, a: u64, b: u64) -> u64 {
    let mut z = seed
        ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ a.wrapping_mul(0xBF58_476D_1CE4_E5B9)
        ^ b.rotate_left(17).wrapping_mul(0x94D0_49BB_1331_11EB);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn pareto_minimum_is_scale() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            assert!(r.pareto(1.5, 2.0) >= 1.5);
        }
    }

    #[test]
    fn pareto_mean_matches_formula() {
        // E[X] = α·xm/(α-1) for α > 1
        let mut r = Rng::new(19);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.pareto(1.0, 3.0)).sum::<f64>() / n as f64;
        assert!((mean - 1.5).abs() < 0.03, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(29);
        let picks = r.choose_k(50, 10);
        assert_eq!(picks.len(), 10);
        let mut s = picks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 10);
    }

    #[test]
    fn at_offset_matches_sequential_stepping() {
        let base = Rng::new(123);
        for &k in &[0u64, 1, 2, 3, 17, 64, 255, 1000, 4097] {
            let mut stepped = base.clone();
            for _ in 0..k {
                stepped.next_u64();
            }
            let mut jumped = base.at_offset(k);
            for i in 0..8 {
                assert_eq!(stepped.next_u64(), jumped.next_u64(), "offset {k}, draw {i}");
            }
        }
    }

    #[test]
    fn at_offset_composes_additively() {
        let base = Rng::new(9);
        let a = base.at_offset(12_345).at_offset(678);
        let b = base.at_offset(13_023);
        assert_eq!(a.s, b.s);
        // and a large jump still agrees with two half-jumps
        let c = base.at_offset(1u64 << 40).at_offset(1u64 << 40);
        let d = base.at_offset(1u64 << 41);
        assert_eq!(c.s, d.s);
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::new(5);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn stream_seed_is_pure_and_sensitive_to_every_coordinate() {
        let base = stream_seed(7, 1, 2, 3);
        assert_eq!(stream_seed(7, 1, 2, 3), base); // pure
        for (s, t, a, b) in [(8, 1, 2, 3), (7, 2, 2, 3), (7, 1, 3, 3), (7, 1, 2, 4)] {
            assert_ne!(stream_seed(s, t, a, b), base);
        }
        // swapped coordinates land on different streams too
        assert_ne!(stream_seed(7, 1, 3, 2), base);
        // streams derived from adjacent tuples are decorrelated
        let mut x = Rng::new(stream_seed(7, 1, 2, 3));
        let mut y = Rng::new(stream_seed(7, 1, 2, 4));
        let same = (0..64).filter(|_| x.next_u64() == y.next_u64()).count();
        assert_eq!(same, 0);
    }
}
