//! One shared error type for every `name()`/`parse()` spec-string pair.
//!
//! `WaitPolicy`, `Fidelity`, `Topology`, `straggler::Dist`, and
//! `data::Partition` all round-trip through short spec strings
//! (`"dybw"`, `"timing"`, `"racks:8"`, `"sexp:0.08,25"`). Historically
//! each type invented its own failure convention — `Option`, panic, or
//! an ad hoc `anyhow!` at the call site — so callers could not render a
//! uniform message or test the contract generically. [`ParseError`] is
//! the single typed failure: which kind of spec was being parsed, the
//! offending input, and the grammar it should have matched.
//!
//! The contract every participating type upholds (property-tested in each
//! type's module): `T::parse(&t.name()) == Ok(t)` for every value `t`,
//! and every rejected input yields a `ParseError` whose `what` names the
//! type — never a panic.

use std::fmt;

/// A spec string that failed to parse.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// What was being parsed, e.g. `"wait policy"` / `"fidelity"` /
    /// `"topology"`.
    pub what: &'static str,
    /// The rejected input, verbatim.
    pub input: String,
    /// The accepted grammar, e.g. `"full | static:<b> | dybw"`.
    pub expected: &'static str,
}

impl ParseError {
    pub fn new(what: &'static str, input: &str, expected: &'static str) -> ParseError {
        ParseError {
            what,
            input: input.to_string(),
            expected,
        }
    }
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "bad {} '{}' (expected {})",
            self.what, self.input, self.expected
        )
    }
}

impl std::error::Error for ParseError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_all_three_parts() {
        let e = ParseError::new("topology", "dodecahedron", "ring | complete");
        let s = e.to_string();
        assert!(s.contains("topology") && s.contains("dodecahedron") && s.contains("ring"));
    }

    #[test]
    fn is_a_std_error() {
        let e = ParseError::new("fidelity", "x", "timing | full");
        let boxed: Box<dyn std::error::Error> = Box::new(e);
        assert!(boxed.to_string().contains("fidelity"));
    }
}
