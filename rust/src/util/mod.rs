//! Self-contained utility substrates.
//!
//! The offline vendor set ships only `xla` + `anyhow`, so the library
//! carries its own implementations of what would normally be crates:
//!
//! - [`rng`] — xoshiro256++ PRNG + sampling distributions (→ `rand`)
//! - [`json`] — RFC 8259 subset parser/writer (→ `serde_json`)
//! - [`cli`] — declarative argument parser (→ `clap`)
//! - [`log`] — leveled logger (→ `env_logger`)
//! - [`pool`] — fixed worker thread pool (→ `rayon`/`tokio` tasks)
//! - [`vecmath`] — flat-f32-vector kernels for the consensus hot path

pub mod cli;
pub mod json;
pub mod log;
pub mod parse;
pub mod pool;
pub mod rng;
pub mod vecmath;
