//! Metrics: per-iteration time series, summaries, CSV/JSON export.
//!
//! Every training run produces a [`RunHistory`]: one [`IterRecord`] per
//! iteration (duration, losses, backup-worker counts — the series behind
//! the paper's Figures 1/4/6) and periodic [`EvalRecord`]s (test error /
//! loss versus wall-clock — Figures 5/7). [`summary`] computes the
//! headline numbers (mean iteration duration, time-to-loss) the paper
//! quotes in §5 and Appendix B.

pub mod export;
pub mod summary;

/// One training iteration's observables.
#[derive(Debug, Clone)]
pub struct IterRecord {
    pub k: usize,
    /// Iteration duration T(k) in (virtual or real) seconds.
    pub duration: f64,
    /// Cumulative wall-clock at the END of this iteration.
    pub clock: f64,
    /// Mean training loss across participating workers' local batches.
    pub train_loss: f64,
    /// Number of active (non-backup) workers |V'(k)|.
    pub active: usize,
    /// Mean number of backup workers per node: avg_j b_j(k).
    pub backup_avg: f64,
    /// DTUR threshold θ(k) (= duration for cb-DyBW; NaN for baselines).
    pub theta: f64,
}

/// One periodic evaluation on the held-out set (network-average params).
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub k: usize,
    pub clock: f64,
    pub test_loss: f64,
    /// Fraction in [0,1] of misclassified test examples.
    pub test_error: f64,
    /// Max_j ||w_j - ȳ|| consensus disagreement at eval time.
    pub consensus_error: f64,
}

/// Full run history.
#[derive(Debug, Clone, Default)]
pub struct RunHistory {
    pub algo: String,
    pub model: String,
    pub dataset: String,
    pub workers: usize,
    pub iters: Vec<IterRecord>,
    pub evals: Vec<EvalRecord>,
}

impl RunHistory {
    pub fn new(algo: &str, model: &str, dataset: &str, workers: usize) -> Self {
        RunHistory {
            algo: algo.to_string(),
            model: model.to_string(),
            dataset: dataset.to_string(),
            workers,
            iters: Vec::new(),
            evals: Vec::new(),
        }
    }

    pub fn total_time(&self) -> f64 {
        self.iters.last().map(|r| r.clock).unwrap_or(0.0)
    }

    pub fn mean_iter_duration(&self) -> f64 {
        if self.iters.is_empty() {
            return 0.0;
        }
        self.iters.iter().map(|r| r.duration).sum::<f64>() / self.iters.len() as f64
    }

    pub fn mean_backup_workers(&self) -> f64 {
        if self.iters.is_empty() {
            return 0.0;
        }
        self.iters.iter().map(|r| r.backup_avg).sum::<f64>() / self.iters.len() as f64
    }

    /// First wall-clock time at which the TRAIN loss fell to `target`
    /// (smoothed over a small window to tame mini-batch noise).
    pub fn time_to_train_loss(&self, target: f64) -> Option<f64> {
        const W: usize = 5;
        if self.iters.len() < W {
            return None;
        }
        for i in W..=self.iters.len() {
            let avg: f64 =
                self.iters[i - W..i].iter().map(|r| r.train_loss).sum::<f64>() / W as f64;
            if avg <= target {
                return Some(self.iters[i - 1].clock);
            }
        }
        None
    }

    /// First wall-clock time at which TEST loss fell to `target`.
    pub fn time_to_test_loss(&self, target: f64) -> Option<f64> {
        self.evals
            .iter()
            .find(|e| e.test_loss <= target)
            .map(|e| e.clock)
    }

    /// First iteration at which TEST loss fell to `target`.
    pub fn iters_to_test_loss(&self, target: f64) -> Option<usize> {
        self.evals.iter().find(|e| e.test_loss <= target).map(|e| e.k)
    }

    pub fn final_eval(&self) -> Option<&EvalRecord> {
        self.evals.last()
    }

    /// Bit-exact equality of the recorded series: every `f64` in every
    /// [`IterRecord`] and [`EvalRecord`] compared via `to_bits` (so NaN
    /// thetas compare equal when produced identically), plus the integer
    /// fields. This is the determinism oracle used by the engine-pool
    /// tests and the speedup bench: two runs of the same seed must
    /// satisfy `bits_eq` regardless of pool size.
    pub fn bits_eq(&self, other: &RunHistory) -> bool {
        self.workers == other.workers
            && self.iters.len() == other.iters.len()
            && self.evals.len() == other.evals.len()
            && self.iters.iter().zip(&other.iters).all(|(x, y)| {
                x.k == y.k
                    && x.duration.to_bits() == y.duration.to_bits()
                    && x.clock.to_bits() == y.clock.to_bits()
                    && x.train_loss.to_bits() == y.train_loss.to_bits()
                    && x.active == y.active
                    && x.backup_avg.to_bits() == y.backup_avg.to_bits()
                    && x.theta.to_bits() == y.theta.to_bits()
            })
            && self.evals.iter().zip(&other.evals).all(|(x, y)| {
                x.k == y.k
                    && x.clock.to_bits() == y.clock.to_bits()
                    && x.test_loss.to_bits() == y.test_loss.to_bits()
                    && x.test_error.to_bits() == y.test_error.to_bits()
                    && x.consensus_error.to_bits() == y.consensus_error.to_bits()
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_history() -> RunHistory {
        let mut h = RunHistory::new("cb-dybw", "lrm", "mnist-like", 6);
        let mut clock = 0.0;
        for k in 0..20 {
            clock += 0.1;
            h.iters.push(IterRecord {
                k,
                duration: 0.1,
                clock,
                train_loss: 2.0 / (k + 1) as f64,
                active: 5,
                backup_avg: 1.0,
                theta: 0.1,
            });
            if k % 5 == 4 {
                h.evals.push(EvalRecord {
                    k,
                    clock,
                    test_loss: 2.0 / (k + 1) as f64,
                    test_error: 0.5 / (k + 1) as f64,
                    consensus_error: 0.01,
                });
            }
        }
        h
    }

    #[test]
    fn totals() {
        let h = fake_history();
        assert!((h.total_time() - 2.0).abs() < 1e-9);
        assert!((h.mean_iter_duration() - 0.1).abs() < 1e-12);
        assert!((h.mean_backup_workers() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn time_to_train_loss_monotone() {
        let h = fake_history();
        let t_easy = h.time_to_train_loss(1.0).unwrap();
        let t_hard = h.time_to_train_loss(0.2).unwrap();
        assert!(t_easy < t_hard);
        assert!(h.time_to_train_loss(0.0001).is_none());
    }

    #[test]
    fn time_to_test_loss_uses_evals() {
        let h = fake_history();
        // the first recorded eval (k=4, test_loss 0.4) already beats 0.5
        assert_eq!(h.time_to_test_loss(0.5), Some(h.evals[0].clock));
        assert!(h.iters_to_test_loss(0.11).is_some());
        assert!(h.time_to_test_loss(1e-9).is_none());
    }
}
