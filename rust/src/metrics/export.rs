//! CSV / JSON export of run histories (the raw material for replotting
//! the paper's figures with any external tool).

use std::io::Write;
use std::path::Path;

use super::RunHistory;
use crate::util::json::Json;

/// Write `<prefix>.iters.csv` and `<prefix>.evals.csv`.
pub fn write_csv(h: &RunHistory, dir: &Path, prefix: &str) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut f = std::fs::File::create(dir.join(format!("{prefix}.iters.csv")))?;
    writeln!(f, "k,duration,clock,train_loss,active,backup_avg,theta")?;
    for r in &h.iters {
        writeln!(
            f,
            "{},{:.6},{:.6},{:.6},{},{:.4},{:.6}",
            r.k, r.duration, r.clock, r.train_loss, r.active, r.backup_avg, r.theta
        )?;
    }
    let mut f = std::fs::File::create(dir.join(format!("{prefix}.evals.csv")))?;
    writeln!(f, "k,clock,test_loss,test_error,consensus_error")?;
    for e in &h.evals {
        writeln!(
            f,
            "{},{:.6},{:.6},{:.6},{:.8}",
            e.k, e.clock, e.test_loss, e.test_error, e.consensus_error
        )?;
    }
    Ok(())
}

/// Serialise a run summary as JSON.
pub fn to_json(h: &RunHistory) -> Json {
    let mut obj = Json::obj();
    obj.set("algo", h.algo.as_str().into())
        .set("model", h.model.as_str().into())
        .set("dataset", h.dataset.as_str().into())
        .set("workers", h.workers.into())
        .set("iterations", h.iters.len().into())
        .set("total_time", h.total_time().into())
        .set("mean_iter_duration", h.mean_iter_duration().into())
        .set("mean_backup_workers", h.mean_backup_workers().into());
    if let Some(e) = h.final_eval() {
        obj.set("final_test_loss", e.test_loss.into())
            .set("final_test_error", e.test_error.into())
            .set("final_consensus_error", e.consensus_error.into());
    }
    let evals: Vec<Json> = h
        .evals
        .iter()
        .map(|e| {
            let mut o = Json::obj();
            o.set("k", e.k.into())
                .set("clock", e.clock.into())
                .set("test_loss", e.test_loss.into())
                .set("test_error", e.test_error.into());
            o
        })
        .collect();
    obj.set("evals", Json::Arr(evals));
    obj
}

pub fn write_json(h: &RunHistory, dir: &Path, prefix: &str) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(
        dir.join(format!("{prefix}.json")),
        to_json(h).to_string_pretty(),
    )?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{EvalRecord, IterRecord};

    fn h() -> RunHistory {
        let mut h = RunHistory::new("cb-full", "lrm", "x", 4);
        h.iters.push(IterRecord {
            k: 0,
            duration: 0.5,
            clock: 0.5,
            train_loss: 2.3,
            active: 4,
            backup_avg: 0.0,
            theta: f64::NAN,
        });
        h.evals.push(EvalRecord {
            k: 0,
            clock: 0.5,
            test_loss: 2.2,
            test_error: 0.9,
            consensus_error: 0.0,
        });
        h
    }

    #[test]
    fn csv_roundtrip_shape() {
        let dir = std::env::temp_dir().join("dybw_test_csv");
        write_csv(&h(), &dir, "t").unwrap();
        let iters = std::fs::read_to_string(dir.join("t.iters.csv")).unwrap();
        assert_eq!(iters.lines().count(), 2);
        assert!(iters.starts_with("k,duration"));
        let evals = std::fs::read_to_string(dir.join("t.evals.csv")).unwrap();
        assert!(evals.contains("2.2"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn json_parses_back() {
        let j = to_json(&h());
        let re = Json::parse(&j.to_string()).unwrap();
        assert_eq!(re.path("algo").unwrap().as_str().unwrap(), "cb-full");
        assert_eq!(re.path("workers").unwrap().as_usize().unwrap(), 4);
        assert_eq!(re.path("evals").unwrap().as_arr().unwrap().len(), 1);
    }
}
