//! Paper-style comparison tables.
//!
//! The paper's quantitative claims are comparative: "cb-DyBW reduces the
//! duration of one iteration by 65-70% (Fig. 1c)", "reduces convergence
//! time by 62% (Fig. 5)". [`Comparison`] computes exactly those ratios
//! between a treatment run and a baseline run and renders the aligned
//! rows the figure harnesses print.

use super::RunHistory;

/// Head-to-head of two runs (typically cb-DyBW vs cb-Full).
#[derive(Debug, Clone)]
pub struct Comparison {
    pub label_a: String,
    pub label_b: String,
    pub mean_iter_a: f64,
    pub mean_iter_b: f64,
    /// 1 - a/b : fraction of per-iteration time saved by A.
    pub iter_duration_reduction: f64,
    /// time-to-target-loss for each (None = never reached).
    pub time_to_loss_a: Option<f64>,
    pub time_to_loss_b: Option<f64>,
    /// 1 - a/b when both reached the target.
    pub convergence_time_reduction: Option<f64>,
    pub iters_to_loss_a: Option<usize>,
    pub iters_to_loss_b: Option<usize>,
    pub target_loss: f64,
}

impl Comparison {
    pub fn new(a: &RunHistory, b: &RunHistory, target_loss: f64) -> Comparison {
        let t_a = a.time_to_test_loss(target_loss);
        let t_b = b.time_to_test_loss(target_loss);
        let conv_red = match (t_a, t_b) {
            (Some(x), Some(y)) if y > 0.0 => Some(1.0 - x / y),
            _ => None,
        };
        Comparison {
            label_a: a.algo.clone(),
            label_b: b.algo.clone(),
            mean_iter_a: a.mean_iter_duration(),
            mean_iter_b: b.mean_iter_duration(),
            iter_duration_reduction: 1.0
                - a.mean_iter_duration() / b.mean_iter_duration().max(1e-12),
            time_to_loss_a: t_a,
            time_to_loss_b: t_b,
            convergence_time_reduction: conv_red,
            iters_to_loss_a: a.iters_to_test_loss(target_loss),
            iters_to_loss_b: b.iters_to_test_loss(target_loss),
            target_loss,
        }
    }

    /// Render the paper-style rows.
    pub fn render(&self) -> String {
        let fmt_opt = |v: Option<f64>| match v {
            Some(x) => format!("{x:.1}s"),
            None => "n/a".into(),
        };
        let fmt_opt_k = |v: Option<usize>| match v {
            Some(x) => format!("{x}"),
            None => "n/a".into(),
        };
        let mut out = String::new();
        out.push_str(&format!(
            "{:<28} {:>14} {:>14}\n",
            "", self.label_a, self.label_b
        ));
        out.push_str(&format!(
            "{:<28} {:>13.3}s {:>13.3}s\n",
            "mean iteration duration", self.mean_iter_a, self.mean_iter_b
        ));
        out.push_str(&format!(
            "{:<28} {:>14} {:>14}\n",
            "  -> reduction",
            format!("{:.0}%", self.iter_duration_reduction * 100.0),
            "-"
        ));
        out.push_str(&format!(
            "{:<28} {:>14} {:>14}\n",
            format!("time to test loss {:.2}", self.target_loss),
            fmt_opt(self.time_to_loss_a),
            fmt_opt(self.time_to_loss_b)
        ));
        if let Some(r) = self.convergence_time_reduction {
            out.push_str(&format!(
                "{:<28} {:>14} {:>14}\n",
                "  -> reduction",
                format!("{:.0}%", r * 100.0),
                "-"
            ));
        }
        out.push_str(&format!(
            "{:<28} {:>14} {:>14}\n",
            format!("iters to test loss {:.2}", self.target_loss),
            fmt_opt_k(self.iters_to_loss_a),
            fmt_opt_k(self.iters_to_loss_b)
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{EvalRecord, IterRecord};

    fn run(algo: &str, iter_dur: f64, evals: &[(usize, f64, f64)]) -> RunHistory {
        let mut h = RunHistory::new(algo, "m", "d", 6);
        let mut clock = 0.0;
        let n = evals.last().map(|e| e.0 + 1).unwrap_or(10);
        for k in 0..n {
            clock += iter_dur;
            h.iters.push(IterRecord {
                k,
                duration: iter_dur,
                clock,
                train_loss: 1.0,
                active: 6,
                backup_avg: 0.0,
                theta: f64::NAN,
            });
            if let Some(e) = evals.iter().find(|e| e.0 == k) {
                h.evals.push(EvalRecord {
                    k,
                    clock,
                    test_loss: e.1,
                    test_error: e.2,
                    consensus_error: 0.0,
                });
            }
        }
        h
    }

    #[test]
    fn reductions_computed() {
        // A reaches loss 0.5 at iteration 10 with 0.1s iters = 1.1s.
        // B reaches loss 0.5 at iteration 10 with 0.3s iters = 3.3s.
        let a = run("dybw", 0.1, &[(5, 0.8, 0.3), (10, 0.4, 0.2)]);
        let b = run("full", 0.3, &[(5, 0.8, 0.3), (10, 0.4, 0.2)]);
        let c = Comparison::new(&a, &b, 0.5);
        assert!((c.iter_duration_reduction - (1.0 - 0.1 / 0.3)).abs() < 1e-9);
        let r = c.convergence_time_reduction.unwrap();
        assert!((r - (1.0 - 1.1 / 3.3)).abs() < 1e-6, "r={r}");
        assert_eq!(c.iters_to_loss_a, Some(10));
        let text = c.render();
        assert!(text.contains("dybw"));
        assert!(text.contains("reduction"));
    }

    #[test]
    fn unreachable_target_is_none() {
        let a = run("dybw", 0.1, &[(5, 0.8, 0.3)]);
        let b = run("full", 0.3, &[(5, 0.8, 0.3)]);
        let c = Comparison::new(&a, &b, 0.01);
        assert!(c.time_to_loss_a.is_none());
        assert!(c.convergence_time_reduction.is_none());
        assert!(c.render().contains("n/a"));
    }
}
